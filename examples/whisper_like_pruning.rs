//! §4.4 reproduction: training-free pruning of a whisper-like transcription
//! model.  Trains a small encoder-decoder on synthetic signal→token pairs,
//! then compares CLOVER vs vanilla structured pruning of the encoder's
//! attention at matched ratios — the paper's result is that CLOVER stays
//! near-lossless at ~50% while vanilla output collapses.
//!
//! ```sh
//! cargo run --release --example whisper_like_pruning [-- --full]
//! ```

use anyhow::Result;
use clover::coordinator::experiments::{self, ExpOpts};
use clover::runtime::Runtime;

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let rt = Runtime::new("artifacts")?;
    let opts = ExpOpts { preset: "tiny".into(), quick: !full, seed: 42 };
    experiments::fig3_whisper(&rt, &opts)?.emit("whisper_like_pruning")
}
