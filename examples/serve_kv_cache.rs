//! KV-cache serving demo: the paper's motivating memory argument made
//! concrete.  Serves the same mixed-length workload through the dense
//! decode path and through CLOVER-pruned decode paths at several ranks
//! under the continuous-batching scheduler, reporting throughput, decode
//! steps, TTFT, tail latency, and peak KV bytes for each.
//!
//! ```sh
//! cargo run --release --example serve_kv_cache [requests] [max_new]
//! ```

use anyhow::Result;
use clover::coordinator::ops;
use clover::report::Table;
use clover::runtime::Runtime;
use clover::serve::{BatchPolicy, Engine, Request};
use clover::util::human_bytes;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let max_new: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(24);
    let preset = "tiny";

    let rt = Runtime::new("artifacts")?;
    let entry = rt.manifest().config(preset)?.clone();
    let dense = ops::init_params(&rt, preset, 42)?;
    let vocab = entry.dim("vocab")?;

    let mut rng = clover::util::rng::Rng::new(7);
    let now = std::time::Instant::now();
    // One fixed mixed-length workload, served identically by every engine
    // so the table compares pruning, not request luck.  Lengths span
    // [2, max_new] so requests finish at different steps — the regime
    // where slot-level admission pays off.
    let requests: Vec<Request> = (0..n_requests as u64)
        .map(|id| {
            let prompt = (0..6).map(|_| rng.below(vocab) as i32).collect();
            let n = 2 + rng.below(max_new.saturating_sub(1).max(1));
            Request::greedy(id, prompt, n, now)
        })
        .collect();
    let policy = BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(2) };

    let mut table = Table::new(
        &format!("KV-cache serving: {n_requests} requests × ≤{max_new} new tokens (continuous batching)"),
        &["engine", "rank", "tok/s", "steps", "ttft_p50_s", "lat_p50_s", "lat_p99_s", "peak_KV", "KV/token"],
    );
    let (n_layers, n_heads) = (entry.dim("n_layers")?, entry.dim("n_heads")?);
    let mut push_row = |name: String, rank: usize, m: &clover::serve::ServeMetrics| {
        table.row(vec![
            name,
            rank.to_string(),
            format!("{:.1}", m.tokens_per_s()),
            m.decode_steps.to_string(),
            format!("{:.3}", m.ttft_p50_s),
            format!("{:.3}", m.latency_p50_s),
            format!("{:.3}", m.latency_p99_s),
            human_bytes(m.kv_peak_bytes),
            human_bytes(clover::clover::analysis::kv_bytes_per_token(n_layers, n_heads, rank)),
        ]);
    };

    let dh = entry.dim("d_head")?;
    let (_, m) = Engine::new(&rt, preset, "decode_b8", dense.clone())?
        .serve_all(requests.clone(), policy.clone())?;
    push_row("dense".into(), dh, &m);

    for ratio in [0.25, 0.5, 0.75] {
        let (fac, r) = ops::prune_to_ratio(&entry, &dense, ratio, "clover")?;
        let engine = Engine::new(&rt, preset, &format!("decode_fac_r{r}_b8"), fac)?;
        let (_, m) = engine.serve_all(requests.clone(), policy.clone())?;
        push_row(format!("clover {:.0}%", ratio * 100.0), r, &m);
    }
    table.emit("serve_kv_cache")
}
