//! KV-cache serving demo: the paper's motivating memory argument made
//! concrete.  Serves the same batched workload through the dense decode
//! path and through CLOVER-pruned decode paths at several ranks, reporting
//! throughput, mean latency, and peak KV bytes for each.
//!
//! ```sh
//! cargo run --release --example serve_kv_cache [requests] [max_new]
//! ```

use anyhow::Result;
use clover::coordinator::ops;
use clover::report::Table;
use clover::runtime::Runtime;
use clover::serve::{BatchPolicy, Engine, Request};
use clover::util::human_bytes;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let max_new: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(24);
    let preset = "tiny";

    let rt = Runtime::new("artifacts")?;
    let entry = rt.manifest().config(preset)?.clone();
    let dense = ops::init_params(&rt, preset, 42)?;
    let vocab = entry.dim("vocab")?;

    let mut rng = clover::util::rng::Rng::new(7);
    let now = std::time::Instant::now();
    let mk_reqs = |rng: &mut clover::util::rng::Rng| -> Vec<Request> {
        (0..n_requests as u64)
            .map(|id| Request {
                id,
                prompt: (0..6).map(|_| rng.below(vocab) as i32).collect(),
                max_new,
                arrived: now,
            })
            .collect()
    };
    let policy = BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(2) };

    let mut table = Table::new(
        &format!("KV-cache serving: {n_requests} requests × {max_new} new tokens"),
        &["engine", "rank", "tok/s", "mean_latency_s", "peak_KV", "KV/token"],
    );

    let (_, m) = Engine::new(&rt, preset, "decode_b8", dense.clone())?
        .serve_all(mk_reqs(&mut rng), policy.clone())?;
    let dh = entry.dim("d_head")?;
    table.row(vec![
        "dense".into(), dh.to_string(), format!("{:.1}", m.tokens_per_s()),
        format!("{:.3}", m.wall_s / n_requests as f64),
        human_bytes(m.kv_peak_bytes),
        human_bytes(clover::clover::analysis::kv_bytes_per_token(
            entry.dim("n_layers")?, entry.dim("n_heads")?, dh)),
    ]);

    for ratio in [0.25, 0.5, 0.75] {
        let (fac, r) = ops::prune_to_ratio(&entry, &dense, ratio, "clover")?;
        let engine = Engine::new(&rt, preset, &format!("decode_fac_r{r}_b8"), fac)?;
        let (_, m) = engine.serve_all(mk_reqs(&mut rng), policy.clone())?;
        table.row(vec![
            format!("clover {:.0}%", ratio * 100.0), r.to_string(),
            format!("{:.1}", m.tokens_per_s()),
            format!("{:.3}", m.wall_s / n_requests as f64),
            human_bytes(m.kv_peak_bytes),
            human_bytes(clover::clover::analysis::kv_bytes_per_token(
                entry.dim("n_layers")?, entry.dim("n_heads")?, r)),
        ]);
    }
    table.emit("serve_kv_cache")
}
