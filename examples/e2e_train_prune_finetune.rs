//! End-to-end driver (DESIGN.md §5): proves all three layers compose on a
//! real workload.
//!
//! 1. Generate a synthetic corpus, train a BPE tokenizer (L3 data pipeline)
//! 2. Pretrain the decoder for a few hundred steps via the AOT train-step
//!    HLO (L2 graph wrapping the L1 Pallas kernels), logging the loss curve
//! 3. Apply the CLOVER transform + prune 50% of every head (L3 linalg)
//! 4. Recovery-fine-tune only the singular values (CLOVER†)
//! 5. Evaluate perplexity at every stage and boot the batched KV-cache
//!    serving engine, reporting throughput and KV bytes before/after
//!
//! ```sh
//! cargo run --release --example e2e_train_prune_finetune [steps] [preset]
//! ```
//!
//! Defaults: 300 steps on `tiny` (~minutes on one CPU core).  `small`
//! (~4M params) and `large` (~100M) presets exist; see DESIGN.md §5 for
//! the wallclock scale note.  Results recorded in EXPERIMENTS.md.

use anyhow::Result;
use clover::coordinator::{eval, ops};
use clover::data::build_lm_stream;
use clover::runtime::Runtime;
use clover::serve::{BatchPolicy, Engine, Request};
use clover::util::{human_bytes, Stopwatch};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let preset = args.get(2).cloned().unwrap_or_else(|| "tiny".to_string());
    let sw = Stopwatch::new();

    let rt = Runtime::new("artifacts")?;
    let entry = rt.manifest().config(&preset)?.clone();
    let vocab = entry.dim("vocab")?;
    println!("== e2e: preset {preset}, {steps} pretrain steps ==");

    // 1. Data pipeline.
    let (_tok, stream) = build_lm_stream("mixture", vocab, 400_000, 42);
    println!("[{:6.1}s] corpus+tokenizer ready ({} train tokens)", sw.elapsed_s(),
             stream.train_len());

    // 2. Pretrain; loss curve goes to stderr via the coordinator logger.
    let init = ops::init_params(&rt, &preset, 42)?;
    let (dense, curve) = ops::pretrain(&rt, &preset, init, &stream, steps, 1e-3, 42, "e2e")?;
    println!("[{:6.1}s] pretrain done; loss curve:", sw.elapsed_s());
    for (step, loss) in &curve {
        println!("    step {step:>5}  ema-loss {loss:.4}");
    }
    let ppl0 = eval::perplexity(&rt, &preset, "nll", &dense, &stream, 8)?;
    println!("[{:6.1}s] base ppl          {ppl0:8.2}", sw.elapsed_s());

    // 3. CLOVER-prune 50% (and the vanilla baseline for contrast).
    let (clv, r) = ops::prune_to_ratio(&entry, &dense, 0.5, "clover")?;
    let (van, _) = ops::prune_to_ratio(&entry, &dense, 0.5, "vanilla")?;
    let ppl_clv = ops::fac_perplexity(&rt, &preset, &clv, r, &stream, 8)?;
    let ppl_van = ops::fac_perplexity(&rt, &preset, &van, r, &stream, 8)?;
    println!("[{:6.1}s] 50% pruned         CLOVER {ppl_clv:8.2} | vanilla {ppl_van:8.2}",
             sw.elapsed_s());

    // 4. CLOVER†: fine-tune singular values only.
    let ft_steps = (steps / 2).max(20);
    let (recovered, _) = ops::recover(&rt, &preset, clv, r, "s", &stream, ft_steps, 6e-3, 42)?;
    let ppl_rec = ops::fac_perplexity(&rt, &preset, &recovered, r, &stream, 8)?;
    println!("[{:6.1}s] CLOVER† recovered  ppl {ppl_rec:8.2} ({ft_steps} S-only steps)",
             sw.elapsed_s());

    // 5. Serve: batched KV-cache decode, dense vs pruned.
    let now = std::time::Instant::now();
    let mk_reqs = || -> Vec<Request> {
        (0..8u64).map(|id| Request::greedy(id, vec![3, 5, 7, 11], 16, now)).collect()
    };
    let policy = BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(1) };
    let dense_engine = Engine::new(&rt, &preset, "decode_b8", dense)?;
    let (_, md) = dense_engine.serve_all(mk_reqs(), policy.clone())?;
    let fac_engine = Engine::new(&rt, &preset, &format!("decode_fac_r{r}_b8"), recovered)?;
    let (_, mf) = fac_engine.serve_all(mk_reqs(), policy)?;
    println!(
        "[{:6.1}s] serve dense : {:6.1} tok/s, peak KV {}",
        sw.elapsed_s(), md.tokens_per_s(), human_bytes(md.kv_peak_bytes)
    );
    println!(
        "[{:6.1}s] serve pruned: {:6.1} tok/s, peak KV {} ({:.1}x smaller)",
        sw.elapsed_s(), mf.tokens_per_s(), human_bytes(mf.kv_peak_bytes),
        md.kv_peak_bytes as f64 / mf.kv_peak_bytes.max(1) as f64
    );
    println!("== e2e complete in {:.1}s ==", sw.elapsed_s());
    Ok(())
}
