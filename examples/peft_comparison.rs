//! PEFT comparison on the 8-task synthetic commonsense suite (Table 2),
//! plus the ΔW rank (Fig 5) and intruder-dimension (Fig 6) analyses that
//! fall out of the same training runs.
//!
//! ```sh
//! cargo run --release --example peft_comparison [-- --full]
//! ```
//! Quick mode by default (~minutes); `--full` uses the paper-scale step
//! budgets recorded in EXPERIMENTS.md.

use anyhow::Result;
use clover::coordinator::experiments::{self, ExpOpts};
use clover::runtime::Runtime;

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let rt = Runtime::new("artifacts")?;
    let opts = ExpOpts { preset: "tiny".into(), quick: !full, seed: 42 };
    let (table, outcomes) = experiments::table2(&rt, &opts)?;
    table.emit("table2")?;
    experiments::fig5_from(&outcomes).emit("fig5")?;
    experiments::fig6_from(&outcomes).emit("fig6")?;
    Ok(())
}
