//! Quickstart: the CLOVER pipeline in ~40 lines.
//!
//! Loads the AOT artifacts, initializes a tiny decoder, applies the
//! cross-layer orthogonalization (lossless at full rank), prunes 50% of
//! every head's directions, and reports perplexity plus the KV-cache
//! saving.  Run after `make artifacts`:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use clover::clover::analysis::kv_bytes_per_token;
use clover::coordinator::ops;
use clover::data::build_lm_stream;
use clover::runtime::Runtime;
use clover::util::human_bytes;

fn main() -> Result<()> {
    let rt = Runtime::new("artifacts")?;
    let preset = "tiny";
    let entry = rt.manifest().config(preset)?.clone();
    let (l, h, dh) = (
        entry.dim("n_layers")?, entry.dim("n_heads")?, entry.dim("d_head")?,
    );

    // Fresh model + held-out stream.
    let dense = ops::init_params(&rt, preset, 42)?;
    let (_tok, stream) = build_lm_stream("mixture", entry.dim("vocab")?, 200_000, 1);
    let base = clover::coordinator::eval::perplexity(&rt, preset, "nll", &dense, &stream, 4)?;
    println!("dense model          ppl {base:8.2}");

    // CLOVER at full rank is an exact re-parameterization.
    let (fac_full, r_full) = ops::prune_to_ratio(&entry, &dense, 0.0, "clover")?;
    let full = ops::fac_perplexity(&rt, preset, &fac_full, r_full, &stream, 4)?;
    println!("CLOVER r={r_full:<2} (exact)  ppl {full:8.2}   (Δ {:+.4})", full - base);

    // Prune half the directions per head — vs the vanilla baseline.
    for method in ["clover", "vanilla"] {
        let (fac, r) = ops::prune_to_ratio(&entry, &dense, 0.5, method)?;
        let ppl = ops::fac_perplexity(&rt, preset, &fac, r, &stream, 4)?;
        println!(
            "{method:<7} 50% pruned  ppl {ppl:8.2}   KV {}/token (dense {})",
            human_bytes(kv_bytes_per_token(l, h, r)),
            human_bytes(kv_bytes_per_token(l, h, dh)),
        );
    }
    println!("\n(An untrained model shows the mechanics; run the e2e example for the\ntrained-model result where CLOVER's advantage appears.)");
    Ok(())
}
