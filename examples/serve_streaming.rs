//! Streaming server demo: the gateway/stream/cancel/router stack end to
//! end, against live engines running the token-slab step API.
//!
//! Spawns three gateways — dense, CLOVER r=8, CLOVER r=4 — behind the
//! rank-aware router (scored by pending prefill tokens × per-rank KV
//! cost), feeds an open-loop trace of 24-token prompts through it, prints
//! tokens as they stream out, fires a cancel token mid-decode, and lets
//! one request expire on a deadline.  Each completion reports its
//! `prefill_steps`: with the exported chunk ladder a 24-token prompt
//! prefills in 2 fused steps instead of 24.  Finishes with each engine's
//! share of the trace and its serving metrics: the paper's KV claim as
//! live routing behaviour.
//!
//! ```sh
//! cargo run --release --example serve_streaming [requests] [max_new] [prompt_len]
//! ```

use anyhow::Result;
use clover::serve::SamplingParams;
use clover::server::{EngineSpec, Gateway, GatewayConfig, Router, StreamEvent};
use clover::util::human_bytes;
use std::time::Duration;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let max_new: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);
    let prompt_len: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(24).max(1);
    let (artifacts, preset, batch) = ("artifacts", "tiny", 8);

    // Three engines at different pruning ranks, each on its own thread
    // with its own Runtime (the PJRT handles never cross threads).
    // Listed cheapest-KV first: the router breaks score ties toward the
    // front of the list.
    println!("spawning gateways (each compiles its decode artifact)...");
    let cfg = GatewayConfig { queue_capacity: 2 * n_requests.max(1), ..Default::default() };
    let router = Router::new(vec![
        Gateway::spawn("r4", cfg.clone(), EngineSpec::pruned(artifacts, preset, batch, 42, 0.75))?,
        Gateway::spawn("r8", cfg.clone(), EngineSpec::pruned(artifacts, preset, batch, 42, 0.5))?,
        Gateway::spawn("dense", cfg, EngineSpec::dense(artifacts, preset, batch, 42))?,
    ])?;
    for g in router.gateways() {
        println!("  {:<6} rank {:>2} | {:>5} B KV/token", g.name(), g.rank(), g.kv_bytes_per_token());
    }

    // Open-loop trace: submissions a few ms apart, routed by queue depth ×
    // per-rank KV cost.  Request 3 gets a cancel token fired mid-decode;
    // request 5 gets a deadline it cannot meet.
    let mut rng = clover::util::rng::Rng::new(7);
    let mut tickets = Vec::new();
    for i in 0..n_requests {
        let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(64) as i32).collect();
        let deadline = (i == 5).then_some(Duration::from_millis(1));
        let (idx, ticket) =
            router.submit(prompt, max_new, SamplingParams::greedy(), deadline)?;
        println!("[{}@{}] submitted", ticket.id, router.gateways()[idx].name());
        if i == 3 {
            let cancel = ticket.cancel.clone();
            // Cancel from another thread once the request is mid-flight —
            // the lane frees between decode steps and is re-admitted.
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                cancel.cancel();
            });
        }
        tickets.push((idx, ticket));
        std::thread::sleep(Duration::from_millis(2));
    }

    // Stream everything to completion, printing the interesting moments.
    let mut streamed_tokens = 0usize;
    for (idx, ticket) in tickets {
        let name = router.gateways()[idx].name().to_string();
        let stream = ticket.stream;
        let id = stream.id();
        while let Some(ev) = stream.next_event() {
            match ev {
                StreamEvent::Token { .. } => streamed_tokens += 1,
                StreamEvent::Done { completion } => {
                    println!(
                        "[{id}@{name}] done: {:>2} tokens | prefill {} steps for {prompt_len} prompt tokens | ttft {:.3}s | latency {:.3}s",
                        completion.tokens.len(),
                        completion.prefill_steps,
                        completion.ttft_s,
                        completion.latency_s,
                    );
                    break;
                }
                StreamEvent::Cancelled { reason, tokens, step, .. } => {
                    println!(
                        "[{id}@{name}] cancelled ({reason:?}) at step {step} with {} tokens",
                        tokens.len()
                    );
                    break;
                }
                _ => {}
            }
        }
    }
    println!("{streamed_tokens} tokens streamed while decoding (not at wave end)");

    // Graceful shutdown; each engine reports its own metrics.
    println!("\nper-engine share of the trace:");
    let shares = router.shares();
    let metrics = router.join()?;
    for ((name, rank, submitted), (_, m)) in shares.iter().zip(&metrics) {
        println!(
            "  {name:<6} rank {rank:>2} | {submitted:>3} requests | {:>6.1} tok/s | {:>3} steps | peak KV {}",
            m.tokens_per_s(),
            m.decode_steps,
            human_bytes(m.kv_peak_bytes),
        );
    }
    Ok(())
}
