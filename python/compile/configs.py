"""Model configurations shared by the L2 JAX programs and the AOT exporter.

Every config here corresponds to a family of HLO artifacts under
``artifacts/<name>/`` and to a ``[model]`` preset in the Rust config system
(`rust/src/config/presets.rs`).  The Rust side never re-derives shapes: it
reads them from ``artifacts/manifest.json`` which is generated from these
dataclasses, so this file is the single source of truth for parameter
layouts.

CLOVER rank grid
----------------
Structured pruning keeps the same rank ``r`` in every head (the paper prunes
"a fixed percentage of the smallest singular vectors" per head to stay
hardware friendly).  One HLO artifact is exported per rank in
``clover_ranks``; the Rust pruning engine picks the artifact matching the
requested ratio.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A GPT-style decoder-only transformer (pre-LN, learned positions,
    weight-tied LM head, bias-free projections — see DESIGN.md for the
    deviation notes vs GPT-2)."""

    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    seq_len: int
    d_ff: int
    # Ranks (per head) for which factorized/pruned artifacts are exported.
    # Always includes d_head (the lossless CLOVER orthogonalization).
    clover_ranks: Tuple[int, ...] = ()
    # LoRA-class adapter rank used by the PEFT train-step artifacts.
    lora_rank: int = 8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        """Dense parameter count (embeddings + blocks + final LN)."""
        d, f, l, v, t = self.d_model, self.d_ff, self.n_layers, self.vocab, self.seq_len
        per_layer = 4 * d * d + 2 * d * f + 4 * d  # attn + mlp + 2 LN (g,b)
        return v * d + t * d + l * per_layer + 2 * d

    def ranks(self) -> Tuple[int, ...]:
        if self.clover_ranks:
            return self.clover_ranks
        return (self.d_head,)


@dataclasses.dataclass(frozen=True)
class Seq2SeqConfig:
    """Whisper-like encoder-decoder used by the §4.4 training-free pruning
    experiment: a continuous feature sequence in, token transcript out."""

    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_enc_layers: int
    n_dec_layers: int
    feat_dim: int
    src_len: int
    tgt_len: int
    d_ff: int
    clover_ranks: Tuple[int, ...] = ()

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def ranks(self) -> Tuple[int, ...]:
        if self.clover_ranks:
            return self.clover_ranks
        return (self.d_head,)


def _rank_grid(d_head: int) -> Tuple[int, ...]:
    """Ranks matching Table 1's pruning ratios 0%..87.5% in steps of 12.5%."""
    grid = []
    for k in range(8, 0, -1):  # 8/8 .. 1/8
        r = max(1, d_head * k // 8)
        if r not in grid:
            grid.append(r)
    return tuple(grid)


# --- decoder presets -------------------------------------------------------

TINY = ModelConfig(
    name="tiny",
    vocab=256,
    d_model=64,
    n_heads=4,
    n_layers=2,
    seq_len=64,
    d_ff=256,
    clover_ranks=_rank_grid(16),
    lora_rank=4,
)

SMALL = ModelConfig(
    name="small",
    vocab=512,
    d_model=256,
    n_heads=8,
    n_layers=4,
    seq_len=128,
    d_ff=1024,
    clover_ranks=_rank_grid(32),
    lora_rank=8,
)

# ~100M-class preset: AOT-exports fine; a few hundred training steps of it
# is ~10h on this 1-core box, so recorded runs use SMALL (see DESIGN.md §5).
LARGE = ModelConfig(
    name="large",
    vocab=8192,
    d_model=768,
    n_heads=12,
    n_layers=12,
    seq_len=256,
    d_ff=3072,
    clover_ranks=(64, 48, 32, 16),
    lora_rank=16,
)

# --- seq2seq (whisper-like) preset ----------------------------------------

S2S_TINY = Seq2SeqConfig(
    name="s2s_tiny",
    vocab=64,
    d_model=128,
    n_heads=4,
    n_enc_layers=2,
    n_dec_layers=2,
    feat_dim=16,
    src_len=96,
    tgt_len=48,
    d_ff=512,
    clover_ranks=(32, 24, 16, 12, 8, 4),
)

DECODERS: List[ModelConfig] = [TINY, SMALL, LARGE]
SEQ2SEQ: List[Seq2SeqConfig] = [S2S_TINY]


def decoder_by_name(name: str) -> ModelConfig:
    for c in DECODERS:
        if c.name == name:
            return c
    raise KeyError(f"unknown decoder config {name!r}")


def seq2seq_by_name(name: str) -> Seq2SeqConfig:
    for c in SEQ2SEQ:
        if c.name == name:
            return c
    raise KeyError(f"unknown seq2seq config {name!r}")
