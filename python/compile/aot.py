"""AOT exporter: lower every L2 program to HLO text + manifest + goldens.

This is the only place Python runs in the whole system, and it runs once
(``make artifacts``).  Each jitted entry point is lowered over a *flat*
argument list (ordering defined by the param specs in ``model.py`` /
``s2s.py``), converted to an XlaComputation, and dumped as **HLO text** —
xla_extension 0.5.1 rejects jax≥0.5's serialized protos (64-bit instruction
ids), but the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs under ``--out`` (default ``../artifacts``):
  <cfg>/<program>.hlo.txt      one per program
  <cfg>/golden_<program>.npz   inputs (arg0..) + expected outputs (out0..)
                               for the Rust integration tests
  manifest.json                every config, param layout, program signature

Usage:  python -m compile.aot --out ../artifacts [--configs tiny,small]
                              [--skip-goldens] [--force]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import s2s as S
from .configs import DECODERS, SEQ2SEQ, ModelConfig, Seq2SeqConfig

# Training/eval batch sizes baked into the artifacts (HLO is shape-static).
TRAIN_BATCH = {"tiny": 16, "small": 16, "large": 8}
DECODE_BATCHES = (1, 8)
# Chunked-prefill slab widths (HLO is shape-static, so the serve engine
# picks from this fixed ladder per step; width 1 is the decode program).
# Exported only for the serving batch size — prefill is a serving-path
# concern, and each extra width is another artifact per config and rank.
PREFILL_CHUNKS = (8, 32)
PREFILL_BATCHES = (8,)
S2S_BATCH = 8


def prefill_chunks_for(cfg: ModelConfig) -> Tuple[int, ...]:
    """Slab widths exported for `cfg`: the ladder, capped by the context
    window (a chunk as wide as the whole window could never be scheduled
    alongside generation)."""
    return tuple(w for w in PREFILL_CHUNKS if w < cfg.seq_len)

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Program:
    """One exportable entry point: a function plus its flat input signature."""

    def __init__(self, name: str, fn: Callable, inputs: List[Tuple[str, Sequence[int], str]],
                 outputs: List[str], golden: bool = False):
        self.name = name
        self.fn = fn
        self.inputs = inputs  # (name, shape, dtype-str)
        self.outputs = outputs  # names only; shapes filled at export
        self.golden = golden

    def input_specs(self):
        return [sds(shape, jnp.dtype(dt)) for _, shape, dt in self.inputs]


def _sig_params(spec) -> List[Tuple[str, Sequence[int], str]]:
    return [(n, s, "float32") for n, s in spec]


def _sig_opt(train_names, spec) -> List[Tuple[str, Sequence[int], str]]:
    shapes = dict(spec)
    out = []
    for kind in ("m", "v"):
        out += [(f"{kind}_{n}", shapes[n], "float32") for n in train_names]
    return out


def _sig_batch(b, t) -> List[Tuple[str, Sequence[int], str]]:
    return [("step", (), "int32"), ("inputs", (b, t), "int32"),
            ("targets", (b, t), "int32"), ("lr", (), "float32")]


def decoder_programs(cfg: ModelConfig) -> List[Program]:
    progs: List[Program] = []
    b = TRAIN_BATCH[cfg.name]
    t = cfg.seq_len
    dense = M.dense_param_spec(cfg)
    dense_sig = _sig_params(dense)

    # ---- init ------------------------------------------------------------
    def init_fn(seed):
        p = M.init_dense(cfg, seed)
        return tuple(M.flat_from_params(dense, p))

    progs.append(Program("init", init_fn, [("seed", (), "int32")],
                         [n for n, _ in dense], golden=True))

    # ---- dense forward / nll / hidden -------------------------------------
    def fwd_fn(*flat):
        params = M.params_from_flat(dense, flat[:-1])
        return (M.forward_dense(cfg, params, flat[-1]),)

    progs.append(Program("fwd", fwd_fn,
                         dense_sig + [("tokens", (b, t), "int32")],
                         ["logits"], golden=True))

    def nll_fn(*flat):
        params = M.params_from_flat(dense, flat[:-2])
        return (M.nll(M.forward_dense(cfg, params, flat[-2]), flat[-1]),)

    progs.append(Program("nll", nll_fn,
                         dense_sig + [("inputs", (b, t), "int32"), ("targets", (b, t), "int32")],
                         ["loss"], golden=True))

    def hidden_fn(*flat):
        """Per-layer post-LN1 activations for the Fig-4 projection study.

        Also returns the final-LN output so every parameter is live — jax
        DCEs unused arguments out of the lowered signature, which would
        desync the manifest."""
        params = M.params_from_flat(dense, flat[:-1])
        tokens = flat[-1]
        x = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]
        stacked = {n: params[n] for n in M._LAYER_DENSE}

        def per_example(xe):
            def body(h, lp):
                h1 = M.ref.layernorm(h, lp["ln1_g"], lp["ln1_b"])
                nxt = M._block_dense(cfg, h, lp, use_pallas=False)
                return nxt, h1

            last, hs = jax.lax.scan(body, xe, stacked)
            final = M.ref.layernorm(last, params["lnf_g"], params["lnf_b"])
            return hs, final  # [L, T, D], [T, D]

        hs, final = jax.vmap(per_example)(x)
        return (hs, final)  # [B, L, T, D], [B, T, D]

    progs.append(Program("hidden", hidden_fn,
                         dense_sig + [("tokens", (b, t), "int32")], ["hidden", "final"]))

    # ---- dense train steps -------------------------------------------------
    def loss_dense(params, inputs, targets):
        return M.nll(M.forward_dense(cfg, params, inputs), targets)

    for pname, trainable, wd in [
        ("train_full", [n for n, _ in dense], 0.01),
        ("train_attn", ["wq", "wk", "wv", "wo"], 0.0),
    ]:
        step_fn, train_names = M.make_train_step(loss_dense, dense, trainable, wd)
        sig = dense_sig + _sig_opt(train_names, dense) + _sig_batch(b, t)
        outs = train_names + [f"m_{n}" for n in train_names] + \
            [f"v_{n}" for n in train_names] + ["step", "loss"]
        progs.append(Program(pname, step_fn, sig, outs, golden=(pname == "train_full")))

    # ---- dense decode ------------------------------------------------------
    for db in DECODE_BATCHES:
        def mk_decode(db):
            def decode_fn(*flat):
                params = M.params_from_flat(dense, flat[:-4])
                kc, vc, toks, positions = flat[-4:]
                return M.decode_step_dense(cfg, params, kc, vc, toks, positions)
            return decode_fn

        cache = (cfg.n_layers, db, cfg.n_heads, t, cfg.d_head)
        progs.append(Program(
            f"decode_b{db}", mk_decode(db),
            dense_sig + [("k_cache", cache, "float32"), ("v_cache", cache, "float32"),
                         ("tokens", (db,), "int32"), ("positions", (db,), "int32")],
            ["logits", "k_cache", "v_cache"], golden=(db == 1)))

    # ---- dense chunked prefill ---------------------------------------------
    # Same cache signature as the decode programs (the runtime carries one
    # literal-side cache set across every width), tokens/positions widened
    # to [B, K] token slabs.  One jax function serves every width — the
    # slab shape is fixed entirely by the Program's input signature.  The
    # logits output is [B, K, V] (every slab position), which is what lets
    # the serve engine reuse these programs as speculative-decode
    # verifiers: one fused step scores a whole K-token draft.
    def prefill_fn(*flat):
        params = M.params_from_flat(dense, flat[:-4])
        kc, vc, toks, positions = flat[-4:]
        return M.prefill_step_dense(cfg, params, kc, vc, toks, positions)

    chunks = prefill_chunks_for(cfg)
    for db in PREFILL_BATCHES:
        for ck in chunks:
            cache = (cfg.n_layers, db, cfg.n_heads, t, cfg.d_head)
            progs.append(Program(
                f"prefill_k{ck}_b{db}", prefill_fn,
                dense_sig + [("k_cache", cache, "float32"), ("v_cache", cache, "float32"),
                             ("tokens", (db, ck), "int32"), ("positions", (db, ck), "int32")],
                ["logits", "k_cache", "v_cache"], golden=(ck == chunks[0])))

    # ---- PEFT train steps (adapters over frozen dense base) ----------------
    for kind in ("lora", "dora", "hira"):
        ad_spec = (M.dora_param_spec if kind == "dora" else M.lora_param_spec)(cfg, cfg.lora_rank)
        step_fn = M.make_peft_train_step(cfg, kind, dense, ad_spec)
        ad_names = [n for n, _ in ad_spec]
        sig = dense_sig + _sig_params(ad_spec) + _sig_opt(ad_names, ad_spec) + _sig_batch(b, t)
        outs = ad_names + [f"m_{n}" for n in ad_names] + [f"v_{n}" for n in ad_names] + \
            ["step", "loss"]
        progs.append(Program(f"train_{kind}", step_fn, sig, outs, golden=(kind == "lora")))

        def mk_peft_fwd(kind, ad_spec):
            def peft_fwd_fn(*flat):
                nb, na = len(dense), len(ad_spec)
                params = M.params_from_flat(dense, flat[:nb])
                ad = M.params_from_flat(ad_spec, flat[nb:nb + na])
                return (M.peft_forward(cfg, kind, params, ad, flat[-1]),)
            return peft_fwd_fn

        progs.append(Program(f"fwd_{kind}", mk_peft_fwd(kind, ad_spec),
                             dense_sig + _sig_params(ad_spec) + [("tokens", (b, t), "int32")],
                             ["logits"]))

    # ---- factorized programs per rank ---------------------------------------
    ranks = cfg.ranks() if cfg.name != "large" else cfg.clover_ranks[:2]
    for r in ranks:
        fac = M.fac_param_spec(cfg, r)
        fac_sig = _sig_params(fac)

        def mk(r, fac):
            def fwd_fac_fn(*flat):
                params = M.params_from_flat(fac, flat[:-1])
                return (M.forward_fac(cfg, params, flat[-1]),)

            def nll_fac_fn(*flat):
                params = M.params_from_flat(fac, flat[:-2])
                return (M.nll(M.forward_fac(cfg, params, flat[-2]), flat[-1]),)

            def loss_fac(params, inputs, targets):
                return M.nll(M.forward_fac(cfg, params, inputs), targets)

            def decode_fac_fn(*flat):
                params = M.params_from_flat(fac, flat[:-4])
                kc, voc, toks, positions = flat[-4:]
                return M.decode_step_fac(cfg, r, params, kc, voc, toks, positions)

            def prefill_fac_fn(*flat):
                params = M.params_from_flat(fac, flat[:-4])
                kc, voc, toks, positions = flat[-4:]
                return M.prefill_step_fac(cfg, r, params, kc, voc, toks, positions)

            return fwd_fac_fn, nll_fac_fn, loss_fac, decode_fac_fn, prefill_fac_fn

        fwd_fac_fn, nll_fac_fn, loss_fac, decode_fac_fn, prefill_fac_fn = mk(r, fac)
        progs.append(Program(f"fwd_fac_r{r}", fwd_fac_fn,
                             fac_sig + [("tokens", (b, t), "int32")], ["logits"],
                             golden=(r == cfg.d_head)))
        progs.append(Program(f"nll_fac_r{r}", nll_fac_fn,
                             fac_sig + [("inputs", (b, t), "int32"),
                                        ("targets", (b, t), "int32")],
                             ["loss"], golden=(r == cfg.d_head)))

        for pname, trainable in [
            (f"train_fac_attn_r{r}", ["u_qk", "s_qk", "v_qk", "u_vo", "s_vo", "v_vo"]),
            (f"train_clover_s_r{r}", ["s_qk", "s_vo"]),
        ]:
            step_fn, train_names = M.make_train_step(loss_fac, fac, trainable, 0.0)
            sig = fac_sig + _sig_opt(train_names, fac) + _sig_batch(b, t)
            outs = train_names + [f"m_{n}" for n in train_names] + \
                [f"v_{n}" for n in train_names] + ["step", "loss"]
            progs.append(Program(pname, step_fn, sig, outs))

        for db in DECODE_BATCHES:
            cache = (cfg.n_layers, db, cfg.n_heads, t, r)

            def mk_decode_fac(db, fac, decode_fac_fn):
                def f(*flat):
                    return decode_fac_fn(*flat)
                return f

            progs.append(Program(
                f"decode_fac_r{r}_b{db}", mk_decode_fac(db, fac, decode_fac_fn),
                fac_sig + [("k_cache", cache, "float32"), ("vo_cache", cache, "float32"),
                           ("tokens", (db,), "int32"), ("positions", (db,), "int32")],
                ["logits", "k_cache", "vo_cache"]))

        for db in PREFILL_BATCHES:
            cache = (cfg.n_layers, db, cfg.n_heads, t, r)
            # prefill_fac_fn is already bound per rank by mk(r, fac); the
            # slab width comes from the input signature alone.
            for ck in chunks:
                progs.append(Program(
                    f"prefill_fac_r{r}_k{ck}_b{db}", prefill_fac_fn,
                    fac_sig + [("k_cache", cache, "float32"), ("vo_cache", cache, "float32"),
                               ("tokens", (db, ck), "int32"), ("positions", (db, ck), "int32")],
                    ["logits", "k_cache", "vo_cache"]))

    # ---- CLOVER fine-tuning config (full rank + factorized MLP.Up) ----------
    facud = M.fac_param_spec(cfg, cfg.d_head, with_ud=True)
    facud_sig = _sig_params(facud)

    def loss_facud(params, inputs, targets):
        return M.nll(M.forward_fac(cfg, params, inputs), targets)

    step_fn, train_names = M.make_train_step(
        loss_facud, facud, ["s_qk", "s_vo", "s_ud"], 0.0)
    sig = facud_sig + _sig_opt(train_names, facud) + _sig_batch(b, t)
    outs = train_names + [f"m_{n}" for n in train_names] + \
        [f"v_{n}" for n in train_names] + ["step", "loss"]
    progs.append(Program("train_cloverft", step_fn, sig, outs))

    def fwd_facud_fn(*flat):
        params = M.params_from_flat(facud, flat[:-1])
        return (M.forward_fac(cfg, params, flat[-1]),)

    progs.append(Program("fwd_cloverft", fwd_facud_fn,
                         facud_sig + [("tokens", (b, t), "int32")], ["logits"]))

    return progs


def s2s_programs(cfg: Seq2SeqConfig) -> List[Program]:
    progs: List[Program] = []
    b = S2S_BATCH
    spec = S.s2s_param_spec(cfg)
    sig = _sig_params(spec)
    feats = ("feats", (b, cfg.src_len, cfg.feat_dim), "float32")
    tok_in = ("tokens_in", (b, cfg.tgt_len), "int32")
    tok_tgt = ("tokens_tgt", (b, cfg.tgt_len), "int32")

    def init_fn(seed):
        return tuple(S.init_s2s(cfg, seed)[n] for n, _ in spec)

    progs.append(Program("init", init_fn, [("seed", (), "int32")],
                         [n for n, _ in spec], golden=True))

    def fwd_fn(*flat):
        params = {n: a for (n, _), a in zip(spec, flat[:-2])}
        return (S.s2s_logits(cfg, params, flat[-2], flat[-1]),)

    progs.append(Program("fwd", fwd_fn, sig + [feats, tok_in], ["logits"], golden=True))

    def nll_fn(*flat):
        params = {n: a for (n, _), a in zip(spec, flat[:-3])}
        return (S.s2s_nll(cfg, params, flat[-3], flat[-2], flat[-1]),)

    progs.append(Program("nll", nll_fn, sig + [feats, tok_in, tok_tgt], ["loss"]))

    def loss_fn(params, inputs, targets):
        # inputs packs (feats, tokens_in) — handled below by closure instead.
        raise NotImplementedError

    # Full train step (custom signature: feats + tokens).
    names = [n for n, _ in spec]

    def train_fn(*flat):
        n = len(spec)
        params = {nm: a for (nm, _), a in zip(spec, flat[:n])}
        ms = dict(zip(names, flat[n:2 * n]))
        vs = dict(zip(names, flat[2 * n:3 * n]))
        step_count, feats_, tin, ttgt, lr = flat[3 * n:]

        def loss_of(p):
            return S.s2s_nll(cfg, p, feats_, tin, ttgt)

        loss, grads = jax.value_and_grad(loss_of)(params)
        grads = M.global_norm_clip(grads)
        new_step = step_count + 1
        outs, oms, ovs = [], [], []
        for nm in names:
            p2, m2, v2 = M.adamw_update(params[nm], grads[nm], ms[nm], vs[nm],
                                        new_step.astype(jnp.float32), lr, 0.01)
            outs.append(p2)
            oms.append(m2)
            ovs.append(v2)
        return tuple(outs + oms + ovs + [new_step, loss])

    shapes = dict(spec)
    opt_sig = [(f"m_{n}", shapes[n], "float32") for n in names] + \
              [(f"v_{n}", shapes[n], "float32") for n in names]
    progs.append(Program(
        "train_full", train_fn,
        sig + opt_sig + [("step", (), "int32"), feats, tok_in, tok_tgt, ("lr", (), "float32")],
        names + [f"m_{n}" for n in names] + [f"v_{n}" for n in names] + ["step", "loss"]))

    # Factorized-encoder variants per rank.
    for r in cfg.ranks():
        fspec = S.s2s_fac_param_spec(cfg, r)
        fsig = _sig_params(fspec)

        def mk(fspec):
            def fwd_fac_fn(*flat):
                params = {n: a for (n, _), a in zip(fspec, flat[:-2])}
                return (S.s2s_logits(cfg, params, flat[-2], flat[-1], factorized=True),)

            def nll_fac_fn(*flat):
                params = {n: a for (n, _), a in zip(fspec, flat[:-3])}
                return (S.s2s_nll(cfg, params, flat[-3], flat[-2], flat[-1], factorized=True),)

            return fwd_fac_fn, nll_fac_fn

        fwd_fac_fn, nll_fac_fn = mk(fspec)
        progs.append(Program(f"fwd_fac_r{r}", fwd_fac_fn, fsig + [feats, tok_in], ["logits"],
                             golden=(r == cfg.d_head)))
        progs.append(Program(f"nll_fac_r{r}", nll_fac_fn, fsig + [feats, tok_in, tok_tgt],
                             ["loss"]))

    return progs


# --------------------------------------------------------------------------
# Export driver
# --------------------------------------------------------------------------


def _golden_inputs(prog: Program, rng: np.random.Generator):
    """Deterministic pseudo-random concrete inputs for golden generation."""
    args = []
    for name, shape, dt in prog.inputs:
        if dt == "int32":
            if name in ("step", "pos"):
                args.append(np.asarray(0, np.int32))
            elif name == "positions":
                if len(shape) == 2:
                    # Prefill slab: each lane writes positions 0..K-1.
                    args.append(np.tile(np.arange(shape[1], dtype=np.int32),
                                        (shape[0], 1)))
                else:
                    args.append(np.zeros(shape, np.int32))
            elif name == "seed":
                args.append(np.asarray(42, np.int32))
            else:
                args.append(rng.integers(0, 17, size=shape).astype(np.int32))
        else:
            if name == "lr":
                args.append(np.asarray(1e-3, np.float32))
            elif name.startswith(("m_", "v_")) or "cache" in name:
                args.append(np.zeros(shape, np.float32))
            else:
                args.append((rng.standard_normal(shape) * 0.05).astype(np.float32))
    return args


GOLDEN_CONFIGS = {"tiny", "s2s_tiny"}  # goldens for big configs cost ~100MB each


def export_config(cfg_name: str, progs: List[Program], out_dir: str,
                  skip_goldens: bool, force: bool) -> Dict:
    skip_goldens = skip_goldens or cfg_name not in GOLDEN_CONFIGS
    cdir = os.path.join(out_dir, cfg_name)
    os.makedirs(cdir, exist_ok=True)
    entry: Dict = {"programs": {}}
    for prog in progs:
        path = os.path.join(cdir, f"{prog.name}.hlo.txt")
        out_shapes = jax.eval_shape(prog.fn, *prog.input_specs())
        if not isinstance(out_shapes, tuple):
            out_shapes = (out_shapes,)
        if force or not os.path.exists(path):
            lowered = jax.jit(prog.fn).lower(*prog.input_specs())
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
        assert len(out_shapes) == len(prog.outputs), (
            prog.name, len(out_shapes), len(prog.outputs))
        entry["programs"][prog.name] = {
            "file": f"{cfg_name}/{prog.name}.hlo.txt",
            "inputs": [
                {"name": n, "shape": list(s), "dtype": d} for n, s, d in prog.inputs
            ],
            "outputs": [
                {"name": n, "shape": [int(x) for x in o.shape], "dtype": str(o.dtype)}
                for n, o in zip(prog.outputs, out_shapes)
            ],
        }
        gpath = os.path.join(cdir, f"golden_{prog.name}.npz")
        if prog.golden and not skip_goldens and (force or not os.path.exists(gpath)):
            rng = np.random.default_rng(7)
            args = _golden_inputs(prog, rng)
            outs = jax.jit(prog.fn)(*args)
            if not isinstance(outs, tuple):
                outs = (outs,)
            payload = {f"arg{i}": a for i, a in enumerate(args)}
            payload.update({f"out{i}": np.asarray(o) for i, o in enumerate(outs)})
            np.savez(gpath, **payload)
            entry["programs"][prog.name]["golden"] = f"{cfg_name}/golden_{prog.name}.npz"
        elif prog.golden and os.path.exists(gpath):
            entry["programs"][prog.name]["golden"] = f"{cfg_name}/golden_{prog.name}.npz"
        print(f"  [{cfg_name}] {prog.name}", flush=True)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,s2s_tiny")
    ap.add_argument("--skip-goldens", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    want = set(args.configs.split(","))
    manifest: Dict = {"configs": {}}
    mpath = os.path.join(args.out, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)

    for cfg in DECODERS:
        if cfg.name not in want:
            continue
        print(f"exporting decoder config {cfg.name} "
              f"({cfg.n_params/1e6:.1f}M params)", flush=True)
        entry = export_config(cfg.name, decoder_programs(cfg), args.out,
                              args.skip_goldens, args.force)
        entry.update({
            "kind": "decoder",
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers, "seq_len": cfg.seq_len, "d_ff": cfg.d_ff,
            "d_head": cfg.d_head, "ranks": list(cfg.ranks()),
            "lora_rank": cfg.lora_rank, "train_batch": TRAIN_BATCH[cfg.name],
            "decode_batches": list(DECODE_BATCHES),
            "prefill_chunks": list(prefill_chunks_for(cfg)),
            # The prefill slab programs emit logits at every slab position
            # ([B, K, V]), so each chunk width doubles as a speculative-
            # decode verify width: the dense engine can score a K-token
            # draft in one fused step.  Advertised separately so the Rust
            # engine can gate speculation on manifests that predate the
            # all-position logits export.
            "verify_widths": list(prefill_chunks_for(cfg)),
            "prefill_batches": list(PREFILL_BATCHES), "ud_block": M.UD_BLOCK,
            "params_dense": [{"name": n, "shape": list(s)}
                             for n, s in M.dense_param_spec(cfg)],
            "params_fac": {str(r): [{"name": n, "shape": list(s)}
                                    for n, s in M.fac_param_spec(cfg, r)]
                           for r in cfg.ranks()},
            "params_facud": [{"name": n, "shape": list(s)}
                             for n, s in M.fac_param_spec(cfg, cfg.d_head, with_ud=True)],
            "params_lora": [{"name": n, "shape": list(s)}
                            for n, s in M.lora_param_spec(cfg, cfg.lora_rank)],
            "params_dora": [{"name": n, "shape": list(s)}
                            for n, s in M.dora_param_spec(cfg, cfg.lora_rank)],
        })
        manifest["configs"][cfg.name] = entry

    for cfg in SEQ2SEQ:
        if cfg.name not in want:
            continue
        print(f"exporting seq2seq config {cfg.name}", flush=True)
        entry = export_config(cfg.name, s2s_programs(cfg), args.out,
                              args.skip_goldens, args.force)
        entry.update({
            "kind": "seq2seq",
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "n_enc_layers": cfg.n_enc_layers, "n_dec_layers": cfg.n_dec_layers,
            "feat_dim": cfg.feat_dim, "src_len": cfg.src_len, "tgt_len": cfg.tgt_len,
            "d_ff": cfg.d_ff, "d_head": cfg.d_head, "ranks": list(cfg.ranks()),
            "batch": S2S_BATCH,
            "params": [{"name": n, "shape": list(s)} for n, s in S.s2s_param_spec(cfg)],
            "params_fac": {str(r): [{"name": n, "shape": list(s)}
                                    for n, s in S.s2s_fac_param_spec(cfg, r)]
                           for r in cfg.ranks()},
        })
        manifest["configs"][cfg.name] = entry

    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
