"""Pallas kernel: head-wise CLOVER factorized projection.

Computes ``out[h] = (x @ u[h]) @ s[h]`` for every attention head — the
building block the paper's factorization reduces attention to.  The D×D
cross-layer matrix ``W = U S Vᵀ`` is never materialized: only the rank-r
factors are streamed through VMEM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is (head,
query-row-block); each step keeps one ``[bt, D]`` activation tile, one
``[D, r]`` factor and one ``[r, r]`` transition matrix resident in VMEM and
issues two MXU contractions.  Rank pruning shrinks both the VMEM footprint
and the MXU work linearly in ``r``.

Runs under ``interpret=True`` — on this CPU-only image the kernel lowers to
plain HLO ops so the Rust PJRT client can execute it (real TPU lowering
emits a Mosaic custom-call the CPU plugin cannot run).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _project_kernel(x_ref, u_ref, s_ref, o_ref):
    """One (head, row-block) grid step: o = (x @ u_h) @ s_h."""
    x = x_ref[...]  # [bt, D]
    u = u_ref[0]  # [D, r]
    s = s_ref[0]  # [r, r]
    xu = jnp.dot(x, u, preferred_element_type=jnp.float32)
    o_ref[0] = jnp.dot(xu, s, preferred_element_type=jnp.float32)


def _pick_block(t: int, want: int = 128) -> int:
    """Largest divisor of ``t`` not exceeding ``want`` (MXU-friendly when
    t is a multiple of 128; degrades gracefully for tiny test shapes)."""
    b = min(t, want)
    while t % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_t",))
def clover_project(x: jnp.ndarray, u: jnp.ndarray, s: jnp.ndarray, block_t: int = 0):
    """x [T, D], u [H, D, r], s [H, r, r] -> [H, T, r].

    Oracle: :func:`compile.kernels.ref.clover_project`.
    """
    t, d = x.shape
    h, _, r = u.shape
    bt = block_t or _pick_block(t)
    grid = (h, t // bt)
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda hh, i: (i, 0)),
            pl.BlockSpec((1, d, r), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((1, r, r), lambda hh, i: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, r), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, r), jnp.float32),
        interpret=True,
    )(x, u, s)
