"""Pallas kernels: fused CLOVER factorized attention.

Two implementations of the paper's Figure-1a structure — attention whose
score matrix is the cross-layer factorization ``(X U_qk S_qk)(X V_qk)ᵀ``
and whose value path is ``(X U_vo S_vo)`` — executed without ever
materializing the D×D ``W_QK`` / ``W_VO`` matrices:

* :func:`attention_ctx` — one grid step per head; the whole ``[T, D]``
  activation tile plus the rank-r factors stay VMEM-resident.  Best for
  short sequences (prefill at T ≤ ~512 in f32 fits a TPU core's VMEM).

* :func:`attention_ctx_blocked` — FlashAttention-style online softmax: the
  grid is (head, query-block) and key/value-side blocks are streamed
  innermost with running max / normalizer accumulators.  This is the
  HBM↔VMEM schedule the paper's GPU framing expresses with thread blocks,
  restated as a BlockSpec + fori_loop (DESIGN.md §Hardware-Adaptation).

Both return ctx [H, T, r]; the final ``V_voᵀ`` contraction + head-sum is a
single einsum left to XLA (it fuses with the residual add).  Oracle:
``ref.factorized_attention_ctx``.  Numerics note: masked scores use -1e30
(not -inf) so fully-masked rows stay NaN-free, matching the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .clover_matmul import _pick_block

NEG_INF = -1e30


def _ctx_kernel(scale, causal, x_ref, uq_ref, sq_ref, vq_ref, uv_ref, sv_ref, o_ref):
    """Whole-sequence fused attention for one head."""
    x = x_ref[...]  # [T, D]
    t = x.shape[0]
    q = jnp.dot(jnp.dot(x, uq_ref[0]), sq_ref[0])  # [T, r]
    k = jnp.dot(x, vq_ref[0])  # [T, r]
    scores = jnp.dot(q, k.T) * scale  # [T, T]
    if causal:
        i = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
        j = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
        scores = jnp.where(j <= i, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    attn = e / jnp.sum(e, axis=-1, keepdims=True)
    vo = jnp.dot(jnp.dot(x, uv_ref[0]), sv_ref[0])  # [T, r]
    o_ref[0] = jnp.dot(attn, vo)


@functools.partial(jax.jit, static_argnames=("scale", "causal"))
def attention_ctx(x, u_qk, s_qk, v_qk, u_vo, s_vo, scale: float, causal: bool = True):
    """x [T,D]; factors [H,D,r]/[H,r,r] -> ctx [H,T,r] (whole-seq kernel)."""
    t, d = x.shape
    h, _, r = u_qk.shape
    dr = pl.BlockSpec((1, d, r), lambda hh: (hh, 0, 0))
    rr = pl.BlockSpec((1, r, r), lambda hh: (hh, 0, 0))
    return pl.pallas_call(
        functools.partial(_ctx_kernel, scale, causal),
        grid=(h,),
        # args: x, u_qk, s_qk, v_qk, u_vo, s_vo
        in_specs=[pl.BlockSpec((t, d), lambda hh: (0, 0)), dr, rr, dr, dr, rr],
        out_specs=pl.BlockSpec((1, t, r), lambda hh: (hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, r), jnp.float32),
        interpret=True,
    )(x, u_qk, s_qk, v_qk, u_vo, s_vo)


def _ctx_blocked_kernel(
    scale, causal, bq, bk, x_q_ref, x_kv_ref, uq_ref, sq_ref, vq_ref, uv_ref, sv_ref, o_ref
):
    """Online-softmax fused attention: one (head, query-block) grid step.

    Streams key/value blocks of size ``bk`` through VMEM keeping the
    FlashAttention running statistics (m: row max, l: normalizer, acc:
    unnormalized context).
    """
    qi = pl.program_id(1)
    x_q = x_q_ref[...]  # [bq, D]
    q = jnp.dot(jnp.dot(x_q, uq_ref[0]), sq_ref[0])  # [bq, r]
    t = x_kv_ref.shape[0]
    r = q.shape[1]
    n_kb = t // bk

    def body(jb, carry):
        m_i, l_i, acc = carry
        x_kv = jax.lax.dynamic_slice_in_dim(x_kv_ref[...], jb * bk, bk, axis=0)
        k = jnp.dot(x_kv, vq_ref[0])  # [bk, r]
        vo = jnp.dot(jnp.dot(x_kv, uv_ref[0]), sv_ref[0])  # [bk, r]
        s = jnp.dot(q, k.T) * scale  # [bq, bk]
        if causal:
            qi_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kj_idx = jb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kj_idx <= qi_idx, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, vo)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc0 = jnp.zeros((bq, r), jnp.float32)
    if causal:
        # Causal masking zeroes every key block strictly above the current
        # query block, so stop streaming there: ~2x fewer inner iterations.
        n_iter = qi + 1
    else:
        n_iter = n_kb
    m_f, l_f, acc_f = jax.lax.fori_loop(0, n_iter, body, (m0, l0, acc0))
    o_ref[0] = acc_f / l_f


@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q", "block_k"))
def attention_ctx_blocked(
    x, u_qk, s_qk, v_qk, u_vo, s_vo, scale: float, causal: bool = True,
    block_q: int = 0, block_k: int = 0,
):
    """Blocked online-softmax variant; requires block_q == block_k when
    causal (the early-exit loop bound assumes aligned blocks)."""
    t, d = x.shape
    h, _, r = u_qk.shape
    bq = block_q or _pick_block(t, 64)
    bk = block_k or bq
    if causal and bq != bk:
        raise ValueError("causal blocked kernel requires block_q == block_k")
    dr = pl.BlockSpec((1, d, r), lambda hh, ii: (hh, 0, 0))
    rr = pl.BlockSpec((1, r, r), lambda hh, ii: (hh, 0, 0))
    return pl.pallas_call(
        functools.partial(_ctx_blocked_kernel, scale, causal, bq, bk),
        grid=(h, t // bq),
        # args: x_q, x_kv, u_qk, s_qk, v_qk, u_vo, s_vo
        in_specs=[
            pl.BlockSpec((bq, d), lambda hh, ii: (ii, 0)),
            pl.BlockSpec((t, d), lambda hh, ii: (0, 0)),
            dr, rr, dr, dr, rr,
        ],
        out_specs=pl.BlockSpec((1, bq, r), lambda hh, ii: (hh, ii, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, r), jnp.float32),
        interpret=True,
    )(x, x, u_qk, s_qk, v_qk, u_vo, s_vo)
