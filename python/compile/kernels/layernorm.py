"""Pallas kernel: fused residual-add + LayerNorm.

One grid step normalizes a ``[bt, D]`` block of rows entirely in VMEM —
the residual add, the mean/variance reduction, and the affine transform
never round-trip to HBM between ops (the fusion XLA would have to
rediscover).  Oracle: ``ref.layernorm(res + x, g, b)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .clover_matmul import _pick_block


def _ln_kernel(eps, x_ref, res_ref, g_ref, b_ref, o_ref):
    x = x_ref[...] + res_ref[...]  # fused residual add, [bt, D]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    o_ref[...] = xc * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("eps", "block_t"))
def add_layernorm(
    x: jnp.ndarray,
    res: jnp.ndarray,
    g: jnp.ndarray,
    b: jnp.ndarray,
    eps: float = 1e-5,
    block_t: int = 0,
):
    """x, res [T, D]; g, b [D] -> layernorm(x + res) [T, D]."""
    t, d = x.shape
    bt = block_t or _pick_block(t)
    kern = functools.partial(_ln_kernel, eps)
    return pl.pallas_call(
        kern,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, res, g, b)
