"""Layer-1 Pallas kernels for CLOVER factorized attention.

Public surface used by the L2 model (``compile.model``):

* :func:`fused_attention_ctx` — differentiable fused factorized-attention
  context: Pallas forward (whole-seq or blocked online-softmax), oracle
  (``ref``) backward via ``jax.custom_vjp``.
* :func:`clover_matmul.clover_project` — head-wise factorized projection.
* :func:`layernorm.add_layernorm` — fused residual + LayerNorm.
* ``ref`` — the pure-jnp oracle module.

All kernels run ``interpret=True`` (CPU PJRT); see the module docstrings
for the TPU mapping that the BlockSpecs encode.
"""

from __future__ import annotations

import functools

import jax

from . import clover_attention, clover_matmul, layernorm, ref  # noqa: F401


@functools.lru_cache(maxsize=None)
def _make_fused_ctx(scale: float, causal: bool, blocked: bool):
    """Build a custom_vjp'd fused attention-context function.

    Forward: the Pallas kernel.  Backward: jax.vjp of the jnp oracle,
    recomputing the forward (FlashAttention-style rematerialization — the
    [T,T] score matrix is never saved as a residual).
    """

    def fwd_kernel(x, uq, sq, vq, uv, sv):
        if blocked:
            return clover_attention.attention_ctx_blocked(
                x, uq, sq, vq, uv, sv, scale=scale, causal=causal
            )
        return clover_attention.attention_ctx(x, uq, sq, vq, uv, sv, scale=scale, causal=causal)

    def oracle(x, uq, sq, vq, uv, sv):
        return ref.factorized_attention_ctx(x, uq, sq, vq, uv, sv, scale, causal)

    @jax.custom_vjp
    def fused(x, uq, sq, vq, uv, sv):
        return fwd_kernel(x, uq, sq, vq, uv, sv)

    def fused_fwd(x, uq, sq, vq, uv, sv):
        return fwd_kernel(x, uq, sq, vq, uv, sv), (x, uq, sq, vq, uv, sv)

    def fused_bwd(residuals, g):
        _, vjp = jax.vjp(oracle, *residuals)
        return vjp(g)

    fused.defvjp(fused_fwd, fused_bwd)
    return fused


def fused_attention_ctx(x, u_qk, s_qk, v_qk, u_vo, s_vo, scale: float,
                        causal: bool = True, blocked: bool = False):
    """Differentiable fused CLOVER attention context. x [T,D] -> [H,T,r]."""
    return _make_fused_ctx(float(scale), bool(causal), bool(blocked))(
        x, u_qk, s_qk, v_qk, u_vo, s_vo
    )
