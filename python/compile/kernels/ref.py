"""Pure-jnp reference implementations (the correctness oracle).

Every Pallas kernel in this package has an exact counterpart here; pytest +
hypothesis compare them with ``assert_allclose`` across shapes / ranks /
seeds (see ``python/tests/test_kernels.py``).  These functions are also the
backward-path implementations: the Pallas kernels are wired into the L2
model through ``jax.custom_vjp`` whose VJP differentiates *these* functions,
so training numerics are oracle-exact by construction.

Shape conventions (single example; batch is vmapped by callers):
  x      [T, D]        residual-stream activations
  u_qk   [H, D, r]     left CLOVER factors of W_QK  (orthonormal columns)
  s_qk   [H, r, r]     CLOVER transition matrices (diag(singular values) at
                       init; dense after fine-tuning)
  v_qk   [H, D, r]     right CLOVER factors of W_QK
  u_vo, s_vo, v_vo     same for the Value-Output pair
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def layernorm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5):
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def clover_project(x: jnp.ndarray, u: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Head-wise factorized projection ``q_h = (x @ u_h) @ s_h``.

    x [T, D], u [H, D, r], s [H, r, r]  ->  [H, T, r].
    This is the CLOVER hot-spot: the D×D cross-layer matrix is never
    materialized; only the rank-r factors touch memory.
    """
    xu = jnp.einsum("td,hdr->htr", x, u)
    return jnp.einsum("htr,hrk->htk", xu, s)


def causal_mask(t: int) -> jnp.ndarray:
    """[T, T] additive causal mask (0 on/below diagonal, -inf above)."""
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    return jnp.where(j <= i, 0.0, NEG_INF).astype(jnp.float32)


def factorized_attention_ctx(
    x: jnp.ndarray,
    u_qk: jnp.ndarray,
    s_qk: jnp.ndarray,
    v_qk: jnp.ndarray,
    u_vo: jnp.ndarray,
    s_vo: jnp.ndarray,
    scale: float,
    causal: bool = True,
) -> jnp.ndarray:
    """The part of :func:`factorized_attention` the Pallas kernel fuses:
    everything up to (but not including) the final ``V_vo`` contraction and
    head sum.  Returns ctx [H, T, r]."""
    t = x.shape[0]
    q = clover_project(x, u_qk, s_qk)
    k = jnp.einsum("td,hdr->htr", x, v_qk)
    scores = jnp.einsum("htr,hsr->hts", q, k) * scale
    if causal:
        scores = scores + causal_mask(t)[None, :, :]
    attn = jax.nn.softmax(scores, axis=-1)
    vo = clover_project(x, u_vo, s_vo)
    return jnp.einsum("hts,hsr->htr", attn, vo)


def factorized_attention(
    x: jnp.ndarray,
    u_qk: jnp.ndarray,
    s_qk: jnp.ndarray,
    v_qk: jnp.ndarray,
    u_vo: jnp.ndarray,
    s_vo: jnp.ndarray,
    v_vo: jnp.ndarray,
    scale: float,
    causal: bool = True,
) -> jnp.ndarray:
    """CLOVER factorized multi-head attention for one example.

    Computes ``softmax((X U_qk S_qk) (X V_qk)^T * scale) (X U_vo S_vo) V_vo^T``
    summed over heads — i.e. attention with W_QK / W_VO replaced by their
    cross-layer SVD factors (paper §3 and Appendix A.1).

    ``scale`` must be 1/sqrt(d_head_original) even after pruning r < d: the
    score matrix approximates X W_QK X^T / sqrt(d), and W_QK's scale does not
    change when trailing singular directions are dropped.
    """
    ctx = factorized_attention_ctx(x, u_qk, s_qk, v_qk, u_vo, s_vo, scale, causal)
    return jnp.einsum("htr,hdr->td", ctx, v_vo)


def dense_attention(
    x: jnp.ndarray,
    wq: jnp.ndarray,
    wk: jnp.ndarray,
    wv: jnp.ndarray,
    wo: jnp.ndarray,
    n_heads: int,
    causal: bool = True,
) -> jnp.ndarray:
    """Vanilla multi-head attention (bias-free), one example. x [T, D]."""
    t, d = x.shape
    dh = d // n_heads
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    def split(w):
        return (x @ w).reshape(t, n_heads, dh).transpose(1, 0, 2)  # [H,T,dh]

    q, k, v = split(wq), split(wk), split(wv)
    scores = jnp.einsum("htr,hsr->hts", q, k) * scale
    if causal:
        scores = scores + causal_mask(t)[None, :, :]
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hts,hsr->htr", attn, v)  # [H,T,dh]
    ctx = ctx.transpose(1, 0, 2).reshape(t, d)
    return ctx @ wo


def cross_attention_dense(
    xq: jnp.ndarray,
    xkv: jnp.ndarray,
    wq: jnp.ndarray,
    wk: jnp.ndarray,
    wv: jnp.ndarray,
    wo: jnp.ndarray,
    n_heads: int,
) -> jnp.ndarray:
    """Encoder-decoder cross attention (no mask). xq [Tq,D], xkv [Tk,D]."""
    tq, d = xq.shape
    tk = xkv.shape[0]
    dh = d // n_heads
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q = (xq @ wq).reshape(tq, n_heads, dh).transpose(1, 0, 2)
    k = (xkv @ wk).reshape(tk, n_heads, dh).transpose(1, 0, 2)
    v = (xkv @ wv).reshape(tk, n_heads, dh).transpose(1, 0, 2)
    attn = jax.nn.softmax(jnp.einsum("htr,hsr->hts", q, k) * scale, axis=-1)
    ctx = jnp.einsum("hts,hsr->htr", attn, v).transpose(1, 0, 2).reshape(tq, d)
    return ctx @ wo


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (GPT-2 style)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))
