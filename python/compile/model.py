"""Layer-2 JAX model definitions (build-time only; never on the request path).

Defines the GPT-style decoder (dense and CLOVER-factorized attention) and
the whisper-like encoder-decoder, as *pure functions* over explicit
parameter dicts.  ``aot.py`` lowers jitted entry points over flat argument
lists to HLO text; the flat ordering is given by the ``*_param_spec``
functions here and mirrored in ``artifacts/manifest.json`` for the Rust
loader — Rust never re-derives a shape.

Attention paths:
* dense      — plain jnp (XLA fuses it fine on the MXU),
* factorized — the L1 Pallas kernels via ``kernels.fused_attention_ctx``
  (custom_vjp: Pallas forward, oracle backward), so both inference and
  training artifacts execute the paper's fused factorized hot path.

LayerNorm uses the fused Pallas kernel through the same custom_vjp pattern.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .configs import ModelConfig, Seq2SeqConfig
from .kernels import ref

Params = Dict[str, jnp.ndarray]
Spec = List[Tuple[str, Tuple[int, ...]]]

UD_BLOCK = 64  # MLP.Up factorization block size (paper §4.2: "64 consecutive dims")


# --------------------------------------------------------------------------
# Fused LayerNorm with oracle backward (same pattern as fused attention)
# --------------------------------------------------------------------------


@jax.custom_vjp
def _fused_ln(x, res, g, b):
    return kernels.layernorm.add_layernorm(x, res, g, b)


def _fused_ln_fwd(x, res, g, b):
    return _fused_ln(x, res, g, b), (x, res, g, b)


def _fused_ln_bwd(saved, grad):
    x, res, g, b = saved
    _, vjp = jax.vjp(lambda x, res, g, b: ref.layernorm(x + res, g, b), x, res, g, b)
    return vjp(grad)


_fused_ln.defvjp(_fused_ln_fwd, _fused_ln_bwd)


def add_ln(x, res, g, b, use_pallas: bool):
    """layernorm(x + res) — fused Pallas kernel or the jnp oracle."""
    if use_pallas:
        return _fused_ln(x, res, g, b)
    return ref.layernorm(x + res, g, b)


# --------------------------------------------------------------------------
# Parameter specs (single source of truth for flat argument ordering)
# --------------------------------------------------------------------------


def dense_param_spec(cfg: ModelConfig) -> Spec:
    l, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    return [
        ("tok_emb", (cfg.vocab, d)),
        ("pos_emb", (cfg.seq_len, d)),
        ("ln1_g", (l, d)),
        ("ln1_b", (l, d)),
        ("wq", (l, d, d)),
        ("wk", (l, d, d)),
        ("wv", (l, d, d)),
        ("wo", (l, d, d)),
        ("ln2_g", (l, d)),
        ("ln2_b", (l, d)),
        ("w_up", (l, d, f)),
        ("w_down", (l, f, d)),
        ("lnf_g", (d,)),
        ("lnf_b", (d,)),
    ]


def fac_param_spec(cfg: ModelConfig, r: int, with_ud: bool = False) -> Spec:
    """CLOVER-factorized attention params at per-head rank r.

    with_ud=True additionally factorizes MLP.Up into UD_BLOCK-column blocks
    (the Table-2 fine-tuning configuration)."""
    l, d, f, h = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_heads
    spec: Spec = [
        ("tok_emb", (cfg.vocab, d)),
        ("pos_emb", (cfg.seq_len, d)),
        ("ln1_g", (l, d)),
        ("ln1_b", (l, d)),
        ("u_qk", (l, h, d, r)),
        ("s_qk", (l, h, r, r)),
        ("v_qk", (l, h, d, r)),
        ("u_vo", (l, h, d, r)),
        ("s_vo", (l, h, r, r)),
        ("v_vo", (l, h, d, r)),
        ("ln2_g", (l, d)),
        ("ln2_b", (l, d)),
    ]
    if with_ud:
        nb = f // UD_BLOCK
        spec += [
            ("u_ud", (l, nb, d, UD_BLOCK)),
            ("s_ud", (l, nb, UD_BLOCK, UD_BLOCK)),
            ("v_ud", (l, nb, UD_BLOCK, UD_BLOCK)),
        ]
    else:
        spec += [("w_up", (l, d, f))]
    spec += [
        ("w_down", (l, f, d)),
        ("lnf_g", (d,)),
        ("lnf_b", (d,)),
    ]
    return spec


def lora_param_spec(cfg: ModelConfig, rank: int) -> Spec:
    """LoRA adapters on {Q, K, V, Up, Down} (DoRA paper's target set minus O,
    matching Table 3's `Q,K,V,U,D`)."""
    l, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    return [
        ("a_q", (l, d, rank)),
        ("b_q", (l, rank, d)),
        ("a_k", (l, d, rank)),
        ("b_k", (l, rank, d)),
        ("a_v", (l, d, rank)),
        ("b_v", (l, rank, d)),
        ("a_up", (l, d, rank)),
        ("b_up", (l, rank, f)),
        ("a_down", (l, f, rank)),
        ("b_down", (l, rank, d)),
    ]


def dora_param_spec(cfg: ModelConfig, rank: int) -> Spec:
    """DoRA = LoRA + per-output-column magnitude vectors."""
    l, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    return lora_param_spec(cfg, rank) + [
        ("m_q", (l, d)),
        ("m_k", (l, d)),
        ("m_v", (l, d)),
        ("m_up", (l, f)),
        ("m_down", (l, d)),
    ]


def spec_names(spec: Spec) -> List[str]:
    return [n for n, _ in spec]


def params_from_flat(spec: Spec, flat) -> Params:
    assert len(flat) == len(spec), (len(flat), len(spec))
    return {n: a for (n, _), a in zip(spec, flat)}


def flat_from_params(spec: Spec, params: Params):
    return [params[n] for n, _ in spec]


# --------------------------------------------------------------------------
# Initialization (exported as an HLO program so Rust owns the seed)
# --------------------------------------------------------------------------


def init_dense(cfg: ModelConfig, seed: jnp.ndarray) -> Params:
    """GPT-2-style init: N(0, 0.02), residual-out projections scaled by
    1/sqrt(2L), LN at identity. ``seed`` is a scalar int32."""
    key = jax.random.PRNGKey(seed)
    spec = dense_param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    out: Params = {}
    resid_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
    for (name, shape), k in zip(spec, keys):
        if name.startswith("ln") and name.endswith("_g"):
            out[name] = jnp.ones(shape, jnp.float32)
        elif name.startswith("ln") and name.endswith("_b"):
            out[name] = jnp.zeros(shape, jnp.float32)
        elif name in ("wo", "w_down"):
            out[name] = jax.random.normal(k, shape, jnp.float32) * resid_scale
        else:
            out[name] = jax.random.normal(k, shape, jnp.float32) * 0.02
    return out


# --------------------------------------------------------------------------
# Decoder forward (dense / factorized)
# --------------------------------------------------------------------------


_LAYER_DENSE = ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b", "w_up", "w_down"]
_LAYER_FAC = [
    "ln1_g", "ln1_b", "u_qk", "s_qk", "v_qk", "u_vo", "s_vo", "v_vo",
    "ln2_g", "ln2_b", "w_up", "w_down",
]
_LAYER_FAC_UD = [
    "ln1_g", "ln1_b", "u_qk", "s_qk", "v_qk", "u_vo", "s_vo", "v_vo",
    "ln2_g", "ln2_b", "u_ud", "s_ud", "v_ud", "w_down",
]


def _mlp(h: jnp.ndarray, lp: Params) -> jnp.ndarray:
    if "u_ud" in lp:
        # Factorized Up (intra-layer blockwise SVD): never materialize W_up.
        # h [T,D]; u_ud [NB,D,K]; s_ud,v_ud [NB,K,K]
        hu = jnp.einsum("td,ndk->tnk", h, lp["u_ud"])
        hs = jnp.einsum("tnk,nkj->tnj", hu, lp["s_ud"])
        up = jnp.einsum("tnj,nmj->tnm", hs, lp["v_ud"])  # block = U S V^T
        up = up.reshape(h.shape[0], -1)
    else:
        up = h @ lp["w_up"]
    return ref.gelu(up) @ lp["w_down"]


def _block_dense(cfg: ModelConfig, x: jnp.ndarray, lp: Params, use_pallas: bool):
    """One pre-LN transformer block, dense attention. x [T, D]."""
    h = add_ln(x, jnp.zeros_like(x), lp["ln1_g"], lp["ln1_b"], use_pallas)
    attn = ref.dense_attention(h, lp["wq"], lp["wk"], lp["wv"], lp["wo"], cfg.n_heads)
    x = x + attn
    h2 = add_ln(x, jnp.zeros_like(x), lp["ln2_g"], lp["ln2_b"], use_pallas)
    return x + _mlp(h2, lp)


def _block_fac(cfg: ModelConfig, x: jnp.ndarray, lp: Params, use_pallas: bool, blocked: bool):
    """One pre-LN transformer block, CLOVER-factorized attention."""
    scale = 1.0 / float(cfg.d_head) ** 0.5
    h = add_ln(x, jnp.zeros_like(x), lp["ln1_g"], lp["ln1_b"], use_pallas)
    if use_pallas:
        ctx = kernels.fused_attention_ctx(
            h, lp["u_qk"], lp["s_qk"], lp["v_qk"], lp["u_vo"], lp["s_vo"],
            scale, causal=True, blocked=blocked,
        )
    else:
        ctx = ref.factorized_attention_ctx(
            h, lp["u_qk"], lp["s_qk"], lp["v_qk"], lp["u_vo"], lp["s_vo"], scale, True
        )
    attn = jnp.einsum("htr,hdr->td", ctx, lp["v_vo"])
    x = x + attn
    h2 = add_ln(x, jnp.zeros_like(x), lp["ln2_g"], lp["ln2_b"], use_pallas)
    return x + _mlp(h2, lp)


def _run_blocks(cfg, params, x, layer_names, block_fn):
    """scan over stacked layer params: keeps HLO size O(1) in depth."""
    stacked = {n: params[n] for n in layer_names if n in params}

    def body(h, lp):
        return block_fn(h, lp), None

    out, _ = jax.lax.scan(body, x, stacked)
    return out


def forward_dense(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                  use_pallas: bool = False) -> jnp.ndarray:
    """tokens [B, T] int32 -> logits [B, T, V] (weight-tied head)."""
    b, t = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]

    def per_example(xe):
        h = _run_blocks(cfg, params, xe, _LAYER_DENSE,
                        lambda hh, lp: _block_dense(cfg, hh, lp, use_pallas))
        return add_ln(h, jnp.zeros_like(h), params["lnf_g"], params["lnf_b"], use_pallas)

    x = jax.vmap(per_example)(x)
    return x @ params["tok_emb"].T


def forward_fac(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                use_pallas: bool = True, blocked: bool = False) -> jnp.ndarray:
    """Factorized-attention forward. tokens [B, T] -> logits [B, T, V]."""
    b, t = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]
    layer_names = _LAYER_FAC_UD if "u_ud" in params else _LAYER_FAC

    def per_example(xe):
        h = _run_blocks(cfg, params, xe, layer_names,
                        lambda hh, lp: _block_fac(cfg, hh, lp, use_pallas, blocked))
        return add_ln(h, jnp.zeros_like(h), params["lnf_g"], params["lnf_b"], use_pallas)

    x = jax.vmap(per_example)(x)
    return x @ params["tok_emb"].T


def nll(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy. logits [B,T,V], targets [B,T] int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# --------------------------------------------------------------------------
# AdamW + train-step factories
# --------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
CLIP_NORM = 1.0


def adamw_update(p, g, m, v, step, lr, wd: float = 0.0):
    """One AdamW step for a single tensor (step is the *new* 1-based count)."""
    m2 = ADAM_B1 * m + (1 - ADAM_B1) * g
    v2 = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m2 / (1 - ADAM_B1 ** step)
    vhat = v2 / (1 - ADAM_B2 ** step)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * p)
    return p2, m2, v2


def global_norm_clip(grads: Params) -> Params:
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    factor = jnp.minimum(1.0, CLIP_NORM / (gn + 1e-12))
    return {k: g * factor for k, g in grads.items()}


def make_train_step(loss_fn, spec: Spec, trainable: List[str], wd: float = 0.0):
    """Build ``step(params…, m…, v…, step_count, inputs, targets, lr)`` where
    only ``trainable`` tensors get gradients/updates.  Flat signature:

      inputs : spec tensors, then m and v for each trainable (spec order),
               then step_count [], inputs [B,T], targets [B,T], lr []
      outputs: updated trainable tensors (spec order), updated m, v,
               step_count+1, loss
    """
    names = spec_names(spec)
    train_names = [n for n in names if n in trainable]
    assert train_names, "no trainable tensors"

    def step_fn(*flat):
        n = len(names)
        k = len(train_names)
        params = params_from_flat(spec, flat[:n])
        ms = dict(zip(train_names, flat[n : n + k]))
        vs = dict(zip(train_names, flat[n + k : n + 2 * k]))
        step_count, inputs, targets, lr = flat[n + 2 * k : n + 2 * k + 4]

        def loss_of(tr):
            full = dict(params)
            full.update(tr)
            return loss_fn(full, inputs, targets)

        tr = {nm: params[nm] for nm in train_names}
        loss, grads = jax.value_and_grad(loss_of)(tr)
        grads = global_norm_clip(grads)
        new_step = step_count + 1
        outs, out_m, out_v = [], [], []
        for nm in train_names:
            p2, m2, v2 = adamw_update(
                params[nm], grads[nm], ms[nm], vs[nm], new_step.astype(jnp.float32), lr, wd
            )
            outs.append(p2)
            out_m.append(m2)
            out_v.append(v2)
        return tuple(outs + out_m + out_v + [new_step, loss])

    return step_fn, train_names


# --------------------------------------------------------------------------
# PEFT forwards (adapters over a frozen dense base)
# --------------------------------------------------------------------------


def _lora_eff(params: Params, ad: Params) -> Params:
    """Effective weights W + A@B for the LoRA target set (scaling baked to 1;
    PiSSA requires exactly this form, plain LoRA folds alpha into lr/init)."""
    eff = dict(params)
    for tgt, (a, b) in {
        "wq": ("a_q", "b_q"), "wk": ("a_k", "b_k"), "wv": ("a_v", "b_v"),
        "w_up": ("a_up", "b_up"), "w_down": ("a_down", "b_down"),
    }.items():
        eff[tgt] = params[tgt] + jnp.einsum("ldr,lrk->ldk", ad[a], ad[b])
    return eff


def _dora_eff(params: Params, ad: Params) -> Params:
    """DoRA: W' = m * (W + AB) / ||W + AB||_col (column = output unit)."""
    eff = _lora_eff(params, ad)
    for tgt, mag in [("wq", "m_q"), ("wk", "m_k"), ("wv", "m_v"),
                     ("w_up", "m_up"), ("w_down", "m_down")]:
        w = eff[tgt]
        norm = jnp.sqrt(jnp.sum(w * w, axis=1, keepdims=True) + 1e-8)  # [L,1,K]
        eff[tgt] = ad[mag][:, None, :] * w / norm
    return eff


def _hira_eff(params: Params, ad: Params) -> Params:
    """HiRA: ΔW = W0 ⊙ (A@B), i.e. W' = W0 ⊙ (1 + AB) — high-rank update."""
    eff = dict(params)
    for tgt, (a, b) in {
        "wq": ("a_q", "b_q"), "wk": ("a_k", "b_k"), "wv": ("a_v", "b_v"),
        "w_up": ("a_up", "b_up"), "w_down": ("a_down", "b_down"),
    }.items():
        eff[tgt] = params[tgt] * (1.0 + jnp.einsum("ldr,lrk->ldk", ad[a], ad[b]))
    return eff


PEFT_EFF = {"lora": _lora_eff, "dora": _dora_eff, "hira": _hira_eff}


def make_peft_train_step(cfg: ModelConfig, kind: str, base_spec: Spec, ad_spec: Spec):
    """Adapter train step: base params are *frozen inputs*; only adapter
    tensors carry optimizer state.  Flat signature:

      inputs : base spec, adapter spec, m(adapter), v(adapter),
               step_count, inputs, targets, lr
      outputs: adapter', m', v', step_count+1, loss
    """
    eff_fn = PEFT_EFF[kind]
    ad_names = spec_names(ad_spec)

    def step_fn(*flat):
        nb, na = len(base_spec), len(ad_spec)
        params = params_from_flat(base_spec, flat[:nb])
        ad = params_from_flat(ad_spec, flat[nb : nb + na])
        ms = dict(zip(ad_names, flat[nb + na : nb + 2 * na]))
        vs = dict(zip(ad_names, flat[nb + 2 * na : nb + 3 * na]))
        step_count, inputs, targets, lr = flat[nb + 3 * na : nb + 3 * na + 4]

        def loss_of(ad_t):
            eff = eff_fn(params, ad_t)
            return nll(forward_dense(cfg, eff, inputs), targets)

        loss, grads = jax.value_and_grad(loss_of)(ad)
        grads = global_norm_clip(grads)
        new_step = step_count + 1
        outs, out_m, out_v = [], [], []
        for nm in ad_names:
            p2, m2, v2 = adamw_update(
                ad[nm], grads[nm], ms[nm], vs[nm], new_step.astype(jnp.float32), lr
            )
            outs.append(p2)
            out_m.append(m2)
            out_v.append(v2)
        return tuple(outs + out_m + out_v + [new_step, loss])

    return step_fn


def peft_forward(cfg: ModelConfig, kind: str, params: Params, ad: Params, tokens):
    """Inference with an (unmerged) adapter — used for eval goldens."""
    return forward_dense(cfg, PEFT_EFF[kind](params, ad), tokens)


# --------------------------------------------------------------------------
# Incremental decode (KV cache) — the serving hot path
# --------------------------------------------------------------------------


def decode_step_dense(cfg: ModelConfig, params: Params, k_cache, v_cache, tokens, positions):
    """One autoregressive step, dense attention.

    k_cache/v_cache [L, B, H, C, dh]; tokens [B] int32; positions [B]
    int32 — *per-lane* cursors, so a continuous-batching scheduler can run
    lanes at different depths in one fused step (a freed lane restarts at
    position 0 while its neighbors keep decoding).
    Returns (logits [B, V], k_cache', v_cache').  The KV cache grows with
    full head dimension dh — the memory-bound baseline the paper targets.
    """
    b = tokens.shape[0]
    h_, dh = cfg.n_heads, cfg.d_head
    c = k_cache.shape[3]
    scale = 1.0 / float(dh) ** 0.5
    x = params["tok_emb"][tokens] + params["pos_emb"][positions]  # [B, D]

    stacked = {n: params[n] for n in _LAYER_DENSE}
    # Per-lane scatter/mask: lane i writes its own positions[i] and attends
    # to its own prefix only.  The write is an indexed scatter (not a
    # select over the full cache) so the per-step update stays O(B·H·dh).
    lanes = jnp.arange(b)
    mask = jnp.arange(c)[None, None, :] <= positions[:, None, None]  # [B, 1, C]

    def body(x, inputs):
        lp, kc, vc = inputs  # kc/vc [B, H, C, dh]
        hcur = ref.layernorm(x, lp["ln1_g"], lp["ln1_b"])
        q = (hcur @ lp["wq"]).reshape(b, h_, dh)
        k = (hcur @ lp["wk"]).reshape(b, h_, dh)
        v = (hcur @ lp["wv"]).reshape(b, h_, dh)
        kc = kc.at[lanes, :, positions, :].set(k)
        vc = vc.at[lanes, :, positions, :].set(v)
        scores = jnp.einsum("bhd,bhcd->bhc", q, kc) * scale
        scores = jnp.where(mask, scores, ref.NEG_INF)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhc,bhcd->bhd", attn, vc).reshape(b, h_ * dh)
        x = x + ctx @ lp["wo"]
        h2 = ref.layernorm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + _mlp(h2, lp)
        return x, (kc, vc)

    x, (kc2, vc2) = jax.lax.scan(body, x, (stacked, k_cache, v_cache))
    x = ref.layernorm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["tok_emb"].T, kc2, vc2


def _slab_write(cache_b, pos_b, val_b):
    """Scatter one lane's K-token slab into its cache.

    cache_b [H, C, r]; pos_b [K]; val_b [K, H, r].  Duplicate positions
    within a slab (the pad-by-repeat convention: a slab shorter than the
    program width repeats its last valid ``(token, position)`` pair) write
    identical values, so the scatter is idempotent regardless of order.
    """
    return cache_b.at[:, pos_b, :].set(jnp.swapaxes(val_b, 0, 1))


def prefill_step_dense(cfg: ModelConfig, params: Params, k_cache, v_cache, tokens, positions):
    """One chunked-prefill step, dense attention.

    tokens/positions [B, K] int32 — each lane consumes a K-token slab in a
    single fused step, writing K cache positions, instead of burning K
    single-token decode steps.  Causality within the slab comes from the
    same per-position mask the decode step uses (slab index j attends to
    cache positions <= positions[b, j], and all K writes land before
    attention in each layer), so chunked prefill is bit-for-bit the same
    computation as K sequential `decode_step_dense` calls.
    Returns (logits [B, K, V] at *every* slab index, k_cache', v_cache').
    Per-position logits are what make the slab programs double as
    speculative-decode *verifiers*: logits[:, j] equals the logits a
    sequential decode would have produced right after consuming slab
    index j, so a draft of K tokens is scored in one fused step.
    """
    b, k = tokens.shape
    h_, dh = cfg.n_heads, cfg.d_head
    c = k_cache.shape[3]
    scale = 1.0 / float(dh) ** 0.5
    x = params["tok_emb"][tokens] + params["pos_emb"][positions]  # [B, K, D]
    stacked = {n: params[n] for n in _LAYER_DENSE}
    mask = jnp.arange(c)[None, None, :] <= positions[:, :, None]  # [B, K, C]

    def body(x, inputs):
        lp, kc, vc = inputs  # kc/vc [B, H, C, dh]
        hcur = ref.layernorm(x, lp["ln1_g"], lp["ln1_b"])  # [B, K, D]
        q = (hcur @ lp["wq"]).reshape(b, k, h_, dh)
        kk = (hcur @ lp["wk"]).reshape(b, k, h_, dh)
        vv = (hcur @ lp["wv"]).reshape(b, k, h_, dh)
        kc = jax.vmap(_slab_write)(kc, positions, kk)
        vc = jax.vmap(_slab_write)(vc, positions, vv)
        scores = jnp.einsum("bjhd,bhcd->bjhc", q, kc) * scale
        scores = jnp.where(mask[:, :, None, :], scores, ref.NEG_INF)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bjhc,bhcd->bjhd", attn, vc).reshape(b, k, h_ * dh)
        x = x + ctx @ lp["wo"]
        h2 = ref.layernorm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + _mlp(h2.reshape(b * k, -1), lp).reshape(b, k, -1)
        return x, (kc, vc)

    x, (kc2, vc2) = jax.lax.scan(body, x, (stacked, k_cache, v_cache))
    out = ref.layernorm(x, params["lnf_g"], params["lnf_b"])
    return out @ params["tok_emb"].T, kc2, vc2


def prefill_step_fac(cfg: ModelConfig, r: int, params: Params, k_cache, vo_cache, tokens, positions):
    """One chunked-prefill step, CLOVER-factorized attention.

    The [B, K] slab analogue of `decode_step_fac`: K rank-r factor
    projections are scattered per lane per step, so the KV saving of
    pruning (r/dh) compounds with the K× cut in prefill steps.  See
    `prefill_step_dense` for the slab conventions (including the
    all-position [B, K, V] logits that back speculative verification).
    """
    b, k = tokens.shape
    c = k_cache.shape[3]
    scale = 1.0 / float(cfg.d_head) ** 0.5
    x = params["tok_emb"][tokens] + params["pos_emb"][positions]  # [B, K, D]
    layer_names = _LAYER_FAC_UD if "u_ud" in params else _LAYER_FAC
    stacked = {n: params[n] for n in layer_names}
    mask = jnp.arange(c)[None, None, :] <= positions[:, :, None]  # [B, K, C]

    def body(x, inputs):
        lp, kc, voc = inputs  # kc/voc [B, H, C, r]
        hcur = ref.layernorm(x, lp["ln1_g"], lp["ln1_b"])  # [B, K, D]
        q = jnp.einsum("bjd,hdr->bjhr", hcur, lp["u_qk"])
        q = jnp.einsum("bjhr,hrk->bjhk", q, lp["s_qk"])
        kk = jnp.einsum("bjd,hdr->bjhr", hcur, lp["v_qk"])
        vo = jnp.einsum("bjd,hdr->bjhr", hcur, lp["u_vo"])
        vo = jnp.einsum("bjhr,hrk->bjhk", vo, lp["s_vo"])
        kc = jax.vmap(_slab_write)(kc, positions, kk)
        voc = jax.vmap(_slab_write)(voc, positions, vo)
        scores = jnp.einsum("bjhr,bhcr->bjhc", q, kc) * scale
        scores = jnp.where(mask[:, :, None, :], scores, ref.NEG_INF)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bjhc,bhcr->bjhr", attn, voc)
        out = jnp.einsum("bjhr,hdr->bjd", ctx, lp["v_vo"])
        x = x + out
        h2 = ref.layernorm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + _mlp(h2.reshape(b * k, -1), lp).reshape(b, k, -1)
        return x, (kc, voc)

    x, (kc2, voc2) = jax.lax.scan(body, x, (stacked, k_cache, vo_cache))
    out = ref.layernorm(x, params["lnf_g"], params["lnf_b"])
    return out @ params["tok_emb"].T, kc2, voc2


def decode_step_fac(cfg: ModelConfig, r: int, params: Params, k_cache, vo_cache, tokens, positions):
    """One autoregressive step, CLOVER-factorized attention.

    k_cache/vo_cache [L, B, H, C, r] — the caches hold the *rank-r factor
    space* projections (X V_qk and X U_vo S_vo), so pruning to rank r < dh
    shrinks KV memory by exactly r/dh: the paper's KV-cache motivation
    realized end-to-end.  `positions` is [B] int32, per-lane (see
    decode_step_dense).
    """
    b = tokens.shape[0]
    h_ = cfg.n_heads
    c = k_cache.shape[3]
    scale = 1.0 / float(cfg.d_head) ** 0.5
    x = params["tok_emb"][tokens] + params["pos_emb"][positions]
    layer_names = _LAYER_FAC_UD if "u_ud" in params else _LAYER_FAC
    stacked = {n: params[n] for n in layer_names}
    lanes = jnp.arange(b)
    mask = jnp.arange(c)[None, None, :] <= positions[:, None, None]  # [B, 1, C]

    def body(x, inputs):
        lp, kc, voc = inputs  # [B, H, C, r]
        hcur = ref.layernorm(x, lp["ln1_g"], lp["ln1_b"])
        q = jnp.einsum("bd,hdr->bhr", hcur, lp["u_qk"])
        q = jnp.einsum("bhr,hrk->bhk", q, lp["s_qk"])
        k = jnp.einsum("bd,hdr->bhr", hcur, lp["v_qk"])
        vo = jnp.einsum("bd,hdr->bhr", hcur, lp["u_vo"])
        vo = jnp.einsum("bhr,hrk->bhk", vo, lp["s_vo"])
        kc = kc.at[lanes, :, positions, :].set(k)
        voc = voc.at[lanes, :, positions, :].set(vo)
        scores = jnp.einsum("bhr,bhcr->bhc", q, kc) * scale
        scores = jnp.where(mask, scores, ref.NEG_INF)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhc,bhcr->bhr", attn, voc)
        out = jnp.einsum("bhr,hdr->bd", ctx, lp["v_vo"])
        x = x + out
        h2 = ref.layernorm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + _mlp(h2, lp)
        return x, (kc, voc)

    x, (kc2, voc2) = jax.lax.scan(body, x, (stacked, k_cache, vo_cache))
    x = ref.layernorm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["tok_emb"].T, kc2, voc2
