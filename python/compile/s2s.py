"""Whisper-like encoder-decoder for the §4.4 training-free pruning study.

A continuous feature sequence (the stand-in for log-mel audio frames) is
encoded by a non-causal transformer; an autoregressive decoder with cross
attention emits the token transcript.  The encoder's self-attention — where
Figure 2c/7 shows Whisper's strong linear redundancy — is the part CLOVER
factorizes; per-rank artifacts are exported for the pruning sweep.

Same conventions as ``model.py``: pure functions over explicit param dicts,
flat ordering from ``*_param_spec``, scan over stacked layers.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .configs import Seq2SeqConfig
from .kernels import ref
from .model import add_ln, nll

Params = Dict[str, jnp.ndarray]
Spec = List[Tuple[str, Tuple[int, ...]]]


def s2s_param_spec(cfg: Seq2SeqConfig) -> Spec:
    le, ld, d, f = cfg.n_enc_layers, cfg.n_dec_layers, cfg.d_model, cfg.d_ff
    return [
        ("in_proj", (cfg.feat_dim, d)),
        ("enc_pos", (cfg.src_len, d)),
        ("e_ln1_g", (le, d)),
        ("e_ln1_b", (le, d)),
        ("e_wq", (le, d, d)),
        ("e_wk", (le, d, d)),
        ("e_wv", (le, d, d)),
        ("e_wo", (le, d, d)),
        ("e_ln2_g", (le, d)),
        ("e_ln2_b", (le, d)),
        ("e_up", (le, d, f)),
        ("e_down", (le, f, d)),
        ("e_lnf_g", (d,)),
        ("e_lnf_b", (d,)),
        ("tok_emb", (cfg.vocab, d)),
        ("dec_pos", (cfg.tgt_len, d)),
        ("d_ln1_g", (ld, d)),
        ("d_ln1_b", (ld, d)),
        ("d_wq", (ld, d, d)),
        ("d_wk", (ld, d, d)),
        ("d_wv", (ld, d, d)),
        ("d_wo", (ld, d, d)),
        ("d_lnx_g", (ld, d)),
        ("d_lnx_b", (ld, d)),
        ("d_cq", (ld, d, d)),
        ("d_ck", (ld, d, d)),
        ("d_cv", (ld, d, d)),
        ("d_co", (ld, d, d)),
        ("d_ln2_g", (ld, d)),
        ("d_ln2_b", (ld, d)),
        ("d_up", (ld, d, f)),
        ("d_down", (ld, f, d)),
        ("d_lnf_g", (d,)),
        ("d_lnf_b", (d,)),
    ]


def s2s_fac_param_spec(cfg: Seq2SeqConfig, r: int) -> Spec:
    """Encoder self-attention replaced by CLOVER factors at rank r."""
    h = cfg.n_heads
    le = cfg.n_enc_layers
    d = cfg.d_model
    spec = []
    for name, shape in s2s_param_spec(cfg):
        if name in ("e_wq", "e_wk", "e_wv", "e_wo"):
            continue
        spec.append((name, shape))
        if name == "e_ln1_b":
            spec += [
                ("e_u_qk", (le, h, d, r)),
                ("e_s_qk", (le, h, r, r)),
                ("e_v_qk", (le, h, d, r)),
                ("e_u_vo", (le, h, d, r)),
                ("e_s_vo", (le, h, r, r)),
                ("e_v_vo", (le, h, d, r)),
            ]
    return spec


def init_s2s(cfg: Seq2SeqConfig, seed: jnp.ndarray) -> Params:
    key = jax.random.PRNGKey(seed)
    spec = s2s_param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    out: Params = {}
    n_layers = cfg.n_enc_layers + cfg.n_dec_layers
    resid = 0.02 / jnp.sqrt(2.0 * n_layers)
    for (name, shape), k in zip(spec, keys):
        if "_ln" in name or name.startswith(("e_ln", "d_ln")):
            out[name] = jnp.ones(shape, jnp.float32) if name.endswith("_g") else jnp.zeros(shape, jnp.float32)
        elif name in ("e_wo", "e_down", "d_wo", "d_co", "d_down"):
            out[name] = jax.random.normal(k, shape, jnp.float32) * resid
        else:
            out[name] = jax.random.normal(k, shape, jnp.float32) * 0.02
    return out


def _enc_block_dense(cfg, x, lp, use_pallas):
    h = add_ln(x, jnp.zeros_like(x), lp["e_ln1_g"], lp["e_ln1_b"], use_pallas)
    attn = ref.dense_attention(h, lp["e_wq"], lp["e_wk"], lp["e_wv"], lp["e_wo"],
                               cfg.n_heads, causal=False)
    x = x + attn
    h2 = add_ln(x, jnp.zeros_like(x), lp["e_ln2_g"], lp["e_ln2_b"], use_pallas)
    return x + ref.gelu(h2 @ lp["e_up"]) @ lp["e_down"]


def _enc_block_fac(cfg, x, lp, use_pallas):
    scale = 1.0 / float(cfg.d_head) ** 0.5
    h = add_ln(x, jnp.zeros_like(x), lp["e_ln1_g"], lp["e_ln1_b"], use_pallas)
    if use_pallas:
        ctx = kernels.fused_attention_ctx(
            h, lp["e_u_qk"], lp["e_s_qk"], lp["e_v_qk"], lp["e_u_vo"], lp["e_s_vo"],
            scale, causal=False,
        )
    else:
        ctx = ref.factorized_attention_ctx(
            h, lp["e_u_qk"], lp["e_s_qk"], lp["e_v_qk"], lp["e_u_vo"], lp["e_s_vo"],
            scale, False,
        )
    x = x + jnp.einsum("htr,hdr->td", ctx, lp["e_v_vo"])
    h2 = add_ln(x, jnp.zeros_like(x), lp["e_ln2_g"], lp["e_ln2_b"], use_pallas)
    return x + ref.gelu(h2 @ lp["e_up"]) @ lp["e_down"]


_ENC_DENSE = ["e_ln1_g", "e_ln1_b", "e_wq", "e_wk", "e_wv", "e_wo",
              "e_ln2_g", "e_ln2_b", "e_up", "e_down"]
_ENC_FAC = ["e_ln1_g", "e_ln1_b", "e_u_qk", "e_s_qk", "e_v_qk",
            "e_u_vo", "e_s_vo", "e_v_vo", "e_ln2_g", "e_ln2_b", "e_up", "e_down"]
_DEC = ["d_ln1_g", "d_ln1_b", "d_wq", "d_wk", "d_wv", "d_wo",
        "d_lnx_g", "d_lnx_b", "d_cq", "d_ck", "d_cv", "d_co",
        "d_ln2_g", "d_ln2_b", "d_up", "d_down"]


def encode(cfg: Seq2SeqConfig, params: Params, feats: jnp.ndarray,
           factorized: bool, use_pallas: bool) -> jnp.ndarray:
    """feats [B, S, feat_dim] -> encoder states [B, S, D]."""
    x = feats @ params["in_proj"] + params["enc_pos"][None]
    names = _ENC_FAC if factorized else _ENC_DENSE
    stacked = {n: params[n] for n in names}
    block = _enc_block_fac if factorized else _enc_block_dense

    def per_example(xe):
        def body(h, lp):
            return block(cfg, h, lp, use_pallas), None

        h, _ = jax.lax.scan(body, xe, stacked)
        return add_ln(h, jnp.zeros_like(h), params["e_lnf_g"], params["e_lnf_b"], use_pallas)

    return jax.vmap(per_example)(x)


def decode(cfg: Seq2SeqConfig, params: Params, enc: jnp.ndarray,
           tokens: jnp.ndarray, use_pallas: bool) -> jnp.ndarray:
    """Teacher-forced decoder. enc [B,S,D], tokens [B,Tt] -> logits [B,Tt,V]."""
    b, tt = tokens.shape
    x = params["tok_emb"][tokens] + params["dec_pos"][None, :tt, :]
    stacked = {n: params[n] for n in _DEC}

    def per_example(xe, ee):
        def body(h, lp):
            h1 = add_ln(h, jnp.zeros_like(h), lp["d_ln1_g"], lp["d_ln1_b"], use_pallas)
            h = h + ref.dense_attention(h1, lp["d_wq"], lp["d_wk"], lp["d_wv"], lp["d_wo"],
                                        cfg.n_heads, causal=True)
            hx = add_ln(h, jnp.zeros_like(h), lp["d_lnx_g"], lp["d_lnx_b"], use_pallas)
            h = h + ref.cross_attention_dense(hx, ee, lp["d_cq"], lp["d_ck"], lp["d_cv"],
                                              lp["d_co"], cfg.n_heads)
            h2 = add_ln(h, jnp.zeros_like(h), lp["d_ln2_g"], lp["d_ln2_b"], use_pallas)
            return h + ref.gelu(h2 @ lp["d_up"]) @ lp["d_down"], None

        h, _ = jax.lax.scan(body, xe, stacked)
        return add_ln(h, jnp.zeros_like(h), params["d_lnf_g"], params["d_lnf_b"], use_pallas)

    x = jax.vmap(per_example)(x, enc)
    return x @ params["tok_emb"].T


def s2s_logits(cfg: Seq2SeqConfig, params: Params, feats, tokens,
               factorized: bool = False, use_pallas: bool = True) -> jnp.ndarray:
    return decode(cfg, params, encode(cfg, params, feats, factorized, use_pallas),
                  tokens, use_pallas)


def s2s_nll(cfg: Seq2SeqConfig, params: Params, feats, tokens_in, tokens_tgt,
            factorized: bool = False, use_pallas: bool = True) -> jnp.ndarray:
    return nll(s2s_logits(cfg, params, feats, tokens_in, factorized, use_pallas), tokens_tgt)
