"""L2 correctness: decoder model, CLOVER equivalences, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import numpy.linalg as la
import pytest

from compile import model as M
from compile.configs import TINY

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return M.init_dense(CFG, jnp.asarray(42, jnp.int32))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(4, CFG.seq_len)), jnp.int32)


def clover_factorize_np(params, r):
    """NumPy reference of the Rust CLOVER transform: head-wise SVD of
    W_QK = Wq Wk^T and W_VO = Wv Wo, truncated to rank r."""
    L, H, D = CFG.n_layers, CFG.n_heads, CFG.d_model
    dh = CFG.d_head
    fp = {k: v for k, v in params.items() if k not in ("wq", "wk", "wv", "wo")}
    uqk = np.zeros((L, H, D, r), np.float32)
    sqk = np.zeros((L, H, r, r), np.float32)
    vqk = np.zeros((L, H, D, r), np.float32)
    uvo = np.zeros((L, H, D, r), np.float32)
    svo = np.zeros((L, H, r, r), np.float32)
    vvo = np.zeros((L, H, D, r), np.float32)
    wq, wk, wv, wo = [np.asarray(params[k]) for k in ("wq", "wk", "wv", "wo")]
    for l in range(L):
        for h in range(H):
            sl = slice(h * dh, (h + 1) * dh)
            U, S, Vt = la.svd(wq[l][:, sl] @ wk[l][:, sl].T)
            uqk[l, h], sqk[l, h], vqk[l, h] = U[:, :r], np.diag(S[:r]), Vt[:r].T
            U, S, Vt = la.svd(wv[l][:, sl] @ wo[l][sl, :])
            uvo[l, h], svo[l, h], vvo[l, h] = U[:, :r], np.diag(S[:r]), Vt[:r].T
    for k, v in dict(u_qk=uqk, s_qk=sqk, v_qk=vqk, u_vo=uvo, s_vo=svo, v_vo=vvo).items():
        fp[k] = jnp.asarray(v)
    return fp


def test_forward_shapes(params, tokens):
    logits = M.forward_dense(CFG, params, tokens)
    assert logits.shape == (4, CFG.seq_len, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality(params):
    """Changing a future token must not affect earlier logits."""
    rng = np.random.default_rng(1)
    t1 = jnp.asarray(rng.integers(0, CFG.vocab, size=(1, CFG.seq_len)), jnp.int32)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % CFG.vocab)
    l1 = M.forward_dense(CFG, params, t1)
    l2 = M.forward_dense(CFG, params, t2)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)


def test_clover_full_rank_exact(params, tokens):
    """Paper §3: at r = d the factorization is lossless — the factorized
    model reproduces the dense model to float32 precision."""
    fp = clover_factorize_np(params, CFG.d_head)
    dense = M.forward_dense(CFG, params, tokens)
    fac = M.forward_fac(CFG, fp, tokens, use_pallas=False)
    np.testing.assert_allclose(fac, dense, rtol=1e-4, atol=1e-4)
    fac_pl = M.forward_fac(CFG, fp, tokens, use_pallas=True)
    np.testing.assert_allclose(fac_pl, dense, rtol=1e-4, atol=1e-4)


def test_clover_pruning_graceful(params, tokens):
    """NLL degrades monotonically-ish and mildly as rank shrinks (the trained
    structure isn't there in a random init, but rank-d/2 of a random model
    should already be a decent approximation of W_QK by energy)."""
    dense_nll = float(M.nll(M.forward_dense(CFG, params, tokens), tokens))
    nlls = []
    for r in (CFG.d_head, CFG.d_head // 2):
        fp = clover_factorize_np(params, r)
        nlls.append(float(M.nll(M.forward_fac(CFG, fp, tokens, use_pallas=False), tokens)))
    assert abs(nlls[0] - dense_nll) < 1e-3
    assert nlls[1] < dense_nll + 2.0  # half-rank random init: mild damage


def test_decode_matches_forward(params):
    """Incremental decode with a KV cache == teacher-forced forward."""
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(1, 8)), jnp.int32)
    logits_full = M.forward_dense(CFG, params, toks)
    c = CFG.seq_len
    kc = jnp.zeros((CFG.n_layers, 1, CFG.n_heads, c, CFG.d_head), jnp.float32)
    vc = jnp.zeros_like(kc)
    outs = []
    for i in range(8):
        lg, kc, vc = M.decode_step_dense(CFG, params, kc, vc, toks[:, i],
                                         jnp.full((1,), i, jnp.int32))
        outs.append(lg)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(got, logits_full, rtol=1e-4, atol=1e-4)


def test_decode_per_lane_positions(params):
    """Lanes decode independently: running a sequence in lane 0 while lane 1
    restarts at position 0 mid-stream must reproduce the single-lane logits
    — the invariant the continuous-batching scheduler relies on."""
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 6)), jnp.int32)
    c = CFG.seq_len
    # Reference: each row decoded alone in a 1-lane cache.
    ref_logits = []
    for row in range(2):
        kc = jnp.zeros((CFG.n_layers, 1, CFG.n_heads, c, CFG.d_head), jnp.float32)
        vc = jnp.zeros_like(kc)
        outs = []
        for i in range(6):
            lg, kc, vc = M.decode_step_dense(CFG, params, kc, vc, toks[row:row + 1, i],
                                             jnp.full((1,), i, jnp.int32))
            outs.append(lg[0])
        ref_logits.append(outs)
    # Skewed schedule: lane 0 runs positions 0..5; lane 1 idles (re-feeding
    # position 0) for 2 steps, then runs 0..3 — as if a new request had been
    # admitted into a freed lane mid-flight.
    kc = jnp.zeros((CFG.n_layers, 2, CFG.n_heads, c, CFG.d_head), jnp.float32)
    vc = jnp.zeros_like(kc)
    for i in range(6):
        j = max(i - 2, 0)
        step_toks = jnp.stack([toks[0, i], toks[1, j]])
        step_pos = jnp.asarray([i, j], jnp.int32)
        lg, kc, vc = M.decode_step_dense(CFG, params, kc, vc, step_toks, step_pos)
        np.testing.assert_allclose(lg[0], ref_logits[0][i], rtol=1e-4, atol=1e-4)
        if i >= 2:
            np.testing.assert_allclose(lg[1], ref_logits[1][j], rtol=1e-4, atol=1e-4)


def test_decode_fac_matches_forward_fac(params):
    fp = clover_factorize_np(params, CFG.d_head)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 6)), jnp.int32)
    logits_full = M.forward_fac(CFG, fp, toks, use_pallas=False)
    r, c = CFG.d_head, CFG.seq_len
    kc = jnp.zeros((CFG.n_layers, 2, CFG.n_heads, c, r), jnp.float32)
    voc = jnp.zeros_like(kc)
    outs = []
    for i in range(6):
        lg, kc, voc = M.decode_step_fac(CFG, r, fp, kc, voc, toks[:, i],
                                        jnp.full((2,), i, jnp.int32))
        outs.append(lg)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(got, logits_full, rtol=1e-4, atol=1e-4)


def test_prefill_chunk_matches_sequential_decode(params):
    """Chunked prefill is the same computation as K sequential decode
    steps: identical logits at *every* slab position and identical caches.
    The per-position agreement is the speculative-verify contract — the
    dense engine scores a K-token draft by reading logits[:, j] exactly
    where a sequential decode would have sampled."""
    rng = np.random.default_rng(7)
    p, ck = 16, 8
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, p)), jnp.int32)
    c = CFG.seq_len
    kc = jnp.zeros((CFG.n_layers, 2, CFG.n_heads, c, CFG.d_head), jnp.float32)
    vc = jnp.zeros_like(kc)
    seq_logits = []
    for i in range(p):
        lg_seq, kc, vc = M.decode_step_dense(CFG, params, kc, vc, toks[:, i],
                                             jnp.full((2,), i, jnp.int32))
        seq_logits.append(lg_seq)
    kc2 = jnp.zeros_like(kc)
    vc2 = jnp.zeros_like(vc)
    for s in range(0, p, ck):
        pos = jnp.tile(jnp.arange(s, s + ck, dtype=jnp.int32)[None, :], (2, 1))
        lg_chunk, kc2, vc2 = M.prefill_step_dense(CFG, params, kc2, vc2,
                                                  toks[:, s:s + ck], pos)
        assert lg_chunk.shape == (2, ck, CFG.vocab)
        for j in range(ck):
            np.testing.assert_allclose(lg_chunk[:, j], seq_logits[s + j],
                                       rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(kc2, kc, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(vc2, vc, rtol=1e-4, atol=1e-4)


def test_prefill_fac_matches_sequential_decode(params):
    fp = clover_factorize_np(params, CFG.d_head)
    rng = np.random.default_rng(8)
    p, ck, r = 8, 8, CFG.d_head
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, p)), jnp.int32)
    c = CFG.seq_len
    kc = jnp.zeros((CFG.n_layers, 2, CFG.n_heads, c, r), jnp.float32)
    voc = jnp.zeros_like(kc)
    seq_logits = []
    for i in range(p):
        lg_seq, kc, voc = M.decode_step_fac(CFG, r, fp, kc, voc, toks[:, i],
                                            jnp.full((2,), i, jnp.int32))
        seq_logits.append(lg_seq)
    kc2 = jnp.zeros_like(kc)
    voc2 = jnp.zeros_like(voc)
    pos = jnp.tile(jnp.arange(p, dtype=jnp.int32)[None, :], (2, 1))
    lg_chunk, kc2, voc2 = M.prefill_step_fac(CFG, r, fp, kc2, voc2, toks, pos)
    assert lg_chunk.shape == (2, p, CFG.vocab)
    for j in range(p):
        np.testing.assert_allclose(lg_chunk[:, j], seq_logits[j],
                                   rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(kc2, kc, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(voc2, voc, rtol=1e-4, atol=1e-4)


def test_prefill_pad_by_repeat_is_idempotent(params):
    """A slab shorter than the program width pads by repeating its last
    (token, position) pair — the engine's convention for ragged chunks and
    for decode lanes sharing a prefill-width step.  The pads must change
    nothing: same logits, same cache, as the unpadded sequential path."""
    rng = np.random.default_rng(9)
    valid, ck = 3, 8
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(1, valid)), jnp.int32)
    c = CFG.seq_len
    kc = jnp.zeros((CFG.n_layers, 1, CFG.n_heads, c, CFG.d_head), jnp.float32)
    vc = jnp.zeros_like(kc)
    for i in range(valid):
        lg_seq, kc, vc = M.decode_step_dense(CFG, params, kc, vc, toks[:, i],
                                             jnp.full((1,), i, jnp.int32))
    pad_toks = jnp.concatenate(
        [toks, jnp.full((1, ck - valid), toks[0, -1], jnp.int32)], axis=1)
    pad_pos = jnp.concatenate(
        [jnp.arange(valid, dtype=jnp.int32),
         jnp.full((ck - valid,), valid - 1, jnp.int32)])[None, :]
    kc2 = jnp.zeros_like(kc)
    vc2 = jnp.zeros_like(vc)
    lg_pad, kc2, vc2 = M.prefill_step_dense(CFG, params, kc2, vc2, pad_toks, pad_pos)
    # The last valid index and every padded index carry the sequential
    # logits (a pad re-feeds the last pair, so its read state is identical).
    for j in range(valid - 1, ck):
        np.testing.assert_allclose(lg_pad[:, j], lg_seq, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(kc2, kc, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(vc2, vc, rtol=1e-4, atol=1e-4)


def test_train_step_reduces_loss(params):
    """A few full train steps on a fixed batch should overfit it."""
    spec = M.dense_param_spec(CFG)

    def loss_fn(p, i, t):
        return M.nll(M.forward_dense(CFG, p, i), t)

    step_fn, train_names = M.make_train_step(loss_fn, spec, [n for n, _ in spec])
    rng = np.random.default_rng(4)
    batch = jnp.asarray(rng.integers(0, CFG.vocab, size=(16, CFG.seq_len)), jnp.int32)
    flat = M.flat_from_params(spec, params)
    shapes = dict(spec)
    ms = [jnp.zeros(shapes[n], jnp.float32) for n in train_names]
    vs = [jnp.zeros(shapes[n], jnp.float32) for n in train_names]
    step = jnp.asarray(0, jnp.int32)
    lr = jnp.asarray(1e-3, jnp.float32)
    jit_step = jax.jit(step_fn)
    losses = []
    for _ in range(5):
        out = jit_step(*flat, *ms, *vs, step, batch, batch, lr)
        k = len(train_names)
        newp, ms, vs = out[:k], list(out[k:2 * k]), list(out[2 * k:3 * k])
        step, loss = out[-2], out[-1]
        p = M.params_from_flat(spec, flat)
        for n, t_ in zip(train_names, newp):
            p[n] = t_
        flat = M.flat_from_params(spec, p)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses
    assert int(step) == 5


def test_clover_s_train_step_only_updates_s(params):
    fp = clover_factorize_np(params, CFG.d_head)
    fac = M.fac_param_spec(CFG, CFG.d_head)

    def loss_fn(p, i, t):
        return M.nll(M.forward_fac(CFG, p, i, use_pallas=False), t)

    step_fn, train_names = M.make_train_step(loss_fn, fac, ["s_qk", "s_vo"])
    assert train_names == ["s_qk", "s_vo"]
    rng = np.random.default_rng(5)
    batch = jnp.asarray(rng.integers(0, CFG.vocab, size=(16, CFG.seq_len)), jnp.int32)
    flat = M.flat_from_params(fac, fp)
    shapes = dict(fac)
    ms = [jnp.zeros(shapes[n], jnp.float32) for n in train_names]
    vs = [jnp.zeros(shapes[n], jnp.float32) for n in train_names]
    out = jax.jit(step_fn)(*flat, *ms, *vs, jnp.asarray(0, jnp.int32), batch, batch,
                           jnp.asarray(1e-3, jnp.float32))
    s_qk2, s_vo2 = out[0], out[1]
    assert not np.allclose(s_qk2, fp["s_qk"])
    assert not np.allclose(s_vo2, fp["s_vo"])
    assert float(out[-1]) > 0


def test_adamw_matches_manual():
    p = jnp.asarray([1.0, -2.0])
    g = jnp.asarray([0.5, 0.5])
    m = jnp.zeros(2)
    v = jnp.zeros(2)
    p2, m2, v2 = M.adamw_update(p, g, m, v, jnp.asarray(1.0), 0.1)
    mh = 0.5  # m2/(1-b1) = 0.05/0.1... manual: m2 = 0.1*g = 0.05 ; mhat = 0.05/(1-0.9)=0.5
    vh = (1e-3 * 0.25) / (1 - 0.999)  # = 0.25
    expect = np.asarray(p) - 0.1 * mh / (np.sqrt(vh) + M.ADAM_EPS)
    np.testing.assert_allclose(p2, expect, rtol=1e-5)


def test_global_norm_clip():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}  # norm 5
    clipped = M.global_norm_clip(g)
    gn = float(jnp.sqrt(sum(jnp.sum(x * x) for x in clipped.values())))
    np.testing.assert_allclose(gn, M.CLIP_NORM, rtol=1e-5)
    small = {"a": jnp.asarray([0.1])}
    np.testing.assert_allclose(M.global_norm_clip(small)["a"], small["a"], rtol=1e-6)
