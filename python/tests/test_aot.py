"""AOT exporter: manifest integrity and HLO text round-trip sanity.

These tests exercise the exporter machinery on the tiny config without
re-exporting everything (the full export is `make artifacts`)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.configs import TINY

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_smoke():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_program_signature_consistency():
    """Every declared program's eval_shape output count matches its manifest
    `outputs` list — catches drift between fn and signature."""
    progs = aot.decoder_programs(TINY)
    names = {p.name for p in progs}
    assert {"init", "fwd", "nll", "train_full", "train_attn", "hidden",
            "train_lora", "train_dora", "train_hira", "train_cloverft"} <= names
    # Chunked-prefill slab programs, per exported width and serving batch.
    for ck in aot.prefill_chunks_for(TINY):
        for db in aot.PREFILL_BATCHES:
            assert f"prefill_k{ck}_b{db}" in names
            assert f"prefill_fac_r{TINY.d_head}_k{ck}_b{db}" in names
    # A prefill program's token slab is [B, K]; its cache block matches the
    # decode program's so the runtime can carry one cache set across widths.
    by_name = {p.name: p for p in progs}
    pf = by_name["prefill_k8_b8"]
    dec = by_name["decode_b8"]
    assert [i for i in pf.inputs if i[0] == "tokens"][0][1] == (8, 8)
    pf_caches = [(n, s) for n, s, _ in pf.inputs if "cache" in n]
    dec_caches = [(n, s) for n, s, _ in dec.inputs if "cache" in n]
    assert pf_caches == dec_caches
    # Verify-width contract: every prefill slab program emits logits at
    # *all* K slab positions ([B, K, V]) — the shape the serve engine needs
    # to score a speculative draft in one fused step.
    for ck in aot.prefill_chunks_for(TINY):
        for name in (f"prefill_k{ck}_b8", f"prefill_fac_r{TINY.d_head}_k{ck}_b8"):
            p = by_name[name]
            outs = jax.eval_shape(p.fn, *p.input_specs())
            assert outs[0].shape == (8, ck, TINY.vocab), (name, outs[0].shape)
    for p in progs:
        outs = jax.eval_shape(p.fn, *p.input_specs())
        if not isinstance(outs, tuple):
            outs = (outs,)
        assert len(outs) == len(p.outputs), p.name


def test_rank_grid_covers_table1_ratios():
    ranks = TINY.ranks()
    dh = TINY.d_head
    assert dh in ranks
    ratios = sorted(1 - r / dh for r in ranks)
    # Table 1 needs 12.5%..75% — grid must include 0, 1/2, 3/4 pruning
    for want in (0.0, 0.5, 0.75):
        assert any(abs(x - want) < 1e-6 for x in ratios), (want, ratios)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_files_exist():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert "tiny" in manifest["configs"]
    for cname, entry in manifest["configs"].items():
        for pname, prog in entry["programs"].items():
            path = os.path.join(ART, prog["file"])
            assert os.path.exists(path), path
            assert prog["inputs"] and prog["outputs"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "tiny", "golden_nll.npz")),
                    reason="artifacts not built")
def test_golden_nll_reproducible():
    """Re-running the jitted program on the stored golden inputs reproduces
    the stored outputs — the same check Rust integration tests perform."""
    data = np.load(os.path.join(ART, "tiny", "golden_nll.npz"))
    progs = {p.name: p for p in aot.decoder_programs(TINY)}
    p = progs["nll"]
    args = [data[f"arg{i}"] for i in range(len(p.inputs))]
    out = jax.jit(p.fn)(*args)
    np.testing.assert_allclose(np.asarray(out[0]), data["out0"], rtol=1e-5, atol=1e-6)
