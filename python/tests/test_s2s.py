"""Whisper-like seq2seq model: shapes, causality, factorization equivalence."""

import jax.numpy as jnp
import numpy as np
import numpy.linalg as la
import pytest

from compile import s2s as S
from compile.configs import S2S_TINY

CFG = S2S_TINY


@pytest.fixture(scope="module")
def params():
    return S.init_s2s(CFG, jnp.asarray(11, jnp.int32))


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.standard_normal((2, CFG.src_len, CFG.feat_dim)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, CFG.tgt_len)), jnp.int32)
    return feats, toks


def fac_encoder_np(params, r):
    """Factorize encoder self-attention cross-layer (matches Rust transform)."""
    L, H, D, dh = CFG.n_enc_layers, CFG.n_heads, CFG.d_model, CFG.d_head
    fp = {k: v for k, v in params.items() if k not in ("e_wq", "e_wk", "e_wv", "e_wo")}
    shapes = dict(u=np.zeros((L, H, D, r), np.float32), s=np.zeros((L, H, r, r), np.float32))
    uqk, sqk, vqk = shapes["u"].copy(), shapes["s"].copy(), shapes["u"].copy()
    uvo, svo, vvo = shapes["u"].copy(), shapes["s"].copy(), shapes["u"].copy()
    wq, wk, wv, wo = [np.asarray(params[k]) for k in ("e_wq", "e_wk", "e_wv", "e_wo")]
    for l in range(L):
        for h in range(H):
            sl = slice(h * dh, (h + 1) * dh)
            U, Sv, Vt = la.svd(wq[l][:, sl] @ wk[l][:, sl].T)
            uqk[l, h], sqk[l, h], vqk[l, h] = U[:, :r], np.diag(Sv[:r]), Vt[:r].T
            U, Sv, Vt = la.svd(wv[l][:, sl] @ wo[l][sl, :])
            uvo[l, h], svo[l, h], vvo[l, h] = U[:, :r], np.diag(Sv[:r]), Vt[:r].T
    for k, v in dict(e_u_qk=uqk, e_s_qk=sqk, e_v_qk=vqk,
                     e_u_vo=uvo, e_s_vo=svo, e_v_vo=vvo).items():
        fp[k] = jnp.asarray(v)
    return fp


def test_shapes(params, batch):
    feats, toks = batch
    logits = S.s2s_logits(CFG, params, feats, toks, use_pallas=False)
    assert logits.shape == (2, CFG.tgt_len, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_decoder_causality(params, batch):
    feats, toks = batch
    t2 = toks.at[0, -1].set((toks[0, -1] + 1) % CFG.vocab)
    l1 = S.s2s_logits(CFG, params, feats, toks, use_pallas=False)
    l2 = S.s2s_logits(CFG, params, feats, t2, use_pallas=False)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)


def test_encoder_not_causal(params, batch):
    """Changing the last input frame must change logits at position 0 —
    the encoder attends bidirectionally."""
    feats, toks = batch
    f2 = feats.at[0, -1, :].add(3.0)
    l1 = S.s2s_logits(CFG, params, feats, toks, use_pallas=False)
    l2 = S.s2s_logits(CFG, params, f2, toks, use_pallas=False)
    assert float(jnp.abs(l1[0, 0] - l2[0, 0]).max()) > 1e-6


def test_fac_full_rank_exact(params, batch):
    feats, toks = batch
    fp = fac_encoder_np(params, CFG.d_head)
    dense = S.s2s_logits(CFG, params, feats, toks, use_pallas=False)
    fac = S.s2s_logits(CFG, fp, feats, toks, factorized=True, use_pallas=False)
    np.testing.assert_allclose(fac, dense, rtol=1e-4, atol=1e-4)
    fac_pl = S.s2s_logits(CFG, fp, feats, toks, factorized=True, use_pallas=True)
    np.testing.assert_allclose(fac_pl, dense, rtol=1e-4, atol=1e-4)


def test_nll_finite(params, batch):
    feats, toks = batch
    loss = S.s2s_nll(CFG, params, feats, toks, toks, use_pallas=False)
    assert np.isfinite(float(loss))
    # random init ≈ near-uniform: nll within a couple nats of ln(vocab)
    assert abs(float(loss) - np.log(CFG.vocab)) < 2.5
