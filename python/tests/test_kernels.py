"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes / ranks / seeds; every comparison is
``assert_allclose`` — this is the core correctness signal for the kernels
that end up inside every factorized HLO artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.clover_matmul import clover_project, _pick_block
from compile.kernels.layernorm import add_layernorm

RTOL, ATOL = 1e-4, 1e-5


def rand(rng, *shape, scale=0.3):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# --------------------------------------------------------------------------
# clover_project
# --------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([8, 16, 64, 96]),
    d=st.sampled_from([16, 32, 64]),
    h=st.integers(1, 4),
    r=st.sampled_from([1, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_clover_project_matches_ref(t, d, h, r, seed):
    rng = np.random.default_rng(seed)
    x, u, s = rand(rng, t, d), rand(rng, h, d, r), rand(rng, h, r, r)
    got = clover_project(x, u, s)
    want = ref.clover_project(x, u, s)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_clover_project_explicit_block():
    rng = np.random.default_rng(0)
    x, u, s = rand(rng, 64, 32), rand(rng, 2, 32, 8), rand(rng, 2, 8, 8)
    for bt in (8, 16, 32, 64):
        got = clover_project(x, u, s, block_t=bt)
        np.testing.assert_allclose(got, ref.clover_project(x, u, s), rtol=RTOL, atol=ATOL)


def test_pick_block_divides():
    for t in (1, 7, 64, 96, 128, 250, 1024):
        b = _pick_block(t)
        assert t % b == 0 and 1 <= b <= min(t, 128)


# --------------------------------------------------------------------------
# fused attention ctx (whole-seq and blocked online-softmax)
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    t=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([32, 64]),
    h=st.integers(1, 4),
    r=st.sampled_from([2, 8, 16]),
    causal=st.booleans(),
    blocked=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_attention_ctx_matches_ref(t, d, h, r, causal, blocked, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, t, d, scale=1.0)
    uq, vq, uv = rand(rng, h, d, r), rand(rng, h, d, r), rand(rng, h, d, r)
    sq, sv = rand(rng, h, r, r), rand(rng, h, r, r)
    scale = 1.0 / np.sqrt(d / h)
    got = kernels.fused_attention_ctx(x, uq, sq, vq, uv, sv, scale, causal, blocked)
    want = ref.factorized_attention_ctx(x, uq, sq, vq, uv, sv, scale, causal)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_fused_attention_grad_matches_ref():
    """custom_vjp backward == oracle gradient for every operand."""
    rng = np.random.default_rng(3)
    t, d, h, r = 32, 32, 2, 8
    x = rand(rng, t, d, scale=1.0)
    args = [rand(rng, h, d, r), rand(rng, h, r, r), rand(rng, h, d, r),
            rand(rng, h, d, r), rand(rng, h, r, r)]
    scale = 1.0 / 4.0

    def f_kernel(*a):
        return kernels.fused_attention_ctx(x, *a, scale, True).sum()

    def f_ref(*a):
        return ref.factorized_attention_ctx(x, *a, scale, True).sum()

    g_k = jax.grad(f_kernel, argnums=tuple(range(5)))(*args)
    g_r = jax.grad(f_ref, argnums=tuple(range(5)))(*args)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)


def test_blocked_requires_matching_blocks_when_causal():
    from compile.kernels.clover_attention import attention_ctx_blocked
    rng = np.random.default_rng(0)
    x = rand(rng, 32, 16)
    u, s = rand(rng, 1, 16, 4), rand(rng, 1, 4, 4)
    with pytest.raises(ValueError):
        attention_ctx_blocked(x, u, s, u, u, s, scale=1.0, causal=True,
                              block_q=16, block_k=8)


def test_fully_masked_rows_stay_finite():
    """Row 0 under a causal mask attends only to itself; no NaNs anywhere."""
    rng = np.random.default_rng(1)
    x = rand(rng, 16, 16, scale=5.0)
    u, s = rand(rng, 2, 16, 4), rand(rng, 2, 4, 4)
    out = kernels.fused_attention_ctx(x, u, s, u, u, s, 0.5, True, blocked=True)
    assert np.isfinite(np.asarray(out)).all()


# --------------------------------------------------------------------------
# fused layernorm
# --------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([4, 16, 64, 96]),
    d=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_add_layernorm_matches_ref(t, d, seed):
    rng = np.random.default_rng(seed)
    x, res = rand(rng, t, d, scale=2.0), rand(rng, t, d, scale=2.0)
    g, b = rand(rng, d, scale=1.0) + 1.0, rand(rng, d, scale=0.5)
    got = add_layernorm(x, res, g, b)
    want = ref.layernorm(x + res, g, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_layernorm_output_stats():
    rng = np.random.default_rng(0)
    x = rand(rng, 32, 64, scale=3.0)
    out = add_layernorm(x, jnp.zeros_like(x), jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(np.mean(np.asarray(out), -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(np.asarray(out), -1), 1.0, atol=1e-3)
