"""PEFT adapter graphs: LoRA/DoRA/HiRA identities and training behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import TINY

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return M.init_dense(CFG, jnp.asarray(7, jnp.int32))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(2, CFG.seq_len)), jnp.int32)


def zero_adapter(spec, rng=None, a_random=False):
    out = {}
    for n, s in spec:
        if a_random and n.startswith("a_"):
            out[n] = jnp.asarray(rng.standard_normal(s) * 0.02, jnp.float32)
        else:
            out[n] = jnp.zeros(s, jnp.float32)
    return out


def test_lora_zero_b_is_identity(params, tokens):
    """B=0 ⇒ adapter model ≡ base model (standard LoRA init invariant)."""
    spec = M.lora_param_spec(CFG, CFG.lora_rank)
    rng = np.random.default_rng(1)
    ad = zero_adapter(spec, rng, a_random=True)
    base = M.forward_dense(CFG, params, tokens)
    got = M.peft_forward(CFG, "lora", params, ad, tokens)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_hira_zero_b_is_identity(params, tokens):
    spec = M.lora_param_spec(CFG, CFG.lora_rank)
    rng = np.random.default_rng(2)
    ad = zero_adapter(spec, rng, a_random=True)
    base = M.forward_dense(CFG, params, tokens)
    got = M.peft_forward(CFG, "hira", params, ad, tokens)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_dora_init_identity(params, tokens):
    """DoRA with B=0 and m = ||W||_col ⇒ identical to base."""
    spec = M.dora_param_spec(CFG, CFG.lora_rank)
    rng = np.random.default_rng(3)
    ad = zero_adapter(spec, rng, a_random=True)
    for tgt, mag in [("wq", "m_q"), ("wk", "m_k"), ("wv", "m_v"),
                     ("w_up", "m_up"), ("w_down", "m_down")]:
        w = np.asarray(params[tgt])
        ad[mag] = jnp.asarray(np.sqrt((w * w).sum(axis=1) + 1e-8), jnp.float32)
    base = M.forward_dense(CFG, params, tokens)
    got = M.peft_forward(CFG, "dora", params, ad, tokens)
    np.testing.assert_allclose(got, base, rtol=1e-3, atol=1e-3)


def test_lora_merge_equivalence(params, tokens):
    """Running the adapter graph == merging A@B into the dense weights."""
    spec = M.lora_param_spec(CFG, CFG.lora_rank)
    rng = np.random.default_rng(4)
    ad = {n: jnp.asarray(rng.standard_normal(s) * 0.05, jnp.float32) for n, s in spec}
    unmerged = M.peft_forward(CFG, "lora", params, ad, tokens)
    merged = dict(params)
    for tgt, (a, b) in {"wq": ("a_q", "b_q"), "wk": ("a_k", "b_k"), "wv": ("a_v", "b_v"),
                        "w_up": ("a_up", "b_up"), "w_down": ("a_down", "b_down")}.items():
        merged[tgt] = params[tgt] + jnp.einsum("ldr,lrk->ldk", ad[a], ad[b])
    got = M.forward_dense(CFG, merged, tokens)
    np.testing.assert_allclose(got, unmerged, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", ["lora", "dora", "hira"])
def test_peft_train_step_reduces_loss(params, kind):
    spec = M.dora_param_spec(CFG, CFG.lora_rank) if kind == "dora" \
        else M.lora_param_spec(CFG, CFG.lora_rank)
    step_fn = M.make_peft_train_step(CFG, kind, M.dense_param_spec(CFG), spec)
    rng = np.random.default_rng(5)
    ad = zero_adapter(spec, rng, a_random=True)
    if kind == "dora":
        for tgt, mag in [("wq", "m_q"), ("wk", "m_k"), ("wv", "m_v"),
                         ("w_up", "m_up"), ("w_down", "m_down")]:
            w = np.asarray(params[tgt])
            ad[mag] = jnp.asarray(np.sqrt((w * w).sum(axis=1) + 1e-8), jnp.float32)
    batch = jnp.asarray(rng.integers(0, CFG.vocab, size=(16, CFG.seq_len)), jnp.int32)
    base_flat = M.flat_from_params(M.dense_param_spec(CFG), params)
    names = [n for n, _ in spec]
    ad_flat = [ad[n] for n in names]
    shapes = dict(spec)
    ms = [jnp.zeros(shapes[n], jnp.float32) for n in names]
    vs = [jnp.zeros(shapes[n], jnp.float32) for n in names]
    step = jnp.asarray(0, jnp.int32)
    lr = jnp.asarray(5e-3, jnp.float32)
    jit_step = jax.jit(step_fn)
    losses = []
    for _ in range(4):
        out = jit_step(*base_flat, *ad_flat, *ms, *vs, step, batch, batch, lr)
        k = len(names)
        ad_flat, ms, vs = list(out[:k]), list(out[k:2 * k]), list(out[2 * k:3 * k])
        step, loss = out[-2], out[-1]
        losses.append(float(loss))
    assert losses[-1] < losses[0], (kind, losses)


def test_peft_param_counts_match_table3_arithmetic():
    """Appendix A.2: LoRA rank-32 on LLaMA-2-7B == CLOVER head-wise S counts
    (1,753,088 per layer).  We verify the arithmetic identity itself."""
    d, f, rank = 4096, 11008, 32
    lora = 3 * (d * rank + rank * d) + 2 * (d * rank + rank * f)
    h, dh, ud_block = 32, 128, 64
    nb = f // ud_block  # 172
    clover = h * dh * dh * 2 + nb * ud_block * ud_block
    assert lora == 1_753_088
    assert clover == 1_753_088
    assert lora == clover
