//! PEFT adapter initialization and trainable-parameter accounting.
//!
//! The *training graphs* (LoRA/DoRA/HiRA/PiSSA/CLOVER-FT) are HLO
//! artifacts; this module owns their host-side state: adapter
//! initialization (including PiSSA's principal-SVD init, which modifies
//! the base weights) and the Table-3 / Appendix-A.2 parameter accounting.

use anyhow::{bail, Result};

use crate::linalg::svd::svd;
use crate::linalg::{matmul, scale_cols};
use crate::model::manifest::ParamSpec;
use crate::model::params::ParamSet;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// LoRA target layers (matches `python/compile/model.py::lora_param_spec`).
pub const LORA_TARGETS: [(&str, &str, &str); 5] = [
    ("wq", "a_q", "b_q"),
    ("wk", "a_k", "b_k"),
    ("wv", "a_v", "b_v"),
    ("w_up", "a_up", "b_up"),
    ("w_down", "a_down", "b_down"),
];

/// Standard LoRA init: A ~ N(0, 0.02), B = 0 ⇒ identity at step 0.
pub fn lora_init(spec: &ParamSpec, rng: &mut Rng) -> ParamSet {
    let mut out = ParamSet::zeros(spec);
    for (name, shape) in spec {
        if name.starts_with("a_") {
            let numel = shape.iter().product();
            out.set(name, Tensor::new(shape.clone(), rng.normal_vec(numel, 0.02))).unwrap();
        }
    }
    out
}

/// HiRA uses the same A/B layout and init as LoRA (B = 0 ⇒ ΔW = 0).
pub fn hira_init(spec: &ParamSpec, rng: &mut Rng) -> ParamSet {
    lora_init(spec, rng)
}

/// DoRA init: LoRA A/B plus per-output-column magnitudes m = ‖W‖_col so
/// the decomposed model reproduces the base exactly.
pub fn dora_init(spec: &ParamSpec, base: &ParamSet, rng: &mut Rng) -> Result<ParamSet> {
    let mut out = lora_init(spec, rng);
    for (tgt, mag) in [("wq", "m_q"), ("wk", "m_k"), ("wv", "m_v"),
                       ("w_up", "m_up"), ("w_down", "m_down")] {
        let w = base.get(tgt)?; // [L, In, Out]
        let (l, din, dout) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let mut m = Tensor::zeros(&[l, dout]);
        for li in 0..l {
            for j in 0..dout {
                let mut acc = 0.0f32;
                for i in 0..din {
                    let v = w.data()[li * din * dout + i * dout + j];
                    acc += v * v;
                }
                m.data_mut()[li * dout + j] = (acc + 1e-8).sqrt();
            }
        }
        out.set(mag, m)?;
    }
    Ok(out)
}

/// PiSSA init: per layer and target, SVD the base weight, put the top-r
/// principal component into the adapter (A = U√Σ, B = √Σ Vᵀ) and *subtract*
/// it from the base (residual W_res = W − AB).  Returns (modified base,
/// adapter).  Running the plain-LoRA train graph on these is exactly PiSSA.
pub fn pissa_init(
    base: &ParamSet,
    lora_spec: &ParamSpec,
    rank: usize,
) -> Result<(ParamSet, ParamSet)> {
    let mut new_base = base.clone();
    let mut ad = ParamSet::zeros(lora_spec);
    for (tgt, a_name, b_name) in LORA_TARGETS {
        let w = base.get(tgt)?;
        let (l, din, dout) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        if rank > din.min(dout) {
            bail!("pissa rank {rank} > min dim of {tgt}");
        }
        let mut a_parts = Vec::new();
        let mut b_parts = Vec::new();
        let mut res_parts = Vec::new();
        for li in 0..l {
            let w_l = w.index0(li);
            let dec = svd(&w_l);
            let sqrt_s: Vec<f32> = dec.s[..rank].iter().map(|x| x.max(0.0).sqrt()).collect();
            let a = scale_cols(&dec.u.cols(0, rank), &sqrt_s); // [din, r]
            let bt = scale_cols(&dec.vt.transpose2().cols(0, rank), &sqrt_s); // [dout, r]
            let b = bt.transpose2(); // [r, dout]
            let principal = matmul(&a, &b);
            let res = w_l.sub(&principal);
            a_parts.push(a);
            b_parts.push(b);
            res_parts.push(res);
        }
        ad.set(a_name, Tensor::stack(&a_parts)?)?;
        ad.set(b_name, Tensor::stack(&b_parts)?)?;
        new_base.set(tgt, Tensor::stack(&res_parts)?)?;
    }
    Ok((new_base, ad))
}

/// Trainable-parameter accounting for each method on a decoder config.
#[derive(Clone, Debug, PartialEq)]
pub struct Accounting {
    pub method: String,
    pub trainable: usize,
    pub total: usize,
}

impl Accounting {
    pub fn pct(&self) -> f64 {
        100.0 * self.trainable as f64 / self.total as f64
    }
}

/// Count trainable params for a method given the relevant spec subsets.
pub fn account(method: &str, total_params: usize, spec: &ParamSpec,
               trainable_names: &[&str]) -> Accounting {
    let trainable = spec.iter()
        .filter(|(n, _)| trainable_names.iter().any(|t| n == t || n.starts_with(t)))
        .map(|(_, s)| s.iter().product::<usize>())
        .sum();
    Accounting { method: method.into(), trainable, total: total_params }
}

/// Appendix A.2 arithmetic for LLaMA-2-7B: LoRA rank-32 ≡ CLOVER head-wise
/// transition matrices at 1,753,088 trainable params per layer.
pub fn llama2_7b_table3() -> (usize, usize) {
    let (d, f, rank) = (4096usize, 11008usize, 32usize);
    let lora = 3 * (d * rank + rank * d) + (d * rank + rank * f) + (f * rank + rank * d);
    let (h, dh, blk) = (32usize, 128usize, 64usize);
    let clover = 2 * h * dh * dh + (f / blk) * blk * blk;
    (lora, clover)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::rel_err;

    fn base_fixture() -> (ParamSet, ParamSpec) {
        let spec: ParamSpec = vec![
            ("wq".into(), vec![2, 8, 8]),
            ("wk".into(), vec![2, 8, 8]),
            ("wv".into(), vec![2, 8, 8]),
            ("w_up".into(), vec![2, 8, 16]),
            ("w_down".into(), vec![2, 16, 8]),
        ];
        let mut rng = Rng::new(3);
        (ParamSet::gaussian(&spec, &mut rng, 0.5), spec)
    }

    fn lora_spec(rank: usize) -> ParamSpec {
        vec![
            ("a_q".into(), vec![2, 8, rank]), ("b_q".into(), vec![2, rank, 8]),
            ("a_k".into(), vec![2, 8, rank]), ("b_k".into(), vec![2, rank, 8]),
            ("a_v".into(), vec![2, 8, rank]), ("b_v".into(), vec![2, rank, 8]),
            ("a_up".into(), vec![2, 8, rank]), ("b_up".into(), vec![2, rank, 16]),
            ("a_down".into(), vec![2, 16, rank]), ("b_down".into(), vec![2, rank, 8]),
        ]
    }

    #[test]
    fn lora_init_b_zero() {
        let mut rng = Rng::new(0);
        let ad = lora_init(&lora_spec(4), &mut rng);
        assert_eq!(ad.get("b_q").unwrap().norm(), 0.0);
        assert!(ad.get("a_q").unwrap().norm() > 0.0);
    }

    #[test]
    fn pissa_reconstruction() {
        // W_res + A·B == W exactly (per layer, per target).
        let (base, _) = base_fixture();
        let (new_base, ad) = pissa_init(&base, &lora_spec(4), 4).unwrap();
        for (tgt, a_name, b_name) in LORA_TARGETS {
            for li in 0..2 {
                let w = base.get(tgt).unwrap().index0(li);
                let res = new_base.get(tgt).unwrap().index0(li);
                let a = ad.get(a_name).unwrap().index0(li);
                let b = ad.get(b_name).unwrap().index0(li);
                let mut back = matmul(&a, &b);
                back.add_assign(&res);
                assert!(rel_err(back.data(), w.data()) < 1e-3,
                        "{tgt} layer {li}: {}", rel_err(back.data(), w.data()));
            }
        }
    }

    #[test]
    fn pissa_principal_energy() {
        // The adapter holds the top singular directions: ‖AB‖ ≥ ‖W_res‖ for
        // a rank that covers most of the energy.
        let (base, _) = base_fixture();
        let (new_base, ad) = pissa_init(&base, &lora_spec(6), 6).unwrap();
        let w_res = new_base.get("wq").unwrap().index0(0);
        let a = ad.get("a_q").unwrap().index0(0);
        let b = ad.get("b_q").unwrap().index0(0);
        let principal = matmul(&a, &b);
        assert!(principal.norm() > w_res.norm());
    }

    #[test]
    fn dora_magnitudes_match_col_norms() {
        let (base, _) = base_fixture();
        let mut rng = Rng::new(1);
        let mut spec = lora_spec(4);
        spec.extend([
            ("m_q".into(), vec![2usize, 8usize]), ("m_k".into(), vec![2, 8]),
            ("m_v".into(), vec![2, 8]), ("m_up".into(), vec![2, 16]),
            ("m_down".into(), vec![2, 8]),
        ]);
        let ad = dora_init(&spec, &base, &mut rng).unwrap();
        let w = base.get("wq").unwrap();
        let m = ad.get("m_q").unwrap();
        // col 0 of layer 0
        let mut acc = 0.0f32;
        for i in 0..8 {
            let v = w.data()[i * 8];
            acc += v * v;
        }
        assert!((m.data()[0] - (acc + 1e-8).sqrt()).abs() < 1e-5);
    }

    #[test]
    fn table3_identity() {
        let (lora, clover) = llama2_7b_table3();
        assert_eq!(lora, 1_753_088);
        assert_eq!(clover, 1_753_088);
    }

    #[test]
    fn accounting_pct() {
        let spec: ParamSpec = vec![("a_q".into(), vec![10, 10])];
        let acc = account("lora", 10_000, &spec, &["a_"]);
        assert_eq!(acc.trainable, 100);
        assert!((acc.pct() - 1.0).abs() < 1e-9);
    }
}
