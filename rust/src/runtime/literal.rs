//! Conversions between host [`Value`] tensors and PJRT [`xla::Literal`]s.

use anyhow::{bail, Context, Result};

use crate::model::manifest::DType;
use crate::tensor::{Tensor, TensorI, Value};

/// Host tensor → literal (bulk byte copy, no per-element work).
pub fn to_literal(v: &Value) -> Result<xla::Literal> {
    match v {
        Value::F32(t) => {
            let bytes = unsafe {
                std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4)
            };
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                t.shape(),
                bytes,
            )
            .map_err(|e| anyhow::anyhow!("f32 literal {:?}: {e:?}", t.shape()))
        }
        Value::I32(t) => {
            let bytes = unsafe {
                std::slice::from_raw_parts(
                    t.data().as_ptr() as *const u8,
                    t.data().len() * 4,
                )
            };
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                t.shape(),
                bytes,
            )
            .map_err(|e| anyhow::anyhow!("i32 literal {:?}: {e:?}", t.shape()))
        }
    }
}

/// Literal → host tensor.
pub fn from_literal(lit: &xla::Literal) -> Result<Value> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("array_shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?;
            Ok(Value::F32(Tensor::new(dims, data)))
        }
        xla::ElementType::S32 => {
            let data = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?;
            Ok(Value::I32(TensorI::new(dims, data)))
        }
        other => bail!("unsupported literal element type {other:?}"),
    }
}

/// Shape/dtype check of a host value against a manifest arg spec.
pub fn check_arg(name: &str, v: &Value, shape: &[usize], dtype: DType) -> Result<()> {
    let got_dtype = match v {
        Value::F32(_) => DType::F32,
        Value::I32(_) => DType::I32,
    };
    if got_dtype != dtype {
        bail!("arg {name:?}: dtype {got_dtype:?} != spec {dtype:?}");
    }
    if v.shape() != shape {
        bail!("arg {name:?}: shape {:?} != spec {:?}", v.shape(), shape);
    }
    Ok(())
}

/// Load an `.npz` file as named host values (golden fixtures).
pub fn read_npz(path: &std::path::Path) -> Result<Vec<(String, Value)>> {
    use xla::FromRawBytes;
    let lits = xla::Literal::read_npz(path, &())
        .map_err(|e| anyhow::anyhow!("read_npz {path:?}: {e:?}"))?;
    lits.iter()
        .map(|(name, lit)| Ok((name.clone(), from_literal(lit)?)))
        .collect::<Result<Vec<_>>>()
        .with_context(|| format!("converting {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., -2., 3.5, 0., 5., 6.]);
        let lit = to_literal(&Value::F32(t.clone())).unwrap();
        match from_literal(&lit).unwrap() {
            Value::F32(back) => assert_eq!(back, t),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn i32_roundtrip() {
        let t = TensorI::new(vec![4], vec![1, -2, 3, 2_000_000_000]);
        let lit = to_literal(&Value::I32(t.clone())).unwrap();
        match from_literal(&lit).unwrap() {
            Value::I32(back) => assert_eq!(back, t),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = to_literal(&Value::F32(Tensor::scalar(3.25))).unwrap();
        match from_literal(&lit).unwrap() {
            Value::F32(t) => {
                assert_eq!(t.shape(), &[] as &[usize]);
                assert_eq!(t.item(), 3.25);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn check_arg_mismatches() {
        let v = Value::F32(Tensor::zeros(&[2, 2]));
        assert!(check_arg("x", &v, &[2, 2], DType::F32).is_ok());
        assert!(check_arg("x", &v, &[2, 3], DType::F32).is_err());
        assert!(check_arg("x", &v, &[2, 2], DType::I32).is_err());
    }
}
