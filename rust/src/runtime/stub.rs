//! Host-side stub decode backend: a deterministic toy "model" with real
//! KV-cache storage, so the serving stack's *scheduling* logic — slab
//! planning, mixed prefill/decode steps, lane zeroing, cancellation,
//! admission — runs and is testable without a live PJRT backend.
//!
//! The vendored `xla` crate is a build stub whose device entry points
//! error, which used to mean every engine/gateway test skipped on CI.
//! [`StubModel`] fills that gap: it implements the same step contract as
//! the compiled decode/prefill artifacts ([`crate::runtime::DecodeSession`]
//! `run_plan`), over a cache logically shaped `[L, B, H, C, r]`, with
//! properties the tests lean on:
//!
//! * **Slab invariance.**  A cache write depends only on
//!   `(layer, head, rank, position, token, seed)` and logits are a fixed-
//!   order reduction over the lane's cache prefix, so consuming a prompt
//!   as one K-wide slab or as K single-token steps produces *bit-identical*
//!   logits at every sampling point — the property the real chunk
//!   artifacts guarantee mathematically (see
//!   `python/tests/test_model.py::test_prefill_chunk_matches_sequential_decode`)
//!   and the engine's K=1-vs-K=8 bit-identity test checks end to end.
//! * **History sensitivity.**  Logits read the whole cache prefix of the
//!   lane, so stale rows from a previous occupant (a missed lane zeroing)
//!   or a cross-lane write change sampled tokens — scheduler bugs surface
//!   as token diffs, not silent passes.
//! * **Rank truncation.**  Cache writes and readout weights are pure
//!   functions of `(…, k, …)` that do not depend on the spec's rank, and
//!   each rank component's readout contribution decays geometrically
//!   ([`RANK_DECAY`]`^k`).  A rank-4 stub is therefore literally a
//!   truncation of the rank-8 stub with the same seed — a deterministic
//!   analogue of CLOVER's SVD spectrum — so a low-rank *draft* model
//!   agrees with the dense *target* on most (but not all) greedy tokens.
//!   That makes self-speculative decoding testable: acceptance rates are
//!   nontrivial, reproducible, and rank-parameterized.
//!
//! ## Paged, codec-compressed storage
//!
//! The cache is not a dense tensor: it lives in a
//! [`crate::serve::PagedKvStore`], page blocks of `PAGE_TOKENS` positions
//! allocated lazily and passed through a [`crate::serve::PageCodec`] on
//! every write/read.  Under the identity codec this is bit-identical to
//! the dense layout (property-tested against an in-test dense oracle);
//! under the factored codec the store really holds `budget[l]`-rank
//! vectors — and because the stub's readout weights are rank-independent
//! with a geometric spectrum, a factored stub at budget b is *bit-equal*
//! to a rank-b stub with the same seed.  Compression is therefore
//! exercised in storage and observable in logits, not just counted.
//!
//! Slab steps return logits at **every** slab position (`[B, W, V]` for
//! width W > 1), mirroring the compiled `prefill_k{K}` artifacts — which
//! is what lets one fused step *verify* a K-token speculative draft.
//!
//! `step_delay` adds an artificial per-step latency so timing-sensitive
//! tests (cancel/deadline firing *during* a multi-step prefill) have a
//! window to race against deterministically; `width_delay` adds a further
//! per-slab-token latency so step cost scales with slab width (what the
//! `--max-step-tokens` admission budget trades against).
//!
//! ## Fault injection
//!
//! [`FaultPlan`] turns the stub into a chaos backend: a seeded, purely
//! deterministic schedule of transient step errors, fatal backend death,
//! an injected worker panic, latency spikes, and poisoned (non-finite)
//! logits rows.  Every decision is a pure function of
//! `(plan.seed, step number)` — two stubs with the same spec fail at the
//! same steps, so every recovery test in CI replays bit-for-bit.  The
//! `CLOVER_FAULT_SEED` environment variable (read by
//! [`FaultPlan::env_seed`], never implicitly) lets the CI chaos lane run
//! the same suite under a matrix of seeds.

use anyhow::{bail, Result};
use std::fmt;
use std::time::Duration;

use crate::obs::Clock;
use crate::serve::kv::{KvCodecSpec, PagedKvStore, PAGE_TOKENS};
use crate::tensor::Tensor;

/// Salt mixed into every fault decision so fault rolls never collide with
/// the model-weight hash streams (which also consume `spec.seed`).
const FAULT_SALT: u64 = 0xFA17_BAD0;

/// Per-decision channels: each fault class rolls an independent uniform,
/// so e.g. raising the spike rate never shifts *which* steps take a
/// transient fault.
const CH_TRANSIENT: u64 = 1;
const CH_SPIKE: u64 = 2;
const CH_POISON: u64 = 3;
const CH_POISON_LANE: u64 = 4;

/// A deterministic, seeded fault-injection schedule for [`StubModel`].
///
/// Every decision is a pure function of `(seed, step number, channel)`:
/// the n-th call to [`StubModel::step`] either succeeds, fails
/// transiently, spikes its latency, or poisons one lane's logits — and
/// does so identically on every run and every host.  That is what makes
/// recovery properties testable: a retried step re-rolls a *new* step
/// number (the counter advances on every attempt), so a transient fault
/// followed by a retry succeeds or fails by the schedule, not by chance.
///
/// `fatal_after_steps` / `crash_after_steps` model backend death: the
/// first turns every later step into [`StepFault::Fatal`] (a dead device
/// that keeps answering with errors), the second panics the calling
/// thread (a worker crash the gateway supervisor must `catch_unwind`).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for the fault schedule — independent of the model seed, so
    /// the same workload can be replayed under many fault schedules.
    pub seed: u64,
    /// Probability in [0, 1] that a step returns [`StepFault::Transient`]
    /// before touching the cache.
    pub transient_rate: f64,
    /// Probability in [0, 1] that a step's artificial latency is
    /// multiplied by `spike_factor`.
    pub spike_rate: f64,
    /// Latency multiplier for spiked steps (≥ 1).
    pub spike_factor: u32,
    /// Probability in [0, 1] that one lane's logits rows come back
    /// non-finite (NaN) — the cache is still written, mirroring a real
    /// numerical blow-up after the KV append.
    pub poison_rate: f64,
    /// After this many successful-or-failed steps, the backend dies: the
    /// offending step and every later one return [`StepFault::Fatal`].
    pub fatal_after_steps: Option<u64>,
    /// After this many steps, the step call panics outright — the
    /// injected worker crash the gateway supervisor recovers from.
    pub crash_after_steps: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            seed: 0,
            transient_rate: 0.0,
            spike_rate: 0.0,
            spike_factor: 10,
            poison_rate: 0.0,
            fatal_after_steps: None,
            crash_after_steps: None,
        }
    }
}

/// A malformed `--fault-plan` spec — typed so `clover check` can surface
/// the exact locus instead of a stringly error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultPlanError {
    /// A `key=value` entry whose key is not in the schema.
    UnknownKey(String),
    /// A value that failed to parse for its key's type.
    BadValue { key: String, value: String },
    /// A rate outside [0, 1].
    RateOutOfRange { key: String, value: String },
    /// An entry missing its `=` separator.
    MissingValue(String),
}

impl fmt::Display for FaultPlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::UnknownKey(k) => write!(
                f,
                "unknown fault-plan key `{k}` (known: seed, transient, spike, \
                 spike-factor, poison, fatal-after, crash-after)"
            ),
            Self::BadValue { key, value } => {
                write!(f, "fault-plan key `{key}`: cannot parse `{value}`")
            }
            Self::RateOutOfRange { key, value } => {
                write!(f, "fault-plan rate `{key}={value}` outside [0, 1]")
            }
            Self::MissingValue(e) => write!(f, "fault-plan entry `{e}` is missing `=value`"),
        }
    }
}

impl std::error::Error for FaultPlanError {}

impl FaultPlan {
    /// True when the plan injects nothing — the engine skips all fault
    /// bookkeeping for no-op plans.
    pub fn is_noop(&self) -> bool {
        self.transient_rate == 0.0
            && self.spike_rate == 0.0
            && self.poison_rate == 0.0
            && self.fatal_after_steps.is_none()
            && self.crash_after_steps.is_none()
    }

    /// Parse a `key=value,...` spec, e.g.
    /// `seed=7,transient=0.01,spike=0.05,spike-factor=20,poison=0.001,fatal-after=500`.
    /// The empty string, `off`, and `none` all mean the no-op plan.
    pub fn parse(s: &str) -> std::result::Result<Self, FaultPlanError> {
        let s = s.trim();
        let mut plan = Self::default();
        if s.is_empty() || s == "off" || s == "none" {
            return Ok(plan);
        }
        for entry in s.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let Some((key, value)) = entry.split_once('=') else {
                return Err(FaultPlanError::MissingValue(entry.to_string()));
            };
            let (key, value) = (key.trim(), value.trim());
            let bad = || FaultPlanError::BadValue { key: key.into(), value: value.into() };
            let rate = || -> std::result::Result<f64, FaultPlanError> {
                let r: f64 = value.parse().map_err(|_| bad())?;
                if !(0.0..=1.0).contains(&r) || !r.is_finite() {
                    return Err(FaultPlanError::RateOutOfRange {
                        key: key.into(),
                        value: value.into(),
                    });
                }
                Ok(r)
            };
            match key {
                "seed" => plan.seed = value.parse().map_err(|_| bad())?,
                "transient" => plan.transient_rate = rate()?,
                "spike" => plan.spike_rate = rate()?,
                "spike-factor" => {
                    plan.spike_factor = value.parse().map_err(|_| bad())?;
                    if plan.spike_factor == 0 {
                        return Err(bad());
                    }
                }
                "poison" => plan.poison_rate = rate()?,
                "fatal-after" => {
                    plan.fatal_after_steps = Some(value.parse().map_err(|_| bad())?)
                }
                "crash-after" => {
                    plan.crash_after_steps = Some(value.parse().map_err(|_| bad())?)
                }
                _ => return Err(FaultPlanError::UnknownKey(key.to_string())),
            }
        }
        Ok(plan)
    }

    /// The CI chaos lane's seed override: `CLOVER_FAULT_SEED` if set and
    /// parseable.  Never read implicitly — callers opt in.
    pub fn env_seed() -> Option<u64> {
        std::env::var("CLOVER_FAULT_SEED").ok()?.trim().parse().ok()
    }

    /// Apply the `CLOVER_FAULT_SEED` override, if present.
    pub fn with_env_seed(mut self) -> Self {
        if let Some(seed) = Self::env_seed() {
            self.seed = seed;
        }
        self
    }

    /// Uniform in [0, 1) for `(channel, step)` — the schedule's only
    /// source of randomness.
    fn roll(&self, channel: u64, step: u64) -> f64 {
        f64::from(h01(mix(&[self.seed ^ FAULT_SALT, channel, step]))) + 0.5
    }

    /// Which lane a poison event at `step` hits, for `b` lanes.
    fn poison_lane(&self, step: u64, b: usize) -> usize {
        (mix(&[self.seed ^ FAULT_SALT, CH_POISON_LANE, step]) % b.max(1) as u64) as usize
    }
}

/// A fault injected by a [`FaultPlan`] — the typed payload the engine's
/// retry layer classifies by downcast.  Transient faults are worth
/// retrying (the next attempt rolls a fresh step number); fatal faults
/// mean the backend is gone and every in-flight request must fail or be
/// replayed elsewhere.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepFault {
    /// One step failed; the backend is still alive.
    Transient { step: u64 },
    /// The backend is dead; all subsequent steps fail too.
    Fatal { step: u64 },
}

impl fmt::Display for StepFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Transient { step } => write!(f, "injected transient fault at step {step}"),
            Self::Fatal { step } => write!(f, "injected fatal backend death at step {step}"),
        }
    }
}

impl std::error::Error for StepFault {}

/// Shape + behaviour of a stub engine — the stub analogue of picking a
/// `decode_b{B}` artifact family from the manifest.
#[derive(Clone, Debug)]
pub struct StubSpec {
    pub n_layers: usize,
    pub n_heads: usize,
    pub rank: usize,
    /// Context window C of the caches.
    pub max_positions: usize,
    /// Batch lanes B.
    pub batch_slots: usize,
    pub vocab: usize,
    /// Slab widths the stub dispatches (the chunk ladder).  Width 1 is
    /// always available even if not listed.
    pub chunk_widths: Vec<usize>,
    /// Mixed into every hash: two stubs with different seeds are different
    /// "models".
    pub seed: u64,
    /// Artificial latency per fused step (Duration::ZERO for benches that
    /// count steps, a few ms for tests that race cancels against prefill).
    pub step_delay: Duration,
    /// Additional artificial latency *per slab token* of the step's width,
    /// so a W-wide fused step costs `step_delay + W × width_delay` — the
    /// cost model the per-step token budget (`--max-step-tokens`) trades
    /// against.  Duration::ZERO (the default) keeps steps flat-cost.
    pub width_delay: Duration,
    /// Time source the delays burn: the wall clock by default, or a
    /// manual [`Clock`] so simulated step cost advances *virtual* time —
    /// latency/TTFT assertions become exact and the test runs at host
    /// speed.  `Engine::new_stub` adopts this clock as the engine clock,
    /// so one spec field puts the whole serve on a shared timeline.
    pub clock: Clock,
    /// Seeded fault-injection schedule (no-op by default) — see
    /// [`FaultPlan`].
    pub fault_plan: FaultPlan,
}

impl Default for StubSpec {
    fn default() -> Self {
        Self {
            n_layers: 2,
            n_heads: 2,
            rank: 4,
            max_positions: 64,
            batch_slots: 8,
            vocab: 32,
            chunk_widths: vec![1, 8, 32],
            seed: 0,
            step_delay: Duration::ZERO,
            width_delay: Duration::ZERO,
            clock: Clock::wall(),
            fault_plan: FaultPlan::default(),
        }
    }
}

impl StubSpec {
    /// Ascending slab widths including the implicit 1.
    pub fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.chunk_widths.clone();
        w.push(1);
        w.sort_unstable();
        w.dedup();
        w
    }
}

/// Geometric decay of rank component k's readout contribution
/// (`RANK_DECAY^k`): the stub's "singular-value spectrum".  Low-k
/// components dominate the logits, so truncating the rank (a lower-rank
/// stub with the same seed) preserves most greedy decisions — measured at
/// ~97% token agreement between rank 4 and rank 8 over greedy rollouts —
/// while still flipping some, which is exactly the regime a speculative
/// draft/verify pair needs.
pub const RANK_DECAY: f32 = 0.5;

/// SplitMix64 finalizer — the hash behind every stub weight.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn mix(parts: &[u64]) -> u64 {
    let mut h = 0x243F_6A88_85A3_08D3u64;
    for &p in parts {
        h = splitmix(h ^ p);
    }
    h
}

/// Hash to a centered float in [-0.5, 0.5).
fn h01(x: u64) -> f32 {
    ((x >> 40) as f32) / (1u64 << 24) as f32 - 0.5
}

/// Flat index into a dense `[L, B, H, C, r]` view under `s`'s dims — used
/// by the cache materializer ([`StubModel::caches`]) and the tests' dense
/// oracle, so the paged store and the dense reference share one layout
/// formula.
fn flat_idx(s: &StubSpec, l: usize, lane: usize, h: usize, c: usize, k: usize) -> usize {
    (((l * s.batch_slots + lane) * s.n_heads + h) * s.max_positions + c) * s.rank + k
}

/// The cache write value at one `(cache, layer, head, rank, pos, token)`
/// coordinate — a pure function shared by the paged write path and the
/// tests' dense oracle.
fn write_value(seed: u64, salt: usize, l: usize, h: usize, k: usize, pos: usize, token: i32) -> f32 {
    h01(mix(&[
        seed,
        salt as u64,
        l as u64,
        h as u64,
        k as u64,
        pos as u64,
        token as u64,
    ]))
}

/// The stub backend: K + VO factor caches held in a [`PagedKvStore`]
/// behind a page codec, plus deterministic write/readout rules.  See the
/// module docs for the invariants.
pub struct StubModel {
    spec: StubSpec,
    store: PagedKvStore,
    /// Count of `step` calls (including ones that faulted) — the clock
    /// the [`FaultPlan`] schedule runs on.
    steps: u64,
    /// Latched by `fatal_after_steps`: once dead, every step fails.
    dead: bool,
}

impl StubModel {
    /// Identity-codec stub — bit-identical to the historical dense-tensor
    /// backend.
    pub fn new(spec: StubSpec) -> Self {
        Self::with_codec(spec, KvCodecSpec::Identity).expect("identity codec is always valid")
    }

    /// Stub whose cache pages are stored through `codec` — the engine
    /// threads its `KvConfig` codec here so `--kv-codec factored` is
    /// exercised in storage, not just in byte accounting.  Errors when
    /// the codec's layer budgets don't match the spec's geometry.
    pub fn with_codec(spec: StubSpec, codec: KvCodecSpec) -> Result<Self> {
        let codec = codec.build(spec.n_layers, spec.rank)?;
        let store = PagedKvStore::new(
            2,
            spec.n_layers,
            spec.n_heads,
            spec.max_positions,
            spec.batch_slots,
            codec,
        );
        Ok(Self { spec, store, steps: 0, dead: false })
    }

    pub fn spec(&self) -> &StubSpec {
        &self.spec
    }

    /// The page store (tests and byte-accounting assertions).
    pub fn store(&self) -> &PagedKvStore {
        &self.store
    }

    /// Mutable page store — the engine's prefix-cache plumbing (share /
    /// attach / release column references) goes through here; the step
    /// contract itself stays on the methods above.
    pub fn store_mut(&mut self) -> &mut PagedKvStore {
        &mut self.store
    }

    /// Write one `(token, position)` pair into `lane`'s cache rows.  The
    /// written value is a pure function of the coordinates, so rewriting
    /// the same pair (the pad-by-repeat convention for short slabs) is a
    /// no-op — exactly the idempotence contract of the slab artifacts.
    fn write(&mut self, lane: usize, pos: usize, token: i32) {
        let Self { spec, store, .. } = self;
        let mut coeffs = vec![0.0f32; spec.rank];
        for salt in 0..2 {
            for l in 0..spec.n_layers {
                for h in 0..spec.n_heads {
                    for (k, c) in coeffs.iter_mut().enumerate() {
                        *c = write_value(spec.seed, salt, l, h, k, pos, token);
                    }
                    store.write_vec(salt, l, lane, h, pos, &coeffs);
                }
            }
        }
    }

    /// Logits for `lane` reading its cache prefix `[0, pos]` in a fixed
    /// iteration order (bit-identical however the prefix was written).
    /// Rank component k contributes at weight [`RANK_DECAY`]`^k`, so the
    /// logits of a rank-r stub are a spectrum truncation of any
    /// higher-rank stub with the same seed — and a codec that truncates
    /// stored vectors to budget b reproduces the rank-b stub exactly,
    /// because decoded-absent components read 0.0 and are skipped like
    /// unwritten rows (see the module docs).
    fn logits_into(&self, lane: usize, pos: usize, out: &mut [f32]) {
        let s = &self.spec;
        out.fill(0.0);
        let mut coeffs = vec![0.0f32; s.rank];
        for salt in 0..2usize {
            for l in 0..s.n_layers {
                for h in 0..s.n_heads {
                    for c in 0..=pos {
                        self.store.read_vec(salt, l, lane, h, c, &mut coeffs);
                        for (k, &e) in coeffs.iter().enumerate() {
                            if e == 0.0 {
                                continue;
                            }
                            let decay = RANK_DECAY.powi(k as i32);
                            let w = mix(&[
                                s.seed ^ 0xABCD,
                                salt as u64,
                                l as u64,
                                h as u64,
                                c as u64,
                                k as u64,
                            ]);
                            for (v, o) in out.iter_mut().enumerate() {
                                *o += e
                                    * decay
                                    * h01(splitmix(w ^ (v as u64).wrapping_mul(0x100_0193)));
                            }
                        }
                    }
                }
            }
        }
    }

    /// One fused step over all lanes: scatter each lane's `width`-wide
    /// token/position slab into the caches, then read logits.  `toks`/
    /// `poss` are row-major `[B, width]`; short slabs pad by repeating
    /// their last pair (idempotent rewrite).
    ///
    /// Mirroring the compiled artifacts: width 1 returns logits `[B, V]`
    /// (the decode program), width > 1 returns logits at **every** slab
    /// index, `[B, width, V]` (the `prefill_k{K}` slab programs) — the
    /// all-position output a speculative verify step reads a whole draft
    /// from.
    pub fn step(&mut self, width: usize, toks: &[i32], poss: &[i32]) -> Result<Tensor> {
        let (b, vocab, cmax) = (self.spec.batch_slots, self.spec.vocab, self.spec.max_positions);
        let mut delay = self.spec.step_delay + self.spec.width_delay * width as u32;
        // Fault schedule first: a faulted step consumes a step number but
        // never touches the cache, so a retried slab rewrites from a
        // clean (committed) state.  Argument validation stays below —
        // caller bugs must not be maskable by a fault plan.
        let plan = self.spec.fault_plan.clone();
        self.steps += 1;
        let step_no = self.steps;
        let mut poison = None;
        if !plan.is_noop() {
            if plan.crash_after_steps.is_some_and(|n| step_no > n) {
                panic!("injected worker crash at stub step {step_no}");
            }
            if self.dead || plan.fatal_after_steps.is_some_and(|n| step_no > n) {
                self.dead = true;
                return Err(StepFault::Fatal { step: step_no }.into());
            }
            if plan.transient_rate > 0.0 && plan.roll(CH_TRANSIENT, step_no) < plan.transient_rate
            {
                return Err(StepFault::Transient { step: step_no }.into());
            }
            if plan.spike_rate > 0.0 && plan.roll(CH_SPIKE, step_no) < plan.spike_rate {
                delay *= plan.spike_factor;
            }
            if plan.poison_rate > 0.0 && plan.roll(CH_POISON, step_no) < plan.poison_rate {
                poison = Some(plan.poison_lane(step_no, b));
            }
        }
        if !self.spec.widths().contains(&width) {
            bail!("stub: no program for slab width {width} (have {:?})", self.spec.widths());
        }
        if toks.len() != b * width || poss.len() != b * width {
            bail!(
                "stub: width {width} wants {} entries, got {}/{}",
                b * width,
                toks.len(),
                poss.len()
            );
        }
        for lane in 0..b {
            for j in 0..width {
                let (t, p) = (toks[lane * width + j], poss[lane * width + j]);
                if p < 0 || p as usize >= cmax {
                    bail!("stub: lane {lane} position {p} outside the window");
                }
                self.write(lane, p as usize, t);
            }
        }
        let mut logits = vec![0.0f32; b * width * vocab];
        for lane in 0..b {
            for j in 0..width {
                let pos = poss[lane * width + j] as usize;
                let at = (lane * width + j) * vocab;
                self.logits_into(lane, pos, &mut logits[at..at + vocab]);
            }
        }
        // Poison lands *after* the cache writes: the KV append happened,
        // only the readout blew up — the engine must quarantine the lane,
        // not trust a rollback to scrub it.
        if let Some(lane) = poison {
            logits[lane * width * vocab..(lane + 1) * width * vocab].fill(f32::NAN);
        }
        self.spec.clock.sleep(delay);
        let shape = if width == 1 { vec![b, vocab] } else { vec![b, width, vocab] };
        Ok(Tensor::new(shape, logits))
    }

    /// Zero the given batch lanes — the stub analogue of the literal-side
    /// lane zeroing on slot churn.  Page-store semantics: the lane's pages
    /// are dropped outright, reclaiming their encoded bytes.
    pub fn zero_lanes(&mut self, lanes: &[usize]) {
        for &lane in lanes {
            self.store.zero_lane(lane);
        }
    }

    /// Dense `[L, B, H, C, r]` host view of both caches, materialized by
    /// decoding every page (tests only — storage itself stays paged and
    /// encoded).
    pub fn caches(&self) -> Vec<Tensor> {
        let s = &self.spec;
        let shape = [s.n_layers, s.batch_slots, s.n_heads, s.max_positions, s.rank];
        let pages_per_lane = s.max_positions.div_ceil(PAGE_TOKENS);
        let mut block = vec![0.0f32; s.n_heads * PAGE_TOKENS * s.rank];
        (0..2)
            .map(|cache| {
                let mut t = Tensor::zeros(&shape);
                let data = t.data_mut();
                for l in 0..s.n_layers {
                    for lane in 0..s.batch_slots {
                        for page in 0..pages_per_lane {
                            self.store.decode_page(cache, l, lane, page, &mut block);
                            for h in 0..s.n_heads {
                                for off in 0..PAGE_TOKENS {
                                    let pos = page * PAGE_TOKENS + off;
                                    if pos >= s.max_positions {
                                        break;
                                    }
                                    let src = (h * PAGE_TOKENS + off) * s.rank;
                                    let dst = flat_idx(s, l, lane, h, pos, 0);
                                    data[dst..dst + s.rank]
                                        .copy_from_slice(&block[src..src + s.rank]);
                                }
                            }
                        }
                    }
                }
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn spec() -> StubSpec {
        StubSpec { batch_slots: 2, vocab: 16, max_positions: 32, ..Default::default() }
    }

    #[test]
    fn widths_include_one() {
        let s = StubSpec { chunk_widths: vec![8, 32], ..spec() };
        assert_eq!(s.widths(), vec![1, 8, 32]);
    }

    #[test]
    fn slab_write_matches_sequential_writes() {
        // One 8-wide slab vs eight single-token steps: identical caches,
        // and the slab's logits at *every* index equal the corresponding
        // sequential step's logits — the verify contract at stub level.
        let toks: Vec<i32> = (0..8).map(|i| 3 + i).collect();
        let v = spec().vocab;
        let mut a = StubModel::new(spec());
        let mut seq = Vec::new();
        for (i, &t) in toks.iter().enumerate() {
            // Lane 1 idles at (0, 0) like an unoccupied engine lane.
            let lg = a.step(1, &[t, 0], &[i as i32, 0]).unwrap();
            seq.push(lg);
        }
        let mut b = StubModel::new(spec());
        let mut slab_toks = toks.clone();
        let mut slab_poss: Vec<i32> = (0..8).collect();
        // Lane 1: pad-by-repeat of (0, 0).
        slab_toks.extend([0i32; 8]);
        slab_poss.extend([0i32; 8]);
        let lg = b.step(8, &slab_toks, &slab_poss).unwrap();
        assert_eq!(lg.shape(), &[2, 8, v], "slab steps emit all-position logits");
        for j in 0..8 {
            // Lane 0 slab index j == sequential step j's lane-0 logits.
            assert_eq!(
                &lg.data()[j * v..(j + 1) * v],
                &seq[j].data()[..v],
                "slab index {j} must equal sequential step {j}"
            );
        }
        assert_eq!(a.caches()[0].data(), b.caches()[0].data());
        assert_eq!(a.caches()[1].data(), b.caches()[1].data());
    }

    /// The pre-codec backend, verbatim: dense `[L, B, H, C, r]` vectors
    /// written and read with the same value/weight formulas.  The paged
    /// identity-codec store must be bit-identical to this at every logit
    /// and every materialized cache element.
    struct DenseOracle {
        spec: StubSpec,
        caches: [Vec<f32>; 2],
    }

    impl DenseOracle {
        fn new(spec: StubSpec) -> Self {
            let n = spec.n_layers * spec.batch_slots * spec.n_heads * spec.max_positions
                * spec.rank;
            Self { caches: [vec![0.0; n], vec![0.0; n]], spec }
        }

        fn write(&mut self, lane: usize, pos: usize, token: i32) {
            let spec = &self.spec;
            for (salt, cache) in self.caches.iter_mut().enumerate() {
                for l in 0..spec.n_layers {
                    for h in 0..spec.n_heads {
                        for k in 0..spec.rank {
                            cache[flat_idx(spec, l, lane, h, pos, k)] =
                                write_value(spec.seed, salt, l, h, k, pos, token);
                        }
                    }
                }
            }
        }

        fn logits(&self, lane: usize, pos: usize) -> Vec<f32> {
            let s = &self.spec;
            let mut out = vec![0.0f32; s.vocab];
            for (salt, cache) in (0u64..).zip(self.caches.iter()) {
                for l in 0..s.n_layers {
                    for h in 0..s.n_heads {
                        for c in 0..=pos {
                            for k in 0..s.rank {
                                let e = cache[flat_idx(s, l, lane, h, c, k)];
                                if e == 0.0 {
                                    continue;
                                }
                                let decay = RANK_DECAY.powi(k as i32);
                                let w = mix(&[
                                    s.seed ^ 0xABCD,
                                    salt,
                                    l as u64,
                                    h as u64,
                                    c as u64,
                                    k as u64,
                                ]);
                                for (v, o) in out.iter_mut().enumerate() {
                                    *o += e
                                        * decay
                                        * h01(splitmix(
                                            w ^ (v as u64).wrapping_mul(0x100_0193),
                                        ));
                                }
                            }
                        }
                    }
                }
            }
            out
        }
    }

    #[test]
    fn paged_identity_matches_dense_oracle_property() {
        // The tentpole's bit-identity bar at the storage layer: random
        // mixes of slab widths, pad-by-repeat rewrites, and lane zeroing
        // against the dense pre-codec implementation — every logit and
        // every cache element must match to the bit.
        prop("paged identity vs dense oracle", 8, |rng| {
            let sp = StubSpec {
                batch_slots: 2,
                vocab: 8,
                max_positions: 64,
                chunk_widths: vec![1, 4],
                seed: rng.below(1000) as u64,
                ..Default::default()
            };
            let mut paged = StubModel::new(sp.clone());
            let mut oracle = DenseOracle::new(sp.clone());
            let mut pos = [0usize; 2];
            for _ in 0..10 {
                let width = if rng.uniform() < 0.5 { 1 } else { 4 };
                if pos.iter().any(|&p| p + width > sp.max_positions) {
                    break;
                }
                // Tokens are a fixed function of position, so the
                // pad-by-repeat path below rewrites an identical
                // (token, pos) pair — the engine's idempotence convention.
                let tok_at = |p: usize| (p % sp.vocab) as i32;
                let (mut toks, mut poss) = (Vec::new(), Vec::new());
                for lane in 0..2 {
                    // Lane 1 sometimes pads-by-repeat instead of advancing
                    // — the idempotent-rewrite path the engine exercises.
                    let repeat = lane == 1 && rng.uniform() < 0.4 && pos[lane] > 0;
                    for j in 0..width {
                        let p = if repeat { pos[lane] - 1 } else { pos[lane] + j };
                        toks.push(tok_at(p));
                        poss.push(p as i32);
                    }
                    if !repeat {
                        pos[lane] += width;
                    }
                }
                for lane in 0..2 {
                    for j in 0..width {
                        oracle.write(lane, poss[lane * width + j] as usize, toks[lane * width + j]);
                    }
                }
                let lg = paged.step(width, &toks, &poss).map_err(|e| e.to_string())?;
                for lane in 0..2 {
                    for j in 0..width {
                        let at = (lane * width + j) * sp.vocab;
                        let got = &lg.data()[at..at + sp.vocab];
                        let want = oracle.logits(lane, poss[lane * width + j] as usize);
                        if got.iter().zip(&want).any(|(a, b)| a.to_bits() != b.to_bits()) {
                            return Err(format!("lane {lane} slab {j}: logits diverge"));
                        }
                    }
                }
                if rng.uniform() < 0.2 {
                    let lane = rng.below(2);
                    paged.zero_lanes(&[lane]);
                    let s = &oracle.spec;
                    let inner = s.n_heads * s.max_positions * s.rank;
                    for cache in oracle.caches.iter_mut() {
                        for l in 0..s.n_layers {
                            let start = (l * s.batch_slots + lane) * inner;
                            cache[start..start + inner].fill(0.0);
                        }
                    }
                    pos[lane] = 0;
                }
            }
            for (cache, want) in paged.caches().iter().zip(oracle.caches.iter()) {
                if cache.data().iter().zip(want).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err("materialized caches diverge from the dense oracle".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn factored_codec_equals_pruned_rank_stub() {
        // The factored codec stores pages at the pruned rank, and because
        // the stub's write values and readout weights are pure functions
        // of k, a budget-b store on a rank-8 model is *bit-equal* to a
        // rank-b model with the same seed — CLOVER truncation applied at
        // rest equals CLOVER truncation applied to the model.
        let mk = |rank| StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank,
            vocab: 16,
            max_positions: 64,
            batch_slots: 1,
            ..Default::default()
        };
        let mut fact = StubModel::with_codec(
            mk(8),
            KvCodecSpec::Factored { layer_budgets: Some(vec![3]) },
        )
        .unwrap();
        let mut small = StubModel::new(mk(3));
        let mut tok = 3i32;
        for pos in 0..40 {
            let lf = fact.step(1, &[tok], &[pos]).unwrap();
            let ls = small.step(1, &[tok], &[pos]).unwrap();
            let same = lf
                .data()
                .iter()
                .zip(ls.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "position {pos}: factored(3) logits != rank-3 logits");
            tok = crate::util::argmax(ls.data()) as i32;
        }
        // And the factored store holds 3/8 the floats of an identity one.
        let mut dense = StubModel::new(mk(8));
        let mut tok2 = 3i32;
        for pos in 0..40 {
            let l = dense.step(1, &[tok2], &[pos]).unwrap();
            tok2 = crate::util::argmax(l.data()) as i32;
        }
        assert_eq!(fact.store().stored_bytes() * 8, dense.store().stored_bytes() * 3);
    }

    #[test]
    fn with_codec_validates_budgets_against_spec() {
        let s = spec(); // n_layers 2, rank 4
        assert!(StubModel::with_codec(
            s.clone(),
            KvCodecSpec::Factored { layer_budgets: Some(vec![2, 2]) }
        )
        .is_ok());
        assert!(StubModel::with_codec(
            s.clone(),
            KvCodecSpec::Factored { layer_budgets: Some(vec![2]) }
        )
        .is_err());
        assert!(StubModel::with_codec(
            s,
            KvCodecSpec::Factored { layer_budgets: Some(vec![2, 5]) }
        )
        .is_err());
    }

    #[test]
    fn rank_truncation_makes_a_good_draft_model() {
        // A rank-4 stub is a spectrum truncation of the rank-8 stub with
        // the same seed: correlated enough that greedy tokens mostly
        // agree (the speculative-draft regime), yet the logits differ.
        let mk = |rank| StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank,
            vocab: 16,
            max_positions: 128,
            batch_slots: 1,
            ..Default::default()
        };
        let mut target = StubModel::new(mk(8));
        let mut draft = StubModel::new(mk(4));
        // Greedy rollout on the target; at each position ask the draft
        // for its prediction of the same next token.
        let mut tok = 3i32;
        let (mut agree, mut total, mut logits_differ) = (0usize, 0usize, false);
        for pos in 0..40 {
            let lt = target.step(1, &[tok], &[pos]).unwrap();
            let ld = draft.step(1, &[tok], &[pos]).unwrap();
            if lt.data() != ld.data() {
                logits_differ = true;
            }
            let t_next = crate::util::argmax(lt.data()) as i32;
            let d_next = crate::util::argmax(ld.data()) as i32;
            agree += (t_next == d_next) as usize;
            total += 1;
            tok = t_next;
        }
        assert!(logits_differ, "rank must change the distribution");
        assert!(
            agree * 10 >= total * 6,
            "rank-4 draft agreed on only {agree}/{total} greedy tokens — \
             the spectrum decay is not doing its job"
        );
    }

    #[test]
    fn logits_depend_on_history_and_lane_is_isolated() {
        let mut a = StubModel::new(spec());
        let mut b = StubModel::new(spec());
        a.step(1, &[5, 0], &[0, 0]).unwrap();
        b.step(1, &[6, 0], &[0, 0]).unwrap();
        let la = a.step(1, &[7, 0], &[1, 0]).unwrap();
        let lb = b.step(1, &[7, 0], &[1, 0]).unwrap();
        assert_ne!(la.data(), lb.data(), "history must influence logits");
        // Lane 0's rows differ, lane 1 wrote identical junk in both.
        assert_ne!(
            &la.data()[..16],
            &la.data()[16..],
            "different lanes with different rows must not alias"
        );
    }

    #[test]
    fn zero_lanes_restores_fresh_state() {
        let mut a = StubModel::new(spec());
        a.step(1, &[5, 9], &[0, 0]).unwrap();
        a.step(1, &[6, 9], &[1, 1]).unwrap();
        a.zero_lanes(&[0]);
        // Lane 0 replays a fresh prompt and must see logits identical to a
        // brand-new stub (lane 1's live rows must not leak in).
        let l1 = a.step(1, &[4, 9], &[0, 2]).unwrap();
        let mut fresh = StubModel::new(spec());
        fresh.step(1, &[9, 9], &[0, 0]).unwrap();
        fresh.step(1, &[9, 9], &[1, 1]).unwrap();
        fresh.zero_lanes(&[0]);
        let l2 = fresh.step(1, &[4, 9], &[0, 2]).unwrap();
        assert_eq!(&l1.data()[..16], &l2.data()[..16]);
    }

    #[test]
    fn rejects_bad_width_and_positions() {
        let mut a = StubModel::new(spec());
        assert!(a.step(3, &[0; 6], &[0; 6]).is_err(), "width 3 not in the ladder");
        assert!(a.step(1, &[0, 0], &[0]).is_err(), "length mismatch");
        assert!(a.step(1, &[0, 0], &[0, 99]).is_err(), "position outside window");
    }

    #[test]
    fn manual_clock_makes_step_delays_virtual() {
        let clock = Clock::manual();
        let mut s = spec();
        s.step_delay = Duration::from_secs(2);
        s.width_delay = Duration::from_secs(1);
        s.clock = clock.clone();
        let mut a = StubModel::new(s);
        let real = std::time::Instant::now();
        a.step(1, &[5, 9], &[0, 0]).unwrap();
        assert!(real.elapsed() < Duration::from_secs(2), "delay must not block");
        // step_delay + 1 × width_delay, burned entirely on the timeline.
        assert_eq!(clock.secs_since_epoch(clock.now()), 3.0);
    }

    #[test]
    fn fault_plan_parse_roundtrips_and_rejects() {
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert!(FaultPlan::parse("off").unwrap().is_noop());
        let p = FaultPlan::parse(
            "seed=7, transient=0.25, spike=0.5, spike-factor=20, poison=0.1, \
             fatal-after=100, crash-after=200",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.transient_rate, 0.25);
        assert_eq!(p.spike_rate, 0.5);
        assert_eq!(p.spike_factor, 20);
        assert_eq!(p.poison_rate, 0.1);
        assert_eq!(p.fatal_after_steps, Some(100));
        assert_eq!(p.crash_after_steps, Some(200));
        assert!(!p.is_noop());
        assert!(matches!(
            FaultPlan::parse("bogus=1"),
            Err(FaultPlanError::UnknownKey(_))
        ));
        assert!(matches!(
            FaultPlan::parse("transient=1.5"),
            Err(FaultPlanError::RateOutOfRange { .. })
        ));
        assert!(matches!(
            FaultPlan::parse("transient=-0.1"),
            Err(FaultPlanError::RateOutOfRange { .. })
        ));
        assert!(matches!(
            FaultPlan::parse("seed=abc"),
            Err(FaultPlanError::BadValue { .. })
        ));
        assert!(matches!(
            FaultPlan::parse("spike-factor=0"),
            Err(FaultPlanError::BadValue { .. })
        ));
        assert!(matches!(
            FaultPlan::parse("transient"),
            Err(FaultPlanError::MissingValue(_))
        ));
    }

    #[test]
    fn transient_faults_are_deterministic_and_leave_cache_untouched() {
        let mk = || StubSpec {
            fault_plan: FaultPlan { seed: 11, transient_rate: 0.3, ..Default::default() },
            ..spec()
        };
        let run = |mut m: StubModel| {
            let mut faulted = Vec::new();
            let mut last = None;
            for i in 0..40i32 {
                match m.step(1, &[3, 0], &[i % 30, 0]) {
                    Ok(lg) => last = Some(lg.data().to_vec()),
                    Err(e) => {
                        let f = e.downcast_ref::<StepFault>().copied();
                        assert!(
                            matches!(f, Some(StepFault::Transient { .. })),
                            "expected a transient fault, got {e:#}"
                        );
                        faulted.push(i);
                    }
                }
            }
            (faulted, last)
        };
        let (f1, l1) = run(StubModel::new(mk()));
        let (f2, l2) = run(StubModel::new(mk()));
        assert!(!f1.is_empty(), "rate 0.3 over 40 steps must fault at least once");
        assert!(f1.len() < 40, "rate 0.3 must not fault every step");
        assert_eq!(f1, f2, "fault schedule must be deterministic");
        assert_eq!(l1, l2, "logits after identical schedules must match");
        // A transient fault leaves the cache unwritten: replay the same
        // workload skipping faulted attempts on a fault-free stub and the
        // caches agree bit-for-bit.
        let mut faulty = StubModel::new(mk());
        let mut clean = StubModel::new(spec());
        for i in 0..40i32 {
            if faulty.step(1, &[3, 0], &[i % 30, 0]).is_ok() {
                clean.step(1, &[3, 0], &[i % 30, 0]).unwrap();
            }
        }
        assert_eq!(faulty.caches()[0].data(), clean.caches()[0].data());
    }

    #[test]
    fn fatal_after_steps_latches_dead() {
        let mut m = StubModel::new(StubSpec {
            fault_plan: FaultPlan { fatal_after_steps: Some(2), ..Default::default() },
            ..spec()
        });
        assert!(m.step(1, &[3, 0], &[0, 0]).is_ok());
        assert!(m.step(1, &[3, 0], &[1, 0]).is_ok());
        for i in 0..3 {
            let e = m.step(1, &[3, 0], &[2 + i, 0]).unwrap_err();
            assert!(
                matches!(e.downcast_ref::<StepFault>(), Some(StepFault::Fatal { .. })),
                "dead backend must stay dead, got {e:#}"
            );
        }
    }

    #[test]
    fn crash_after_steps_panics() {
        let r = std::panic::catch_unwind(|| {
            let mut m = StubModel::new(StubSpec {
                fault_plan: FaultPlan { crash_after_steps: Some(1), ..Default::default() },
                ..spec()
            });
            m.step(1, &[3, 0], &[0, 0]).unwrap();
            let _ = m.step(1, &[3, 0], &[1, 0]);
        });
        assert!(r.is_err(), "step past crash-after must panic");
    }

    #[test]
    fn spike_multiplies_delay_on_schedule() {
        let clock = Clock::manual();
        let mut s = spec();
        s.step_delay = Duration::from_millis(1);
        s.clock = clock.clone();
        s.fault_plan = FaultPlan { seed: 3, spike_rate: 0.5, spike_factor: 10, ..Default::default() };
        let mut m = StubModel::new(s);
        let mut costs = Vec::new();
        for i in 0..20i32 {
            let t0 = clock.secs_since_epoch(clock.now());
            m.step(1, &[3, 0], &[i, 0]).unwrap();
            costs.push(clock.secs_since_epoch(clock.now()) - t0);
        }
        let spiked = costs.iter().filter(|&&c| c > 0.005).count();
        assert!(spiked > 0, "some steps must spike");
        assert!(spiked < 20, "not every step may spike");
    }

    #[test]
    fn poison_nans_exactly_one_lane_and_cache_is_still_written() {
        let mut s = spec();
        s.fault_plan = FaultPlan { seed: 5, poison_rate: 0.4, ..Default::default() };
        let mut m = StubModel::new(s);
        let mut clean = StubModel::new(spec());
        let mut saw_poison = false;
        for i in 0..20i32 {
            let lg = m.step(1, &[3, 4], &[i, i]).unwrap();
            clean.step(1, &[3, 4], &[i, i]).unwrap();
            let bad_lanes: Vec<usize> = (0..2)
                .filter(|&lane| lg.data()[lane * 16..(lane + 1) * 16].iter().any(|v| v.is_nan()))
                .collect();
            if !bad_lanes.is_empty() {
                saw_poison = true;
                assert_eq!(bad_lanes.len(), 1, "poison hits exactly one lane");
                let lane = bad_lanes[0];
                assert!(
                    lg.data()[lane * 16..(lane + 1) * 16].iter().all(|v| v.is_nan()),
                    "the whole poisoned row is NaN"
                );
            }
        }
        assert!(saw_poison, "rate 0.4 over 20 steps must poison at least once");
        // The cache writes happened despite the poisoned readouts.
        assert_eq!(m.caches()[0].data(), clean.caches()[0].data());
    }

    #[test]
    fn env_seed_override_applies() {
        // Serialized via the env var name being unique to this test run
        // is not possible; keep it simple — set, read, restore.
        let prev = std::env::var("CLOVER_FAULT_SEED").ok();
        std::env::set_var("CLOVER_FAULT_SEED", "42");
        let p = FaultPlan { seed: 1, transient_rate: 0.1, ..Default::default() }.with_env_seed();
        assert_eq!(p.seed, 42);
        match prev {
            Some(v) => std::env::set_var("CLOVER_FAULT_SEED", v),
            None => std::env::remove_var("CLOVER_FAULT_SEED"),
        }
    }
}
