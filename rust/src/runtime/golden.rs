//! Golden-vector integration checks: replay the `.npz` fixtures the AOT
//! exporter captured (inputs `arg0..argN`, expected outputs `out0..outM`)
//! through the Rust runtime and compare.
//!
//! This is the cross-language correctness seal: if these pass, the Rust
//! PJRT path computes bit-comparable results to the jax programs that
//! produced the artifacts (same XLA version, same CPU backend).

use anyhow::{bail, Context, Result};

use super::{literal, Runtime};
use crate::tensor::Value;

/// Max |a-b| tolerated between jax-side and rust-side outputs.  Both run
/// the same HLO on the same backend; differences are compile-flag level.
pub const GOLDEN_ATOL: f32 = 2e-4;
pub const GOLDEN_RTOL: f32 = 2e-3;

/// Replay one golden fixture.  Returns the worst absolute deviation seen.
pub fn check(rt: &Runtime, config: &str, program: &str) -> Result<f32> {
    let sig = rt.manifest().config(config)?.program(program)?.clone();
    let golden_rel = match &sig.golden {
        Some(g) => g.clone(),
        None => bail!("{config}/{program} has no golden fixture"),
    };
    let path = rt.manifest().root.join(&golden_rel);
    let named = literal::read_npz(&path)?;
    let lookup = |key: &str| -> Result<&Value> {
        named.iter().find(|(n, _)| n == key).map(|(_, v)| v)
            .with_context(|| format!("{golden_rel}: missing {key}"))
    };

    let args: Vec<Value> = (0..sig.inputs.len())
        .map(|i| lookup(&format!("arg{i}")).cloned())
        .collect::<Result<_>>()?;
    let outs = rt.run(config, program, &args)?;

    let mut worst = 0.0f32;
    for (i, got) in outs.iter().enumerate() {
        let want = lookup(&format!("out{i}"))?;
        match (got, want) {
            (Value::F32(a), Value::F32(b)) => {
                if a.shape() != b.shape() {
                    bail!("{config}/{program} out{i}: shape {:?} != {:?}", a.shape(), b.shape());
                }
                for (x, y) in a.data().iter().zip(b.data().iter()) {
                    let d = (x - y).abs();
                    if d > GOLDEN_ATOL + GOLDEN_RTOL * y.abs() {
                        bail!("{config}/{program} out{i}: {x} vs {y} (|d|={d})");
                    }
                    worst = worst.max(d);
                }
            }
            (Value::I32(a), Value::I32(b)) => {
                if a != b {
                    bail!("{config}/{program} out{i}: i32 mismatch");
                }
            }
            _ => bail!("{config}/{program} out{i}: dtype mismatch"),
        }
    }
    Ok(worst)
}

/// Replay every golden fixture declared in the manifest for `config`.
pub fn check_all(rt: &Runtime, config: &str) -> Result<Vec<(String, f32)>> {
    let progs: Vec<String> = rt
        .manifest()
        .config(config)?
        .programs
        .iter()
        .filter(|(_, sig)| sig.golden.is_some())
        .map(|(n, _)| n.clone())
        .collect();
    let mut results = Vec::new();
    for p in progs {
        let worst = check(rt, config, &p).with_context(|| format!("golden {config}/{p}"))?;
        crate::info!("golden {config}/{p}: max |Δ| = {worst:.2e}");
        results.push((p, worst));
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn golden_fwd_tiny() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let worst = check(&rt, "tiny", "fwd").unwrap();
        assert!(worst <= GOLDEN_ATOL * 10.0, "worst {worst}");
    }

    #[test]
    fn golden_train_full_tiny() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        check(&rt, "tiny", "train_full").unwrap();
    }

    #[test]
    fn golden_fac_and_decode_tiny() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        check(&rt, "tiny", "fwd_fac_r16").unwrap();
        check(&rt, "tiny", "decode_b1").unwrap();
    }

    #[test]
    fn missing_golden_is_error() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        assert!(check(&rt, "tiny", "train_clover_s_r16").is_err());
    }
}
