//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator/serving hot paths.
//!
//! The flow mirrors `/opt/xla-example/load_hlo`:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.  Compiled executables are cached per
//! `(config, program)`; HLO parsing + XLA compilation happen at most once
//! per process.
//!
//! Every run path funnels through one private execute core
//! ([`Runtime::execute_core`]): argument literals in, output literals out.
//! The public entry points differ only in *when* host values are converted
//! to literals — per call ([`Runtime::run`]), params-once
//! ([`Runtime::run_prepared`]), or carried across a whole decode loop
//! ([`DecodeSession`], which keeps the KV caches literal-side so the
//! per-step marshal traffic is just tokens/positions in and logits out).
//!
//! Threading: `Runtime` is deliberately `!Sync` (the underlying C handles
//! have no documented thread-safety story).  The serving layer owns one
//! `Runtime` on a dedicated executor thread and feeds it through channels
//! (see [`crate::serve`]).

pub mod golden;
pub mod literal;
pub mod stub;

use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::model::manifest::{ArgSpec, Manifest, ProgramSig};
use crate::tensor::{Tensor, TensorI, Value};
use crate::util::Stopwatch;

pub use literal::{from_literal, to_literal};

/// Cumulative execution statistics (perf pass instrumentation).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub compiles: usize,
    pub compile_s: f64,
    pub executes: usize,
    pub execute_s: f64,
    pub marshal_s: f64,
}

pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RunStats>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PjRtClient: {e:?}"))?;
        crate::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Self {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RunStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RunStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = RunStats::default();
    }

    /// Compile (or fetch from cache) a program's executable.
    pub fn executable(&self, config: &str, program: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{config}/{program}");
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let sig = self.manifest.config(config)?.program(program)?;
        let path = self.manifest.hlo_path(sig);
        let sw = Stopwatch::new();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {key}: {e:?}"))?;
        let dt = sw.elapsed_s();
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_s += dt;
        }
        crate::debug!("compiled {key} in {dt:.2}s");
        let rc = Rc::new(exe);
        self.cache.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// The single execute core every run path shares: argument literals in,
    /// output literals out (the `return_tuple=True` root already split).
    ///
    /// Accounts `executes`/`execute_s`, and attributes the device→host
    /// result fetch + untuple to `marshal_s`; host-value *conversions*
    /// (`to_literal`/`from_literal`) are timed by the callers, since that
    /// is exactly where the run paths differ.
    fn execute_core(
        &self,
        config: &str,
        program: &str,
        sig: &ProgramSig,
        lits: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(config, program)?;
        let sw_exec = Stopwatch::new();
        let result = exe
            .execute::<&xla::Literal>(lits)
            .map_err(|e| anyhow::anyhow!("executing {config}/{program}: {e:?}"))?;
        let exec_s = sw_exec.elapsed_s();

        let sw_fetch = Stopwatch::new();
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {config}/{program}: {e:?}"))?;
        // Programs are lowered with return_tuple=True: always a tuple root.
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {config}/{program}: {e:?}"))?;
        if parts.len() != sig.outputs.len() {
            bail!(
                "{config}/{program}: expected {} outputs, got {}",
                sig.outputs.len(),
                parts.len()
            );
        }
        let fetch_s = sw_fetch.elapsed_s();

        let mut st = self.stats.borrow_mut();
        st.executes += 1;
        st.execute_s += exec_s;
        st.marshal_s += fetch_s;
        Ok(parts)
    }

    /// Execute `config/program` on host values, returning host values.
    ///
    /// Arguments are shape- and dtype-checked against the manifest
    /// signature before anything touches the PJRT boundary, so mismatches
    /// fail with names instead of an opaque XLA error.
    pub fn run(&self, config: &str, program: &str, args: &[Value]) -> Result<Vec<Value>> {
        let sig = self.manifest.config(config)?.program(program)?.clone();
        if args.len() != sig.inputs.len() {
            bail!(
                "{config}/{program}: expected {} args, got {}",
                sig.inputs.len(),
                args.len()
            );
        }
        for (v, spec) in args.iter().zip(&sig.inputs) {
            literal::check_arg(&spec.name, v, &spec.shape, spec.dtype)
                .with_context(|| format!("{config}/{program}"))?;
        }
        let sw = Stopwatch::new();
        let lits: Vec<xla::Literal> =
            args.iter().map(literal::to_literal).collect::<Result<_>>()?;
        let marshal_in = sw.elapsed_s();

        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let parts = self.execute_core(config, program, &sig, &refs)?;

        let sw_out = Stopwatch::new();
        let outs: Vec<Value> = parts
            .iter()
            .map(literal::from_literal)
            .collect::<Result<_>>()?;
        let marshal_out = sw_out.elapsed_s();
        self.stats.borrow_mut().marshal_s += marshal_in + marshal_out;
        Ok(outs)
    }

    /// Convenience: run and pull a single scalar f32 output by index.
    pub fn run_scalar(&self, config: &str, program: &str, args: &[Value], idx: usize) -> Result<f32> {
        let outs = self.run(config, program, args)?;
        Ok(outs[idx].as_f32()?.item())
    }

    /// Pre-marshal values that stay constant across many calls (model
    /// params during a decode session): pay the host→literal copy once.
    pub fn prepare(&self, values: &[&Value]) -> Result<Vec<xla::Literal>> {
        values.iter().map(|v| literal::to_literal(v)).collect()
    }

    /// Execute with a prepared literal prefix + per-call suffix values.
    /// §Perf optimization: on the decode hot path the parameter literals
    /// dominated marshal time (33–41% of step wall); reusing them cuts it
    /// to the cache/token tensors only.  (The serving engine goes further:
    /// [`DecodeSession`] keeps the caches literal-side too.)
    pub fn run_prepared(
        &self,
        config: &str,
        program: &str,
        prefix: &[xla::Literal],
        rest: &[Value],
    ) -> Result<Vec<Value>> {
        let sig = self.manifest.config(config)?.program(program)?.clone();
        if prefix.len() + rest.len() != sig.inputs.len() {
            bail!(
                "{config}/{program}: expected {} args, got {} prepared + {}",
                sig.inputs.len(), prefix.len(), rest.len()
            );
        }
        for (v, spec) in rest.iter().zip(&sig.inputs[prefix.len()..]) {
            literal::check_arg(&spec.name, v, &spec.shape, spec.dtype)
                .with_context(|| format!("{config}/{program}"))?;
        }
        let sw = Stopwatch::new();
        let rest_lits: Vec<xla::Literal> =
            rest.iter().map(literal::to_literal).collect::<Result<_>>()?;
        let marshal_in = sw.elapsed_s();

        let all: Vec<&xla::Literal> = prefix.iter().chain(rest_lits.iter()).collect();
        let parts = self.execute_core(config, program, &sig, &all)?;

        let sw_out = Stopwatch::new();
        let outs: Vec<Value> = parts.iter().map(literal::from_literal).collect::<Result<_>>()?;
        let marshal_out = sw_out.elapsed_s();
        self.stats.borrow_mut().marshal_s += marshal_in + marshal_out;
        Ok(outs)
    }
}

/// A decode-loop session over a *family* of step programs sharing one
/// carried cache set — the single-token `decode_*` program (slab width 1)
/// plus any `prefill_k{K}_*` chunk programs exported for the config.
///
/// Both the model parameters *and* the carried KV-cache values live on the
/// literal side of the marshal boundary: the cache tuple elements returned
/// by one [`DecodeSession::run_plan`] are fed back verbatim as the next
/// step's inputs — *whichever width that step dispatches to* — so the
/// per-step host↔device conversion traffic shrinks from the full
/// `[L, B, H, C, r]` caches to the token/position slabs in and the logits
/// row out.  The engine pulls the caches to host only on slot-churn events
/// ([`DecodeSession::update_caches`], e.g. zeroing a freed lane): marshal
/// in once, update lanes host-side, and pay the cache round-trip per churn
/// event rather than per token.  (The literal API is whole-tensor, so a
/// churn event re-marshals the full cache set; the worst case — churn
/// every step — matches the old per-step cost, and steady-state decode
/// pays nothing.)
///
/// Construction validates that every width's program agrees on the
/// parameter block and on the cache block (names *and* shapes), which is
/// what makes carrying one literal cache set across widths sound.
struct PlanProgram {
    name: String,
    sig: ProgramSig,
}

pub struct DecodeSession<'rt> {
    rt: &'rt Runtime,
    config: String,
    /// Slab width → program.  Width 1 is always present.
    progs: std::collections::BTreeMap<usize, PlanProgram>,
    params: Vec<xla::Literal>,
    caches: Vec<xla::Literal>,
    n_params: usize,
    n_caches: usize,
    batch: usize,
}

impl<'rt> DecodeSession<'rt> {
    /// Single-program session (slab width 1) — the pre-plan API, kept for
    /// callers that only ever feed one token per lane per step.
    pub fn new(rt: &'rt Runtime, config: &str, program: &str, params: &[Value]) -> Result<Self> {
        Self::new_planned(rt, config, &[(1, program.to_string())], params)
    }

    /// Build a session over `(width, program)` pairs.  `params` must match
    /// the programs' (shared) leading inputs; the cache inputs (names
    /// ending in `_cache`) are initialized to zeros and thereafter carried
    /// from the programs' own outputs.  Width 1 is mandatory — it is the
    /// decode step every plan degenerates to.
    pub fn new_planned(
        rt: &'rt Runtime,
        config: &str,
        programs: &[(usize, String)],
        params: &[Value],
    ) -> Result<Self> {
        let mut progs = std::collections::BTreeMap::new();
        for (w, name) in programs {
            if *w == 0 {
                bail!("{config}: slab width 0 is meaningless");
            }
            let sig = rt.manifest.config(config)?.program(name)?.clone();
            if progs.insert(*w, PlanProgram { name: name.clone(), sig }).is_some() {
                bail!("{config}: duplicate program for slab width {w}");
            }
        }
        if !progs.contains_key(&1) {
            bail!("{config}: a decode session needs a width-1 (decode) program");
        }

        // Validate each program's block structure against the width-1
        // reference: params, contiguous cache block, carried outputs.
        let mut n_params = 0usize;
        let mut n_caches = 0usize;
        let mut ref_param_specs: Vec<ArgSpec> = Vec::new();
        let mut ref_cache_specs: Vec<ArgSpec> = Vec::new();
        for (w, p) in &progs {
            let (name, sig) = (&p.name, &p.sig);
            let cache_idx: Vec<usize> = sig
                .inputs
                .iter()
                .enumerate()
                .filter(|(_, a)| a.name.ends_with("_cache"))
                .map(|(i, _)| i)
                .collect();
            let (np, nc) = match cache_idx.first() {
                Some(&first) if cache_idx.iter().enumerate().all(|(k, &i)| i == first + k) => {
                    (first, cache_idx.len())
                }
                _ => bail!(
                    "{config}/{name}: no contiguous *_cache input block — not a decode program"
                ),
            };
            // The carried caches must come back as the trailing outputs, in
            // input order — verified by name so a signature change fails loud.
            if sig.outputs.len() < nc + 1 {
                bail!(
                    "{config}/{name}: {} outputs can't carry {nc} caches plus logits",
                    sig.outputs.len()
                );
            }
            let out_tail: Vec<&str> = sig.outputs[sig.outputs.len() - nc..]
                .iter()
                .map(|a| a.name.as_str())
                .collect();
            let in_names: Vec<&str> =
                sig.inputs[np..np + nc].iter().map(|a| a.name.as_str()).collect();
            if out_tail != in_names {
                bail!(
                    "{config}/{name}: trailing outputs {out_tail:?} don't carry the cache inputs {in_names:?}"
                );
            }
            if *w == 1 {
                n_params = np;
                n_caches = nc;
                ref_param_specs = sig.inputs[..np].to_vec();
                ref_cache_specs = sig.inputs[np..np + nc].to_vec();
            }
        }
        for (w, p) in &progs {
            let (name, sig) = (&p.name, &p.sig);
            let same = |a: &[ArgSpec], b: &[ArgSpec]| {
                a.len() == b.len()
                    && a.iter().zip(b).all(|(x, y)| x.name == y.name && x.shape == y.shape)
            };
            if !same(&sig.inputs[..n_params.min(sig.inputs.len())], &ref_param_specs) {
                bail!("{config}/{name}: width-{w} param block differs from the decode program's");
            }
            let lo = n_params;
            let hi = (n_params + n_caches).min(sig.inputs.len());
            if !same(&sig.inputs[lo..hi], &ref_cache_specs) {
                bail!(
                    "{config}/{name}: width-{w} cache block differs from the decode program's — \
                     one literal cache set can't be carried across widths"
                );
            }
        }

        if params.len() != n_params {
            bail!(
                "{config}: expected {n_params} param inputs, got {}",
                params.len()
            );
        }
        for (v, spec) in params.iter().zip(&ref_param_specs) {
            literal::check_arg(&spec.name, v, &spec.shape, spec.dtype)
                .with_context(|| format!("{config}/decode params"))?;
        }
        let batch = ref_cache_specs
            .first()
            .and_then(|a| a.shape.get(1).copied())
            .context("cache input lacks a batch dim")?;

        let sw = Stopwatch::new();
        let param_lits: Vec<xla::Literal> =
            params.iter().map(literal::to_literal).collect::<Result<_>>()?;
        let caches: Vec<xla::Literal> = ref_cache_specs
            .iter()
            .map(|a| literal::to_literal(&Value::F32(Tensor::zeros(&a.shape))))
            .collect::<Result<_>>()?;
        rt.stats.borrow_mut().marshal_s += sw.elapsed_s();
        Ok(Self {
            rt,
            config: config.into(),
            progs,
            params: param_lits,
            caches,
            n_params,
            n_caches,
            batch,
        })
    }

    /// Slab widths this session can dispatch, ascending (always starts
    /// with 1).
    pub fn widths(&self) -> Vec<usize> {
        self.progs.keys().copied().collect()
    }

    /// Batch lanes of the carried caches.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// One decode step through the width-1 program.  `step_args` are the
    /// per-step inputs after the cache block (tokens, positions); returns
    /// the non-carried outputs (the logits), while the cache outputs stay
    /// literal-side for the next step.
    pub fn step(&mut self, step_args: &[Value]) -> Result<Vec<Value>> {
        self.step_width(1, step_args)
    }

    /// Dispatch one fused step to the program for `width`, feeding each
    /// lane's token/position slab row-major.  `toks`/`poss` must hold
    /// `batch × width` entries; lanes with fewer than `width` real tokens
    /// pad by repeating their last `(token, position)` pair, which the
    /// slab programs treat as an idempotent rewrite.  Returns the logits:
    /// `[B, V]` from the width-1 decode program, `[B, width, V]` (every
    /// slab position) from the chunk programs — the multi-position output
    /// the serve engine samples prefills from (last valid index) and
    /// scores speculative drafts with (all indices).  Manifests exported
    /// before the all-position change return `[B, V]` here for every
    /// width; the engine detects that by shape and only disallows
    /// speculation, not prefill.
    pub fn run_plan(&mut self, width: usize, toks: Vec<i32>, poss: Vec<i32>) -> Result<Vec<Value>> {
        if toks.len() != self.batch * width || poss.len() != self.batch * width {
            bail!(
                "{}: run_plan width {width} wants {} entries, got {}/{}",
                self.config,
                self.batch * width,
                toks.len(),
                poss.len()
            );
        }
        // Width-1 programs keep the original flat `[B]` signature; chunk
        // programs take `[B, K]` slabs.
        let shape = if width == 1 { vec![self.batch] } else { vec![self.batch, width] };
        let args = [
            Value::I32(TensorI::new(shape.clone(), toks)),
            Value::I32(TensorI::new(shape, poss)),
        ];
        self.step_width(width, &args)
    }

    fn step_width(&mut self, width: usize, step_args: &[Value]) -> Result<Vec<Value>> {
        let prog = self
            .progs
            .get(&width)
            .with_context(|| {
                format!(
                    "{}: no program for slab width {width} (have {:?})",
                    self.config,
                    self.progs.keys().collect::<Vec<_>>()
                )
            })?;
        let (program, sig) = (&prog.name, &prog.sig);
        let tail = &sig.inputs[self.n_params + self.n_caches..];
        if step_args.len() != tail.len() {
            bail!(
                "{}/{}: expected {} step args, got {}",
                self.config, program, tail.len(), step_args.len()
            );
        }
        for (v, spec) in step_args.iter().zip(tail) {
            literal::check_arg(&spec.name, v, &spec.shape, spec.dtype)
                .with_context(|| format!("{}/{}", self.config, program))?;
        }
        let sw = Stopwatch::new();
        let step_lits: Vec<xla::Literal> =
            step_args.iter().map(literal::to_literal).collect::<Result<_>>()?;
        let marshal_in = sw.elapsed_s();

        let all: Vec<&xla::Literal> = self
            .params
            .iter()
            .chain(self.caches.iter())
            .chain(step_lits.iter())
            .collect();
        let mut parts = self.rt.execute_core(&self.config, program, sig, &all)?;
        self.caches = parts.split_off(parts.len() - self.n_caches);

        let sw_out = Stopwatch::new();
        let outs: Vec<Value> = parts.iter().map(literal::from_literal).collect::<Result<_>>()?;
        self.rt.stats.borrow_mut().marshal_s += marshal_in + sw_out.elapsed_s();
        Ok(outs)
    }

    /// Pull the carried caches to host, let `f` edit them in place, and
    /// re-marshal.  This is the only full-cache copy in the decode loop —
    /// paid on slot-churn events (lane zeroing), not per token.
    pub fn update_caches<F>(&mut self, f: F) -> Result<()>
    where
        F: FnOnce(&mut [Tensor]) -> Result<()>,
    {
        let sw = Stopwatch::new();
        let mut host: Vec<Tensor> = self
            .caches
            .iter()
            .map(|l| literal::from_literal(l)?.into_f32())
            .collect::<Result<_>>()?;
        f(&mut host)?;
        self.caches = host
            .into_iter()
            .map(|t| literal::to_literal(&Value::F32(t)))
            .collect::<Result<_>>()?;
        self.rt.stats.borrow_mut().marshal_s += sw.elapsed_s();
        Ok(())
    }

    /// Host copy of the carried caches (tests / debugging only — this is
    /// the copy the step loop exists to avoid).
    pub fn caches_host(&self) -> Result<Vec<Tensor>> {
        self.caches
            .iter()
            .map(|l| literal::from_literal(l)?.into_f32())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Tensor, TensorI};

    fn art() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn init_and_fwd_tiny() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let tiny = rt.manifest().config("tiny").unwrap().clone();
        // init: seed -> dense params
        let outs = rt.run("tiny", "init", &[Value::I32(TensorI::scalar(42))]).unwrap();
        assert_eq!(outs.len(), tiny.params_dense.len());
        for (v, (name, shape)) in outs.iter().zip(&tiny.params_dense) {
            assert_eq!(v.shape(), shape.as_slice(), "{name}");
        }
        // nll over a zero batch: finite scalar
        let b = tiny.dim("train_batch").unwrap();
        let t = tiny.dim("seq_len").unwrap();
        let mut args = outs;
        args.push(Value::I32(TensorI::zeros(&[b, t])));
        args.push(Value::I32(TensorI::zeros(&[b, t])));
        let loss = rt.run_scalar("tiny", "nll", &args, 0).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // ~uniform at init: close to ln(vocab)
        let vocab = tiny.dim("vocab").unwrap() as f32;
        assert!((loss - vocab.ln()).abs() < 2.0, "loss {loss} vs ln V {}", vocab.ln());
    }

    #[test]
    fn arg_checking_rejects_bad_shapes() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let r = rt.run("tiny", "init", &[Value::F32(Tensor::scalar(1.0))]);
        assert!(r.is_err()); // wrong dtype
        let r2 = rt.run("tiny", "init", &[]);
        assert!(r2.is_err()); // wrong arity
    }

    #[test]
    fn executable_cache_hits() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        rt.run("tiny", "init", &[Value::I32(TensorI::scalar(1))]).unwrap();
        rt.run("tiny", "init", &[Value::I32(TensorI::scalar(2))]).unwrap();
        assert_eq!(rt.stats().compiles, 1);
        assert_eq!(rt.stats().executes, 2);
    }

    #[test]
    fn decode_session_matches_run_prepared() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = crate::coordinator::ops::init_params(&rt, "tiny", 5).unwrap();
        let sig = rt.manifest().config("tiny").unwrap().program("decode_b8").unwrap().clone();
        let cache_shape = sig.inputs.iter().find(|a| a.name.ends_with("_cache"))
            .unwrap().shape.clone();
        let b = cache_shape[1];
        let param_values: Vec<Value> =
            params.flat().iter().map(|&t| Value::F32(t.clone())).collect();
        let toks = Value::I32(TensorI::new(vec![b], (0..b as i32).collect()));
        let poss = Value::I32(TensorI::zeros(&[b]));

        // Reference: one-shot path with explicit zero caches.
        let prepared = rt.prepare(&param_values.iter().collect::<Vec<_>>()).unwrap();
        let rest = vec![
            Value::F32(Tensor::zeros(&cache_shape)),
            Value::F32(Tensor::zeros(&cache_shape)),
            toks.clone(),
            poss.clone(),
        ];
        let want = rt.run_prepared("tiny", "decode_b8", &prepared, &rest).unwrap();

        // Session path: caches owned literal-side.
        let mut dec = DecodeSession::new(&rt, "tiny", "decode_b8", &param_values).unwrap();
        let got = dec.step(&[toks, poss]).unwrap();
        assert_eq!(got.len(), 1, "session returns only the non-carried outputs");
        let a = got[0].as_f32().unwrap();
        let w = want[0].as_f32().unwrap();
        assert_eq!(a.shape(), w.shape());
        assert!(a.max_abs_diff(w) < 1e-5);

        // Carried caches match the reference outputs too.
        let carried = dec.caches_host().unwrap();
        assert_eq!(carried.len(), 2);
        assert!(carried[0].max_abs_diff(want[1].as_f32().unwrap()) < 1e-5);
        assert!(carried[1].max_abs_diff(want[2].as_f32().unwrap()) < 1e-5);

        // update_caches round-trips and edits stick.
        dec.update_caches(|caches| {
            for c in caches.iter_mut() {
                c.data_mut()[0] = 7.5;
            }
            Ok(())
        })
        .unwrap();
        let edited = dec.caches_host().unwrap();
        assert_eq!(edited[0].data()[0], 7.5);
    }
}
