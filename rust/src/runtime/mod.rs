//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the coordinator/serving hot paths.
//!
//! The flow mirrors `/opt/xla-example/load_hlo`:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.  Compiled executables are cached per
//! `(config, program)`; HLO parsing + XLA compilation happen at most once
//! per process.
//!
//! Threading: `Runtime` is deliberately `!Sync` (the underlying C handles
//! have no documented thread-safety story).  The serving layer owns one
//! `Runtime` on a dedicated executor thread and feeds it through channels
//! (see [`crate::serve`]).

pub mod golden;
pub mod literal;

use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::model::manifest::{Manifest, ProgramSig};
use crate::tensor::Value;
use crate::util::Stopwatch;

pub use literal::{from_literal, to_literal};

/// Cumulative execution statistics (perf pass instrumentation).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub compiles: usize,
    pub compile_s: f64,
    pub executes: usize,
    pub execute_s: f64,
    pub marshal_s: f64,
}

pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RunStats>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PjRtClient: {e:?}"))?;
        crate::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Self {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RunStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RunStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = RunStats::default();
    }

    /// Compile (or fetch from cache) a program's executable.
    pub fn executable(&self, config: &str, program: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{config}/{program}");
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let sig = self.manifest.config(config)?.program(program)?;
        let path = self.manifest.hlo_path(sig);
        let sw = Stopwatch::new();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {key}: {e:?}"))?;
        let dt = sw.elapsed_s();
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_s += dt;
        }
        crate::debug!("compiled {key} in {dt:.2}s");
        let rc = Rc::new(exe);
        self.cache.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Execute `config/program` on host values, returning host values.
    ///
    /// Arguments are shape- and dtype-checked against the manifest
    /// signature before anything touches the PJRT boundary, so mismatches
    /// fail with names instead of an opaque XLA error.
    pub fn run(&self, config: &str, program: &str, args: &[Value]) -> Result<Vec<Value>> {
        let sig = self.manifest.config(config)?.program(program)?.clone();
        self.run_with_sig(config, program, &sig, args)
    }

    fn run_with_sig(
        &self,
        config: &str,
        program: &str,
        sig: &ProgramSig,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        if args.len() != sig.inputs.len() {
            bail!(
                "{config}/{program}: expected {} args, got {}",
                sig.inputs.len(),
                args.len()
            );
        }
        for (v, spec) in args.iter().zip(&sig.inputs) {
            literal::check_arg(&spec.name, v, &spec.shape, spec.dtype)
                .with_context(|| format!("{config}/{program}"))?;
        }
        let exe = self.executable(config, program)?;

        let sw = Stopwatch::new();
        let lits: Vec<xla::Literal> =
            args.iter().map(literal::to_literal).collect::<Result<_>>()?;
        let marshal_in = sw.elapsed_s();

        let sw_exec = Stopwatch::new();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("executing {config}/{program}: {e:?}"))?;
        let exec_s = sw_exec.elapsed_s();

        let sw_out = Stopwatch::new();
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {config}/{program}: {e:?}"))?;
        // Programs are lowered with return_tuple=True: always a tuple root.
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {config}/{program}: {e:?}"))?;
        if parts.len() != sig.outputs.len() {
            bail!(
                "{config}/{program}: expected {} outputs, got {}",
                sig.outputs.len(),
                parts.len()
            );
        }
        let outs: Vec<Value> = parts
            .iter()
            .map(literal::from_literal)
            .collect::<Result<_>>()?;
        let marshal_out = sw_out.elapsed_s();

        let mut st = self.stats.borrow_mut();
        st.executes += 1;
        st.execute_s += exec_s;
        st.marshal_s += marshal_in + marshal_out;
        Ok(outs)
    }

    /// Convenience: run and pull a single scalar f32 output by index.
    pub fn run_scalar(&self, config: &str, program: &str, args: &[Value], idx: usize) -> Result<f32> {
        let outs = self.run(config, program, args)?;
        Ok(outs[idx].as_f32()?.item())
    }

    /// Pre-marshal values that stay constant across many calls (model
    /// params during a decode session): pay the host→literal copy once.
    pub fn prepare(&self, values: &[&Value]) -> Result<Vec<xla::Literal>> {
        values.iter().map(|v| literal::to_literal(v)).collect()
    }

    /// Execute with a prepared literal prefix + per-call suffix values.
    /// §Perf optimization: on the decode hot path the parameter literals
    /// dominated marshal time (33–41% of step wall); reusing them cuts it
    /// to the cache/token tensors only.
    pub fn run_prepared(
        &self,
        config: &str,
        program: &str,
        prefix: &[xla::Literal],
        rest: &[Value],
    ) -> Result<Vec<Value>> {
        let sig = self.manifest.config(config)?.program(program)?.clone();
        if prefix.len() + rest.len() != sig.inputs.len() {
            bail!(
                "{config}/{program}: expected {} args, got {} prepared + {}",
                sig.inputs.len(), prefix.len(), rest.len()
            );
        }
        for (v, spec) in rest.iter().zip(&sig.inputs[prefix.len()..]) {
            literal::check_arg(&spec.name, v, &spec.shape, spec.dtype)
                .with_context(|| format!("{config}/{program}"))?;
        }
        let exe = self.executable(config, program)?;
        let sw = Stopwatch::new();
        let rest_lits: Vec<xla::Literal> =
            rest.iter().map(literal::to_literal).collect::<Result<_>>()?;
        let all: Vec<&xla::Literal> = prefix.iter().chain(rest_lits.iter()).collect();
        let marshal_in = sw.elapsed_s();
        let sw_exec = Stopwatch::new();
        let result = exe
            .execute::<&xla::Literal>(&all)
            .map_err(|e| anyhow::anyhow!("executing {config}/{program}: {e:?}"))?;
        let exec_s = sw_exec.elapsed_s();
        let sw_out = Stopwatch::new();
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {config}/{program}: {e:?}"))?;
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {config}/{program}: {e:?}"))?;
        if parts.len() != sig.outputs.len() {
            bail!("{config}/{program}: expected {} outputs, got {}",
                  sig.outputs.len(), parts.len());
        }
        let outs: Vec<Value> = parts.iter().map(literal::from_literal).collect::<Result<_>>()?;
        let marshal_out = sw_out.elapsed_s();
        let mut st = self.stats.borrow_mut();
        st.executes += 1;
        st.execute_s += exec_s;
        st.marshal_s += marshal_in + marshal_out;
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Tensor, TensorI};

    fn art() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn init_and_fwd_tiny() {
        let rt = Runtime::new(&art()).expect("runtime (run `make artifacts` first)");
        let tiny = rt.manifest().config("tiny").unwrap().clone();
        // init: seed -> dense params
        let outs = rt.run("tiny", "init", &[Value::I32(TensorI::scalar(42))]).unwrap();
        assert_eq!(outs.len(), tiny.params_dense.len());
        for (v, (name, shape)) in outs.iter().zip(&tiny.params_dense) {
            assert_eq!(v.shape(), shape.as_slice(), "{name}");
        }
        // nll over a zero batch: finite scalar
        let b = tiny.dim("train_batch").unwrap();
        let t = tiny.dim("seq_len").unwrap();
        let mut args = outs;
        args.push(Value::I32(TensorI::zeros(&[b, t])));
        args.push(Value::I32(TensorI::zeros(&[b, t])));
        let loss = rt.run_scalar("tiny", "nll", &args, 0).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // ~uniform at init: close to ln(vocab)
        let vocab = tiny.dim("vocab").unwrap() as f32;
        assert!((loss - vocab.ln()).abs() < 2.0, "loss {loss} vs ln V {}", vocab.ln());
    }

    #[test]
    fn arg_checking_rejects_bad_shapes() {
        let rt = Runtime::new(&art()).expect("runtime");
        let r = rt.run("tiny", "init", &[Value::F32(Tensor::scalar(1.0))]);
        assert!(r.is_err()); // wrong dtype
        let r2 = rt.run("tiny", "init", &[]);
        assert!(r2.is_err()); // wrong arity
    }

    #[test]
    fn executable_cache_hits() {
        let rt = Runtime::new(&art()).expect("runtime");
        rt.run("tiny", "init", &[Value::I32(TensorI::scalar(1))]).unwrap();
        rt.run("tiny", "init", &[Value::I32(TensorI::scalar(2))]).unwrap();
        assert_eq!(rt.stats().compiles, 1);
        assert_eq!(rt.stats().executes, 2);
    }
}
