//! Typed view of `artifacts/manifest.json` (written by `python -m
//! compile.aot`).  The manifest is the *only* channel through which shape
//! information crosses the Python→Rust boundary; nothing in the Rust tree
//! re-derives a model dimension.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// A manifest advertised a dtype this runtime has no layout for.  Typed
/// (rather than a bare `anyhow!`) so `clover check` can map it to its own
/// diagnostic code without string-matching the message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DTypeError {
    pub got: String,
}

impl std::fmt::Display for DTypeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unsupported dtype {:?} (expected float32|int32)", self.got)
    }
}

impl std::error::Error for DTypeError {}

impl DType {
    pub fn parse(s: &str) -> Result<Self, DTypeError> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(DTypeError { got: other.to_string() }),
        }
    }
}

/// One program argument or result.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One AOT-lowered HLO program.
#[derive(Clone, Debug)]
pub struct ProgramSig {
    pub file: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    pub golden: Option<String>,
}

/// Named parameter layout (ordering == flat argument ordering).
pub type ParamSpec = Vec<(String, Vec<usize>)>;

/// One model configuration (a python `configs.py` preset).
#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub name: String,
    pub kind: String, // "decoder" | "seq2seq"
    pub dims: BTreeMap<String, usize>,
    pub ranks: Vec<usize>,
    /// Chunked-prefill slab widths exported for this config (`prefill_k{K}`
    /// program family); empty for configs or manifests without prefill
    /// artifacts.  Width 1 (the decode program) is implicit and never
    /// listed.
    pub prefill_chunks: Vec<usize>,
    /// Slab widths whose programs emit logits at *every* slab position
    /// (`[B, K, V]`) rather than only the last — the widths a speculative
    /// verify step can score a draft at.  Empty for manifests exported
    /// before the all-position logits change; the serve engine refuses to
    /// speculate on those.
    pub verify_widths: Vec<usize>,
    pub programs: BTreeMap<String, ProgramSig>,
    pub params_dense: ParamSpec,
    pub params_fac: BTreeMap<usize, ParamSpec>,
    pub params_facud: ParamSpec,
    pub params_lora: ParamSpec,
    pub params_dora: ParamSpec,
}

impl ConfigEntry {
    pub fn dim(&self, key: &str) -> Result<usize> {
        self.dims.get(key).copied().with_context(|| format!("config {} missing dim {key}", self.name))
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSig> {
        self.programs.get(name)
            .with_context(|| format!("config {} has no program {name:?}", self.name))
    }

    /// Total element count of a param spec.
    pub fn param_count(spec: &ParamSpec) -> usize {
        spec.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub configs: BTreeMap<String, ConfigEntry>,
}

fn parse_spec(v: &Json) -> Result<ParamSpec> {
    v.as_arr()?
        .iter()
        .map(|e| Ok((e.req("name")?.as_str()?.to_string(), e.req("shape")?.as_shape()?)))
        .collect()
}

fn parse_args(v: &Json) -> Result<Vec<ArgSpec>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(ArgSpec {
                name: e.req("name")?.as_str()?.to_string(),
                shape: e.req("shape")?.as_shape()?,
                dtype: DType::parse(e.req("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let doc = Json::parse(&text).context("parsing manifest.json")?;
        let mut configs = BTreeMap::new();
        for (name, entry) in doc.req("configs")?.as_obj()? {
            let kind = entry.req("kind")?.as_str()?.to_string();
            let mut dims = BTreeMap::new();
            for key in [
                "vocab", "d_model", "n_heads", "n_layers", "seq_len", "d_ff", "d_head",
                "lora_rank", "train_batch", "ud_block", "n_enc_layers", "n_dec_layers",
                "feat_dim", "src_len", "tgt_len", "batch",
            ] {
                if let Some(v) = entry.get(key) {
                    dims.insert(key.to_string(), v.as_usize()?);
                }
            }
            let ranks = entry.req("ranks")?.as_shape()?;
            // Optional: older manifests (and seq2seq configs) have no
            // prefill artifacts; the serve engine then runs width-1 only.
            let prefill_chunks = match entry.get("prefill_chunks") {
                Some(v) => v.as_shape()?,
                None => Vec::new(),
            };
            let verify_widths = match entry.get("verify_widths") {
                Some(v) => v.as_shape()?,
                None => Vec::new(),
            };
            let mut programs = BTreeMap::new();
            for (pname, p) in entry.req("programs")?.as_obj()? {
                programs.insert(
                    pname.clone(),
                    ProgramSig {
                        file: p.req("file")?.as_str()?.to_string(),
                        inputs: parse_args(p.req("inputs")?)?,
                        outputs: parse_args(p.req("outputs")?)?,
                        golden: p.get("golden").map(|g| g.as_str().map(String::from)).transpose()?,
                    },
                );
            }
            let params_dense = match entry.get("params_dense").or_else(|| entry.get("params")) {
                Some(v) => parse_spec(v)?,
                None => Vec::new(),
            };
            let mut params_fac = BTreeMap::new();
            if let Some(pf) = entry.get("params_fac") {
                for (r, spec) in pf.as_obj()? {
                    params_fac.insert(r.parse::<usize>()?, parse_spec(spec)?);
                }
            }
            let params_facud = match entry.get("params_facud") {
                Some(v) => parse_spec(v)?,
                None => Vec::new(),
            };
            let params_lora = match entry.get("params_lora") {
                Some(v) => parse_spec(v)?,
                None => Vec::new(),
            };
            let params_dora = match entry.get("params_dora") {
                Some(v) => parse_spec(v)?,
                None => Vec::new(),
            };
            configs.insert(
                name.clone(),
                ConfigEntry {
                    name: name.clone(),
                    kind,
                    dims,
                    ranks,
                    prefill_chunks,
                    verify_widths,
                    programs,
                    params_dense,
                    params_fac,
                    params_facud,
                    params_lora,
                    params_dora,
                },
            );
        }
        Ok(Manifest { root, configs })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs.get(name).with_context(|| {
            format!("manifest has no config {name:?} (have: {:?})",
                    self.configs.keys().collect::<Vec<_>>())
        })
    }

    pub fn hlo_path(&self, sig: &ProgramSig) -> PathBuf {
        self.root.join(&sig.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let Ok(m) = Manifest::load(art_dir()) else {
            eprintln!("SKIP (no artifacts): run `make artifacts` first");
            return;
        };
        let tiny = m.config("tiny").unwrap();
        assert_eq!(tiny.kind, "decoder");
        assert_eq!(tiny.dim("d_model").unwrap(), 64);
        assert_eq!(tiny.dim("d_head").unwrap(), 16);
        assert!(tiny.ranks.contains(&16));
        // Prefill slab programs are discoverable through the manifest: one
        // `prefill_k{K}_b{B}` per exported chunk width, cache block shared
        // with the decode program of the same batch.
        assert!(tiny.prefill_chunks.contains(&8), "{:?}", tiny.prefill_chunks);
        // Every prefill width is a verify width: the slab programs emit
        // all-position logits [B, K, V] (the speculative-verify contract).
        assert_eq!(tiny.verify_widths, tiny.prefill_chunks);
        let vocab = tiny.dim("vocab").unwrap();
        for &ck in &tiny.prefill_chunks {
            let pf = tiny.program(&format!("prefill_k{ck}_b8")).unwrap();
            let toks = pf.inputs.iter().find(|a| a.name == "tokens").unwrap();
            assert_eq!(toks.shape, vec![8, ck]);
            assert_eq!(pf.outputs[0].shape, vec![8, ck, vocab], "all-position logits");
            let dec = tiny.program("decode_b8").unwrap();
            assert_eq!(dec.outputs[0].shape, vec![8, vocab], "decode logits stay [B, V]");
            let cache = |sig: &ProgramSig| {
                sig.inputs.iter().find(|a| a.name.ends_with("_cache")).unwrap().shape.clone()
            };
            assert_eq!(cache(pf), cache(dec));
        }
        let fwd = tiny.program("fwd").unwrap();
        assert_eq!(fwd.inputs.last().unwrap().dtype, DType::I32);
        assert_eq!(fwd.outputs[0].name, "logits");
        // dense spec: 14 tensors, starts with tok_emb
        assert_eq!(tiny.params_dense[0].0, "tok_emb");
        assert_eq!(tiny.params_dense.len(), 14);
        // factorized spec exists for full rank
        assert!(tiny.params_fac.contains_key(&16));
    }

    #[test]
    fn param_count_matches_formula() {
        let Ok(m) = Manifest::load(art_dir()) else {
            eprintln!("SKIP (no artifacts): run `make artifacts` first");
            return;
        };
        let tiny = m.config("tiny").unwrap();
        let (v, d, t, l, f) = (256usize, 64usize, 64usize, 2usize, 256usize);
        let expect = v * d + t * d + l * (4 * d * d + 2 * d * f + 4 * d) + 2 * d;
        assert_eq!(ConfigEntry::param_count(&tiny.params_dense), expect);
    }

    #[test]
    fn missing_config_is_error() {
        let Ok(m) = Manifest::load(art_dir()) else {
            eprintln!("SKIP (no artifacts): run `make artifacts` first");
            return;
        };
        assert!(m.config("nope").is_err());
    }
}
