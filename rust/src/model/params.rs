//! Named parameter sets with a canonical flat ordering.
//!
//! A [`ParamSet`] pairs a manifest [`ParamSpec`] (ordering + shapes) with
//! the actual tensors.  The coordinator passes `flat()` slices to the
//! runtime, and rebuilds updated sets from program outputs by name.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

use super::manifest::ParamSpec;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ParamSet {
    spec: ParamSpec,
    map: BTreeMap<String, Tensor>,
}

impl ParamSet {
    /// All-zeros set for a spec (optimizer moments start here).
    pub fn zeros(spec: &ParamSpec) -> Self {
        let map = spec.iter()
            .map(|(n, s)| (n.clone(), Tensor::zeros(s)))
            .collect();
        Self { spec: spec.clone(), map }
    }

    /// Gaussian init (used for adapter A matrices and test fixtures).
    pub fn gaussian(spec: &ParamSpec, rng: &mut Rng, std: f32) -> Self {
        let map = spec.iter()
            .map(|(n, s)| {
                let numel = s.iter().product();
                (n.clone(), Tensor::new(s.clone(), rng.normal_vec(numel, std)))
            })
            .collect();
        Self { spec: spec.clone(), map }
    }

    /// Build from tensors in spec order.
    pub fn from_flat(spec: &ParamSpec, tensors: Vec<Tensor>) -> Result<Self> {
        if tensors.len() != spec.len() {
            bail!("expected {} tensors, got {}", spec.len(), tensors.len());
        }
        let mut map = BTreeMap::new();
        for ((name, shape), t) in spec.iter().zip(tensors) {
            if t.shape() != shape.as_slice() {
                bail!("param {name}: shape {:?} != spec {:?}", t.shape(), shape);
            }
            map.insert(name.clone(), t);
        }
        Ok(Self { spec: spec.clone(), map })
    }

    pub fn spec(&self) -> &ParamSpec {
        &self.spec
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.spec.iter().map(|(n, _)| n.as_str())
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).with_context(|| format!("no param {name:?}"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let (_, shape) = self.spec.iter().find(|(n, _)| n == name)
            .with_context(|| format!("param {name:?} not in spec"))?;
        if t.shape() != shape.as_slice() {
            bail!("param {name}: shape {:?} != spec {:?}", t.shape(), shape);
        }
        self.map.insert(name.to_string(), t);
        Ok(())
    }

    /// Tensors in spec order (for marshalling to program arguments).
    pub fn flat(&self) -> Vec<&Tensor> {
        self.spec.iter().map(|(n, _)| &self.map[n]).collect()
    }

    pub fn into_map(self) -> BTreeMap<String, Tensor> {
        self.map
    }

    pub fn n_params(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Total squared difference against another set (drift diagnostics).
    pub fn max_abs_diff(&self, other: &ParamSet) -> f32 {
        let mut worst = 0.0f32;
        for (n, _) in &self.spec {
            worst = worst.max(self.map[n].max_abs_diff(&other.map[n]));
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ParamSpec {
        vec![("a".into(), vec![2, 2]), ("b".into(), vec![3])]
    }

    #[test]
    fn zeros_and_flat_order() {
        let p = ParamSet::zeros(&spec());
        assert_eq!(p.n_params(), 7);
        let flat = p.flat();
        assert_eq!(flat[0].shape(), &[2, 2]);
        assert_eq!(flat[1].shape(), &[3]);
    }

    #[test]
    fn from_flat_validates() {
        let good = ParamSet::from_flat(&spec(), vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[3])]);
        assert!(good.is_ok());
        let bad = ParamSet::from_flat(&spec(), vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[4])]);
        assert!(bad.is_err());
        let short = ParamSet::from_flat(&spec(), vec![Tensor::zeros(&[2, 2])]);
        assert!(short.is_err());
    }

    #[test]
    fn set_checks_shape() {
        let mut p = ParamSet::zeros(&spec());
        assert!(p.set("a", Tensor::zeros(&[2, 2])).is_ok());
        assert!(p.set("a", Tensor::zeros(&[2, 3])).is_err());
        assert!(p.set("zz", Tensor::zeros(&[1])).is_err());
    }

    #[test]
    fn gaussian_is_seeded() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = ParamSet::gaussian(&spec(), &mut r1, 0.1);
        let b = ParamSet::gaussian(&spec(), &mut r2, 0.1);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }
}
