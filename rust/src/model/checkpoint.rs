//! Checkpoint format `CLVR1`: a dead-simple binary container for named f32
//! tensors plus a small string-keyed metadata block.
//!
//! Layout (all little-endian):
//!
//! ```text
//! magic   b"CLVR1\0"                         6 bytes
//! n_meta  u32; then n_meta × (str key, str value)
//! n_tens  u32; then n_tens × (str name, u32 ndim, ndim × u64 dims,
//!                             numel × f32 data)
//! str     := u32 length + utf-8 bytes
//! ```
//!
//! Checkpoints store the *dense* or *factorized* parameter map together
//! with metadata like the config name, training step, and the CLOVER rank —
//! enough for `clover prune` / `clover finetune` / `clover serve` to resume
//! from each other's outputs.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::tensor::Tensor;

const MAGIC: &[u8; 6] = b"CLVR1\0";

#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub meta: BTreeMap<String, String>,
    pub tensors: BTreeMap<String, Tensor>,
}

fn write_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str<R: Read>(r: &mut R) -> Result<String> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > 1 << 20 {
        bail!("unreasonable string length {len}");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_meta(mut self, key: &str, value: &str) -> Self {
        self.meta.insert(key.into(), value.into());
        self
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("checkpoint missing tensor {name:?}"))
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        let v = self.meta.get(key).with_context(|| format!("checkpoint missing meta {key:?}"))?;
        Ok(v.parse::<usize>()?)
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(std::fs::File::create(path.as_ref())?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.meta.len() as u32).to_le_bytes())?;
        for (k, v) in &self.meta {
            write_str(&mut w, k)?;
            write_str(&mut w, v)?;
        }
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            write_str(&mut w, name)?;
            w.write_all(&(t.ndim() as u32).to_le_bytes())?;
            for &d in t.shape() {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            // Bulk-copy the f32 payload.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4)
            };
            w.write_all(bytes)?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let mut r = BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 6];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{:?}: not a CLVR1 checkpoint", path.as_ref());
        }
        let mut n4 = [0u8; 4];
        r.read_exact(&mut n4)?;
        let n_meta = u32::from_le_bytes(n4) as usize;
        let mut meta = BTreeMap::new();
        for _ in 0..n_meta {
            let k = read_str(&mut r)?;
            let v = read_str(&mut r)?;
            meta.insert(k, v);
        }
        r.read_exact(&mut n4)?;
        let n_tens = u32::from_le_bytes(n4) as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n_tens {
            let name = read_str(&mut r)?;
            r.read_exact(&mut n4)?;
            let ndim = u32::from_le_bytes(n4) as usize;
            if ndim > 16 {
                bail!("tensor {name}: unreasonable ndim {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut d8 = [0u8; 8];
                r.read_exact(&mut d8)?;
                shape.push(u64::from_le_bytes(d8) as usize);
            }
            let numel: usize = shape.iter().product();
            let mut bytes = vec![0u8; numel * 4];
            r.read_exact(&mut bytes)?;
            let mut data = vec![0.0f32; numel];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(),
                    data.as_mut_ptr() as *mut u8,
                    bytes.len(),
                );
            }
            tensors.insert(name, Tensor::new(shape, data));
        }
        Ok(Self { meta, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("clover_ckpt_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(0);
        let mut ck = Checkpoint::new().with_meta("config", "tiny").with_meta("step", "100");
        ck.insert("w", Tensor::new(vec![3, 4], rng.normal_vec(12, 1.0)));
        ck.insert("scalar", Tensor::scalar(7.5));
        let path = tmp("roundtrip");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.meta["config"], "tiny");
        assert_eq!(back.meta_usize("step").unwrap(), 100);
        assert_eq!(back.get("w").unwrap(), ck.get("w").unwrap());
        assert_eq!(back.get("scalar").unwrap().item(), 7.5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOTCKPT").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_tensor_is_error() {
        let ck = Checkpoint::new();
        assert!(ck.get("nope").is_err());
    }

    #[test]
    fn large_tensor_roundtrip() {
        let mut rng = Rng::new(1);
        let mut ck = Checkpoint::new();
        ck.insert("big", Tensor::new(vec![128, 257], rng.normal_vec(128 * 257, 0.5)));
        let path = tmp("large");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.get("big").unwrap(), ck.get("big").unwrap());
        std::fs::remove_file(path).ok();
    }
}
