//! Model-state plumbing: the AOT manifest, named parameter sets, and the
//! `CLVR1` checkpoint format.
//!
//! The actual compute graphs live in `artifacts/` (lowered from
//! `python/compile/model.py`); this module owns their *state* on the Rust
//! side and the metadata needed to marshal it.

pub mod checkpoint;
pub mod manifest;
pub mod params;

pub use checkpoint::Checkpoint;
pub use manifest::{ArgSpec, ConfigEntry, DType, DTypeError, Manifest, ParamSpec, ProgramSig};
pub use params::ParamSet;

use anyhow::Result;

/// Save a [`ParamSet`] as a checkpoint with standard metadata.
pub fn save_params(
    params: &ParamSet,
    config_name: &str,
    kind: &str,
    step: usize,
    path: &std::path::Path,
) -> Result<()> {
    let mut ck = Checkpoint::new()
        .with_meta("config", config_name)
        .with_meta("kind", kind)
        .with_meta("step", &step.to_string());
    for (name, _) in params.spec() {
        ck.insert(name, params.get(name)?.clone());
    }
    ck.save(path)
}

/// Load a [`ParamSet`] for `spec` from a checkpoint (shape-checked).
pub fn load_params(ck: &Checkpoint, spec: &ParamSpec) -> Result<ParamSet> {
    let tensors = spec.iter()
        .map(|(n, _)| ck.get(n).cloned())
        .collect::<Result<Vec<_>>>()?;
    ParamSet::from_flat(spec, tensors)
}

/// Resolve a checkpoint to serving state: its parameters plus the name of
/// the `B`-lane decode artifact that matches its kind — `decode_b{B}` for
/// dense, `decode_fac_r{r}_b{B}` for a factorized checkpoint (rank from
/// metadata).  The single owner of this naming convention; the CLI and
/// the server gateway both resolve through here.
pub fn decode_params_for_checkpoint(
    ck: &Checkpoint,
    entry: &ConfigEntry,
    batch_slots: usize,
) -> Result<(ParamSet, String)> {
    use anyhow::Context;
    if ck.meta.get("kind").map(|s| s.as_str()) == Some("factorized") {
        let r = ck.meta_usize("rank")?;
        let spec = entry
            .params_fac
            .get(&r)
            .with_context(|| format!("config {} has no rank-{r} param spec", entry.name))?;
        Ok((load_params(ck, spec)?, format!("decode_fac_r{r}_b{batch_slots}")))
    } else {
        Ok((load_params(ck, &entry.params_dense)?, format!("decode_b{batch_slots}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn params_checkpoint_roundtrip() {
        let spec: ParamSpec = vec![("x".into(), vec![4]), ("y".into(), vec![2, 2])];
        let mut rng = Rng::new(2);
        let p = ParamSet::gaussian(&spec, &mut rng, 1.0);
        let path = std::env::temp_dir().join(format!("clover_mod_rt_{}", std::process::id()));
        save_params(&p, "tiny", "dense", 7, &path).unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.meta["kind"], "dense");
        let back = load_params(&ck, &spec).unwrap();
        assert_eq!(back.max_abs_diff(&p), 0.0);
        std::fs::remove_file(path).ok();
    }
}
