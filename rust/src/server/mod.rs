//! Streaming server front-end: the thread-owning layer above [`crate::serve`].
//!
//! The serving engine is a library call — `Engine::serve_all` takes a
//! complete request vector and blocks until every completion returns.
//! This module turns it into a *server*: traffic is fed in over time
//! through channels, tokens stream out as they are sampled, requests can
//! be cancelled (or expire) mid-decode with their KV lane reclaimed
//! between decode steps, and a router spreads live traffic across engines
//! compiled at different CLOVER pruning ranks.
//!
//! * [`gateway`] — the thread-owning core.  [`Gateway::spawn`] starts a
//!   worker thread that owns its `Runtime` + `Engine` (the PJRT handles
//!   are not `Sync`, so they never cross threads) and drives
//!   `Engine::serve_open`.  Clients reach it only through channels: a
//!   *bounded* ingress channel (`submit` blocks when full — backpressure;
//!   `try_submit` refuses with [`SubmitError::Saturated`]) and an
//!   unbounded control channel for cancels/shutdown, so control is never
//!   stuck behind a full queue.
//! * [`stream`] — per-request event streams.  Each submission returns a
//!   [`RequestStream`] that yields `Queued → Started → Token{pos,id}… →
//!   Done{completion} | Cancelled`, with `Token` events delivered as
//!   tokens are sampled rather than at wave end.  Every submitted request
//!   receives exactly one terminal event.
//! * [`cancel`] — [`CancelToken`]s clients fire, per-request deadlines,
//!   and the [`CancelRegistry`] the gateway keeps them in; the engine
//!   retires cancelled sessions between decode steps, freeing their KV
//!   lane for the next waiter without skipping a step.
//! * [`router`] — the fleet scheduler: rank-aware dispatch across several
//!   gateways (e.g. dense / r=8 / r=4).  Each request goes to the gateway
//!   minimizing `(in_flight + 1 + queued_prefill_tokens + fresh_prompt_tokens)
//!   × KvConfig::bytes_per_token`: pending prefill is weighted in *tokens*
//!   (a 512-token prompt is 256× the work of a 2-token one), pruning
//!   rank shrinks per-token KV cost by r/d, and a prompt's
//!   `fresh_prompt_tokens` are discounted by the prefix its shadow
//!   directory says a gateway already caches ([`Router::pick_for`]).  On
//!   top of placement: queued-request migration off saturated engines
//!   ([`Router::rebalance`]), interactive-vs-batch degradation
//!   ([`Router::submit_classed`], [`TrafficClass`]), and load shedding
//!   ([`SubmitError::Overloaded`] at `GatewayConfig::max_pending`).
//!
//! Engines behind a gateway run the chunked-prefill slab API by default
//! (cap it per engine with [`EngineSpec::with_prefill_chunk`]); a
//! deadline or cancel landing while a request is still *prefilling*
//! retires it with the untouched prompt as its partial row and frees the
//! lane for the same iteration's admission pass.  [`EngineSpec::stub`]
//! runs a gateway over the deterministic host-side stub backend — the
//! full channel/stream/cancel stack without a PJRT runtime.
//!
//! A gateway can also host a **speculative draft+verify pair**
//! ([`EngineSpec::with_speculative`]): the worker builds the target
//! engine *and* a lower-rank draft engine, opted-in greedy requests
//! decode via draft → verify → accept/rollback rounds, and the gateway
//! reports the pair's *combined* per-token KV cost — so the router's
//! score correctly treats it as two engines' worth of cache pinned per
//! admitted token.

pub mod cancel;
pub mod gateway;
pub mod router;
pub mod stream;

pub use cancel::{CancelRegistry, CancelToken};
pub use gateway::{
    DraftSource, EngineSpec, Gateway, GatewayConfig, Obs, ParamSource, SpecSpec, SubmitError,
    Ticket,
};
pub use router::{BreakerConfig, Health, Router, TrafficClass};
pub use stream::{RequestStream, StreamEvent, StreamOutcome, TryNext};
