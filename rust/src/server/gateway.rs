//! The thread-owning gateway: channels in, events out, one engine inside.
//!
//! [`Gateway::spawn`] starts a worker thread that builds its own
//! [`Runtime`] + [`Engine`] (the PJRT handles are not `Sync`, so they are
//! born and die on that thread) and parks in [`Engine::serve_open`].  All
//! interaction crosses two channels:
//!
//! * **ingress** — a *bounded* `sync_channel` of submissions.  This is the
//!   admission/backpressure point: [`Gateway::submit`] blocks while the
//!   queue is full, [`Gateway::try_submit`] refuses with
//!   [`SubmitError::Saturated`].
//! * **control** — an unbounded channel for cancels and shutdown, so
//!   control is never stuck behind a full ingress queue.
//!
//! Between decode steps the worker's [`StepHook`] drains both channels:
//! new submissions enter the engine's batcher (blocking on the ingress
//! channel when the engine is fully idle, so an empty server sleeps), and
//! cancels/deadlines retire sessions with their KV lane freed for the same
//! iteration's admission pass.
//!
//! Lifecycle guarantee: every submission accepted by `submit`/`try_submit`
//! flows through the engine and receives exactly one terminal event —
//! `Done` when it completes, `Cancelled` on token fire or deadline
//! expiry.  [`Gateway::join`] shuts down gracefully: ingress closes,
//! everything already accepted is served to completion (cancels and
//! deadlines stay effective during the drain), and the worker's final
//! [`ServeMetrics`] comes back — so the engine's metrics account for
//! every accepted request, pre-cancelled ones included.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::{Duration, Instant};

// Sync primitives come through the shim so the loom lane models the
// worker's protocols with the same types this build links.
use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::thread::{self, JoinHandle};
use crate::util::sync::{Arc, Mutex};

use crate::coordinator::ops;
use crate::model::params::ParamSet;
use crate::model::{decode_params_for_checkpoint, load_params, Checkpoint};
use crate::obs::{Clock, Registry, SpanEvent, SpanPoint, StepEvent, TraceSink};
use crate::runtime::stub::{FaultPlan, StubSpec};
use crate::runtime::Runtime;
use crate::serve::{
    BatchPolicy, CancelReason, Cancellation, Completion, Engine, FailReason, KvCodecSpec, Request,
    RetryPolicy, SamplingParams, ServeMetrics, SpecConfig, StepHook,
};

use super::cancel::{CancelRegistry, CancelToken};
use super::stream::{RequestStream, StreamEvent};

/// How often the idle worker wakes to check the control channel while
/// blocked on ingress (std mpsc has no select; cancels and shutdown stay
/// responsive at this granularity without busy-spinning).
const IDLE_POLL_TICK: Duration = Duration::from_millis(5);

/// Where the worker gets its engine parameters.
#[derive(Clone, Debug)]
pub enum ParamSource {
    /// Fresh dense params from the artifact `init` program.
    Init { seed: i32 },
    /// Fresh dense params, CLOVER-pruned to `ratio` (the pruner picks the
    /// rank, which selects the `decode_fac_r{r}_b{B}` artifact).
    InitPruned { seed: i32, ratio: f64, method: String },
    /// A `.clvr` checkpoint, dense or factorized (rank from metadata).
    Checkpoint { path: String },
    /// No parameters at all: the deterministic host-side stub backend
    /// ([`crate::runtime::stub`]) — gateway/router behaviour without a
    /// PJRT runtime (tests, bare-checkout benches).
    Stub(StubSpec),
}

/// Where a speculative engine's *draft* model comes from.
#[derive(Clone, Debug)]
pub enum DraftSource {
    /// A stub draft (stub engines only) — typically the target's
    /// [`StubSpec`] with a lower rank, making it a spectrum truncation of
    /// the target.
    Stub(StubSpec),
    /// CLOVER-prune the engine's dense parameters to (approximately)
    /// `rank` and draft on the `decode_fac_r{rank}` artifact family.
    /// Requires a dense parameter source (`Init`, `InitPruned`'s seed, or
    /// a dense checkpoint).
    PrunedRank { rank: usize },
}

/// Draft + policy for a speculative (draft+verify) engine pair.
#[derive(Clone, Debug)]
pub struct SpecSpec {
    pub draft: DraftSource,
    pub cfg: SpecConfig,
}

/// Everything a worker thread needs to build its engine from scratch —
/// plain data, because the engine itself cannot cross threads.
#[derive(Clone, Debug)]
pub struct EngineSpec {
    pub artifacts_dir: String,
    pub preset: String,
    /// Batch lanes of the decode artifact family (`decode_b{B}`).
    pub batch_slots: usize,
    pub source: ParamSource,
    /// Cap on the chunked-prefill slab width (`Some(1)` disables
    /// chunking, `None` keeps every width the manifest exports) — see
    /// [`Engine::with_prefill_chunk`].
    pub prefill_chunk: Option<usize>,
    /// Attach a draft model for self-speculative decoding (the gateway
    /// then hosts a draft+verify *pair*, and reports the combined KV cost
    /// to the router).
    pub speculative: Option<SpecSpec>,
    /// Per-step token budget (prefill-aware admission) — see
    /// [`Engine::with_max_step_tokens`].
    pub max_step_tokens: Option<usize>,
    /// KV page codec the engine stores its cache through — identity or
    /// CLOVER-factored with optional per-layer rank budgets.  Validated
    /// against the engine's geometry inside the worker
    /// ([`Engine::with_kv_codec`]), so a bad budget list fails the spawn,
    /// not the first request.  The router sees the compressed cost via
    /// [`Gateway::kv_bytes_per_token`].
    pub kv_codec: KvCodecSpec,
    /// Radix prefix cache block size in tokens
    /// ([`Engine::with_prefix_cache`]): shared prompt prefixes prefill
    /// once and later requests attach copy-on-write.  Stub engines only;
    /// mutually exclusive with `speculative` — both validated at spawn.
    pub prefix_cache_block: Option<usize>,
    /// Clock the whole gateway reads: the worker's engine (stub step
    /// delays, step timestamps, deadline expiry) and the handle's submit
    /// stamping.  Wall by default; a [`Clock::manual`] makes the gateway
    /// fully virtual-time — see [`crate::obs::clock`].
    pub clock: Clock,
    /// Transient-fault retry policy for the worker's engine (CLI
    /// `--retry-budget`) — see [`Engine::with_retry_policy`].
    pub retry: RetryPolicy,
}

impl EngineSpec {
    pub fn dense(artifacts_dir: &str, preset: &str, batch_slots: usize, seed: i32) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            preset: preset.into(),
            batch_slots,
            source: ParamSource::Init { seed },
            prefill_chunk: None,
            speculative: None,
            max_step_tokens: None,
            kv_codec: KvCodecSpec::Identity,
            prefix_cache_block: None,
            clock: Clock::wall(),
            retry: RetryPolicy::default(),
        }
    }

    pub fn pruned(
        artifacts_dir: &str,
        preset: &str,
        batch_slots: usize,
        seed: i32,
        ratio: f64,
    ) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            preset: preset.into(),
            batch_slots,
            source: ParamSource::InitPruned { seed, ratio, method: "clover".into() },
            prefill_chunk: None,
            speculative: None,
            max_step_tokens: None,
            kv_codec: KvCodecSpec::Identity,
            prefix_cache_block: None,
            clock: Clock::wall(),
            retry: RetryPolicy::default(),
        }
    }

    pub fn checkpoint(artifacts_dir: &str, preset: &str, batch_slots: usize, path: &str) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            preset: preset.into(),
            batch_slots,
            source: ParamSource::Checkpoint { path: path.into() },
            prefill_chunk: None,
            speculative: None,
            max_step_tokens: None,
            kv_codec: KvCodecSpec::Identity,
            prefix_cache_block: None,
            clock: Clock::wall(),
            retry: RetryPolicy::default(),
        }
    }

    /// A stub-backed engine (no artifacts, no PJRT) — the serving stack's
    /// behaviour with the model math replaced by
    /// [`crate::runtime::stub::StubModel`].
    pub fn stub(spec: StubSpec) -> Self {
        // Adopt the stub's own clock so a manual-clock StubSpec keeps its
        // timeline without also needing `with_clock` here.
        let clock = spec.clock.clone();
        Self {
            artifacts_dir: String::new(),
            preset: "stub".into(),
            batch_slots: spec.batch_slots,
            source: ParamSource::Stub(spec),
            prefill_chunk: None,
            speculative: None,
            max_step_tokens: None,
            kv_codec: KvCodecSpec::Identity,
            prefix_cache_block: None,
            clock,
            retry: RetryPolicy::default(),
        }
    }

    /// Cap (or with `Some(1)`, disable) chunked prefill for this engine.
    pub fn with_prefill_chunk(mut self, cap: Option<usize>) -> Self {
        self.prefill_chunk = cap;
        self
    }

    /// Attach a draft model: the worker builds a speculative draft+verify
    /// pair instead of a single engine.
    pub fn with_speculative(mut self, draft: DraftSource, cfg: SpecConfig) -> Self {
        self.speculative = Some(SpecSpec { draft, cfg });
        self
    }

    /// Cap one fused step's summed slab tokens (prefill-aware admission).
    pub fn with_max_step_tokens(mut self, cap: Option<usize>) -> Self {
        self.max_step_tokens = cap;
        self
    }

    /// Store the KV cache through `codec` (CLI `--kv-codec` /
    /// `--kv-layer-budgets`).  Geometry validation happens in the worker
    /// at engine construction.
    pub fn with_kv_codec(mut self, codec: KvCodecSpec) -> Self {
        self.kv_codec = codec;
        self
    }

    /// Enable the radix prefix cache with `block`-token nodes (CLI
    /// `--prefix-cache-block`).  Alignment and backing validation happen
    /// in the worker at engine construction — a bad block fails the
    /// spawn, not the first request.
    pub fn with_prefix_cache(mut self, block: Option<usize>) -> Self {
        self.prefix_cache_block = block;
        self
    }

    /// Read time from `clock` everywhere this gateway measures it — the
    /// worker's engine and the handle's submit/deadline stamping.
    pub fn with_clock(mut self, clock: Clock) -> Self {
        self.clock = clock;
        self
    }

    /// Retry transient step faults under `retry` (CLI `--retry-budget`)
    /// instead of the default 3-attempt / 1ms-backoff policy.
    pub fn with_retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Arm the stub backend's deterministic fault plan (CLI
    /// `--fault-plan`).  Stub engines only — fault injection drives chaos
    /// tests, not devices — so any other source fails here, at spec
    /// construction, not inside the worker.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Result<Self> {
        let ParamSource::Stub(spec) = &mut self.source else {
            bail!("--fault-plan requires the stub backing — fault injection drives chaos tests, not devices");
        };
        spec.fault_plan = plan;
        Ok(self)
    }
}

/// The replacement engine a supervisor builds must not inherit its
/// predecessor's death sentence: scheduled fatal/crash faults fire once
/// per plan, while transient noise, latency spikes, and poisoned rows
/// keep running (they are exactly what the retry and quarantine layers
/// absorb).  No-op for artifact engines.
fn defuse_fault_plan(spec: &mut EngineSpec) {
    if let ParamSource::Stub(s) = &mut spec.source {
        s.fault_plan.fatal_after_steps = None;
        s.fault_plan.crash_after_steps = None;
    }
}

/// Best-effort text of a panic payload (`&str` and `String` cover
/// everything `panic!` in this crate produces).
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// Build the worker's engine from its spec (plus the thread's [`Runtime`]
/// for artifact engines).  Called once at spawn and again on every
/// supervisor restart — the runtime outlives the engines it backs.
fn build_worker_engine<'rt>(spec: &EngineSpec, rt: Option<&'rt Runtime>) -> Result<Engine<'rt>> {
    let engine = if let ParamSource::Stub(stub_spec) = &spec.source {
        let mut engine = Engine::new_stub(stub_spec.clone())
            .with_prefill_chunk(spec.prefill_chunk)
            .with_max_step_tokens(spec.max_step_tokens)
            .with_kv_codec(spec.kv_codec.clone())
            .and_then(|e| e.with_prefix_cache(spec.prefix_cache_block))?;
        if let Some(sp) = &spec.speculative {
            let DraftSource::Stub(draft) = &sp.draft else {
                bail!("stub engines take DraftSource::Stub drafts");
            };
            engine = engine.with_speculative_stub(draft.clone(), sp.cfg.clone())?;
        }
        engine
    } else {
        let rt = rt.ok_or_else(|| anyhow!("artifact engines need a Runtime"))?;
        let (params, program) = build_params(spec, rt)?;
        let mut engine = Engine::new(rt, &spec.preset, &program, params)?
            .with_prefill_chunk(spec.prefill_chunk)
            .with_max_step_tokens(spec.max_step_tokens)
            .with_kv_codec(spec.kv_codec.clone())?
            .with_prefix_cache(spec.prefix_cache_block)?;
        if let Some(sp) = &spec.speculative {
            engine = match &sp.draft {
                DraftSource::Stub(_) => {
                    bail!("PJRT engines take DraftSource::PrunedRank drafts")
                }
                DraftSource::PrunedRank { rank } => {
                    let (dparams, dprog) = build_draft(spec, rt, *rank)?;
                    engine.with_speculative(&dprog, dparams, sp.cfg.clone())?
                }
            };
        }
        engine
    };
    // The spec's clock wins over a StubSpec's own, so `with_clock` on the
    // EngineSpec rules every timeline.
    Ok(engine.with_retry_policy(spec.retry).with_clock(spec.clock.clone()))
}

/// Shared observability sinks a gateway publishes into: a metrics
/// [`Registry`] whose series carry a `{gateway="NAME"}` label, and a
/// [`TraceSink`] fed every step and span event the worker's engine emits.
/// `Obs` is cheap to clone and clones share the same sinks — hand one to
/// several gateways (or a whole [`super::Router`] fleet) to aggregate
/// them, then read Prometheus text / JSON / Chrome traces from the
/// controlling thread while the workers serve.
#[derive(Clone, Default)]
pub struct Obs {
    pub registry: Arc<Registry>,
    pub trace: Arc<Mutex<TraceSink>>,
}

/// Resolve an [`EngineSpec`]'s parameters and decode program name.
fn build_params(spec: &EngineSpec, rt: &Runtime) -> Result<(ParamSet, String)> {
    let entry = rt.manifest().config(&spec.preset)?.clone();
    let b = spec.batch_slots;
    match &spec.source {
        ParamSource::Init { seed } => {
            Ok((ops::init_params(rt, &spec.preset, *seed)?, format!("decode_b{b}")))
        }
        ParamSource::InitPruned { seed, ratio, method } => {
            let dense = ops::init_params(rt, &spec.preset, *seed)?;
            let (fac, r) = ops::prune_to_ratio(&entry, &dense, *ratio, method)?;
            Ok((fac, format!("decode_fac_r{r}_b{b}")))
        }
        ParamSource::Checkpoint { path } => {
            let ck = Checkpoint::load(path)?;
            decode_params_for_checkpoint(&ck, &entry, b)
        }
        ParamSource::Stub(_) => bail!("stub engines have no artifact params"),
    }
}

/// Resolve a [`DraftSource::PrunedRank`] draft: CLOVER-prune the spec's
/// *dense* parameters to (approximately) `rank` and name the factored
/// decode program the draft runs on.
fn build_draft(spec: &EngineSpec, rt: &Runtime, rank: usize) -> Result<(ParamSet, String)> {
    let entry = rt.manifest().config(&spec.preset)?.clone();
    let b = spec.batch_slots;
    let dense = match &spec.source {
        ParamSource::Init { seed } | ParamSource::InitPruned { seed, .. } => {
            ops::init_params(rt, &spec.preset, *seed)?
        }
        ParamSource::Checkpoint { path } => {
            let ck = Checkpoint::load(path)?;
            if ck.meta.get("kind").map(|s| s.as_str()) == Some("factorized") {
                bail!("draft pruning needs the dense parameters — checkpoint is factorized");
            }
            load_params(&ck, &entry.params_dense)?
        }
        ParamSource::Stub(_) => bail!("stub engines take DraftSource::Stub drafts"),
    };
    let d_head = entry.dim("d_head")?;
    if rank == 0 || rank >= d_head {
        bail!("draft rank {rank} must be in 1..{d_head} (below the dense head dim)");
    }
    let ratio = 1.0 - rank as f64 / d_head as f64;
    let (fac, r) = ops::prune_to_ratio(&entry, &dense, ratio, "clover")?;
    Ok((fac, format!("decode_fac_r{r}_b{b}")))
}

/// What the worker reports once its engine is up.
struct Ready {
    rank: usize,
    /// Combined per-token KV cost — target cache plus the draft cache for
    /// a speculative pair ([`Engine::kv_bytes_per_token_total`]).
    kv_bytes_per_token: usize,
    /// The draft model's rank, when this gateway hosts a speculative
    /// pair.
    draft_rank: Option<usize>,
}

#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Bounded ingress depth — the backpressure point.
    pub queue_capacity: usize,
    pub policy: BatchPolicy,
    /// Load-shedding cap on accepted-but-not-terminal requests.  Beyond
    /// it, `submit`/`try_submit` refuse with [`SubmitError::Overloaded`]
    /// *before* an id or a stream is allocated — the caller sheds or
    /// retries elsewhere instead of deepening an already-hopeless queue.
    /// `None` (the default) keeps the classic behaviour: backpressure
    /// only, via the bounded ingress channel.
    pub max_pending: Option<usize>,
    /// Supervisor restart budget: how many times a dead engine (fatal
    /// step error or a panic caught around the serve loop) is rebuilt
    /// with every interrupted request replayed losslessly — resubmitted
    /// as prompt ⧺ already-streamed tokens, so the client's stream simply
    /// resumes.  `0` disables supervision: a backend death delivers a
    /// terminal [`StreamEvent::Failed`] to every in-flight request.
    pub max_restarts: usize,
    /// When the engine is dead for good (restart budget spent, or a
    /// rebuild itself failed), park the interrupted requests as
    /// resubmittable orphans ([`Gateway::take_orphans`]) for a
    /// [`super::Router`] to fail over to sibling engines, instead of
    /// failing them out.  Leave off for a solo gateway — parked orphans
    /// that nobody collects would strand their client streams.
    pub failover: bool,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            queue_capacity: 64,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            max_pending: None,
            max_restarts: 2,
            failover: false,
        }
    }
}

/// Why a submission was refused at the gateway handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded ingress full — backpressure; retry or block with `submit`.
    Saturated,
    /// Load shed: in-flight depth reached `GatewayConfig::max_pending`.
    /// Refused before any state was allocated — nothing to reclaim, and
    /// requests already accepted are unaffected.
    Overloaded,
    /// Gateway is shutting down or its worker is gone.
    Closed,
    /// The prompt is empty.  The engine has nothing to feed such a
    /// request (and would have to invent a position-0 token), so it is
    /// refused here, before an id or a stream is allocated.
    EmptyPrompt,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Saturated => write!(f, "gateway ingress saturated"),
            SubmitError::Overloaded => write!(f, "gateway overloaded: queue depth cap reached"),
            SubmitError::Closed => write!(f, "gateway closed"),
            SubmitError::EmptyPrompt => write!(f, "empty prompt rejected at admission"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// What a successful submission hands back: the event stream and a cancel
/// token, bound to the assigned request id.
pub struct Ticket {
    pub id: u64,
    pub stream: RequestStream,
    pub cancel: CancelToken,
}

/// One submission travelling the bounded ingress channel.
pub(crate) struct Submission {
    pub(crate) req: Request,
    deadline: Option<Instant>,
    events: mpsc::Sender<StreamEvent>,
    /// True when this submission was reclaimed from another gateway's
    /// queue and is entering its second engine — the receiving worker
    /// stamps a [`SpanPoint::Migrated`] on the request's timeline.
    migrated: bool,
}

impl Submission {
    /// Last resort when no engine is left to serve an orphan: deliver its
    /// terminal `Failed` directly so the client's stream still ends with
    /// exactly one terminal event instead of a silent disconnect.
    pub(crate) fn fail(self, reason: FailReason) {
        let _ = self.events.send(StreamEvent::Failed {
            id: self.req.id,
            reason,
            tokens: self.req.prompt,
            step: 0,
        });
    }
}

/// Control-plane messages (unbounded channel).
pub(crate) enum Ctrl {
    Cancel(u64),
    /// Queue migration: surrender up to `max` *queued* requests (never
    /// in-flight lanes) back through `reply` as resubmittable
    /// [`Submission`]s.  The worker answers between decode steps; the
    /// reply channel closing marks the end of the exchange.
    Reclaim { max: usize, reply: mpsc::Sender<Submission> },
    Shutdown,
}

pub struct Gateway {
    name: String,
    rank: usize,
    kv_bytes_per_token: usize,
    /// The draft model's rank when this gateway hosts a speculative
    /// draft+verify pair.
    draft_rank: Option<usize>,
    /// Engine batch lanes — the router's saturation yardstick: more
    /// in-flight requests than lanes means a real queue has formed.
    batch_slots: usize,
    /// The engine's prefix-cache block size, when caching is on — the
    /// router keys its shadow prefix directory on it.
    prefix_cache_block: Option<usize>,
    /// Load-shedding cap ([`GatewayConfig::max_pending`]).
    max_pending: Option<usize>,
    submit_tx: mpsc::SyncSender<Submission>,
    ctrl_tx: mpsc::Sender<Ctrl>,
    /// Shared across all gateways behind one [`super::Router`] (see
    /// [`Gateway::share_id_counter`]) so ids are fleet-unique and a muxed
    /// event consumer can key on [`super::StreamEvent::id`] safely.
    next_id: Arc<AtomicU64>,
    in_flight: Arc<AtomicUsize>,
    /// Prompt tokens accepted but not yet prefilled (decremented by the
    /// worker at each request's first sampled token or terminal event) —
    /// the router's measure of pending prefill work.
    queued_prefill: Arc<AtomicUsize>,
    submitted: AtomicUsize,
    /// Shared with the worker's engine so submit arrival stamps and
    /// deadlines live on the same timeline the engine measures against.
    clock: Clock,
    /// Cleared by the worker on every exit path (drain, death past the
    /// restart budget) — the router's liveness probe.
    alive: Arc<AtomicBool>,
    /// Replayable requests a dead worker parked for router failover
    /// (`GatewayConfig::failover`); drained by [`Gateway::take_orphans`].
    orphans: Arc<Mutex<Vec<Submission>>>,
    worker: Option<JoinHandle<Result<ServeMetrics>>>,
}

/// Clears the shared liveness flag when the worker thread exits, on
/// *every* path — normal drain, death past the restart budget, and any
/// unwind that escapes the supervisor's `catch_unwind`.
struct AliveGuard(Arc<AtomicBool>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

impl Gateway {
    /// Spawn the worker thread, build the engine inside it, and block
    /// until it reports ready (or dies — build errors surface here, not on
    /// first submit).
    pub fn spawn(name: &str, cfg: GatewayConfig, spec: EngineSpec) -> Result<Self> {
        Self::spawn_with_obs(name, cfg, spec, None)
    }

    /// [`Gateway::spawn`] plus observability taps: the worker labels the
    /// shared registry's series `{gateway="name"}`, feeds every step and
    /// span event into the shared trace sink, and arms the sink's
    /// `shutdown` flight dump when the engine drains out.
    pub fn spawn_with_obs(
        name: &str,
        cfg: GatewayConfig,
        spec: EngineSpec,
        obs: Option<Obs>,
    ) -> Result<Self> {
        if cfg.queue_capacity == 0 {
            bail!("GatewayConfig.queue_capacity must be >= 1");
        }
        // Checked here, not just in serve_core: a zero max_batch would kill
        // the worker *after* it reported ready, stranding racing submits
        // with a stream that never sees a terminal event.
        if cfg.policy.max_batch == 0 {
            bail!("GatewayConfig.policy.max_batch must be >= 1");
        }
        let (submit_tx, submit_rx) = mpsc::sync_channel::<Submission>(cfg.queue_capacity);
        let (ctrl_tx, ctrl_rx) = mpsc::channel::<Ctrl>();
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<Ready, String>>();
        let in_flight = Arc::new(AtomicUsize::new(0));
        let queued_prefill = Arc::new(AtomicUsize::new(0));
        let policy = cfg.policy.clone();
        let clock = spec.clock.clone();
        let batch_slots = spec.batch_slots;
        let prefix_cache_block = spec.prefix_cache_block;
        let worker_in_flight = in_flight.clone();
        let worker_queued_prefill = queued_prefill.clone();
        let worker_obs = obs.map(|o| ObsWiring::new(o, name));
        let alive = Arc::new(AtomicBool::new(true));
        let orphans: Arc<Mutex<Vec<Submission>>> = Arc::new(Mutex::new(Vec::new()));
        let (max_restarts, failover) = (cfg.max_restarts, cfg.failover);
        let worker_alive = alive.clone();
        let worker_orphans = orphans.clone();
        let worker = thread::Builder::new()
            .name(format!("gateway-{name}"))
            .spawn(move || -> Result<ServeMetrics> {
                let _alive = AliveGuard(worker_alive);
                let mut hook = GatewayHook {
                    submit_rx: Some(submit_rx),
                    ctrl_rx,
                    in_flight: worker_in_flight,
                    queued_prefill: worker_queued_prefill,
                    pending_prefill: HashMap::new(),
                    streams: HashMap::new(),
                    deadlines: HashMap::new(),
                    registry: CancelRegistry::new(),
                    backlog: Vec::new(),
                    reclaim: None,
                    reclaim_reply: None,
                    clock: spec.clock.clone(),
                    obs: worker_obs,
                    book: HashMap::new(),
                    supervised: max_restarts > 0 || failover,
                    orphans: worker_orphans,
                };
                let mut spec = spec;
                // Stub engines have no runtime at all; artifact engines own
                // a Runtime for the thread's lifetime (the PJRT handles are
                // born and die here) — it outlives the engines the
                // supervisor rebuilds on top of it.
                let rt = if matches!(spec.source, ParamSource::Stub(_)) {
                    None
                } else {
                    match Runtime::new(&spec.artifacts_dir) {
                        Ok(rt) => Some(rt),
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("{e:#}")));
                            return Err(e);
                        }
                    }
                };
                let mut ready_tx = Some(ready_tx);
                let mut restarts_left = max_restarts;
                // The supervisor loop: build an engine, serve until it
                // drains (done) or dies (rebuild, replay the interrupted
                // requests, and keep serving — budget permitting).
                loop {
                    let engine = match build_worker_engine(&spec, rt.as_ref()) {
                        Ok(e) => e,
                        Err(e) => {
                            return if let Some(tx) = ready_tx.take() {
                                // First build: the error surfaces from spawn.
                                let _ = tx.send(Err(format!("{e:#}")));
                                Err(e)
                            } else {
                                // A rebuild failed mid-supervision: no
                                // replacement engine is coming.
                                let e = e.context("rebuilding the supervised engine");
                                hook.engine_lost(failover);
                                hook.shutdown_dump();
                                Err(e)
                            };
                        }
                    };
                    if let Some(tx) = ready_tx.take() {
                        let _ = tx.send(Ok(Ready {
                            rank: engine.kv_config().rank,
                            kv_bytes_per_token: engine.kv_bytes_per_token_total(),
                            draft_rank: engine.draft_kv_config().map(|kc| kc.rank),
                        }));
                    }
                    // The panic guard turns a crashing backend (or any
                    // unwind escaping the step loop) into the same shape as
                    // a fatal step error, so both death modes recover
                    // through the same replay path.
                    let served = catch_unwind(AssertUnwindSafe(|| {
                        engine.serve_open(policy.clone(), &mut hook)
                    }));
                    let died = match served {
                        Ok(Ok(metrics)) => {
                            hook.shutdown_dump();
                            return Ok(metrics);
                        }
                        Ok(Err(e)) => e,
                        Err(payload) => {
                            anyhow!("worker panicked mid-serve: {}", panic_msg(payload.as_ref()))
                        }
                    };
                    if restarts_left > 0 {
                        restarts_left -= 1;
                        defuse_fault_plan(&mut spec);
                        hook.note_restart();
                        hook.stage_replays();
                        continue;
                    }
                    hook.engine_lost(failover);
                    hook.shutdown_dump();
                    return Err(died);
                }
            })
            .context("spawning gateway worker thread")?;
        match ready_rx.recv() {
            Ok(Ok(ready)) => Ok(Self {
                name: name.to_string(),
                rank: ready.rank,
                kv_bytes_per_token: ready.kv_bytes_per_token,
                draft_rank: ready.draft_rank,
                batch_slots,
                prefix_cache_block,
                max_pending: cfg.max_pending,
                submit_tx,
                ctrl_tx,
                next_id: Arc::new(AtomicU64::new(0)),
                in_flight,
                queued_prefill,
                submitted: AtomicUsize::new(0),
                clock,
                alive,
                orphans,
                worker: Some(worker),
            }),
            Ok(Err(msg)) => {
                let _ = worker.join();
                bail!("gateway {name} failed to start: {msg}")
            }
            Err(_) => {
                // The worker died before reporting ready: surface its real
                // error — or its panic payload — instead of a generic
                // "died during startup".
                match worker.join() {
                    Ok(Ok(_)) => {
                        bail!("gateway {name} worker exited during startup without reporting ready")
                    }
                    Ok(Err(e)) => {
                        Err(e.context(format!("gateway {name} worker died during startup")))
                    }
                    Err(payload) => bail!(
                        "gateway {name} worker panicked during startup: {}",
                        panic_msg(payload.as_ref())
                    ),
                }
            }
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// KV rank of the engine this gateway owns (head dim for dense).
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Per-token KV cost of this gateway's engine — the router's weight.
    /// For a speculative pair this is the *combined* target + draft cost:
    /// a draft+verify pair consumes two engines' worth of cache.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.kv_bytes_per_token
    }

    /// Rank of the draft model, when this gateway hosts a speculative
    /// draft+verify pair.
    pub fn draft_rank(&self) -> Option<usize> {
        self.draft_rank
    }

    /// Does this gateway host a speculative draft+verify pair?
    pub fn speculative(&self) -> bool {
        self.draft_rank.is_some()
    }

    /// Batch lanes of the engine behind this gateway.  The router treats
    /// `in_flight() > batch_slots()` as saturation: a queue has formed.
    pub fn batch_slots(&self) -> usize {
        self.batch_slots
    }

    /// Block size of the engine's radix prefix cache, when enabled.
    pub fn prefix_cache_block(&self) -> Option<usize> {
        self.prefix_cache_block
    }

    /// The load-shedding cap, when configured.
    pub fn max_pending(&self) -> Option<usize> {
        self.max_pending
    }

    /// Is the worker thread still serving?  Cleared on every exit path —
    /// graceful drain and death past the restart budget alike — so a
    /// router can detect a dead engine without joining it.
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Drain the replayable requests a dead worker parked for failover
    /// (`GatewayConfig::failover`).  Each keeps its fleet-unique id, its
    /// client stream, its deadline, and the tokens already streamed
    /// (merged into the prompt), so resubmitting it to a sibling gateway
    /// resumes the client's stream losslessly.
    pub(crate) fn take_orphans(&self) -> Vec<Submission> {
        std::mem::take(&mut *self.orphans.lock().unwrap_or_else(|e| e.into_inner()))
    }

    /// Requests accepted and not yet terminal (queued + decoding).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Prompt tokens accepted whose prefill has not finished — pending
    /// prefill work in tokens.  A burst of long prompts shows up here
    /// immediately (counted at submit), and drains as requests reach
    /// their first sampled token or terminal event.
    pub fn queued_prefill_tokens(&self) -> usize {
        self.queued_prefill.load(Ordering::SeqCst)
    }

    /// Total submissions accepted over this gateway's lifetime.
    pub fn submitted(&self) -> usize {
        self.submitted.load(Ordering::SeqCst)
    }

    /// Submit, blocking while the bounded ingress is full (backpressure).
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Duration>,
    ) -> std::result::Result<Ticket, SubmitError> {
        self.submit_inner(prompt, max_new, sampling, deadline, true)
    }

    /// Non-blocking submit: [`SubmitError::Saturated`] when the ingress is
    /// full.
    pub fn try_submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Duration>,
    ) -> std::result::Result<Ticket, SubmitError> {
        self.submit_inner(prompt, max_new, sampling, deadline, false)
    }

    fn submit_inner(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Duration>,
        block: bool,
    ) -> std::result::Result<Ticket, SubmitError> {
        // Nothing to feed: refused before an id or stream exists (the
        // engine-level contract is the same — it bails on empty prompts).
        if prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt);
        }
        // Load shedding, also before any allocation: an overloaded
        // refusal reclaims nothing because nothing was ever claimed, and
        // the requests already in flight never notice.  (Racing submits
        // may briefly land one past the cap — the cap bounds queue growth,
        // it is not an exact semaphore.)
        if let Some(cap) = self.max_pending {
            if self.in_flight.load(Ordering::SeqCst) >= cap {
                return Err(SubmitError::Overloaded);
            }
        }
        // `join` consumes the Gateway, so a live `&self` implies the worker
        // has not been asked to shut down; a dead worker (panic/error)
        // surfaces as a disconnected channel below.
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (events_tx, events_rx) = mpsc::channel();
        // Queued goes out on the same channel the worker will feed, before
        // the worker can see the submission — ordering is preserved.
        let _ = events_tx.send(StreamEvent::Queued { id });
        let now = self.clock.now();
        let prompt_len = prompt.len();
        let sub = Submission {
            req: Request { id, prompt, max_new, arrived: now, sampling },
            deadline: deadline.map(|d| now + d),
            events: events_tx,
            migrated: false,
        };
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        // Counted at submit so a burst of long prompts is visible to the
        // router before the worker has even swept the channel.
        self.queued_prefill.fetch_add(prompt_len, Ordering::SeqCst);
        let sent = if block {
            self.submit_tx.send(sub).map_err(|_| SubmitError::Closed)
        } else {
            self.submit_tx.try_send(sub).map_err(|e| match e {
                mpsc::TrySendError::Full(_) => SubmitError::Saturated,
                mpsc::TrySendError::Disconnected(_) => SubmitError::Closed,
            })
        };
        if let Err(e) = sent {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            self.queued_prefill.fetch_sub(prompt_len, Ordering::SeqCst);
            return Err(e);
        }
        self.submitted.fetch_add(1, Ordering::SeqCst);
        Ok(Ticket {
            id,
            stream: RequestStream::new(id, events_rx),
            cancel: CancelToken::new(id, self.ctrl_tx.clone()),
        })
    }

    /// Queue migration, surrendering side: ask the worker for up to `max`
    /// *queued* requests (in-flight lanes are never taken) and collect
    /// them as resubmittable [`Submission`]s.  Blocks until the worker
    /// closes the exchange — one decode-step latency in the common case,
    /// bounded by a 1-second stall guard per item.  An idle or empty
    /// engine answers with nothing.
    pub(crate) fn reclaim_queued(&self, max: usize) -> Vec<Submission> {
        let (reply, rx) = mpsc::channel();
        if max == 0 || self.ctrl_tx.send(Ctrl::Reclaim { max, reply }).is_err() {
            return Vec::new();
        }
        let mut out = Vec::new();
        while out.len() < max {
            match rx.recv_timeout(Duration::from_secs(1)) {
                Ok(sub) => out.push(sub),
                Err(_) => break, // exchange closed (or the worker stalled)
            }
        }
        out
    }

    /// Queue migration, receiving side: hand a reclaimed submission to
    /// this gateway's engine.  The submission keeps its fleet-unique id,
    /// its client stream, and its deadline — only the serving engine
    /// changes.  Blocks on the bounded ingress like `submit`; the
    /// load-shedding cap is *not* applied (the router only migrates
    /// toward spare capacity, and refusing here would strand the client's
    /// stream).  A closed ingress (this gateway died too) hands the
    /// submission *back* so the caller can try a sibling or deliver a
    /// terminal `Failed` — dropping it would strand the client's stream
    /// without a terminal event.
    pub(crate) fn resubmit(&self, mut sub: Submission) -> std::result::Result<(), Submission> {
        sub.migrated = true;
        let prompt_len = sub.req.prompt.len();
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.queued_prefill.fetch_add(prompt_len, Ordering::SeqCst);
        if let Err(mpsc::SendError(sub)) = self.submit_tx.send(sub) {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            self.queued_prefill.fetch_sub(prompt_len, Ordering::SeqCst);
            return Err(sub);
        }
        self.submitted.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Begin a graceful shutdown without waiting for it.  Idempotent;
    /// [`Router::join`](super::Router::join) uses this to overlap the
    /// drains of several engines instead of serializing them.
    pub(crate) fn signal_shutdown(&self) {
        let _ = self.ctrl_tx.send(Ctrl::Shutdown);
    }

    /// Rebind this gateway's id counter — [`super::Router::new`] points
    /// every member at one shared counter so request ids are unique across
    /// the whole fleet, not just within one gateway.
    pub(crate) fn share_id_counter(&mut self, counter: Arc<AtomicU64>) {
        self.next_id = counter;
    }

    /// Graceful shutdown: close the ingress, serve everything already
    /// accepted to completion, and return the worker's final metrics.
    pub fn join(mut self) -> Result<ServeMetrics> {
        self.signal_shutdown();
        let worker = self.worker.take().expect("gateway joined once");
        match worker.join() {
            Ok(result) => result,
            // The supervisor catches serve-loop panics; reaching here
            // means the worker's own plumbing unwound.
            Err(payload) => {
                bail!("gateway {} worker panicked: {}", self.name, panic_msg(payload.as_ref()))
            }
        }
    }
}

/// The worker-side [`StepHook`]: owns the channel receivers, the
/// per-request event senders, and the cancel registry.
struct GatewayHook {
    /// `None` once the ingress is closed (shutdown or handle dropped).
    submit_rx: Option<mpsc::Receiver<Submission>>,
    ctrl_rx: mpsc::Receiver<Ctrl>,
    in_flight: Arc<AtomicUsize>,
    /// Shared with the handle's [`Gateway::queued_prefill_tokens`]; the
    /// handle adds each prompt at submit, this side subtracts when the
    /// prefill finishes (first sampled token) or the request goes
    /// terminal without one.
    queued_prefill: Arc<AtomicUsize>,
    /// Prompt length per accepted id still owing its `queued_prefill`
    /// subtraction.
    pending_prefill: HashMap<u64, usize>,
    streams: HashMap<u64, mpsc::Sender<StreamEvent>>,
    /// Deadline per accepted id, kept so a reclaimed request's
    /// [`Submission`] can be rebuilt intact for its next engine.
    deadlines: HashMap<u64, Option<Instant>>,
    registry: CancelRegistry,
    /// Submissions accepted but not yet handed to the engine (filled by
    /// control-channel draining outside `poll_ingress`).  Their ids are
    /// registered with the cancel registry only at hand-off — a
    /// cancellation surfaced for an id the engine cannot see in a lane or
    /// its batcher would be silently dropped by the step loop.
    backlog: Vec<(Request, Option<Instant>)>,
    /// A pending [`Ctrl::Reclaim`] exchange, parked until the engine's
    /// next `reclaim_requests` poll.
    reclaim: Option<(usize, mpsc::Sender<Submission>)>,
    /// The live exchange's reply channel; dropped at the *next* poll,
    /// which is what tells the coordinator the exchange is over.
    reclaim_reply: Option<mpsc::Sender<Submission>>,
    /// The gateway's clock — stamps the `Migrated` span on arrivals.
    clock: Clock,
    /// Observability sinks plus this gateway's pre-rendered series names
    /// (`None` for a tap-less gateway — the engine then skips event
    /// assembly entirely via `wants_step_events`).
    obs: Option<ObsWiring>,
    /// Lossless-replay book: one [`ReplayState`] per live request while
    /// supervision is on, fed by `accept` and `on_token`, dropped at the
    /// terminal event.  After an engine death this is the complete record
    /// of what each interrupted client was promised and has already seen.
    book: HashMap<u64, ReplayState>,
    /// `max_restarts > 0 || failover` — whether the book is maintained
    /// and `Backend` failures are withheld from clients for replay.
    supervised: bool,
    /// Shared with the handle ([`Gateway::take_orphans`]): requests a
    /// dead-for-good worker parked for router failover.
    orphans: Arc<Mutex<Vec<Submission>>>,
}

/// Everything needed to resubmit one interrupted request losslessly.
#[derive(Clone)]
struct ReplayState {
    prompt: Vec<i32>,
    max_new: usize,
    sampling: SamplingParams,
    arrived: Instant,
    /// Tokens already delivered to the client's stream.  A replay
    /// resubmits `prompt ⧺ streamed` with the token budget reduced by
    /// `streamed.len()`, so the engine regenerates nothing the client has
    /// seen and the resumed stream carries no duplicates.
    streamed: Vec<i32>,
}

/// Worker-side wiring of an [`Obs`] pair: the series names are rendered
/// once per gateway (`family{gateway="NAME"}`), and the draft/accept
/// running totals feed the published acceptance-rate gauge.
struct ObsWiring {
    obs: Obs,
    s_in_flight: String,
    s_queued_prefill: String,
    s_kv_live_bytes: String,
    s_steps_total: String,
    s_completed_total: String,
    s_cancelled_total: String,
    s_generated_total: String,
    s_drafted_total: String,
    s_accepted_total: String,
    s_accept_rate: String,
    s_prefix_hits_total: String,
    s_prefix_hit_tokens_total: String,
    s_prefix_cached_bytes: String,
    s_prefix_evicted_total: String,
    s_migrated_total: String,
    s_failed_total: String,
    s_step_retries_total: String,
    s_restarts_total: String,
    drafted: u64,
    accepted: u64,
    /// Last seen cumulative eviction total — the step event carries a
    /// running sum, the registry counter wants deltas.
    evicted_seen: usize,
}

impl ObsWiring {
    fn new(obs: Obs, gateway: &str) -> Self {
        let s = |family: &str| format!("{family}{{gateway=\"{gateway}\"}}");
        Self {
            obs,
            s_in_flight: s("clover_in_flight"),
            s_queued_prefill: s("clover_queued_prefill_tokens"),
            s_kv_live_bytes: s("clover_kv_live_bytes"),
            s_steps_total: s("clover_steps_total"),
            s_completed_total: s("clover_completed_total"),
            s_cancelled_total: s("clover_cancelled_total"),
            s_generated_total: s("clover_generated_tokens_total"),
            s_drafted_total: s("clover_draft_tokens_total"),
            s_accepted_total: s("clover_accepted_tokens_total"),
            s_accept_rate: s("clover_accept_rate"),
            s_prefix_hits_total: s("clover_prefix_hits_total"),
            s_prefix_hit_tokens_total: s("clover_prefix_hit_tokens_total"),
            s_prefix_cached_bytes: s("clover_prefix_cached_bytes"),
            s_prefix_evicted_total: s("clover_prefix_evicted_bytes_total"),
            s_migrated_total: s("clover_migrated_total"),
            s_failed_total: s("clover_failed_total"),
            s_step_retries_total: s("clover_step_retries_total"),
            s_restarts_total: s("clover_engine_restarts_total"),
            drafted: 0,
            accepted: 0,
            evicted_seen: 0,
        }
    }
}

impl GatewayHook {
    /// Refresh the queue-shaped gauges from the atomics shared with the
    /// handle (called on every step and terminal event while tapped).
    fn publish_queue_gauges(&self) {
        if let Some(w) = &self.obs {
            let reg = &w.obs.registry;
            reg.gauge_set(&w.s_in_flight, self.in_flight.load(Ordering::SeqCst) as f64);
            reg.gauge_set(
                &w.s_queued_prefill,
                self.queued_prefill.load(Ordering::SeqCst) as f64,
            );
        }
    }

    /// The engine drained out: arm the trace sink's shutdown flight dump
    /// so whoever holds the [`Obs`] can export the final ring.
    fn shutdown_dump(&mut self) {
        if let Some(w) = &self.obs {
            self.publish_queue_gauges();
            // A panicking tap thread must not take the drain down with it:
            // recover the sink from the poison and dump anyway.
            w.obs.trace.lock().unwrap_or_else(|e| e.into_inner()).request_dump("shutdown");
        }
    }
    /// Accept one submission into the backlog.  Every accepted submission
    /// reaches the engine — even ones already cancelled, whose cancel
    /// fires from the registry right after hand-off — so the engine's
    /// metrics and conservation checks account for all of them.
    fn accept(&mut self, sub: Submission) {
        if sub.migrated {
            // This request's queue wait started on another gateway: stamp
            // the hand-over on its timeline and count the arrival.
            if let Some(w) = &self.obs {
                w.obs.registry.counter_add(&w.s_migrated_total, 1.0);
                let ev = SpanEvent {
                    id: sub.req.id,
                    t_s: self.clock.secs_since_epoch(self.clock.now()),
                    point: SpanPoint::Migrated,
                };
                w.obs.trace.lock().unwrap_or_else(|e| e.into_inner()).record_span(&ev);
            }
        }
        self.streams.insert(sub.req.id, sub.events);
        self.pending_prefill.insert(sub.req.id, sub.req.prompt.len());
        self.deadlines.insert(sub.req.id, sub.deadline);
        if self.supervised {
            self.book.insert(
                sub.req.id,
                ReplayState {
                    prompt: sub.req.prompt.clone(),
                    max_new: sub.req.max_new,
                    sampling: sub.req.sampling.clone(),
                    arrived: sub.req.arrived,
                    streamed: Vec::new(),
                },
            );
        }
        self.backlog.push((sub.req, sub.deadline));
    }

    /// The request's prefill is over (or it went terminal first): return
    /// its prompt tokens to the shared pending-prefill gauge.
    fn prefill_done(&mut self, id: u64) {
        if let Some(n) = self.pending_prefill.remove(&id) {
            self.queued_prefill.fetch_sub(n, Ordering::SeqCst);
        }
    }

    /// Drain the control channel: cancels into the registry; shutdown
    /// closes the ingress (serving everything already accepted).
    fn drain_ctrl(&mut self) {
        loop {
            match self.ctrl_rx.try_recv() {
                Ok(Ctrl::Cancel(id)) => self.registry.cancel(id),
                // Parked for the engine's next reclaim_requests poll; a
                // newer exchange supersedes an unserved older one (whose
                // reply channel drops here, unblocking its coordinator).
                Ok(Ctrl::Reclaim { max, reply }) => self.reclaim = Some((max, reply)),
                Ok(Ctrl::Shutdown) => self.close_ingress(),
                Err(_) => break, // empty or disconnected: nothing more now
            }
        }
    }

    /// Stop reading new submissions forever.  Everything already inside
    /// the bounded channel was accepted by a successful `submit`, so it is
    /// drained into the backlog and served.  No submit can be in flight
    /// *during* this call — `Ctrl::Shutdown` is only sent from
    /// `Gateway::join(self)` / `Router::join(self)`, whose ownership rules
    /// out concurrent `&self` borrows, and the handle-dropped path implies
    /// all senders are gone — so a plain non-blocking drain is complete.
    /// Dropping the receiver makes any later sender fail out with `Closed`.
    fn close_ingress(&mut self) {
        if let Some(rx) = self.submit_rx.take() {
            while let Ok(sub) = rx.try_recv() {
                self.accept(sub);
            }
        }
    }

    /// Non-blocking sweep of the ingress channel into the backlog.
    fn sweep_submits(&mut self) {
        let mut subs = Vec::new();
        let mut disconnected = false;
        if let Some(rx) = &self.submit_rx {
            loop {
                match rx.try_recv() {
                    Ok(s) => subs.push(s),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
        }
        for s in subs {
            self.accept(s);
        }
        if disconnected {
            // Handle dropped without join(): same as a shutdown drain.
            self.submit_rx = None;
        }
    }

    /// Deliver a terminal event and drop all per-request state.
    fn terminal(&mut self, id: u64, ev: StreamEvent) {
        self.registry.retire(id);
        self.prefill_done(id);
        self.deadlines.remove(&id);
        self.book.remove(&id);
        if let Some(tx) = self.streams.remove(&id) {
            let _ = tx.send(ev);
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Deliver a terminal `Failed` (counted in `clover_failed_total` —
    /// the counter tracks client-visible failures, not every backend
    /// death the supervisor absorbs).
    fn fail_event(&mut self, id: u64, reason: FailReason, tokens: Vec<i32>, step: usize) {
        if let Some(w) = &self.obs {
            w.obs.registry.counter_add(&w.s_failed_total, 1.0);
        }
        self.terminal(id, StreamEvent::Failed { id, reason, tokens, step });
    }

    /// The supervisor is about to rebuild the engine: count the restart
    /// and arm a flight dump so the fault window's trace survives.
    fn note_restart(&mut self) {
        if let Some(w) = &self.obs {
            w.obs.registry.counter_add(&w.s_restarts_total, 1.0);
            w.obs
                .trace
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .request_dump("supervisor-restart");
        }
    }

    /// The engine died: rebuild every interrupted request — prompt plus
    /// already-streamed tokens, remaining token budget — and queue it for
    /// the replacement engine, ordered by `(arrived, id)` so admission
    /// order is deterministic across the restart.  Requests still in the
    /// backlog (accepted but never handed to the dead engine) are left
    /// there untouched; cancel tracking survives because `poll_ingress`
    /// re-tracks ids at hand-off and [`CancelRegistry::track`] is
    /// idempotent.
    fn stage_replays(&mut self) {
        let queued: HashSet<u64> = self.backlog.iter().map(|(r, _)| r.id).collect();
        let mut replays: Vec<(u64, ReplayState)> = self
            .book
            .iter()
            .filter(|(id, _)| !queued.contains(id) && self.streams.contains_key(id))
            .map(|(id, st)| (*id, st.clone()))
            .collect();
        replays.sort_by_key(|(id, st)| (st.arrived, *id));
        for (id, st) in replays {
            let mut prompt = st.prompt;
            prompt.extend_from_slice(&st.streamed);
            let req = Request {
                id,
                prompt,
                max_new: st.max_new.saturating_sub(st.streamed.len()),
                arrived: st.arrived,
                sampling: st.sampling,
            };
            let deadline = self.deadlines.get(&id).copied().flatten();
            self.backlog.push((req, deadline));
        }
    }

    /// The engine is dead for good.  With `failover` on, park every
    /// interrupted request as a resubmittable orphan for the router;
    /// otherwise deliver a terminal `Failed` to each so no client stream
    /// is stranded.
    fn engine_lost(&mut self, failover: bool) {
        // Submissions still buffered in the ingress channel would die with
        // it — accept them first so they are parked or failed like
        // everything else, never silently disconnected.
        self.sweep_submits();
        if failover {
            self.park_orphans();
        } else {
            self.fail_out_survivors();
        }
    }

    /// Deliver a terminal `Failed{Backend}` to every request still live —
    /// in dead lanes, in the dead engine's batcher, and in the backlog
    /// alike.  The partial row is prompt ⧺ streamed from the book (empty
    /// prompt only for unsupervised gateways, which never reach here —
    /// their failures were delivered by `on_failed` directly).
    fn fail_out_survivors(&mut self) {
        // Backlogged requests never touched an engine: their partial row
        // is their own untouched prompt.
        for (req, _) in std::mem::take(&mut self.backlog) {
            self.fail_event(req.id, FailReason::Backend, req.prompt, 0);
        }
        let mut ids: Vec<u64> = self.streams.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let tokens = match self.book.get(&id) {
                Some(st) => {
                    let mut t = st.prompt.clone();
                    t.extend_from_slice(&st.streamed);
                    t
                }
                None => Vec::new(),
            };
            self.fail_event(id, FailReason::Backend, tokens, 0);
        }
        self.book.clear();
    }

    /// Rebuild every live request as a replay-shaped [`Submission`] —
    /// stream sender, deadline, and merged prompt intact — and park it
    /// for [`Gateway::take_orphans`].  Mirrors `on_reclaimed`: the
    /// requests leave this gateway's accounting entirely.  Returns how
    /// many were parked.
    fn park_orphans(&mut self) -> usize {
        let mut subs: Vec<Submission> = Vec::new();
        // Backlogged requests first: accepted but never handed to any
        // engine, so their prompts are already submission-shaped.
        for (req, deadline) in std::mem::take(&mut self.backlog) {
            let id = req.id;
            self.book.remove(&id);
            self.registry.retire(id);
            self.deadlines.remove(&id);
            if let Some(n) = self.pending_prefill.remove(&id) {
                self.queued_prefill.fetch_sub(n, Ordering::SeqCst);
            }
            let Some(events) = self.streams.remove(&id) else { continue };
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            subs.push(Submission { req, deadline, events, migrated: true });
        }
        // Then every interrupted in-flight request, replay-shaped.
        let mut book: Vec<(u64, ReplayState)> = self.book.drain().collect();
        book.sort_by_key(|(id, st)| (st.arrived, *id));
        for (id, st) in book {
            let deadline = self.deadlines.remove(&id).flatten();
            self.registry.retire(id);
            if let Some(n) = self.pending_prefill.remove(&id) {
                self.queued_prefill.fetch_sub(n, Ordering::SeqCst);
            }
            let Some(events) = self.streams.remove(&id) else { continue };
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            let mut prompt = st.prompt;
            prompt.extend_from_slice(&st.streamed);
            let req = Request {
                id,
                prompt,
                max_new: st.max_new.saturating_sub(st.streamed.len()),
                arrived: st.arrived,
                sampling: st.sampling,
            };
            subs.push(Submission { req, deadline, events, migrated: true });
        }
        let n = subs.len();
        if n > 0 {
            self.orphans.lock().unwrap_or_else(|e| e.into_inner()).extend(subs);
        }
        n
    }
}

impl StepHook for GatewayHook {
    fn poll_ingress(&mut self, idle: bool) -> Option<Vec<Request>> {
        self.drain_ctrl();
        self.sweep_submits();
        if idle && self.backlog.is_empty() {
            // Nothing live anywhere: sleep on the ingress channel, waking
            // every tick to keep the control channel responsive.
            loop {
                if self.submit_rx.is_none() || !self.backlog.is_empty() {
                    break;
                }
                let polled = self.submit_rx.as_ref().expect("checked above").recv_timeout(IDLE_POLL_TICK);
                match polled {
                    Ok(sub) => self.accept(sub),
                    Err(mpsc::RecvTimeoutError::Timeout) => self.drain_ctrl(),
                    Err(mpsc::RecvTimeoutError::Disconnected) => self.submit_rx = None,
                }
                // A reclaim landing while fully idle has nothing to take
                // (idle means the batcher is empty): close the exchange
                // now so the coordinator isn't left waiting for the next
                // decode step that may never come.
                self.reclaim = None;
                self.reclaim_reply = None;
            }
        }
        if self.backlog.is_empty() && self.submit_rx.is_none() {
            return None; // ingress closed for good: engine drains and exits
        }
        // Hand-off: from here the engine owns the requests, so this is
        // where their ids become live for cancellation and deadlines.
        let handed: Vec<Request> = std::mem::take(&mut self.backlog)
            .into_iter()
            .map(|(req, deadline)| {
                self.registry.track(req.id, deadline);
                req
            })
            .collect();
        Some(handed)
    }

    fn take_cancellations(&mut self, now: Instant) -> Vec<Cancellation> {
        // Cancels must keep flowing while the engine drains after the
        // ingress closed, so the control channel is polled here too.
        self.drain_ctrl();
        self.registry.due(now)
    }

    fn reclaim_requests(&mut self) -> Option<usize> {
        // Dropping the previous exchange's reply sender is the
        // end-of-exchange signal: the coordinator's recv disconnects.
        self.reclaim_reply = None;
        let (max, reply) = self.reclaim.take()?;
        self.reclaim_reply = Some(reply);
        Some(max)
    }

    fn on_reclaimed(&mut self, req: Request) {
        // The request leaves this gateway: return its prompt tokens to
        // the pending-prefill gauge, close its cancel tracking, and ship
        // the rebuilt submission — stream and deadline intact — to the
        // coordinator.  The engine has already booked it as migrated.
        let id = req.id;
        let deadline = self.deadlines.remove(&id).flatten();
        self.registry.retire(id);
        self.book.remove(&id);
        if let Some(n) = self.pending_prefill.remove(&id) {
            self.queued_prefill.fetch_sub(n, Ordering::SeqCst);
        }
        let Some(events) = self.streams.remove(&id) else { return };
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
        if let Some(reply) = &self.reclaim_reply {
            // A send failure means the coordinator stopped waiting; the
            // dropped stream sender surfaces as a disconnect to the
            // client rather than a silent hang.
            let _ = reply.send(Submission { req, deadline, events, migrated: true });
        }
    }

    fn on_started(&mut self, id: u64, lane: usize, step: usize) {
        if let Some(tx) = self.streams.get(&id) {
            let _ = tx.send(StreamEvent::Started { id, lane, step });
        }
    }

    fn on_token(&mut self, id: u64, pos: usize, token: i32, step: usize) {
        // First sampled token == prefill complete.
        self.prefill_done(id);
        if self.supervised {
            if let Some(st) = self.book.get_mut(&id) {
                st.streamed.push(token);
            }
        }
        if let Some(tx) = self.streams.get(&id) {
            let _ = tx.send(StreamEvent::Token { id, pos, token, step });
        }
    }

    fn on_done(&mut self, completion: &Completion) {
        self.terminal(completion.id, StreamEvent::Done { completion: completion.clone() });
    }

    fn on_cancelled(&mut self, id: u64, tokens: Vec<i32>, reason: CancelReason, step: usize) {
        self.terminal(id, StreamEvent::Cancelled { id, reason, tokens, step });
    }

    fn on_failed(&mut self, id: u64, tokens: Vec<i32>, reason: FailReason, step: usize) {
        match reason {
            // Replayable under supervision: the engine is about to die
            // and the supervisor will resubmit this request from the book
            // — the client's stream simply pauses, so no event goes out
            // and all per-request state stays live.
            FailReason::Backend if self.supervised => {}
            // Poisoned lanes are individual failures on a healthy engine
            // (replaying one would just poison another lane), and Backend
            // deaths without a supervisor have no replacement engine
            // coming: both are terminal for the client.
            _ => self.fail_event(id, reason, tokens, step),
        }
    }

    fn wants_step_events(&self) -> bool {
        self.obs.is_some()
    }

    fn on_step(&mut self, ev: &StepEvent) {
        let Some(w) = &mut self.obs else { return };
        let reg = &w.obs.registry;
        reg.counter_add(&w.s_steps_total, 1.0);
        if ev.retries > 0 {
            reg.counter_add(&w.s_step_retries_total, ev.retries as f64);
        }
        reg.gauge_set(&w.s_kv_live_bytes, ev.kv_live_bytes as f64);
        reg.gauge_set(&w.s_prefix_cached_bytes, ev.kv_cached_bytes as f64);
        if ev.prefix_evicted_bytes > w.evicted_seen {
            let delta = ev.prefix_evicted_bytes - w.evicted_seen;
            reg.counter_add(&w.s_prefix_evicted_total, delta as f64);
            w.evicted_seen = ev.prefix_evicted_bytes;
        }
        w.obs.trace.lock().unwrap_or_else(|e| e.into_inner()).record_step(ev);
        self.publish_queue_gauges();
    }

    fn on_span(&mut self, ev: &SpanEvent) {
        let Some(w) = &mut self.obs else { return };
        let reg = &w.obs.registry;
        match ev.point {
            SpanPoint::Done { generated } => {
                reg.counter_add(&w.s_completed_total, 1.0);
                reg.counter_add(&w.s_generated_total, generated as f64);
            }
            SpanPoint::Cancelled { .. } => reg.counter_add(&w.s_cancelled_total, 1.0),
            SpanPoint::SpecRound { drafted, accepted } => {
                w.drafted += drafted as u64;
                w.accepted += accepted as u64;
                reg.counter_add(&w.s_drafted_total, drafted as f64);
                reg.counter_add(&w.s_accepted_total, accepted as f64);
                reg.gauge_set(&w.s_accept_rate, w.accepted as f64 / w.drafted.max(1) as f64);
            }
            SpanPoint::PrefixHit { tokens } => {
                reg.counter_add(&w.s_prefix_hits_total, 1.0);
                reg.counter_add(&w.s_prefix_hit_tokens_total, tokens as f64);
            }
            _ => {}
        }
        w.obs.trace.lock().unwrap_or_else(|e| e.into_inner()).record_span(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::stream::StreamOutcome;
    use crate::testing::prop;
    use std::collections::HashSet;

    fn art() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    /// Streaming-collected output must be bit-identical to the blocking
    /// `serve_all` path for the same prompts, sampling policy, and ids —
    /// the gateway changes *when* tokens are delivered, never *which*.
    #[test]
    fn streaming_tokens_bit_identical_to_serve_all() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = crate::coordinator::ops::init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        // Temperature sampling so the comparison exercises the per-request
        // RNG streams, not just greedy argmax.
        let sampling =
            SamplingParams { temperature: 0.9, top_k: 8, seed: 17, ..Default::default() };
        let now = Instant::now();
        let n = 6u64;
        let mk_prompt = |i: u64| vec![3, 4 + i as i32];
        let reqs: Vec<Request> = (0..n)
            .map(|i| Request {
                id: i,
                prompt: mk_prompt(i),
                max_new: 5,
                arrived: now,
                sampling: sampling.clone(),
            })
            .collect();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let (want, _) = engine.serve_all(reqs, policy).unwrap();

        // Same trace through the gateway; ids are assigned 0..n in submit
        // order, so the per-request sampling streams line up.
        let gw = Gateway::spawn(
            "eq",
            GatewayConfig::default(),
            EngineSpec::dense(&art(), "tiny", 8, 9),
        )
        .unwrap();
        let mut streams = Vec::new();
        for i in 0..n {
            let t = gw.submit(mk_prompt(i), 5, sampling.clone(), None).unwrap();
            assert_eq!(t.id, i, "gateway ids must be dense from 0");
            streams.push(t.stream);
        }
        for (s, w) in streams.into_iter().zip(&want) {
            let mut streamed = Vec::new();
            let mut got = None;
            while let Some(ev) = s.next_event() {
                match ev {
                    StreamEvent::Token { token, .. } => streamed.push(token),
                    StreamEvent::Done { completion } => {
                        got = Some(completion);
                        break;
                    }
                    StreamEvent::Cancelled { id, reason, .. } => {
                        panic!("request {id} unexpectedly cancelled ({reason:?})")
                    }
                    _ => {}
                }
            }
            let got = got.expect("terminal Done event");
            assert_eq!(got.tokens, w.tokens, "request {} diverged from serve_all", w.id);
            // The streamed tokens *are* the generated suffix, in order.
            assert_eq!(streamed.as_slice(), &w.tokens[2..], "request {}", w.id);
        }
        let m = gw.join().unwrap();
        assert_eq!(m.completed, n as usize);
        assert_eq!(m.cancelled, 0);
    }

    /// Under random interleavings of submit / cancel / deadline-expiry,
    /// every submitted id yields exactly one terminal event, the engine's
    /// internal slot-conservation checks hold (join surfaces any breach),
    /// and the worker's metrics agree with the events clients saw.
    #[test]
    fn terminal_event_exactly_once_property() {
        if crate::testing::runtime_or_skip(&art()).is_none() {
            return;
        }
        prop("gateway terminal events", 3, |rng| {
            let gw = Gateway::spawn(
                "prop",
                GatewayConfig { queue_capacity: 32, ..Default::default() },
                EngineSpec::dense(&art(), "tiny", 8, 5),
            )
            .map_err(|e| e.to_string())?;
            let n = 4 + rng.below(8);
            let mut tickets = Vec::new();
            for _ in 0..n {
                let p = 1 + rng.below(3);
                let prompt: Vec<i32> = (0..p).map(|_| rng.below(64) as i32).collect();
                // Mix degenerate (max_new = 0), short, and deadline-doomed
                // requests with plain ones.
                let max_new = rng.below(7);
                let deadline = match rng.below(4) {
                    0 => Some(Duration::ZERO),
                    1 => Some(Duration::from_millis(5)),
                    _ => None,
                };
                let t = gw
                    .submit(prompt, max_new, SamplingParams::greedy(), deadline)
                    .map_err(|e| e.to_string())?;
                tickets.push(t);
            }
            // Fire cancel tokens on a random subset mid-flight.
            for t in &tickets {
                if rng.uniform() < 0.3 {
                    t.cancel.cancel();
                }
            }
            let ids: HashSet<u64> = tickets.iter().map(|t| t.id).collect();
            let mut seen: HashSet<u64> = HashSet::new();
            let (mut done_n, mut cancel_n) = (0usize, 0usize);
            for t in tickets {
                match t.stream.wait().map_err(|e| e.to_string())? {
                    StreamOutcome::Done(c) => {
                        if !seen.insert(c.id) {
                            return Err(format!("id {} terminal twice", c.id));
                        }
                        done_n += 1;
                    }
                    StreamOutcome::Cancelled { id, .. } => {
                        if !seen.insert(id) {
                            return Err(format!("id {id} terminal twice"));
                        }
                        cancel_n += 1;
                    }
                }
            }
            if seen != ids {
                return Err(format!("terminal ids {seen:?} != submitted {ids:?}"));
            }
            let m = gw.join().map_err(|e| e.to_string())?;
            if m.completed != done_n || m.cancelled != cancel_n {
                return Err(format!(
                    "metrics completed/cancelled {}/{} disagree with events {done_n}/{cancel_n}",
                    m.completed, m.cancelled
                ));
            }
            if m.completed + m.cancelled != n {
                return Err(format!("{} + {} != {n}", m.completed, m.cancelled));
            }
            Ok(())
        });
    }

    // ---- stub-backed gateway tests: no PJRT needed, run everywhere ----

    /// One lane, single-token ladder, 5ms per fused step: a 64-token
    /// prompt spends >= 320ms in prefill, a wide-open window for control
    /// events to land mid-prefill even on a loaded CI runner.
    fn prefill_stub_spec() -> StubSpec {
        StubSpec {
            batch_slots: 1,
            chunk_widths: vec![1],
            max_positions: 128,
            step_delay: Duration::from_millis(5),
            ..Default::default()
        }
    }

    #[test]
    fn stub_gateway_serves_end_to_end() {
        // The full gateway stack (channels, streams, metrics, shutdown)
        // over the stub engine — with chunked prefill on by default.
        let spec = StubSpec { max_positions: 128, ..Default::default() };
        let gw = Gateway::spawn("stub", GatewayConfig::default(), EngineSpec::stub(spec)).unwrap();
        let prompt: Vec<i32> = (0..40).map(|i| i % 32).collect();
        let t = gw.submit(prompt.clone(), 4, SamplingParams::greedy(), None).unwrap();
        let c = t.stream.wait().unwrap().completion().unwrap();
        assert_eq!(&c.tokens[..40], prompt.as_slice());
        assert_eq!(c.tokens.len(), 44);
        assert_eq!(c.prefill_steps, 2, "40 prompt tokens = 32 + 8 chunk steps");
        let m = gw.join().unwrap();
        assert_eq!(m.completed, 1);
        assert_eq!(m.slab_tokens, 40 + 3, "prompt + fed-back generated tokens");
    }

    /// Speculative pair end-to-end through the gateway: identical tokens
    /// to a vanilla gateway, fewer dense steps, combined KV cost
    /// reported.
    #[test]
    fn stub_speculative_gateway_matches_vanilla_tokens() {
        let target = StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 8,
            vocab: 16,
            max_positions: 128,
            ..Default::default()
        };
        let draft = StubSpec { rank: 4, ..target.clone() };
        let spec_gw = Gateway::spawn(
            "spec",
            GatewayConfig::default(),
            EngineSpec::stub(target.clone()).with_speculative(
                DraftSource::Stub(draft),
                SpecConfig { draft_len: 4, adaptive: true },
            ),
        )
        .unwrap();
        assert!(spec_gw.speculative());
        assert_eq!(spec_gw.draft_rank(), Some(4));
        let vanilla_gw =
            Gateway::spawn("van", GatewayConfig::default(), EngineSpec::stub(target)).unwrap();
        assert!(
            spec_gw.kv_bytes_per_token() > vanilla_gw.kv_bytes_per_token(),
            "the pair pins target + draft cache bytes per token"
        );
        let prompt = vec![3, 7, 1, 5];
        let a = spec_gw
            .submit(prompt.clone(), 24, SamplingParams::speculative_greedy(), None)
            .unwrap();
        let b = vanilla_gw.submit(prompt, 24, SamplingParams::greedy(), None).unwrap();
        let ca = a.stream.wait().unwrap().completion().unwrap();
        let cb = b.stream.wait().unwrap().completion().unwrap();
        assert_eq!(ca.tokens, cb.tokens, "speculative == vanilla greedy through the stack");
        let ma = spec_gw.join().unwrap();
        let mb = vanilla_gw.join().unwrap();
        assert!(ma.spec_rounds > 0);
        assert!(ma.accepted_draft_tokens > 0);
        assert!(
            ma.decode_steps < mb.decode_steps,
            "speculation: {} dense steps vs {} vanilla",
            ma.decode_steps,
            mb.decode_steps
        );
    }

    /// A factored-codec gateway advertises the compressed per-token cost
    /// to the router, serves the same request set to completion, and a
    /// bad budget list fails the spawn — not the first request.
    #[test]
    fn stub_factored_codec_gateway_reports_compressed_cost() {
        let spec = StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 8,
            vocab: 16,
            max_positions: 128,
            ..Default::default()
        };
        let dense =
            Gateway::spawn("dense", GatewayConfig::default(), EngineSpec::stub(spec.clone()))
                .unwrap();
        let fact = Gateway::spawn(
            "fact",
            GatewayConfig::default(),
            EngineSpec::stub(spec.clone())
                .with_kv_codec(KvCodecSpec::Factored { layer_budgets: Some(vec![4]) }),
        )
        .unwrap();
        assert_eq!(
            fact.kv_bytes_per_token() * 2,
            dense.kv_bytes_per_token(),
            "budget 4 of rank 8 halves the router-visible KV cost"
        );
        let t = fact.submit(vec![3, 7, 1, 5], 8, SamplingParams::greedy(), None).unwrap();
        let c = t.stream.wait().unwrap().completion().unwrap();
        assert_eq!(c.tokens.len(), 12);
        fact.join().unwrap();
        dense.join().unwrap();
        // Validation runs in the worker during spawn: 2 budgets on a
        // 1-layer stub is refused before ready.
        let err = Gateway::spawn(
            "bad",
            GatewayConfig::default(),
            EngineSpec::stub(spec)
                .with_kv_codec(KvCodecSpec::Factored { layer_budgets: Some(vec![4, 4]) }),
        )
        .err()
        .expect("bad budget list must fail the spawn");
        assert!(err.to_string().contains("1-layer"), "{err:#}");
    }

    #[test]
    fn empty_prompt_refused_before_id_allocation() {
        let gw = Gateway::spawn(
            "empty",
            GatewayConfig::default(),
            EngineSpec::stub(StubSpec::default()),
        )
        .unwrap();
        assert_eq!(
            gw.submit(vec![], 4, SamplingParams::greedy(), None).err(),
            Some(SubmitError::EmptyPrompt)
        );
        assert_eq!(gw.in_flight(), 0, "refused submit leaves no state behind");
        assert_eq!(gw.queued_prefill_tokens(), 0);
        // Ids stay dense for real submissions after a refusal.
        let t = gw.submit(vec![1], 1, SamplingParams::greedy(), None).unwrap();
        assert_eq!(t.id, 0);
        assert!(t.stream.wait().unwrap().is_done());
        gw.join().unwrap();
    }

    /// Satellite: a cancel token firing *during prefill* (before any
    /// sampled token) yields exactly one `Cancelled` whose partial row is
    /// the untouched prompt, and the lane is reclaimed by the waiter in
    /// the same iteration.
    #[test]
    fn stub_cancel_during_prefill_one_cancelled_no_tokens_same_step_reclaim() {
        let gw = Gateway::spawn(
            "prefill-cancel",
            GatewayConfig::default(),
            EngineSpec::stub(prefill_stub_spec()),
        )
        .unwrap();
        let prompt: Vec<i32> = (0..64).collect();
        let victim = gw.submit(prompt.clone(), 8, SamplingParams::greedy(), None).unwrap();
        let waiter = gw.submit(vec![1, 2], 2, SamplingParams::greedy(), None).unwrap();
        // Wait until the victim is provably in a lane, then cancel: with a
        // 64-step prefill at 5ms/step the token fires mid-prefill.
        loop {
            match victim.stream.next_event() {
                Some(StreamEvent::Started { .. }) => break,
                Some(_) => continue,
                None => panic!("victim stream closed before Started"),
            }
        }
        victim.cancel.cancel();
        let (mut cancel_step, mut victim_tokens, mut terminals) = (None, 0usize, 0usize);
        while let Some(ev) = victim.stream.next_event() {
            match ev {
                StreamEvent::Token { .. } => victim_tokens += 1,
                StreamEvent::Cancelled { reason, tokens, step, .. } => {
                    terminals += 1;
                    assert_eq!(reason, CancelReason::User);
                    assert_eq!(tokens, prompt, "partial row is the untouched prompt");
                    cancel_step = Some(step);
                }
                StreamEvent::Done { .. } => panic!("victim must not complete"),
                _ => {}
            }
        }
        assert_eq!(terminals, 1, "exactly one terminal event");
        assert_eq!(victim_tokens, 0, "no tokens were sampled during prefill");
        let mut waiter_started = None;
        let mut waiter_done = false;
        while let Some(ev) = waiter.stream.next_event() {
            match ev {
                StreamEvent::Started { step, .. } => waiter_started = Some(step),
                StreamEvent::Done { .. } => waiter_done = true,
                _ => {}
            }
        }
        assert!(waiter_done);
        assert_eq!(
            waiter_started, cancel_step,
            "waiter reclaims the lane in the cancellation's own iteration"
        );
        let m = gw.join().unwrap();
        assert_eq!((m.completed, m.cancelled), (1, 1));
    }

    /// Satellite twin: a deadline expiring during prefill behaves like a
    /// mid-prefill cancel — one `Cancelled{Deadline}`, zero tokens.
    ///
    /// Runs on a *manual* clock: the stub's 5ms step delays advance
    /// virtual time instead of blocking, so the 30ms deadline lands after
    /// exactly six 1-token prefill steps — deterministic mid-prefill
    /// expiry with no wall-clock sleeping at all.
    #[test]
    fn stub_deadline_during_prefill_cancels_with_no_tokens() {
        let clock = Clock::manual();
        let gw = Gateway::spawn(
            "prefill-deadline",
            GatewayConfig::default(),
            EngineSpec::stub(StubSpec { clock: clock.clone(), ..prefill_stub_spec() }),
        )
        .unwrap();
        let prompt: Vec<i32> = (0..64).collect();
        let t = gw
            .submit(prompt.clone(), 8, SamplingParams::greedy(), Some(Duration::from_millis(30)))
            .unwrap();
        match t.stream.wait().unwrap() {
            StreamOutcome::Cancelled { reason, tokens, .. } => {
                assert_eq!(reason, CancelReason::Deadline);
                assert_eq!(tokens, prompt, "nothing generated before the deadline");
            }
            StreamOutcome::Done(c) => panic!("completed past its deadline: {c:?}"),
        }
        let m = gw.join().unwrap();
        assert_eq!((m.completed, m.cancelled), (0, 1));
    }

    /// Regression (observability): after a mid-prefill user cancel *and*
    /// a mid-prefill deadline expiry, the published `queued_prefill` /
    /// `in_flight` gauges return to zero and every span timeline in the
    /// trace sink is closed — the taps leak no per-request state.
    #[test]
    fn obs_gauges_zero_and_spans_closed_after_prefill_cancels() {
        let clock = Clock::manual();
        let obs = Obs::default();
        let gw = Gateway::spawn_with_obs(
            "obs",
            GatewayConfig::default(),
            EngineSpec::stub(StubSpec { clock: clock.clone(), ..prefill_stub_spec() }),
            Some(obs.clone()),
        )
        .unwrap();
        let victim = gw.submit((0..64).collect(), 8, SamplingParams::greedy(), None).unwrap();
        loop {
            match victim.stream.next_event() {
                Some(StreamEvent::Started { .. }) => break,
                Some(_) => continue,
                None => panic!("victim stream closed before Started"),
            }
        }
        victim.cancel.cancel();
        match victim.stream.wait().unwrap() {
            StreamOutcome::Cancelled { reason, .. } => assert_eq!(reason, CancelReason::User),
            StreamOutcome::Done(c) => panic!("victim completed past its cancel: {c:?}"),
        }
        let doomed = gw
            .submit((0..64).collect(), 8, SamplingParams::greedy(), Some(Duration::from_millis(30)))
            .unwrap();
        match doomed.stream.wait().unwrap() {
            StreamOutcome::Cancelled { reason, .. } => assert_eq!(reason, CancelReason::Deadline),
            StreamOutcome::Done(c) => panic!("doomed completed past its deadline: {c:?}"),
        }
        assert_eq!(gw.queued_prefill_tokens(), 0, "atomic drains at terminal events");
        let m = gw.join().unwrap();
        assert_eq!((m.completed, m.cancelled), (0, 2));
        // join() returns only after the worker's shutdown dump republished
        // the final gauge values.
        let reg = &obs.registry;
        assert_eq!(reg.get("clover_queued_prefill_tokens{gateway=\"obs\"}"), Some(0.0));
        assert_eq!(reg.get("clover_in_flight{gateway=\"obs\"}"), Some(0.0));
        assert_eq!(reg.get("clover_cancelled_total{gateway=\"obs\"}"), Some(2.0));
        let sink = obs.trace.lock().unwrap();
        assert_eq!(sink.open_spans(), 0, "cancelled spans are closed, not leaked");
        assert_eq!(sink.spans().count(), 2);
        for s in sink.spans() {
            assert!(s.cancelled && s.closed(), "span {} must end cancelled", s.id);
        }
    }

    #[test]
    fn queued_prefill_gauge_tracks_submit_and_drain() {
        let gw = Gateway::spawn(
            "gauge",
            GatewayConfig::default(),
            EngineSpec::stub(prefill_stub_spec()),
        )
        .unwrap();
        // The lane is busy with a long prefill, so the second submission
        // sits queued with its prompt counted as pending prefill work.
        let a = gw.submit((0..32).collect(), 2, SamplingParams::greedy(), None).unwrap();
        let b = gw.submit((0..16).collect(), 2, SamplingParams::greedy(), None).unwrap();
        assert_eq!(gw.queued_prefill_tokens(), 48, "counted at submit, in tokens");
        assert!(a.stream.wait().unwrap().is_done());
        assert!(b.stream.wait().unwrap().is_done());
        assert_eq!(gw.queued_prefill_tokens(), 0, "drained by first tokens");
        gw.join().unwrap();
    }

    /// Backpressure contract: `try_submit` refuses with `Saturated` when
    /// the bounded ingress is full, and everything accepted before the
    /// refusal still completes.
    #[test]
    fn bounded_ingress_backpressure() {
        if crate::testing::runtime_or_skip(&art()).is_none() {
            return;
        }
        // Tiny queue + long requests so the channel actually fills while
        // the worker is busy decoding.
        let gw = Gateway::spawn(
            "bp",
            GatewayConfig { queue_capacity: 1, ..Default::default() },
            EngineSpec::dense(&art(), "tiny", 8, 5),
        )
        .unwrap();
        let mut tickets = Vec::new();
        let mut saturated = false;
        for _ in 0..64 {
            match gw.try_submit(vec![1, 2], 24, SamplingParams::greedy(), None) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Saturated) => {
                    saturated = true;
                    break;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert!(saturated, "a capacity-1 ingress must saturate under burst");
        for t in tickets {
            assert!(t.stream.wait().unwrap().is_done());
        }
        let m = gw.join().unwrap();
        assert!(m.completed >= 1);
    }

    /// Load-shedding regression: a submit refused with `Overloaded`
    /// reclaims nothing — no id, no stream, no counter movement — and
    /// the requests already in flight complete untouched.  Once the
    /// backlog drains below the cap, submits are accepted again.
    #[test]
    fn overloaded_submit_reclaims_nothing_in_flight_unaffected() {
        let gw = Gateway::spawn(
            "shed",
            GatewayConfig { max_pending: Some(2), ..Default::default() },
            EngineSpec::stub(prefill_stub_spec()),
        )
        .unwrap();
        assert_eq!(gw.max_pending(), Some(2));
        let a = gw.submit((0..32).collect(), 2, SamplingParams::greedy(), None).unwrap();
        let b = gw.submit((0..32).collect(), 2, SamplingParams::greedy(), None).unwrap();
        assert_eq!(gw.in_flight(), 2);
        let depth_before = gw.queued_prefill_tokens();
        assert_eq!(
            gw.submit(vec![1, 2], 2, SamplingParams::greedy(), None).err(),
            Some(SubmitError::Overloaded)
        );
        assert_eq!(
            gw.try_submit(vec![1, 2], 2, SamplingParams::greedy(), None).err(),
            Some(SubmitError::Overloaded),
            "try_submit sheds identically"
        );
        assert_eq!(gw.in_flight(), 2, "refusals leave in-flight requests alone");
        assert_eq!(gw.queued_prefill_tokens(), depth_before, "...and the prefill gauge");
        assert_eq!((a.id, b.id), (0, 1));
        assert!(a.stream.wait().unwrap().is_done());
        assert!(b.stream.wait().unwrap().is_done());
        // Below the cap again: accepted, with the id dense after the
        // refusals (they allocated nothing).
        let c = gw.submit(vec![1], 1, SamplingParams::greedy(), None).unwrap();
        assert_eq!(c.id, 2, "refused submits burned no ids");
        assert!(c.stream.wait().unwrap().is_done());
        let m = gw.join().unwrap();
        assert_eq!(m.completed, 3);
        assert_eq!(m.migrated, 0, "shedding reclaims nothing from the queue");
    }

    /// Queue migration round-trip: a reclaim sweep on a busy gateway
    /// surrenders exactly its *queued* request — never the in-flight lane
    /// — and resubmitting it to a second gateway completes the client's
    /// original stream, with both engines' metrics conserving the move.
    #[test]
    fn reclaimed_queued_request_resubmits_and_completes_elsewhere() {
        let a = Gateway::spawn(
            "mig-a",
            GatewayConfig::default(),
            EngineSpec::stub(prefill_stub_spec()),
        )
        .unwrap();
        let mut b = Gateway::spawn(
            "mig-b",
            GatewayConfig::default(),
            EngineSpec::stub(prefill_stub_spec()),
        )
        .unwrap();
        // Fleet-unique ids, as a router would arrange them.
        b.share_id_counter(a.next_id.clone());
        let p0: Vec<i32> = (0..96).map(|i| i % 32).collect();
        let t0 = a.submit(p0, 8, SamplingParams::greedy(), None).unwrap();
        loop {
            match t0.stream.next_event() {
                Some(StreamEvent::Started { .. }) => break,
                Some(_) => continue,
                None => panic!("stream closed before Started"),
            }
        }
        // t0 holds gateway A's only lane (a 96-step slow prefill); t1
        // must wait in the queue — reclaimable.  Retry the sweep until
        // the worker has ingressed t1: a reclaim that races ahead of the
        // ingress drain legitimately comes back empty.
        let t1 = a.submit((0..16).collect(), 2, SamplingParams::greedy(), None).unwrap();
        let mut subs = Vec::new();
        for _ in 0..50 {
            subs = a.reclaim_queued(4);
            if !subs.is_empty() {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(subs.len(), 1, "only the queued request is surrendered");
        assert_eq!(subs[0].req.id, t1.id);
        assert_eq!(a.in_flight(), 1, "the in-flight request stays put");
        for sub in subs {
            assert!(b.resubmit(sub).is_ok());
        }
        assert!(t1.stream.wait().unwrap().is_done(), "the migrated stream completes on B");
        assert!(t0.stream.wait().unwrap().is_done());
        let ma = a.join().unwrap();
        let mb = b.join().unwrap();
        assert_eq!((ma.completed, ma.migrated), (1, 1), "A: one served, one surrendered");
        assert_eq!((mb.completed, mb.migrated), (1, 0), "B: the migrant completed");
        // An idle gateway's reclaim comes back empty, promptly.
        let idle = Gateway::spawn(
            "mig-idle",
            GatewayConfig::default(),
            EngineSpec::stub(prefill_stub_spec()),
        )
        .unwrap();
        assert!(idle.reclaim_queued(4).is_empty());
        idle.join().unwrap();
    }

    /// The prefix cache through the full gateway stack: an exact repeat
    /// of a served prompt hits, the completion tokens are bit-identical,
    /// and the hit/cached-bytes series land in the shared registry.
    #[test]
    fn prefix_cache_gateway_hits_and_publishes_metrics() {
        let obs = Obs::default();
        let spec = StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 8,
            vocab: 16,
            max_positions: 128,
            batch_slots: 1,
            ..Default::default()
        };
        let gw = Gateway::spawn_with_obs(
            "pfx",
            GatewayConfig::default(),
            EngineSpec::stub(spec).with_prefix_cache(Some(32)),
            Some(obs.clone()),
        )
        .unwrap();
        assert_eq!(gw.prefix_cache_block(), Some(32));
        let prompt: Vec<i32> = (0..64).map(|i| i % 16).collect();
        let t0 = gw.submit(prompt.clone(), 4, SamplingParams::greedy(), None).unwrap();
        let c0 = t0.stream.wait().unwrap().completion().unwrap();
        let t1 = gw.submit(prompt.clone(), 4, SamplingParams::greedy(), None).unwrap();
        let c1 = t1.stream.wait().unwrap().completion().unwrap();
        assert_eq!(c0.tokens, c1.tokens, "a cache hit changes the schedule, never the tokens");
        gw.join().unwrap();
        let reg = &obs.registry;
        assert_eq!(reg.get("clover_prefix_hits_total{gateway=\"pfx\"}"), Some(1.0));
        assert_eq!(reg.get("clover_prefix_hit_tokens_total{gateway=\"pfx\"}"), Some(32.0));
        // Request 0's donated 64-token prompt: 4 pages resident at
        // 2·L·H·r·4 = 128 B/token × 16 = 2048 B each.
        assert_eq!(reg.get("clover_prefix_cached_bytes{gateway=\"pfx\"}"), Some(8192.0));
        let sink = obs.trace.lock().unwrap();
        let hit_span = sink.spans().find(|s| s.id == t1.id).expect("span for the hit");
        assert_eq!(hit_span.prefix_hit_tokens, Some(32));
    }

    // ---- chaos: supervision, replay, failover ----

    #[test]
    fn panic_msg_extracts_str_and_string_payloads() {
        let p = std::panic::catch_unwind(|| panic!("plain str")).expect_err("panics");
        assert_eq!(panic_msg(p.as_ref()), "plain str");
        let p = std::panic::catch_unwind(|| panic!("formatted {}", 7)).expect_err("panics");
        assert_eq!(panic_msg(p.as_ref()), "formatted 7");
        let p = std::panic::catch_unwind(|| std::panic::panic_any(42i32)).expect_err("panics");
        assert_eq!(panic_msg(p.as_ref()), "non-string panic payload");
    }

    /// Serve the same 4 greedy requests through a gateway built on `spec`
    /// and return each completion's full token row, in submit order.
    fn serve_rows(name: &str, cfg: GatewayConfig, spec: StubSpec) -> Vec<Vec<i32>> {
        let gw = Gateway::spawn(name, cfg, EngineSpec::stub(spec)).expect("spawn");
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| {
                gw.submit(vec![1 + i, 2, 3], 8, SamplingParams::greedy(), None).expect("submit")
            })
            .collect();
        let rows = tickets
            .into_iter()
            .map(|t| {
                t.stream
                    .wait()
                    .expect("terminal event")
                    .completion()
                    .expect("completes despite faults")
                    .tokens
            })
            .collect();
        gw.join().expect("supervised worker drains cleanly");
        rows
    }

    /// Tentpole: a mid-serve fatal backend death is invisible to clients.
    /// The supervisor rebuilds the engine (fault plan defused) and
    /// replays every interrupted request as prompt ⧺ streamed tokens —
    /// completions are bit-identical to a fault-free run, and the restart
    /// is visible in the shared registry.
    #[test]
    fn supervisor_replays_fatal_death_bit_identical() {
        let spec = StubSpec { max_positions: 64, ..Default::default() };
        let clean = serve_rows("sup-clean", GatewayConfig::default(), spec.clone());
        let faulty = StubSpec {
            fault_plan: FaultPlan { fatal_after_steps: Some(4), ..Default::default() },
            ..spec
        };
        let obs = Obs::default();
        let gw = Gateway::spawn_with_obs(
            "sup",
            GatewayConfig::default(),
            EngineSpec::stub(faulty),
            Some(obs.clone()),
        )
        .expect("spawn");
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| {
                gw.submit(vec![1 + i, 2, 3], 8, SamplingParams::greedy(), None).expect("submit")
            })
            .collect();
        let rows: Vec<Vec<i32>> = tickets
            .into_iter()
            .map(|t| {
                let mut streamed = Vec::new();
                let mut done = None;
                while let Some(ev) = t.stream.next_event() {
                    match ev {
                        StreamEvent::Token { token, .. } => streamed.push(token),
                        StreamEvent::Done { completion } => {
                            done = Some(completion);
                            break;
                        }
                        StreamEvent::Cancelled { id, .. } | StreamEvent::Failed { id, .. } => {
                            panic!("request {id} must survive the death")
                        }
                        _ => {}
                    }
                }
                let c = done.expect("Done despite the mid-serve death");
                // The resumed stream carries no duplicate tokens: streamed
                // events reassemble exactly the generated suffix.
                assert_eq!(streamed.as_slice(), &c.tokens[3..], "request {}", c.id);
                c.tokens
            })
            .collect();
        gw.join().expect("replacement engine drains cleanly");
        assert_eq!(rows, clean, "replay is lossless and bit-identical");
        assert_eq!(
            obs.registry.get("clover_engine_restarts_total{gateway=\"sup\"}"),
            Some(1.0),
            "the fatal fault cost exactly one supervised restart"
        );
        assert_eq!(
            obs.registry.get("clover_failed_total{gateway=\"sup\"}"),
            None,
            "no client-visible failure was recorded"
        );
    }

    /// A backend *panic* (crash fault) recovers through the same replay
    /// path as a fatal error: `catch_unwind` contains it, the rebuilt
    /// engine finishes everything, and outputs stay bit-identical.
    #[test]
    fn supervisor_recovers_backend_panic_mid_serve() {
        let spec = StubSpec { max_positions: 64, ..Default::default() };
        let clean = serve_rows("crash-clean", GatewayConfig::default(), spec.clone());
        let crashing = StubSpec {
            fault_plan: FaultPlan { crash_after_steps: Some(3), ..Default::default() },
            ..spec
        };
        let rows = serve_rows("crash", GatewayConfig::default(), crashing);
        assert_eq!(rows, clean, "a caught panic replays as losslessly as an error");
    }

    /// Restart budget spent: the supervisor stops rebuilding and every
    /// surviving request gets exactly one terminal `Failed{Backend}`
    /// whose partial row is prompt ⧺ streamed — no stream is stranded,
    /// and `join` surfaces the underlying retry-budget error.
    #[test]
    fn restart_budget_spent_fails_survivors_with_terminal_events() {
        // Every step faults transiently, so every engine incarnation dies
        // on its first step once the per-step retry budget is spent.
        let spec = StubSpec {
            fault_plan: FaultPlan { seed: 1, transient_rate: 1.0, ..Default::default() },
            ..Default::default()
        };
        let gw = Gateway::spawn(
            "doom",
            GatewayConfig { max_restarts: 1, ..Default::default() },
            EngineSpec::stub(spec),
        )
        .expect("spawn succeeds — death comes on the first step, not at build");
        let t = gw.submit(vec![1, 2], 4, SamplingParams::greedy(), None).expect("submit");
        match t.stream.wait().expect("terminal event despite the dead worker") {
            StreamOutcome::Failed { id, reason, tokens } => {
                assert_eq!((id, reason), (0, FailReason::Backend));
                assert_eq!(tokens, vec![1, 2], "no token ever streamed: the row is the prompt");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(gw.in_flight(), 0, "the terminal event released the request");
        let err = gw.join().expect_err("the worker dies with its backend");
        assert!(format!("{err:#}").contains("retry budget"), "{err:#}");
    }

    /// Without supervision (`max_restarts: 0`), a backend death is
    /// delivered directly: the engine's own `on_failed` reaches the
    /// client as `Failed{Backend}` with the partial row it salvaged.
    #[test]
    fn unsupervised_backend_death_fails_clients_directly() {
        let spec = StubSpec {
            fault_plan: FaultPlan { fatal_after_steps: Some(2), ..Default::default() },
            ..Default::default()
        };
        let gw = Gateway::spawn(
            "unsup",
            GatewayConfig { max_restarts: 0, ..Default::default() },
            EngineSpec::stub(spec),
        )
        .expect("spawn");
        let t = gw.submit(vec![1, 2, 3], 8, SamplingParams::greedy(), None).expect("submit");
        match t.stream.wait().expect("terminal event") {
            StreamOutcome::Failed { reason, tokens, .. } => {
                assert_eq!(reason, FailReason::Backend);
                assert_eq!(&tokens[..3], &[1, 2, 3], "row starts with the prompt");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        gw.join().expect_err("unsupervised death surfaces from join");
    }

    /// Failover parking: a dead-for-good worker parks its interrupted
    /// requests as resubmittable orphans — merged prompt, live stream,
    /// fleet-unique id — and a sibling gateway finishes them, with the
    /// client seeing one Done bit-identical to an undisturbed run.
    #[test]
    fn dead_gateway_parks_orphans_for_failover() {
        let clean = serve_rows("orph-clean", GatewayConfig::default(), StubSpec::default());
        // Slow steps: all four submits land before the step-4 death, so
        // none races the dying ingress.
        let spec = StubSpec {
            fault_plan: FaultPlan { fatal_after_steps: Some(4), ..Default::default() },
            step_delay: Duration::from_millis(2),
            ..Default::default()
        };
        let doomed = Gateway::spawn(
            "orph",
            GatewayConfig { max_restarts: 0, failover: true, ..Default::default() },
            EngineSpec::stub(spec),
        )
        .expect("spawn");
        let mut sibling =
            Gateway::spawn("orph-sib", GatewayConfig::default(), EngineSpec::stub(StubSpec::default()))
                .expect("spawn sibling");
        sibling.share_id_counter(doomed.next_id.clone());
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| {
                doomed
                    .submit(vec![1 + i, 2, 3], 8, SamplingParams::greedy(), None)
                    .expect("submit")
            })
            .collect();
        // The fatal fault fires within a few steps; the worker parks its
        // orphans and exits.
        for _ in 0..500 {
            if !doomed.is_alive() {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!doomed.is_alive(), "the fatal fault must kill the unsupervised worker");
        let orphans = doomed.take_orphans();
        assert!(!orphans.is_empty(), "interrupted requests are parked, not failed");
        assert!(doomed.take_orphans().is_empty(), "take_orphans drains");
        for sub in orphans {
            assert!(sibling.resubmit(sub).is_ok(), "sibling accepts the orphan");
        }
        let rows: Vec<Vec<i32>> = tickets
            .into_iter()
            .map(|t| {
                t.stream
                    .wait()
                    .expect("terminal event")
                    .completion()
                    .expect("orphans complete on the sibling")
                    .tokens
            })
            .collect();
        assert_eq!(rows, clean, "failover is lossless and bit-identical");
        sibling.join().expect("sibling drains");
        let _ = doomed.join().expect_err("the doomed worker died");
    }

    /// Prefix caching and a speculative draft pair are mutually exclusive
    /// on one engine — the combination fails the spawn, not the first
    /// request.
    #[test]
    fn prefix_cache_plus_speculative_fails_spawn() {
        let target = StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 8,
            vocab: 16,
            max_positions: 128,
            ..Default::default()
        };
        let draft = StubSpec { rank: 4, ..target.clone() };
        let err = Gateway::spawn(
            "pfx-spec",
            GatewayConfig::default(),
            EngineSpec::stub(target)
                .with_prefix_cache(Some(32))
                .with_speculative(
                    DraftSource::Stub(draft),
                    SpecConfig { draft_len: 4, adaptive: true },
                ),
        )
        .err()
        .expect("prefix cache + speculative pair must be refused");
        assert!(err.to_string().contains("mutually exclusive"), "{err:#}");
    }
}
