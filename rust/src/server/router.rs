//! Rank-aware routing across gateways whose engines were compiled at
//! different CLOVER pruning ranks.
//!
//! The paper's claim, made operational: pruning head rank to r cuts KV
//! bytes per token to r/d of dense ([`crate::serve::KvConfig::bytes_per_token`]),
//! so at equal queue depth a pruned engine is the cheaper place to put the
//! next request.  The per-token cost is *codec-aware*: an engine storing
//! its cache through the factored page codec
//! ([`crate::serve::KvCodecSpec`], `--kv-codec factored`) reports the
//! compressed bytes, so at equal depth the router prefers it the same way
//! it prefers a lower compiled rank.  The router scores each gateway as
//!
//! ```text
//! score(g) = (in_flight(g) + 1 + queued_prefill_tokens(g))
//!              × kv_bytes_per_token(g)
//! ```
//!
//! — the marginal KV pressure of admitting one more request there, with
//! waiting requests weighted by their `prompt.len()` of pending prefill
//! work rather than counting 1 apiece.  Request count alone is blind to
//! prompt length: a burst of 512-token prompts and a burst of 2-token
//! prompts looked identical, so long-prompt traffic piled onto one engine
//! until its queue *length* caught up.  Pending prefill tokens is the
//! actual backlog (it is also, post-prefill, the KV the requests will
//! pin), and it drains as prefills complete —
//! [`Gateway::queued_prefill_tokens`].
//!
//! A **speculative draft+verify pair** consumes two engines: its gateway
//! reports the *combined* target + draft per-token KV cost
//! ([`Gateway::kv_bytes_per_token`] already includes both caches), so at
//! equal queue depth the router correctly prefers a plain engine over a
//! pair of the same target rank — the pair's throughput advantage is per
//! *token*, its cost is per *resident request*.
//!
//! Ties resolve to the earliest gateway in construction order, so callers
//! list their preferred (typically lowest-rank) engine first.
//!
//! ## Fleet scheduling: prefix affinity, migration, degradation
//!
//! Three mechanisms promote the cost-min picker into a fleet scheduler:
//!
//! * **Prefix-affine placement** — for every prefix-cache-enabled
//!   gateway the router keeps a *shadow directory* of the chain hashes
//!   ([`crate::serve::chain_hashes`]) of prompts it has placed there.
//!   [`Router::pick_for`] discounts a candidate's pending-prefill weight
//!   by the prompt's longest directory-matched prefix: the engine that
//!   already holds a prompt's prefix prefills only the cold tail, so it
//!   wins placement even against an otherwise-cheaper sibling.  The
//!   directory is an optimistic estimate (it does not mirror engine-side
//!   eviction); a stale entry costs one mis-ranked pick, never
//!   correctness — the engine's own trie decides what actually attaches.
//! * **Queue migration** — [`Router::rebalance`] sweeps saturated
//!   gateways (`in_flight > batch_slots`: a queue has formed) and moves
//!   *queued* requests — reclaimed from the back of the batcher, never a
//!   running lane — onto gateways with spare capacity, cheapest (and
//!   prefix-affine) first.  At most the fleet's spare lane count moves
//!   per sweep, so rebalancing converges instead of oscillating.
//! * **Graceful degradation** — [`Router::submit_classed`] routes
//!   [`TrafficClass::Interactive`] traffic away from a saturated
//!   preferred gateway onto the cheapest unsaturated engine, even when
//!   that means a lower CLOVER rank (counted in
//!   `clover_router_degraded_total`); [`TrafficClass::Batch`] traffic
//!   keeps its cost-min pick and simply queues.  Load shedding
//!   ([`SubmitError::Overloaded`], `GatewayConfig::max_pending`)
//!   propagates to the caller for both classes.

use anyhow::{bail, Result};
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::obs::Registry;
use crate::serve::{chain_hashes, SamplingParams, ServeMetrics};

use super::gateway::{Gateway, SubmitError, Ticket};

/// Latency tolerance of a submission, for [`Router::submit_classed`]:
/// interactive traffic degrades to a lower-rank engine rather than queue
/// behind a saturated one; batch traffic queues for its cost-min pick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrafficClass {
    Interactive,
    Batch,
}

pub struct Router {
    gateways: Vec<Gateway>,
    /// Per-gateway shadow prefix directory: chain hashes of prompts this
    /// router has placed there (empty for gateways without a prefix
    /// cache).  See the module docs — an estimate, not a mirror.
    dirs: Vec<Mutex<HashSet<u64>>>,
    /// Queued requests moved between gateways by [`Router::rebalance`].
    migrated: AtomicUsize,
    /// Interactive submissions placed on a lower rank than their
    /// preferred (saturated) gateway.
    degraded: AtomicUsize,
}

impl Router {
    pub fn new(mut gateways: Vec<Gateway>) -> Result<Self> {
        if gateways.is_empty() {
            bail!("Router needs at least one gateway");
        }
        // One id counter for the whole fleet: a consumer muxing events
        // from several gateways can key on `StreamEvent::id` without
        // cross-gateway collisions.
        let ids = Arc::new(AtomicU64::new(0));
        for g in &mut gateways {
            g.share_id_counter(ids.clone());
        }
        let dirs = gateways.iter().map(|_| Mutex::new(HashSet::new())).collect();
        Ok(Self {
            gateways,
            dirs,
            migrated: AtomicUsize::new(0),
            degraded: AtomicUsize::new(0),
        })
    }

    pub fn gateways(&self) -> &[Gateway] {
        &self.gateways
    }

    /// Marginal KV pressure of admitting one more request to `g`:
    /// in-flight depth plus pending prefill work in tokens, weighted by
    /// the engine's per-token KV cost.
    fn score(g: &Gateway) -> u128 {
        (g.in_flight() as u128 + 1 + g.queued_prefill_tokens() as u128)
            * g.kv_bytes_per_token() as u128
    }

    /// Index of the gateway the next request would go to, prompt unseen.
    pub fn pick(&self) -> usize {
        self.gateways
            .iter()
            .enumerate()
            .min_by_key(|(_, g)| Self::score(g))
            .map(|(i, _)| i)
            .expect("router is non-empty")
    }

    /// A gateway with more accepted requests than KV lanes has a queue —
    /// the scheduler's saturation predicate (migration source, the
    /// trigger for interactive degradation).
    fn saturated(g: &Gateway) -> bool {
        g.in_flight() > g.batch_slots()
    }

    /// Tokens of `prompt` gateway `i` is *estimated* to already hold in
    /// its prefix cache: the longest chain-hash prefix present in the
    /// shadow directory, capped at `len − 1` exactly like the engine's
    /// attach (the last prompt token always prefills).
    fn est_hit_tokens(&self, i: usize, prompt: &[i32]) -> usize {
        let Some(block) = self.gateways[i].prefix_cache_block() else {
            return 0;
        };
        let dir = self.dirs[i].lock().unwrap();
        let mut hit = 0;
        for h in chain_hashes(prompt, block) {
            if !dir.contains(&h) {
                break;
            }
            hit += block;
        }
        hit.min(prompt.len().saturating_sub(1))
    }

    /// [`Router::score`] for a *known* prompt: the prompt's own prefill
    /// work joins the pending-token backlog, discounted by the prefix
    /// tokens gateway `i` is estimated to serve from cache.
    fn score_for(&self, i: usize, prompt: &[i32]) -> u128 {
        let g = &self.gateways[i];
        let fresh = (prompt.len() - self.est_hit_tokens(i, prompt)) as u128;
        (g.in_flight() as u128 + 1 + g.queued_prefill_tokens() as u128 + fresh)
            * g.kv_bytes_per_token() as u128
    }

    /// Index of the gateway `prompt` would go to: cost-min placement with
    /// prefix-cache affinity (a directory-matched prefix prefills from
    /// cache, so only the cold tail is weighed).
    pub fn pick_for(&self, prompt: &[i32]) -> usize {
        (0..self.gateways.len())
            .min_by_key(|&i| self.score_for(i, prompt))
            .expect("router is non-empty")
    }

    /// Record `prompt`'s chain hashes in gateway `i`'s shadow directory
    /// (no-op for gateways without a prefix cache).
    fn note_prompt(&self, i: usize, prompt: &[i32]) {
        if let Some(block) = self.gateways[i].prefix_cache_block() {
            self.dirs[i].lock().unwrap().extend(chain_hashes(prompt, block));
        }
    }

    /// Route one request (blocking submit — backpressure applies at the
    /// chosen gateway).  Returns the chosen gateway index with the ticket.
    /// Equivalent to [`Router::submit_classed`] with
    /// [`TrafficClass::Batch`].
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Duration>,
    ) -> std::result::Result<(usize, Ticket), SubmitError> {
        self.submit_classed(prompt, max_new, sampling, deadline, TrafficClass::Batch)
    }

    /// Route one request with a latency class.  Batch traffic takes the
    /// prefix-affine cost-min pick and queues if that gateway is busy.
    /// Interactive traffic *degrades*: when its preferred gateway is
    /// saturated, it goes to the cheapest unsaturated engine instead —
    /// trading CLOVER rank (answer quality) for latency, which is counted
    /// in `clover_router_degraded_total` when the fallback's rank is
    /// lower.  With the whole fleet saturated, both classes queue at the
    /// preferred gateway.  [`SubmitError::Overloaded`] (load shedding at
    /// the gateway's `max_pending` cap) propagates to the caller.
    pub fn submit_classed(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Duration>,
        class: TrafficClass,
    ) -> std::result::Result<(usize, Ticket), SubmitError> {
        let preferred = self.pick_for(&prompt);
        let mut idx = preferred;
        if class == TrafficClass::Interactive && Self::saturated(&self.gateways[preferred]) {
            let fallback = (0..self.gateways.len())
                .filter(|&j| !Self::saturated(&self.gateways[j]))
                .min_by_key(|&j| self.score_for(j, &prompt));
            if let Some(j) = fallback {
                if self.gateways[j].rank() < self.gateways[preferred].rank() {
                    self.degraded.fetch_add(1, Ordering::Relaxed);
                }
                idx = j;
            }
        }
        let hashes = self
            .gateways[idx]
            .prefix_cache_block()
            .map(|block| chain_hashes(&prompt, block));
        let ticket = self.gateways[idx].submit(prompt, max_new, sampling, deadline)?;
        if let Some(hs) = hashes {
            self.dirs[idx].lock().unwrap().extend(hs);
        }
        Ok((idx, ticket))
    }

    /// One migration sweep: every saturated gateway surrenders queued
    /// requests — reclaimed from the *back* of its batcher, so running
    /// lanes and the head-of-line waiter are untouched — and each moves
    /// to the cheapest (prefix-affine) gateway with a free KV lane.  At
    /// most the fleet's spare lane count moves per sweep, which is what
    /// makes repeated sweeps converge instead of ping-ponging requests
    /// between two saturated engines.  Returns the number migrated; the
    /// running total is exported as `clover_router_migrated_total`.
    pub fn rebalance(&self) -> usize {
        let mut moved = 0;
        for (i, src) in self.gateways.iter().enumerate() {
            let excess = src.in_flight().saturating_sub(src.batch_slots());
            if excess == 0 {
                continue;
            }
            let spare: usize = self
                .gateways
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, g)| g.batch_slots().saturating_sub(g.in_flight()))
                .sum();
            let take = excess.min(spare);
            if take == 0 {
                continue;
            }
            for sub in src.reclaim_queued(take) {
                let prompt = sub.req.prompt.clone();
                // Free-lane gateways first; if a racing submit just took
                // the last lane, fall back to the cheapest other gateway
                // — the request must land somewhere, and its origin would
                // reject the id as a duplicate.
                let target = (0..self.gateways.len())
                    .filter(|&j| {
                        j != i && self.gateways[j].in_flight() < self.gateways[j].batch_slots()
                    })
                    .min_by_key(|&j| self.score_for(j, &prompt))
                    .or_else(|| {
                        (0..self.gateways.len())
                            .filter(|&j| j != i)
                            .min_by_key(|&j| self.score_for(j, &prompt))
                    });
                let Some(j) = target else { break };
                if self.gateways[j].resubmit(sub).is_ok() {
                    self.note_prompt(j, &prompt);
                    moved += 1;
                }
            }
        }
        self.migrated.fetch_add(moved, Ordering::Relaxed);
        moved
    }

    /// Queued requests moved between gateways by [`Router::rebalance`],
    /// over this router's lifetime.
    pub fn migrated_total(&self) -> usize {
        self.migrated.load(Ordering::Relaxed)
    }

    /// Interactive submissions served by a lower rank than their
    /// preferred gateway's ([`Router::submit_classed`]).
    pub fn degraded_total(&self) -> usize {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Per-gateway share of all submissions routed so far, as
    /// `(name, rank, submitted)` rows.
    pub fn shares(&self) -> Vec<(String, usize, usize)> {
        self.gateways
            .iter()
            .map(|g| (g.name().to_string(), g.rank(), g.submitted()))
            .collect()
    }

    /// Publish every gateway's routing-visible state into `reg` as
    /// per-rank gauges labelled `{gateway="NAME",rank="R"}` — the
    /// handle-side view (queue depth, pending prefill tokens, per-token
    /// KV cost, lifetime submissions, routing score).  Complements the
    /// worker-side series a [`super::gateway::Obs`]-tapped gateway
    /// publishes itself.
    pub fn export_metrics(&self, reg: &Registry) {
        for g in &self.gateways {
            let labels = format!("{{gateway=\"{}\",rank=\"{}\"}}", g.name(), g.rank());
            reg.gauge_set(&format!("clover_router_in_flight{labels}"), g.in_flight() as f64);
            reg.gauge_set(
                &format!("clover_router_queued_prefill_tokens{labels}"),
                g.queued_prefill_tokens() as f64,
            );
            reg.gauge_set(
                &format!("clover_router_kv_bytes_per_token{labels}"),
                g.kv_bytes_per_token() as f64,
            );
            reg.gauge_set(&format!("clover_router_submitted{labels}"), g.submitted() as f64);
            reg.gauge_set(&format!("clover_router_score{labels}"), Self::score(g) as f64);
        }
        for (g, dir) in self.gateways.iter().zip(&self.dirs) {
            if g.prefix_cache_block().is_none() {
                continue;
            }
            let labels = format!("{{gateway=\"{}\",rank=\"{}\"}}", g.name(), g.rank());
            reg.gauge_set(
                &format!("clover_router_prefix_dir_blocks{labels}"),
                dir.lock().unwrap().len() as f64,
            );
        }
        reg.gauge_set("clover_router_migrated_total", self.migrated_total() as f64);
        reg.gauge_set("clover_router_degraded_total", self.degraded_total() as f64);
    }

    /// One-shot Prometheus text of the routing gauges (stats lines, CLI).
    pub fn prometheus_text(&self) -> String {
        let reg = Registry::new();
        self.export_metrics(&reg);
        reg.prometheus_text()
    }

    /// Gracefully shut every gateway down, returning each engine's final
    /// metrics keyed by gateway name.  Shutdown is signalled to all
    /// gateways *before* any is joined, so the engines drain in parallel
    /// (wall time ≈ the slowest drain, not the sum).
    pub fn join(self) -> Result<Vec<(String, ServeMetrics)>> {
        for g in &self.gateways {
            g.signal_shutdown();
        }
        self.gateways
            .into_iter()
            .map(|g| {
                let name = g.name().to_string();
                g.join().map(|m| (name, m))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::stub::StubSpec;
    use crate::serve::SamplingParams;
    use crate::server::gateway::{EngineSpec, GatewayConfig};
    use std::time::Duration;

    /// Single-lane, single-token-ladder stub with a slow step: requests
    /// submitted while the lane prefills stay queued for ~200ms — plenty
    /// of time for deterministic routing assertions.
    fn slow_stub() -> EngineSpec {
        EngineSpec::stub(StubSpec {
            batch_slots: 1,
            chunk_widths: vec![1],
            max_positions: 256,
            step_delay: Duration::from_millis(3),
            ..Default::default()
        })
    }

    #[test]
    fn long_prompt_bursts_spread_by_pending_prefill_tokens() {
        let router = Router::new(vec![
            Gateway::spawn("a", GatewayConfig::default(), slow_stub()).unwrap(),
            Gateway::spawn("b", GatewayConfig::default(), slow_stub()).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        // Occupy both single-lane engines with identical long prefills so
        // in_flight ties and everything submitted below stays queued.
        let mut tickets = Vec::new();
        for gw in g {
            tickets
                .push(gw.submit((0..64).collect(), 4, SamplingParams::greedy(), None).unwrap());
        }
        // A long prompt queues on "a", a short one on "b": request *count*
        // ties 2–2, but pending prefill is 64+100 vs 64+4 tokens.
        tickets.push(g[0].submit((0..100).collect(), 2, SamplingParams::greedy(), None).unwrap());
        tickets.push(g[1].submit((0..4).collect(), 2, SamplingParams::greedy(), None).unwrap());
        assert_eq!(g[0].in_flight(), g[1].in_flight(), "request count is tied");
        assert!(g[0].queued_prefill_tokens() > g[1].queued_prefill_tokens());
        // The old `(in_flight + 1) × bytes` score tied here and resolved
        // to "a" — piling the long-prompt burst onto one engine.  Weighted
        // by pending prefill tokens, the next request goes to "b".
        assert_eq!(router.pick(), 1);
        // Retire everything quickly and drain.
        for t in &tickets {
            t.cancel.cancel();
        }
        for (name, m) in router.join().unwrap() {
            assert_eq!(m.completed + m.cancelled, 2, "{name}");
        }
    }

    #[test]
    fn speculative_pair_costs_two_engines() {
        use crate::serve::SpecConfig;
        use crate::server::gateway::DraftSource;
        // Same target everywhere; gateway "pair" carries a rank-4 draft on
        // top.  At equal (zero) queue depth the plain engine must win —
        // the pair pins target + draft cache per resident token.
        let target = StubSpec { rank: 8, ..Default::default() };
        let draft = StubSpec { rank: 4, ..target.clone() };
        let pair_spec = EngineSpec::stub(target.clone())
            .with_speculative(DraftSource::Stub(draft), SpecConfig::default());
        let router = Router::new(vec![
            Gateway::spawn("pair", GatewayConfig::default(), pair_spec).unwrap(),
            Gateway::spawn("plain", GatewayConfig::default(), EngineSpec::stub(target)).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        assert!(g[0].speculative() && !g[1].speculative());
        assert_eq!(
            g[0].kv_bytes_per_token(),
            g[1].kv_bytes_per_token() * 3 / 2,
            "rank-4 draft adds half a rank-8 target's bytes"
        );
        // "pair" is listed first, so only its higher KV cost can explain
        // the router preferring "plain".
        assert_eq!(router.pick(), 1);
        router.join().unwrap();
    }

    #[test]
    fn factored_codec_engine_attracts_traffic_like_a_lower_rank() {
        use crate::serve::KvCodecSpec;
        // Two engines at the same compiled rank; "fact" stores its cache
        // through the factored codec at half budgets.  The router's
        // codec-aware per-token cost makes it the cheaper target at equal
        // depth, exactly as if it had been compiled one rank down.
        let target = StubSpec { rank: 8, ..Default::default() };
        let fact_spec = EngineSpec::stub(target.clone())
            .with_kv_codec(KvCodecSpec::Factored { layer_budgets: None });
        let router = Router::new(vec![
            Gateway::spawn("plain", GatewayConfig::default(), EngineSpec::stub(target)).unwrap(),
            Gateway::spawn("fact", GatewayConfig::default(), fact_spec).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        assert_eq!(g[0].rank(), g[1].rank(), "same compiled rank");
        assert_eq!(g[1].kv_bytes_per_token() * 2, g[0].kv_bytes_per_token());
        // "plain" is listed first and ties resolve to it, so only the
        // compressed cost can explain the router preferring "fact".
        assert_eq!(router.pick(), 1);
        router.join().unwrap();
    }

    #[test]
    fn export_metrics_publishes_per_rank_gauges() {
        let target = StubSpec { rank: 8, ..Default::default() };
        let low = StubSpec { rank: 4, ..target.clone() };
        let router = Router::new(vec![
            Gateway::spawn("r8", GatewayConfig::default(), EngineSpec::stub(target)).unwrap(),
            Gateway::spawn("r4", GatewayConfig::default(), EngineSpec::stub(low)).unwrap(),
        ])
        .unwrap();
        let reg = crate::obs::Registry::new();
        router.export_metrics(&reg);
        assert_eq!(reg.get("clover_router_in_flight{gateway=\"r8\",rank=\"8\"}"), Some(0.0));
        assert_eq!(
            reg.get("clover_router_kv_bytes_per_token{gateway=\"r4\",rank=\"4\"}"),
            Some(router.gateways()[1].kv_bytes_per_token() as f64),
        );
        let text = router.prometheus_text();
        assert!(text.contains("# TYPE clover_router_score gauge\n"));
        assert!(text.contains("clover_router_score{gateway=\"r8\",rank=\"8\"}"));
        router.join().unwrap();
    }

    /// A prompt goes back to the engine that already holds its prefix:
    /// the shadow directory's discount beats the construction-order
    /// tie-break that would otherwise send an idle-fleet submit to
    /// gateway 0.
    #[test]
    fn prefix_affinity_routes_repeat_prompts_to_their_cache() {
        let spec = || {
            EngineSpec::stub(StubSpec {
                batch_slots: 1,
                chunk_widths: vec![1],
                max_positions: 256,
                step_delay: Duration::from_millis(3),
                ..Default::default()
            })
            .with_prefix_cache(Some(32))
        };
        let router = Router::new(vec![
            Gateway::spawn("pa", GatewayConfig::default(), spec()).unwrap(),
            Gateway::spawn("pb", GatewayConfig::default(), spec()).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        let p: Vec<i32> = (0..64).map(|i| i % 32).collect();
        // Occupy "pa" so the first routed submit of `p` lands on "pb"
        // and seeds its directory.
        let filler =
            g[0].submit((0..100).map(|i| i % 32).collect(), 2, SamplingParams::greedy(), None)
                .unwrap();
        let (idx, t) =
            router.submit(p.clone(), 2, SamplingParams::greedy(), None).unwrap();
        assert_eq!(idx, 1, "busy pa loses the cold pick");
        assert!(t.stream.wait().unwrap().is_done());
        assert!(filler.stream.wait().unwrap().is_done());
        // Fleet idle again: promptless pick ties back to gateway 0, but
        // the prompt-aware pick follows the cached prefix to "pb" — and
        // an unrelated prompt does not.
        assert_eq!(router.pick(), 0);
        assert_eq!(router.pick_for(&p), 1);
        assert_eq!(router.pick_for(&[7; 64]), 0);
        let (idx, t) = router.submit(p, 2, SamplingParams::greedy(), None).unwrap();
        assert_eq!(idx, 1, "affinity routes the repeat to its cache");
        assert!(t.stream.wait().unwrap().is_done());
        let reg = crate::obs::Registry::new();
        router.export_metrics(&reg);
        assert_eq!(reg.get("clover_router_prefix_dir_blocks{gateway=\"pb\",rank=\"4\"}"), Some(2.0));
        assert_eq!(reg.get("clover_router_prefix_dir_blocks{gateway=\"pa\",rank=\"4\"}"), Some(0.0));
        router.join().unwrap();
    }

    /// Interactive traffic degrades off a saturated prefix-affine rank-8
    /// gateway onto the idle rank-4 engine; batch traffic keeps its
    /// affinity pick and queues.
    #[test]
    fn interactive_degrades_to_lower_rank_batch_queues() {
        let slow = |rank: usize, batch_slots: usize| StubSpec {
            batch_slots,
            chunk_widths: vec![1],
            max_positions: 256,
            step_delay: Duration::from_millis(3),
            rank,
            ..Default::default()
        };
        let router = Router::new(vec![
            Gateway::spawn(
                "hi",
                GatewayConfig::default(),
                EngineSpec::stub(slow(8, 1)).with_prefix_cache(Some(32)),
            )
            .unwrap(),
            Gateway::spawn("lo", GatewayConfig::default(), EngineSpec::stub(slow(4, 1))).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        let p: Vec<i32> = (0..64).map(|i| i % 32).collect();
        // Seed affinity for `p` on "hi": a 200-token backlog on "lo"
        // outweighs its half-price rank (needs > 64 pending tokens, so
        // the margin holds even after prefill has chewed a while), then
        // serve `p` to completion.
        let filler =
            g[1].submit((0..200).map(|i| i % 32).collect(), 2, SamplingParams::greedy(), None)
                .unwrap();
        let (idx, t) = router.submit(p.clone(), 2, SamplingParams::greedy(), None).unwrap();
        assert_eq!(idx, 0, "rank-8 wins while rank-4 is backlogged");
        assert!(t.stream.wait().unwrap().is_done());
        assert!(filler.stream.wait().unwrap().is_done());
        // Saturate "hi": one long decode holds the lane, one waiter
        // queues behind it (in_flight 2 > 1 lane).
        let hold = g[0].submit(vec![1, 2, 3, 4], 64, SamplingParams::greedy(), None).unwrap();
        let _wait = g[0].submit(vec![5, 6, 7, 8], 2, SamplingParams::greedy(), None).unwrap();
        assert!(g[0].in_flight() > g[0].batch_slots());
        // Interactive: preferred is still the affine "hi" (its short
        // queue plus the 63-token cache discount beats a cold 64-token
        // prefill on "lo") — but it is saturated, so the request degrades
        // to the idle rank-4 engine.
        let (idx, ti) = router
            .submit_classed(p.clone(), 2, SamplingParams::greedy(), None, TrafficClass::Interactive)
            .unwrap();
        assert_eq!(idx, 1, "interactive degrades to the idle lower rank");
        assert_eq!(router.degraded_total(), 1);
        // Batch: same preference, no degradation — it queues on "hi".
        let (idx, tb) = router
            .submit_classed(p, 2, SamplingParams::greedy(), None, TrafficClass::Batch)
            .unwrap();
        assert_eq!(idx, 0, "batch waits for its prefix-affine pick");
        assert_eq!(router.degraded_total(), 1, "batch never counts as degraded");
        hold.cancel.cancel();
        assert!(ti.stream.wait().unwrap().is_done());
        assert!(tb.stream.wait().unwrap().is_done());
        router.join().unwrap();
    }

    /// The ISSUE's acceptance scenario: a burst that saturates the rank-8
    /// gateway spreads across the fleet — queued requests migrate to the
    /// idle rank-4 variant, bounded by its spare lanes, and every client
    /// stream still completes.
    #[test]
    fn queued_burst_migrates_to_idle_rank_variant() {
        use crate::server::stream::StreamEvent;
        let slow = |rank: usize, batch_slots: usize| {
            EngineSpec::stub(StubSpec {
                batch_slots,
                chunk_widths: vec![1],
                max_positions: 256,
                step_delay: Duration::from_millis(3),
                rank,
                ..Default::default()
            })
        };
        let router = Router::new(vec![
            Gateway::spawn("r8", GatewayConfig::default(), slow(8, 1)).unwrap(),
            Gateway::spawn("r4", GatewayConfig::default(), slow(4, 2)).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        // Long prefill pins r8's only lane...
        let head =
            g[0].submit((0..96).map(|i| i % 32).collect(), 8, SamplingParams::greedy(), None)
                .unwrap();
        loop {
            match head.stream.next_event() {
                Some(StreamEvent::Started { .. }) => break,
                Some(_) => continue,
                None => panic!("stream closed before Started"),
            }
        }
        // ...and a burst of three requests queues behind it (32-token
        // prompts: ~120ms of work each on r4, so the fleet stays busy
        // through the convergence assertions below).
        let burst: Vec<_> = (0..3)
            .map(|_| {
                g[0].submit((0..32).map(|i| i % 32).collect(), 8, SamplingParams::greedy(), None)
                    .unwrap()
            })
            .collect();
        assert_eq!(g[0].in_flight(), 4);
        assert_eq!(g[1].in_flight(), 0);
        // Rebalance until r4's two spare lanes are filled.  Sweeps race
        // the worker's ingress drain, so retry; each sweep moves at most
        // the spare-lane count, so the total is exactly 2 and the third
        // queued request stays on r8 (no ping-pong).
        let mut moved = 0;
        for _ in 0..50 {
            moved += router.rebalance();
            if moved >= 2 {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(moved, 2, "migration is bounded by the idle variant's spare lanes");
        assert_eq!(router.migrated_total(), 2);
        assert_eq!(g[1].in_flight(), 2, "the burst spread to the rank variant");
        assert_eq!(router.rebalance(), 0, "no spare lanes left — the sweep converges");
        assert!(head.stream.wait().unwrap().is_done());
        for t in burst {
            assert!(t.stream.wait().unwrap().is_done(), "migrated streams still complete");
        }
        let reg = crate::obs::Registry::new();
        router.export_metrics(&reg);
        assert_eq!(reg.get("clover_router_migrated_total"), Some(2.0));
        let metrics: std::collections::HashMap<String, _> =
            router.join().unwrap().into_iter().collect();
        assert_eq!(metrics["r8"].migrated, 2, "the source engine counted its surrendered queue");
        assert_eq!(metrics["r8"].completed, 2);
        assert_eq!(metrics["r4"].completed, 2);
        assert_eq!(metrics["r4"].migrated, 0);
    }
}
