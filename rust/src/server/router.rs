//! Rank-aware routing across gateways whose engines were compiled at
//! different CLOVER pruning ranks.
//!
//! The paper's claim, made operational: pruning head rank to r cuts KV
//! bytes per token to r/d of dense ([`crate::serve::KvConfig::bytes_per_token`]),
//! so at equal queue depth a pruned engine is the cheaper place to put the
//! next request.  The router scores each gateway as
//!
//! ```text
//! score(g) = (in_flight(g) + 1) × kv_bytes_per_token(g)
//! ```
//!
//! — the marginal KV pressure of admitting one more request there — and
//! dispatches to the minimum.  Cheap-rank engines therefore absorb
//! traffic until their backlog outweighs the rank saving, at which point
//! the dense engine starts taking overflow; the per-gateway shares the
//! bench reports are the measured version of that trade-off.
//!
//! Ties resolve to the earliest gateway in construction order, so callers
//! list their preferred (typically lowest-rank) engine first.

use anyhow::{bail, Result};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Duration;

use crate::serve::{SamplingParams, ServeMetrics};

use super::gateway::{Gateway, SubmitError, Ticket};

pub struct Router {
    gateways: Vec<Gateway>,
}

impl Router {
    pub fn new(mut gateways: Vec<Gateway>) -> Result<Self> {
        if gateways.is_empty() {
            bail!("Router needs at least one gateway");
        }
        // One id counter for the whole fleet: a consumer muxing events
        // from several gateways can key on `StreamEvent::id` without
        // cross-gateway collisions.
        let ids = Arc::new(AtomicU64::new(0));
        for g in &mut gateways {
            g.share_id_counter(ids.clone());
        }
        Ok(Self { gateways })
    }

    pub fn gateways(&self) -> &[Gateway] {
        &self.gateways
    }

    /// Marginal KV pressure of admitting one more request to `g`.
    fn score(g: &Gateway) -> u128 {
        (g.in_flight() as u128 + 1) * g.kv_bytes_per_token() as u128
    }

    /// Index of the gateway the next request would go to.
    pub fn pick(&self) -> usize {
        self.gateways
            .iter()
            .enumerate()
            .min_by_key(|(_, g)| Self::score(g))
            .map(|(i, _)| i)
            .expect("router is non-empty")
    }

    /// Route one request (blocking submit — backpressure applies at the
    /// chosen gateway).  Returns the chosen gateway index with the ticket.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Duration>,
    ) -> std::result::Result<(usize, Ticket), SubmitError> {
        let idx = self.pick();
        let ticket = self.gateways[idx].submit(prompt, max_new, sampling, deadline)?;
        Ok((idx, ticket))
    }

    /// Per-gateway share of all submissions routed so far, as
    /// `(name, rank, submitted)` rows.
    pub fn shares(&self) -> Vec<(String, usize, usize)> {
        self.gateways
            .iter()
            .map(|g| (g.name().to_string(), g.rank(), g.submitted()))
            .collect()
    }

    /// Gracefully shut every gateway down, returning each engine's final
    /// metrics keyed by gateway name.  Shutdown is signalled to all
    /// gateways *before* any is joined, so the engines drain in parallel
    /// (wall time ≈ the slowest drain, not the sum).
    pub fn join(self) -> Result<Vec<(String, ServeMetrics)>> {
        for g in &self.gateways {
            g.signal_shutdown();
        }
        self.gateways
            .into_iter()
            .map(|g| {
                let name = g.name().to_string();
                g.join().map(|m| (name, m))
            })
            .collect()
    }
}
