//! Rank-aware routing across gateways whose engines were compiled at
//! different CLOVER pruning ranks.
//!
//! The paper's claim, made operational: pruning head rank to r cuts KV
//! bytes per token to r/d of dense ([`crate::serve::KvConfig::bytes_per_token`]),
//! so at equal queue depth a pruned engine is the cheaper place to put the
//! next request.  The per-token cost is *codec-aware*: an engine storing
//! its cache through the factored page codec
//! ([`crate::serve::KvCodecSpec`], `--kv-codec factored`) reports the
//! compressed bytes, so at equal depth the router prefers it the same way
//! it prefers a lower compiled rank.  The router scores each gateway as
//!
//! ```text
//! score(g) = (in_flight(g) + 1 + queued_prefill_tokens(g))
//!              × kv_bytes_per_token(g)
//! ```
//!
//! — the marginal KV pressure of admitting one more request there, with
//! waiting requests weighted by their `prompt.len()` of pending prefill
//! work rather than counting 1 apiece.  Request count alone is blind to
//! prompt length: a burst of 512-token prompts and a burst of 2-token
//! prompts looked identical, so long-prompt traffic piled onto one engine
//! until its queue *length* caught up.  Pending prefill tokens is the
//! actual backlog (it is also, post-prefill, the KV the requests will
//! pin), and it drains as prefills complete —
//! [`Gateway::queued_prefill_tokens`].
//!
//! A **speculative draft+verify pair** consumes two engines: its gateway
//! reports the *combined* target + draft per-token KV cost
//! ([`Gateway::kv_bytes_per_token`] already includes both caches), so at
//! equal queue depth the router correctly prefers a plain engine over a
//! pair of the same target rank — the pair's throughput advantage is per
//! *token*, its cost is per *resident request*.
//!
//! Ties resolve to the earliest gateway in construction order, so callers
//! list their preferred (typically lowest-rank) engine first.
//!
//! ## Fleet scheduling: prefix affinity, migration, degradation
//!
//! Three mechanisms promote the cost-min picker into a fleet scheduler:
//!
//! * **Prefix-affine placement** — for every prefix-cache-enabled
//!   gateway the router keeps a *shadow directory* of the chain hashes
//!   ([`crate::serve::chain_hashes`]) of prompts it has placed there.
//!   [`Router::pick_for`] discounts a candidate's pending-prefill weight
//!   by the prompt's longest directory-matched prefix: the engine that
//!   already holds a prompt's prefix prefills only the cold tail, so it
//!   wins placement even against an otherwise-cheaper sibling.  The
//!   directory is an optimistic estimate (it does not mirror engine-side
//!   eviction); a stale entry costs one mis-ranked pick, never
//!   correctness — the engine's own trie decides what actually attaches.
//! * **Queue migration** — [`Router::rebalance`] sweeps saturated
//!   gateways (`in_flight > batch_slots`: a queue has formed) and moves
//!   *queued* requests — reclaimed from the back of the batcher, never a
//!   running lane — onto gateways with spare capacity, cheapest (and
//!   prefix-affine) first.  At most the fleet's spare lane count moves
//!   per sweep, so rebalancing converges instead of oscillating.
//! * **Graceful degradation** — [`Router::submit_classed`] routes
//!   [`TrafficClass::Interactive`] traffic away from a saturated
//!   preferred gateway onto the cheapest unsaturated engine, even when
//!   that means a lower CLOVER rank (counted in
//!   `clover_router_degraded_total`); [`TrafficClass::Batch`] traffic
//!   keeps its cost-min pick and simply queues.  Load shedding
//!   ([`SubmitError::Overloaded`], `GatewayConfig::max_pending`)
//!   propagates to the caller for both classes.
//!
//! ## Health: circuit breakers and engine failover
//!
//! Each gateway carries a router-side circuit breaker driven by an EWMA
//! of recent request outcomes ([`Router::note_result`]) plus the
//! worker's liveness flag:
//!
//! ```text
//! Healthy ──ewma > degraded_threshold──▶ Degraded (score ×2)
//! Degraded ──ewma > open_threshold────▶ Open     (unroutable)
//! Open ──probe_after elapsed──▶ half-open: ONE probe request allowed
//!       probe succeeds → Degraded/Healthy;  probe fails → Open re-arms
//! ```
//!
//! [`Router::pick`]/[`Router::pick_for`] route around `Open` gateways
//! (falling back to the full fleet only when *nothing* is routable, so a
//! caller still gets a deterministic pick), and weight a `Degraded`
//! gateway's score ×2 so traffic drains away before the breaker opens.
//!
//! **Failover** ([`Router::fail_over`]): a gateway whose worker died for
//! good (`Gateway::is_alive` false — restart budget spent with
//! `GatewayConfig::failover` set) has parked its interrupted requests as
//! replayable orphans.  The sweep marks the dead engine `Open`, drains
//! its orphans, and resubmits each — original id, live client stream,
//! merged `prompt ⧺ streamed` — to the cheapest live sibling, preferring
//! the lower compiled rank under pressure.  With no live sibling left the
//! orphan's stream gets a terminal `Failed` instead of a silent
//! disconnect, preserving the exactly-one-terminal-event contract.

use anyhow::{bail, Result};
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::obs::Registry;
use crate::serve::{chain_hashes, FailReason, SamplingParams, ServeMetrics};

use super::gateway::{Gateway, SubmitError, Ticket};

/// Latency tolerance of a submission, for [`Router::submit_classed`]:
/// interactive traffic degrades to a lower-rank engine rather than queue
/// behind a saturated one; batch traffic queues for its cost-min pick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrafficClass {
    Interactive,
    Batch,
}

/// Routing health of one gateway, as its circuit breaker sees it (module
/// docs, *Health*).  Exported as `clover_router_health` (0/1/2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Fault EWMA below the degraded threshold: full traffic.
    Healthy,
    /// Elevated fault rate: still routable, score weighted ×2 so traffic
    /// drains toward healthier siblings.
    Degraded,
    /// Breaker tripped (fault EWMA past the open threshold, or the
    /// worker died): unroutable except for a single half-open probe
    /// after [`BreakerConfig::probe_after`].
    Open,
}

/// Circuit-breaker tuning (one config for the whole fleet).  Thresholds
/// are fault *rates* in `[0, 1]` and must be ordered
/// `0 < degraded_threshold < open_threshold <= 1` — `clover check`
/// validates CLI-provided values before a server ever starts.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// EWMA smoothing factor: weight of the newest outcome.
    pub alpha: f64,
    /// Fault EWMA above this marks the gateway [`Health::Degraded`].
    pub degraded_threshold: f64,
    /// Fault EWMA above this trips the breaker to [`Health::Open`].
    pub open_threshold: f64,
    /// How long an open breaker waits before admitting one half-open
    /// probe request.
    pub probe_after: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        // alpha 0.2 ≈ a ~5-request memory: 4 consecutive failures from
        // healthy (EWMA 0.59) trip the breaker, a single blip (0.2) only
        // degrades.
        Self {
            alpha: 0.2,
            degraded_threshold: 0.1,
            open_threshold: 0.5,
            probe_after: Duration::from_millis(250),
        }
    }
}

/// Mutable breaker state for one gateway.
struct BreakerState {
    /// EWMA of request outcomes (0 = success, 1 = failure).
    ewma: f64,
    health: Health,
    /// When the breaker last tripped; `probe_after` is measured from here.
    opened_at: Option<Instant>,
    /// A half-open probe has been routed and has not reported back yet —
    /// at most one probe is in flight per open breaker.
    probe_in_flight: bool,
}

impl BreakerState {
    fn new() -> Self {
        Self { ewma: 0.0, health: Health::Healthy, opened_at: None, probe_in_flight: false }
    }
}

pub struct Router {
    gateways: Vec<Gateway>,
    /// Per-gateway shadow prefix directory: chain hashes of prompts this
    /// router has placed there (empty for gateways without a prefix
    /// cache).  See the module docs — an estimate, not a mirror.
    dirs: Vec<Mutex<HashSet<u64>>>,
    /// Queued requests moved between gateways by [`Router::rebalance`].
    migrated: AtomicUsize,
    /// Interactive submissions placed on a lower rank than their
    /// preferred (saturated) gateway.
    degraded: AtomicUsize,
    /// Per-gateway circuit breakers (module docs, *Health*).
    breakers: Vec<Mutex<BreakerState>>,
    breaker_cfg: BreakerConfig,
    /// Orphans of dead engines re-homed onto siblings by
    /// [`Router::fail_over`].
    failed_over: AtomicUsize,
}

impl Router {
    pub fn new(mut gateways: Vec<Gateway>) -> Result<Self> {
        if gateways.is_empty() {
            bail!("Router needs at least one gateway");
        }
        // One id counter for the whole fleet: a consumer muxing events
        // from several gateways can key on `StreamEvent::id` without
        // cross-gateway collisions.
        let ids = Arc::new(AtomicU64::new(0));
        for g in &mut gateways {
            g.share_id_counter(ids.clone());
        }
        let dirs = gateways.iter().map(|_| Mutex::new(HashSet::new())).collect();
        let breakers = gateways.iter().map(|_| Mutex::new(BreakerState::new())).collect();
        Ok(Self {
            gateways,
            dirs,
            migrated: AtomicUsize::new(0),
            degraded: AtomicUsize::new(0),
            breakers,
            breaker_cfg: BreakerConfig::default(),
            failed_over: AtomicUsize::new(0),
        })
    }

    /// Replace the fleet's breaker tuning (builder style, before traffic).
    pub fn with_breaker(mut self, cfg: BreakerConfig) -> Self {
        self.breaker_cfg = cfg;
        self
    }

    pub fn gateways(&self) -> &[Gateway] {
        &self.gateways
    }

    /// Marginal KV pressure of admitting one more request to `g`:
    /// in-flight depth plus pending prefill work in tokens, weighted by
    /// the engine's per-token KV cost.
    fn score(g: &Gateway) -> u128 {
        (g.in_flight() as u128 + 1 + g.queued_prefill_tokens() as u128)
            * g.kv_bytes_per_token() as u128
    }

    /// Current breaker verdict for gateway `i`.
    pub fn health(&self, i: usize) -> Health {
        self.breakers[i].lock().unwrap_or_else(|e| e.into_inner()).health
    }

    /// Fault-rate EWMA for gateway `i` (exported as
    /// `clover_router_fault_ewma`).
    pub fn fault_ewma(&self, i: usize) -> f64 {
        self.breakers[i].lock().unwrap_or_else(|e| e.into_inner()).ewma
    }

    /// Report one request outcome observed on gateway `i` and advance its
    /// breaker: `ok` is "the stream ended in `Done` or a client cancel",
    /// false is a backend-attributed failure.  Drives the state machine in
    /// the module docs — including closing an open breaker when its
    /// half-open probe succeeds.
    pub fn note_result(&self, i: usize, ok: bool) {
        let cfg = self.breaker_cfg;
        let mut b = self.breakers[i].lock().unwrap_or_else(|e| e.into_inner());
        b.probe_in_flight = false;
        b.ewma = cfg.alpha * if ok { 0.0 } else { 1.0 } + (1.0 - cfg.alpha) * b.ewma;
        match b.health {
            Health::Open => {
                if ok {
                    // The half-open probe came back: close the breaker
                    // (to Degraded while the EWMA is still elevated).
                    b.health = if b.ewma > cfg.degraded_threshold {
                        Health::Degraded
                    } else {
                        Health::Healthy
                    };
                    b.opened_at = None;
                } else {
                    // Failed probe: re-arm the open timer.
                    b.opened_at = Some(Instant::now());
                }
            }
            Health::Healthy | Health::Degraded => {
                if b.ewma > cfg.open_threshold {
                    b.health = Health::Open;
                    b.opened_at = Some(Instant::now());
                } else if b.ewma > cfg.degraded_threshold {
                    b.health = Health::Degraded;
                } else {
                    b.health = Health::Healthy;
                }
            }
        }
    }

    /// Can the router place traffic on gateway `i` right now?  Dead
    /// workers never; open breakers only as a half-open probe (one at a
    /// time, `probe_after` past the trip).
    fn routable(&self, i: usize) -> bool {
        if !self.gateways[i].is_alive() {
            return false;
        }
        let b = self.breakers[i].lock().unwrap_or_else(|e| e.into_inner());
        match b.health {
            Health::Healthy | Health::Degraded => true,
            Health::Open => {
                !b.probe_in_flight
                    && b.opened_at.map_or(true, |t| t.elapsed() >= self.breaker_cfg.probe_after)
            }
        }
    }

    /// If gateway `i`'s breaker is open, the submission about to be placed
    /// there is its half-open probe — record that so only one flies.
    fn note_probe(&self, i: usize) {
        let mut b = self.breakers[i].lock().unwrap_or_else(|e| e.into_inner());
        if b.health == Health::Open {
            b.probe_in_flight = true;
        }
    }

    /// Breaker weight on gateway `i`'s score: a degraded engine looks
    /// twice as expensive, so traffic drains away before the breaker
    /// opens.
    fn health_weight(&self, i: usize) -> u128 {
        match self.health(i) {
            Health::Degraded => 2,
            Health::Healthy | Health::Open => 1,
        }
    }

    /// Cost-min index over the routable subset of the fleet; only when
    /// *nothing* is routable (whole fleet open/dead) does the pick fall
    /// back to every gateway, so callers still get a deterministic index.
    fn pick_among<F: Fn(usize) -> u128>(&self, cost: F) -> usize {
        (0..self.gateways.len())
            .filter(|&i| self.routable(i))
            .min_by_key(|&i| cost(i))
            .or_else(|| (0..self.gateways.len()).min_by_key(|&i| cost(i)))
            .expect("router is non-empty")
    }

    /// Index of the gateway the next request would go to, prompt unseen.
    pub fn pick(&self) -> usize {
        self.pick_among(|i| self.health_weight(i) * Self::score(&self.gateways[i]))
    }

    /// A gateway with more accepted requests than KV lanes has a queue —
    /// the scheduler's saturation predicate (migration source, the
    /// trigger for interactive degradation).
    fn saturated(g: &Gateway) -> bool {
        g.in_flight() > g.batch_slots()
    }

    /// Tokens of `prompt` gateway `i` is *estimated* to already hold in
    /// its prefix cache: the longest chain-hash prefix present in the
    /// shadow directory, capped at `len − 1` exactly like the engine's
    /// attach (the last prompt token always prefills).
    fn est_hit_tokens(&self, i: usize, prompt: &[i32]) -> usize {
        let Some(block) = self.gateways[i].prefix_cache_block() else {
            return 0;
        };
        let dir = self.dirs[i].lock().unwrap_or_else(|e| e.into_inner());
        let mut hit = 0;
        for h in chain_hashes(prompt, block) {
            if !dir.contains(&h) {
                break;
            }
            hit += block;
        }
        hit.min(prompt.len().saturating_sub(1))
    }

    /// [`Router::score`] for a *known* prompt: the prompt's own prefill
    /// work joins the pending-token backlog, discounted by the prefix
    /// tokens gateway `i` is estimated to serve from cache.
    fn score_for(&self, i: usize, prompt: &[i32]) -> u128 {
        let g = &self.gateways[i];
        let fresh = (prompt.len() - self.est_hit_tokens(i, prompt)) as u128;
        (g.in_flight() as u128 + 1 + g.queued_prefill_tokens() as u128 + fresh)
            * g.kv_bytes_per_token() as u128
    }

    /// Index of the gateway `prompt` would go to: cost-min placement with
    /// prefix-cache affinity (a directory-matched prefix prefills from
    /// cache, so only the cold tail is weighed).
    pub fn pick_for(&self, prompt: &[i32]) -> usize {
        self.pick_among(|i| self.health_weight(i) * self.score_for(i, prompt))
    }

    /// Record `prompt`'s chain hashes in gateway `i`'s shadow directory
    /// (no-op for gateways without a prefix cache).
    fn note_prompt(&self, i: usize, prompt: &[i32]) {
        if let Some(block) = self.gateways[i].prefix_cache_block() {
            self.dirs[i]
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .extend(chain_hashes(prompt, block));
        }
    }

    /// Route one request (blocking submit — backpressure applies at the
    /// chosen gateway).  Returns the chosen gateway index with the ticket.
    /// Equivalent to [`Router::submit_classed`] with
    /// [`TrafficClass::Batch`].
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Duration>,
    ) -> std::result::Result<(usize, Ticket), SubmitError> {
        self.submit_classed(prompt, max_new, sampling, deadline, TrafficClass::Batch)
    }

    /// Route one request with a latency class.  Batch traffic takes the
    /// prefix-affine cost-min pick and queues if that gateway is busy.
    /// Interactive traffic *degrades*: when its preferred gateway is
    /// saturated, it goes to the cheapest unsaturated engine instead —
    /// trading CLOVER rank (answer quality) for latency, which is counted
    /// in `clover_router_degraded_total` when the fallback's rank is
    /// lower.  With the whole fleet saturated, both classes queue at the
    /// preferred gateway.  [`SubmitError::Overloaded`] (load shedding at
    /// the gateway's `max_pending` cap) propagates to the caller.
    pub fn submit_classed(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Duration>,
        class: TrafficClass,
    ) -> std::result::Result<(usize, Ticket), SubmitError> {
        let preferred = self.pick_for(&prompt);
        let mut idx = preferred;
        if class == TrafficClass::Interactive && Self::saturated(&self.gateways[preferred]) {
            let fallback = (0..self.gateways.len())
                .filter(|&j| self.routable(j) && !Self::saturated(&self.gateways[j]))
                .min_by_key(|&j| self.health_weight(j) * self.score_for(j, &prompt));
            if let Some(j) = fallback {
                if self.gateways[j].rank() < self.gateways[preferred].rank() {
                    self.degraded.fetch_add(1, Ordering::Relaxed);
                }
                idx = j;
            }
        }
        // Placing traffic on an open breaker means this request *is* the
        // half-open probe — record it so only one flies at a time.
        self.note_probe(idx);
        let hashes = self
            .gateways[idx]
            .prefix_cache_block()
            .map(|block| chain_hashes(&prompt, block));
        let ticket = self.gateways[idx].submit(prompt, max_new, sampling, deadline)?;
        if let Some(hs) = hashes {
            self.dirs[idx].lock().unwrap_or_else(|e| e.into_inner()).extend(hs);
        }
        Ok((idx, ticket))
    }

    /// One migration sweep: every saturated gateway surrenders queued
    /// requests — reclaimed from the *back* of its batcher, so running
    /// lanes and the head-of-line waiter are untouched — and each moves
    /// to the cheapest (prefix-affine) gateway with a free KV lane.  At
    /// most the fleet's spare lane count moves per sweep, which is what
    /// makes repeated sweeps converge instead of ping-ponging requests
    /// between two saturated engines.  Returns the number migrated; the
    /// running total is exported as `clover_router_migrated_total`.
    pub fn rebalance(&self) -> usize {
        let mut moved = 0;
        for (i, src) in self.gateways.iter().enumerate() {
            let excess = src.in_flight().saturating_sub(src.batch_slots());
            if excess == 0 {
                continue;
            }
            let spare: usize = self
                .gateways
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i)
                .map(|(_, g)| g.batch_slots().saturating_sub(g.in_flight()))
                .sum();
            let take = excess.min(spare);
            if take == 0 {
                continue;
            }
            for sub in src.reclaim_queued(take) {
                let prompt = sub.req.prompt.clone();
                // Free-lane gateways first; if a racing submit just took
                // the last lane, fall back to the cheapest other gateway
                // — the request must land somewhere, and its origin would
                // reject the id as a duplicate.
                let target = (0..self.gateways.len())
                    .filter(|&j| {
                        j != i && self.gateways[j].in_flight() < self.gateways[j].batch_slots()
                    })
                    .min_by_key(|&j| self.score_for(j, &prompt))
                    .or_else(|| {
                        (0..self.gateways.len())
                            .filter(|&j| j != i)
                            .min_by_key(|&j| self.score_for(j, &prompt))
                    });
                let Some(j) = target else { break };
                match self.gateways[j].resubmit(sub) {
                    Ok(()) => {
                        self.note_prompt(j, &prompt);
                        moved += 1;
                    }
                    // The target's ingress closed under us (its worker
                    // died): the submission comes back, and the client
                    // still gets its one terminal event.
                    Err(sub) => sub.fail(FailReason::Backend),
                }
            }
        }
        self.migrated.fetch_add(moved, Ordering::Relaxed);
        moved
    }

    /// One failover sweep (module docs, *Failover*): every dead gateway is
    /// marked [`Health::Open`] and its parked orphans — interrupted
    /// requests with their original id, live client stream, and merged
    /// `prompt ⧺ streamed` row — are resubmitted to the cheapest live
    /// sibling, lower compiled rank winning ties (shedding quality, not
    /// requests, under pressure).  An orphan no live sibling will take
    /// gets a terminal `Failed{Backend}` so its stream never dangles.
    /// Returns the number re-homed; the running total is exported as
    /// `clover_router_failed_over_total`.
    pub fn fail_over(&self) -> usize {
        let mut moved = 0;
        for i in 0..self.gateways.len() {
            if self.gateways[i].is_alive() {
                continue;
            }
            {
                let mut b = self.breakers[i].lock().unwrap_or_else(|e| e.into_inner());
                if b.health != Health::Open {
                    b.health = Health::Open;
                    b.opened_at = Some(Instant::now());
                    b.ewma = 1.0;
                }
            }
            for orphan in self.gateways[i].take_orphans() {
                let prompt = orphan.req.prompt.clone();
                let mut targets: Vec<usize> = (0..self.gateways.len())
                    .filter(|&j| j != i && self.routable(j))
                    .collect();
                targets.sort_by_key(|&j| (self.score_for(j, &prompt), self.gateways[j].rank()));
                let mut orphan = Some(orphan);
                for j in targets {
                    let Some(sub) = orphan.take() else { break };
                    match self.gateways[j].resubmit(sub) {
                        Ok(()) => {
                            self.note_prompt(j, &prompt);
                            moved += 1;
                        }
                        // That sibling died between the liveness check and
                        // the send — try the next one.
                        Err(back) => orphan = Some(back),
                    }
                }
                if let Some(sub) = orphan {
                    sub.fail(FailReason::Backend);
                }
            }
        }
        self.failed_over.fetch_add(moved, Ordering::Relaxed);
        moved
    }

    /// Orphans of dead engines re-homed by [`Router::fail_over`], over
    /// this router's lifetime.
    pub fn failed_over_total(&self) -> usize {
        self.failed_over.load(Ordering::Relaxed)
    }

    /// Queued requests moved between gateways by [`Router::rebalance`],
    /// over this router's lifetime.
    pub fn migrated_total(&self) -> usize {
        self.migrated.load(Ordering::Relaxed)
    }

    /// Interactive submissions served by a lower rank than their
    /// preferred gateway's ([`Router::submit_classed`]).
    pub fn degraded_total(&self) -> usize {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Per-gateway share of all submissions routed so far, as
    /// `(name, rank, submitted)` rows.
    pub fn shares(&self) -> Vec<(String, usize, usize)> {
        self.gateways
            .iter()
            .map(|g| (g.name().to_string(), g.rank(), g.submitted()))
            .collect()
    }

    /// Publish every gateway's routing-visible state into `reg` as
    /// per-rank gauges labelled `{gateway="NAME",rank="R"}` — the
    /// handle-side view (queue depth, pending prefill tokens, per-token
    /// KV cost, lifetime submissions, routing score).  Complements the
    /// worker-side series a [`super::gateway::Obs`]-tapped gateway
    /// publishes itself.
    pub fn export_metrics(&self, reg: &Registry) {
        for g in &self.gateways {
            let labels = format!("{{gateway=\"{}\",rank=\"{}\"}}", g.name(), g.rank());
            reg.gauge_set(&format!("clover_router_in_flight{labels}"), g.in_flight() as f64);
            reg.gauge_set(
                &format!("clover_router_queued_prefill_tokens{labels}"),
                g.queued_prefill_tokens() as f64,
            );
            reg.gauge_set(
                &format!("clover_router_kv_bytes_per_token{labels}"),
                g.kv_bytes_per_token() as f64,
            );
            reg.gauge_set(&format!("clover_router_submitted{labels}"), g.submitted() as f64);
            reg.gauge_set(&format!("clover_router_score{labels}"), Self::score(g) as f64);
        }
        for (i, g) in self.gateways.iter().enumerate() {
            let labels = format!("{{gateway=\"{}\",rank=\"{}\"}}", g.name(), g.rank());
            let health = match self.health(i) {
                Health::Healthy => 0.0,
                Health::Degraded => 1.0,
                Health::Open => 2.0,
            };
            reg.gauge_set(&format!("clover_router_health{labels}"), health);
            reg.gauge_set(&format!("clover_router_fault_ewma{labels}"), self.fault_ewma(i));
            reg.gauge_set(
                &format!("clover_router_alive{labels}"),
                if g.is_alive() { 1.0 } else { 0.0 },
            );
        }
        for (g, dir) in self.gateways.iter().zip(&self.dirs) {
            if g.prefix_cache_block().is_none() {
                continue;
            }
            let labels = format!("{{gateway=\"{}\",rank=\"{}\"}}", g.name(), g.rank());
            reg.gauge_set(
                &format!("clover_router_prefix_dir_blocks{labels}"),
                dir.lock().unwrap_or_else(|e| e.into_inner()).len() as f64,
            );
        }
        reg.gauge_set("clover_router_migrated_total", self.migrated_total() as f64);
        reg.gauge_set("clover_router_degraded_total", self.degraded_total() as f64);
        reg.gauge_set("clover_router_failed_over_total", self.failed_over_total() as f64);
    }

    /// One-shot Prometheus text of the routing gauges (stats lines, CLI).
    pub fn prometheus_text(&self) -> String {
        let reg = Registry::new();
        self.export_metrics(&reg);
        reg.prometheus_text()
    }

    /// Gracefully shut every gateway down, returning each engine's final
    /// metrics keyed by gateway name.  Shutdown is signalled to all
    /// gateways *before* any is joined, so the engines drain in parallel
    /// (wall time ≈ the slowest drain, not the sum).
    pub fn join(self) -> Result<Vec<(String, ServeMetrics)>> {
        for g in &self.gateways {
            g.signal_shutdown();
        }
        self.gateways
            .into_iter()
            .map(|g| {
                let name = g.name().to_string();
                g.join().map(|m| (name, m))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::stub::{FaultPlan, StubSpec};
    use crate::serve::SamplingParams;
    use crate::server::gateway::{EngineSpec, GatewayConfig};
    use crate::server::stream::StreamOutcome;
    use std::time::Duration;

    /// Single-lane, single-token-ladder stub with a slow step: requests
    /// submitted while the lane prefills stay queued for ~200ms — plenty
    /// of time for deterministic routing assertions.
    fn slow_stub() -> EngineSpec {
        EngineSpec::stub(StubSpec {
            batch_slots: 1,
            chunk_widths: vec![1],
            max_positions: 256,
            step_delay: Duration::from_millis(3),
            ..Default::default()
        })
    }

    #[test]
    fn long_prompt_bursts_spread_by_pending_prefill_tokens() {
        let router = Router::new(vec![
            Gateway::spawn("a", GatewayConfig::default(), slow_stub()).unwrap(),
            Gateway::spawn("b", GatewayConfig::default(), slow_stub()).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        // Occupy both single-lane engines with identical long prefills so
        // in_flight ties and everything submitted below stays queued.
        let mut tickets = Vec::new();
        for gw in g {
            tickets
                .push(gw.submit((0..64).collect(), 4, SamplingParams::greedy(), None).unwrap());
        }
        // A long prompt queues on "a", a short one on "b": request *count*
        // ties 2–2, but pending prefill is 64+100 vs 64+4 tokens.
        tickets.push(g[0].submit((0..100).collect(), 2, SamplingParams::greedy(), None).unwrap());
        tickets.push(g[1].submit((0..4).collect(), 2, SamplingParams::greedy(), None).unwrap());
        assert_eq!(g[0].in_flight(), g[1].in_flight(), "request count is tied");
        assert!(g[0].queued_prefill_tokens() > g[1].queued_prefill_tokens());
        // The old `(in_flight + 1) × bytes` score tied here and resolved
        // to "a" — piling the long-prompt burst onto one engine.  Weighted
        // by pending prefill tokens, the next request goes to "b".
        assert_eq!(router.pick(), 1);
        // Retire everything quickly and drain.
        for t in &tickets {
            t.cancel.cancel();
        }
        for (name, m) in router.join().unwrap() {
            assert_eq!(m.completed + m.cancelled, 2, "{name}");
        }
    }

    #[test]
    fn speculative_pair_costs_two_engines() {
        use crate::serve::SpecConfig;
        use crate::server::gateway::DraftSource;
        // Same target everywhere; gateway "pair" carries a rank-4 draft on
        // top.  At equal (zero) queue depth the plain engine must win —
        // the pair pins target + draft cache per resident token.
        let target = StubSpec { rank: 8, ..Default::default() };
        let draft = StubSpec { rank: 4, ..target.clone() };
        let pair_spec = EngineSpec::stub(target.clone())
            .with_speculative(DraftSource::Stub(draft), SpecConfig::default());
        let router = Router::new(vec![
            Gateway::spawn("pair", GatewayConfig::default(), pair_spec).unwrap(),
            Gateway::spawn("plain", GatewayConfig::default(), EngineSpec::stub(target)).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        assert!(g[0].speculative() && !g[1].speculative());
        assert_eq!(
            g[0].kv_bytes_per_token(),
            g[1].kv_bytes_per_token() * 3 / 2,
            "rank-4 draft adds half a rank-8 target's bytes"
        );
        // "pair" is listed first, so only its higher KV cost can explain
        // the router preferring "plain".
        assert_eq!(router.pick(), 1);
        router.join().unwrap();
    }

    #[test]
    fn factored_codec_engine_attracts_traffic_like_a_lower_rank() {
        use crate::serve::KvCodecSpec;
        // Two engines at the same compiled rank; "fact" stores its cache
        // through the factored codec at half budgets.  The router's
        // codec-aware per-token cost makes it the cheaper target at equal
        // depth, exactly as if it had been compiled one rank down.
        let target = StubSpec { rank: 8, ..Default::default() };
        let fact_spec = EngineSpec::stub(target.clone())
            .with_kv_codec(KvCodecSpec::Factored { layer_budgets: None });
        let router = Router::new(vec![
            Gateway::spawn("plain", GatewayConfig::default(), EngineSpec::stub(target)).unwrap(),
            Gateway::spawn("fact", GatewayConfig::default(), fact_spec).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        assert_eq!(g[0].rank(), g[1].rank(), "same compiled rank");
        assert_eq!(g[1].kv_bytes_per_token() * 2, g[0].kv_bytes_per_token());
        // "plain" is listed first and ties resolve to it, so only the
        // compressed cost can explain the router preferring "fact".
        assert_eq!(router.pick(), 1);
        router.join().unwrap();
    }

    #[test]
    fn export_metrics_publishes_per_rank_gauges() {
        let target = StubSpec { rank: 8, ..Default::default() };
        let low = StubSpec { rank: 4, ..target.clone() };
        let router = Router::new(vec![
            Gateway::spawn("r8", GatewayConfig::default(), EngineSpec::stub(target)).unwrap(),
            Gateway::spawn("r4", GatewayConfig::default(), EngineSpec::stub(low)).unwrap(),
        ])
        .unwrap();
        let reg = crate::obs::Registry::new();
        router.export_metrics(&reg);
        assert_eq!(reg.get("clover_router_in_flight{gateway=\"r8\",rank=\"8\"}"), Some(0.0));
        assert_eq!(
            reg.get("clover_router_kv_bytes_per_token{gateway=\"r4\",rank=\"4\"}"),
            Some(router.gateways()[1].kv_bytes_per_token() as f64),
        );
        let text = router.prometheus_text();
        assert!(text.contains("# TYPE clover_router_score gauge\n"));
        assert!(text.contains("clover_router_score{gateway=\"r8\",rank=\"8\"}"));
        router.join().unwrap();
    }

    /// A prompt goes back to the engine that already holds its prefix:
    /// the shadow directory's discount beats the construction-order
    /// tie-break that would otherwise send an idle-fleet submit to
    /// gateway 0.
    #[test]
    fn prefix_affinity_routes_repeat_prompts_to_their_cache() {
        let spec = || {
            EngineSpec::stub(StubSpec {
                batch_slots: 1,
                chunk_widths: vec![1],
                max_positions: 256,
                step_delay: Duration::from_millis(3),
                ..Default::default()
            })
            .with_prefix_cache(Some(32))
        };
        let router = Router::new(vec![
            Gateway::spawn("pa", GatewayConfig::default(), spec()).unwrap(),
            Gateway::spawn("pb", GatewayConfig::default(), spec()).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        let p: Vec<i32> = (0..64).map(|i| i % 32).collect();
        // Occupy "pa" so the first routed submit of `p` lands on "pb"
        // and seeds its directory.
        let filler =
            g[0].submit((0..100).map(|i| i % 32).collect(), 2, SamplingParams::greedy(), None)
                .unwrap();
        let (idx, t) =
            router.submit(p.clone(), 2, SamplingParams::greedy(), None).unwrap();
        assert_eq!(idx, 1, "busy pa loses the cold pick");
        assert!(t.stream.wait().unwrap().is_done());
        assert!(filler.stream.wait().unwrap().is_done());
        // Fleet idle again: promptless pick ties back to gateway 0, but
        // the prompt-aware pick follows the cached prefix to "pb" — and
        // an unrelated prompt does not.
        assert_eq!(router.pick(), 0);
        assert_eq!(router.pick_for(&p), 1);
        assert_eq!(router.pick_for(&[7; 64]), 0);
        let (idx, t) = router.submit(p, 2, SamplingParams::greedy(), None).unwrap();
        assert_eq!(idx, 1, "affinity routes the repeat to its cache");
        assert!(t.stream.wait().unwrap().is_done());
        let reg = crate::obs::Registry::new();
        router.export_metrics(&reg);
        assert_eq!(reg.get("clover_router_prefix_dir_blocks{gateway=\"pb\",rank=\"4\"}"), Some(2.0));
        assert_eq!(reg.get("clover_router_prefix_dir_blocks{gateway=\"pa\",rank=\"4\"}"), Some(0.0));
        router.join().unwrap();
    }

    /// Interactive traffic degrades off a saturated prefix-affine rank-8
    /// gateway onto the idle rank-4 engine; batch traffic keeps its
    /// affinity pick and queues.
    #[test]
    fn interactive_degrades_to_lower_rank_batch_queues() {
        let slow = |rank: usize, batch_slots: usize| StubSpec {
            batch_slots,
            chunk_widths: vec![1],
            max_positions: 256,
            step_delay: Duration::from_millis(3),
            rank,
            ..Default::default()
        };
        let router = Router::new(vec![
            Gateway::spawn(
                "hi",
                GatewayConfig::default(),
                EngineSpec::stub(slow(8, 1)).with_prefix_cache(Some(32)),
            )
            .unwrap(),
            Gateway::spawn("lo", GatewayConfig::default(), EngineSpec::stub(slow(4, 1))).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        let p: Vec<i32> = (0..64).map(|i| i % 32).collect();
        // Seed affinity for `p` on "hi": a 200-token backlog on "lo"
        // outweighs its half-price rank (needs > 64 pending tokens, so
        // the margin holds even after prefill has chewed a while), then
        // serve `p` to completion.
        let filler =
            g[1].submit((0..200).map(|i| i % 32).collect(), 2, SamplingParams::greedy(), None)
                .unwrap();
        let (idx, t) = router.submit(p.clone(), 2, SamplingParams::greedy(), None).unwrap();
        assert_eq!(idx, 0, "rank-8 wins while rank-4 is backlogged");
        assert!(t.stream.wait().unwrap().is_done());
        assert!(filler.stream.wait().unwrap().is_done());
        // Saturate "hi": one long decode holds the lane, one waiter
        // queues behind it (in_flight 2 > 1 lane).
        let hold = g[0].submit(vec![1, 2, 3, 4], 64, SamplingParams::greedy(), None).unwrap();
        let _wait = g[0].submit(vec![5, 6, 7, 8], 2, SamplingParams::greedy(), None).unwrap();
        assert!(g[0].in_flight() > g[0].batch_slots());
        // Interactive: preferred is still the affine "hi" (its short
        // queue plus the 63-token cache discount beats a cold 64-token
        // prefill on "lo") — but it is saturated, so the request degrades
        // to the idle rank-4 engine.
        let (idx, ti) = router
            .submit_classed(p.clone(), 2, SamplingParams::greedy(), None, TrafficClass::Interactive)
            .unwrap();
        assert_eq!(idx, 1, "interactive degrades to the idle lower rank");
        assert_eq!(router.degraded_total(), 1);
        // Batch: same preference, no degradation — it queues on "hi".
        let (idx, tb) = router
            .submit_classed(p, 2, SamplingParams::greedy(), None, TrafficClass::Batch)
            .unwrap();
        assert_eq!(idx, 0, "batch waits for its prefix-affine pick");
        assert_eq!(router.degraded_total(), 1, "batch never counts as degraded");
        hold.cancel.cancel();
        assert!(ti.stream.wait().unwrap().is_done());
        assert!(tb.stream.wait().unwrap().is_done());
        router.join().unwrap();
    }

    /// The ISSUE's acceptance scenario: a burst that saturates the rank-8
    /// gateway spreads across the fleet — queued requests migrate to the
    /// idle rank-4 variant, bounded by its spare lanes, and every client
    /// stream still completes.
    #[test]
    fn queued_burst_migrates_to_idle_rank_variant() {
        use crate::server::stream::StreamEvent;
        let slow = |rank: usize, batch_slots: usize| {
            EngineSpec::stub(StubSpec {
                batch_slots,
                chunk_widths: vec![1],
                max_positions: 256,
                step_delay: Duration::from_millis(3),
                rank,
                ..Default::default()
            })
        };
        let router = Router::new(vec![
            Gateway::spawn("r8", GatewayConfig::default(), slow(8, 1)).unwrap(),
            Gateway::spawn("r4", GatewayConfig::default(), slow(4, 2)).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        // Long prefill pins r8's only lane...
        let head =
            g[0].submit((0..96).map(|i| i % 32).collect(), 8, SamplingParams::greedy(), None)
                .unwrap();
        loop {
            match head.stream.next_event() {
                Some(StreamEvent::Started { .. }) => break,
                Some(_) => continue,
                None => panic!("stream closed before Started"),
            }
        }
        // ...and a burst of three requests queues behind it (32-token
        // prompts: ~120ms of work each on r4, so the fleet stays busy
        // through the convergence assertions below).
        let burst: Vec<_> = (0..3)
            .map(|_| {
                g[0].submit((0..32).map(|i| i % 32).collect(), 8, SamplingParams::greedy(), None)
                    .unwrap()
            })
            .collect();
        assert_eq!(g[0].in_flight(), 4);
        assert_eq!(g[1].in_flight(), 0);
        // Rebalance until r4's two spare lanes are filled.  Sweeps race
        // the worker's ingress drain, so retry; each sweep moves at most
        // the spare-lane count, so the total is exactly 2 and the third
        // queued request stays on r8 (no ping-pong).
        let mut moved = 0;
        for _ in 0..50 {
            moved += router.rebalance();
            if moved >= 2 {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(moved, 2, "migration is bounded by the idle variant's spare lanes");
        assert_eq!(router.migrated_total(), 2);
        assert_eq!(g[1].in_flight(), 2, "the burst spread to the rank variant");
        assert_eq!(router.rebalance(), 0, "no spare lanes left — the sweep converges");
        assert!(head.stream.wait().unwrap().is_done());
        for t in burst {
            assert!(t.stream.wait().unwrap().is_done(), "migrated streams still complete");
        }
        let reg = crate::obs::Registry::new();
        router.export_metrics(&reg);
        assert_eq!(reg.get("clover_router_migrated_total"), Some(2.0));
        let metrics: std::collections::HashMap<String, _> =
            router.join().unwrap().into_iter().collect();
        assert_eq!(metrics["r8"].migrated, 2, "the source engine counted its surrendered queue");
        assert_eq!(metrics["r8"].completed, 2);
        assert_eq!(metrics["r4"].completed, 2);
        assert_eq!(metrics["r4"].migrated, 0);
    }

    /// Fault storm on gateway 0: one failure degrades it (score ×2 drains
    /// traffic), four open the breaker (unroutable while the probe timer
    /// runs), and the health/EWMA gauges export the whole episode.
    #[test]
    fn breaker_trips_on_fault_storm_and_routes_around() {
        let spec = || EngineSpec::stub(StubSpec::default());
        let router = Router::new(vec![
            Gateway::spawn("bk-a", GatewayConfig::default(), spec()).unwrap(),
            Gateway::spawn("bk-b", GatewayConfig::default(), spec()).unwrap(),
        ])
        .unwrap()
        .with_breaker(BreakerConfig {
            probe_after: Duration::from_secs(3600),
            ..Default::default()
        });
        assert_eq!(router.pick(), 0, "idle fleet ties to construction order");
        router.note_result(0, false);
        assert_eq!(router.health(0), Health::Degraded, "one blip only degrades");
        assert_eq!(router.pick(), 1, "a degraded engine costs double — traffic drains");
        assert_eq!(router.pick_for(&[1, 2, 3]), 1);
        for _ in 0..3 {
            router.note_result(0, false);
        }
        assert_eq!(router.health(0), Health::Open, "four consecutive faults trip the breaker");
        assert_eq!(router.pick(), 1, "an open breaker is unroutable before probe_after");
        let reg = crate::obs::Registry::new();
        router.export_metrics(&reg);
        assert_eq!(reg.get("clover_router_health{gateway=\"bk-a\",rank=\"4\"}"), Some(2.0));
        assert_eq!(reg.get("clover_router_health{gateway=\"bk-b\",rank=\"4\"}"), Some(0.0));
        assert_eq!(reg.get("clover_router_alive{gateway=\"bk-a\",rank=\"4\"}"), Some(1.0));
        let ewma = reg.get("clover_router_fault_ewma{gateway=\"bk-a\",rank=\"4\"}").unwrap();
        assert!((ewma - 0.5904).abs() < 1e-9, "1 - 0.8^4, got {ewma}");
        router.join().unwrap();
    }

    /// Half-open: past `probe_after` exactly one request is routed to the
    /// open engine as a probe; its success closes the breaker back to
    /// Degraded and a run of clean traffic restores Healthy.
    #[test]
    fn half_open_probe_closes_breaker() {
        let spec = || EngineSpec::stub(StubSpec::default());
        let router = Router::new(vec![
            Gateway::spawn("hp-a", GatewayConfig::default(), spec()).unwrap(),
            Gateway::spawn("hp-b", GatewayConfig::default(), spec()).unwrap(),
        ])
        .unwrap()
        .with_breaker(BreakerConfig { probe_after: Duration::ZERO, ..Default::default() });
        for _ in 0..4 {
            router.note_result(0, false);
        }
        assert_eq!(router.health(0), Health::Open);
        // probe_after ZERO: the open engine is immediately probe-eligible,
        // and at equal score the tie-break sends the next submit there.
        let (idx, t) = router.submit(vec![1, 2, 3], 2, SamplingParams::greedy(), None).unwrap();
        assert_eq!(idx, 0, "the open engine admits one half-open probe");
        assert_eq!(router.pick(), 1, "only one probe flies at a time");
        assert!(t.stream.wait().unwrap().is_done());
        router.note_result(0, true);
        assert_eq!(router.health(0), Health::Degraded, "a good probe closes to Degraded first");
        for _ in 0..20 {
            router.note_result(0, true);
        }
        assert_eq!(router.health(0), Health::Healthy, "clean traffic restores full health");
        router.join().unwrap();
    }

    /// The chaos acceptance scenario: an engine dies for good mid-decode
    /// with `failover` set, the router marks it Open and re-homes its
    /// parked orphans onto the live sibling — original ids, live streams,
    /// completions bit-identical to a run that never saw the death.
    #[test]
    fn dead_engine_fails_over_orphans_to_sibling() {
        // Reference rows from an undisturbed engine of the same spec.
        let clean_gw =
            Gateway::spawn("fo-clean", GatewayConfig::default(), EngineSpec::stub(StubSpec::default()))
                .unwrap();
        let clean: Vec<Vec<i32>> = (0..3)
            .map(|i| {
                clean_gw
                    .submit(vec![1 + i, 2, 3], 8, SamplingParams::greedy(), None)
                    .unwrap()
                    .stream
                    .wait()
                    .unwrap()
                    .completion()
                    .unwrap()
                    .tokens
            })
            .collect();
        clean_gw.join().unwrap();
        // Slow steps: all three submits land before the step-4 death, so
        // none races the dying ingress.
        let doomed = EngineSpec::stub(StubSpec {
            fault_plan: FaultPlan { fatal_after_steps: Some(4), ..Default::default() },
            step_delay: Duration::from_millis(2),
            ..Default::default()
        });
        let router = Router::new(vec![
            Gateway::spawn(
                "fo-a",
                GatewayConfig { max_restarts: 0, failover: true, ..Default::default() },
                doomed,
            )
            .unwrap(),
            Gateway::spawn("fo-b", GatewayConfig::default(), EngineSpec::stub(StubSpec::default()))
                .unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        let tickets: Vec<_> = (0..3)
            .map(|i| g[0].submit(vec![1 + i, 2, 3], 8, SamplingParams::greedy(), None).unwrap())
            .collect();
        for _ in 0..500 {
            if !g[0].is_alive() {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!g[0].is_alive(), "the fatal fault kills the unrestartable worker");
        let moved = router.fail_over();
        assert_eq!(moved, 3, "every interrupted request re-homes");
        assert_eq!(router.failed_over_total(), 3);
        assert_eq!(router.health(0), Health::Open, "the dead engine is out of rotation");
        assert_eq!(router.pick(), 1, "new traffic routes around the corpse");
        let rows: Vec<Vec<i32>> = tickets
            .into_iter()
            .map(|t| t.stream.wait().unwrap().completion().unwrap().tokens)
            .collect();
        assert_eq!(rows, clean, "failover is lossless and bit-identical");
        assert_eq!(router.fail_over(), 0, "a second sweep finds nothing to move");
        // Joining the fleet surfaces the dead worker's underlying error.
        assert!(router.join().is_err());
    }

    /// Last-engine-standing dies: with nowhere to re-home the orphans,
    /// the sweep delivers each stream a terminal `Failed{Backend}` —
    /// never a silent disconnect.
    #[test]
    fn fail_over_with_no_sibling_fails_streams_terminally() {
        let doomed = EngineSpec::stub(StubSpec {
            fault_plan: FaultPlan { fatal_after_steps: Some(2), ..Default::default() },
            step_delay: Duration::from_millis(2),
            ..Default::default()
        });
        let router = Router::new(vec![Gateway::spawn(
            "solo",
            GatewayConfig { max_restarts: 0, failover: true, ..Default::default() },
            doomed,
        )
        .unwrap()])
        .unwrap();
        let g = router.gateways();
        let tickets: Vec<_> = (0..2)
            .map(|i| g[0].submit(vec![1 + i, 2], 8, SamplingParams::greedy(), None).unwrap())
            .collect();
        for _ in 0..500 {
            if !g[0].is_alive() {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!g[0].is_alive());
        assert_eq!(router.fail_over(), 0, "no sibling can take the orphans");
        for t in tickets {
            match t.stream.wait().unwrap() {
                StreamOutcome::Failed { reason, .. } => assert_eq!(reason, FailReason::Backend),
                other => panic!("expected terminal Failed, got {other:?}"),
            }
        }
        assert!(router.join().is_err());
    }
}
