//! Rank-aware routing across gateways whose engines were compiled at
//! different CLOVER pruning ranks.
//!
//! The paper's claim, made operational: pruning head rank to r cuts KV
//! bytes per token to r/d of dense ([`crate::serve::KvConfig::bytes_per_token`]),
//! so at equal queue depth a pruned engine is the cheaper place to put the
//! next request.  The per-token cost is *codec-aware*: an engine storing
//! its cache through the factored page codec
//! ([`crate::serve::KvCodecSpec`], `--kv-codec factored`) reports the
//! compressed bytes, so at equal depth the router prefers it the same way
//! it prefers a lower compiled rank.  The router scores each gateway as
//!
//! ```text
//! score(g) = (in_flight(g) + 1 + queued_prefill_tokens(g))
//!              × kv_bytes_per_token(g)
//! ```
//!
//! — the marginal KV pressure of admitting one more request there, with
//! waiting requests weighted by their `prompt.len()` of pending prefill
//! work rather than counting 1 apiece.  Request count alone is blind to
//! prompt length: a burst of 512-token prompts and a burst of 2-token
//! prompts looked identical, so long-prompt traffic piled onto one engine
//! until its queue *length* caught up.  Pending prefill tokens is the
//! actual backlog (it is also, post-prefill, the KV the requests will
//! pin), and it drains as prefills complete —
//! [`Gateway::queued_prefill_tokens`].
//!
//! A **speculative draft+verify pair** consumes two engines: its gateway
//! reports the *combined* target + draft per-token KV cost
//! ([`Gateway::kv_bytes_per_token`] already includes both caches), so at
//! equal queue depth the router correctly prefers a plain engine over a
//! pair of the same target rank — the pair's throughput advantage is per
//! *token*, its cost is per *resident request*.
//!
//! Ties resolve to the earliest gateway in construction order, so callers
//! list their preferred (typically lowest-rank) engine first.

use anyhow::{bail, Result};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Duration;

use crate::obs::Registry;
use crate::serve::{SamplingParams, ServeMetrics};

use super::gateway::{Gateway, SubmitError, Ticket};

pub struct Router {
    gateways: Vec<Gateway>,
}

impl Router {
    pub fn new(mut gateways: Vec<Gateway>) -> Result<Self> {
        if gateways.is_empty() {
            bail!("Router needs at least one gateway");
        }
        // One id counter for the whole fleet: a consumer muxing events
        // from several gateways can key on `StreamEvent::id` without
        // cross-gateway collisions.
        let ids = Arc::new(AtomicU64::new(0));
        for g in &mut gateways {
            g.share_id_counter(ids.clone());
        }
        Ok(Self { gateways })
    }

    pub fn gateways(&self) -> &[Gateway] {
        &self.gateways
    }

    /// Marginal KV pressure of admitting one more request to `g`:
    /// in-flight depth plus pending prefill work in tokens, weighted by
    /// the engine's per-token KV cost.
    fn score(g: &Gateway) -> u128 {
        (g.in_flight() as u128 + 1 + g.queued_prefill_tokens() as u128)
            * g.kv_bytes_per_token() as u128
    }

    /// Index of the gateway the next request would go to.
    pub fn pick(&self) -> usize {
        self.gateways
            .iter()
            .enumerate()
            .min_by_key(|(_, g)| Self::score(g))
            .map(|(i, _)| i)
            .expect("router is non-empty")
    }

    /// Route one request (blocking submit — backpressure applies at the
    /// chosen gateway).  Returns the chosen gateway index with the ticket.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingParams,
        deadline: Option<Duration>,
    ) -> std::result::Result<(usize, Ticket), SubmitError> {
        let idx = self.pick();
        let ticket = self.gateways[idx].submit(prompt, max_new, sampling, deadline)?;
        Ok((idx, ticket))
    }

    /// Per-gateway share of all submissions routed so far, as
    /// `(name, rank, submitted)` rows.
    pub fn shares(&self) -> Vec<(String, usize, usize)> {
        self.gateways
            .iter()
            .map(|g| (g.name().to_string(), g.rank(), g.submitted()))
            .collect()
    }

    /// Publish every gateway's routing-visible state into `reg` as
    /// per-rank gauges labelled `{gateway="NAME",rank="R"}` — the
    /// handle-side view (queue depth, pending prefill tokens, per-token
    /// KV cost, lifetime submissions, routing score).  Complements the
    /// worker-side series a [`super::gateway::Obs`]-tapped gateway
    /// publishes itself.
    pub fn export_metrics(&self, reg: &Registry) {
        for g in &self.gateways {
            let labels = format!("{{gateway=\"{}\",rank=\"{}\"}}", g.name(), g.rank());
            reg.gauge_set(&format!("clover_router_in_flight{labels}"), g.in_flight() as f64);
            reg.gauge_set(
                &format!("clover_router_queued_prefill_tokens{labels}"),
                g.queued_prefill_tokens() as f64,
            );
            reg.gauge_set(
                &format!("clover_router_kv_bytes_per_token{labels}"),
                g.kv_bytes_per_token() as f64,
            );
            reg.gauge_set(&format!("clover_router_submitted{labels}"), g.submitted() as f64);
            reg.gauge_set(&format!("clover_router_score{labels}"), Self::score(g) as f64);
        }
    }

    /// One-shot Prometheus text of the routing gauges (stats lines, CLI).
    pub fn prometheus_text(&self) -> String {
        let reg = Registry::new();
        self.export_metrics(&reg);
        reg.prometheus_text()
    }

    /// Gracefully shut every gateway down, returning each engine's final
    /// metrics keyed by gateway name.  Shutdown is signalled to all
    /// gateways *before* any is joined, so the engines drain in parallel
    /// (wall time ≈ the slowest drain, not the sum).
    pub fn join(self) -> Result<Vec<(String, ServeMetrics)>> {
        for g in &self.gateways {
            g.signal_shutdown();
        }
        self.gateways
            .into_iter()
            .map(|g| {
                let name = g.name().to_string();
                g.join().map(|m| (name, m))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::stub::StubSpec;
    use crate::serve::SamplingParams;
    use crate::server::gateway::{EngineSpec, GatewayConfig};
    use std::time::Duration;

    /// Single-lane, single-token-ladder stub with a slow step: requests
    /// submitted while the lane prefills stay queued for ~200ms — plenty
    /// of time for deterministic routing assertions.
    fn slow_stub() -> EngineSpec {
        EngineSpec::stub(StubSpec {
            batch_slots: 1,
            chunk_widths: vec![1],
            max_positions: 256,
            step_delay: Duration::from_millis(3),
            ..Default::default()
        })
    }

    #[test]
    fn long_prompt_bursts_spread_by_pending_prefill_tokens() {
        let router = Router::new(vec![
            Gateway::spawn("a", GatewayConfig::default(), slow_stub()).unwrap(),
            Gateway::spawn("b", GatewayConfig::default(), slow_stub()).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        // Occupy both single-lane engines with identical long prefills so
        // in_flight ties and everything submitted below stays queued.
        let mut tickets = Vec::new();
        for gw in g {
            tickets
                .push(gw.submit((0..64).collect(), 4, SamplingParams::greedy(), None).unwrap());
        }
        // A long prompt queues on "a", a short one on "b": request *count*
        // ties 2–2, but pending prefill is 64+100 vs 64+4 tokens.
        tickets.push(g[0].submit((0..100).collect(), 2, SamplingParams::greedy(), None).unwrap());
        tickets.push(g[1].submit((0..4).collect(), 2, SamplingParams::greedy(), None).unwrap());
        assert_eq!(g[0].in_flight(), g[1].in_flight(), "request count is tied");
        assert!(g[0].queued_prefill_tokens() > g[1].queued_prefill_tokens());
        // The old `(in_flight + 1) × bytes` score tied here and resolved
        // to "a" — piling the long-prompt burst onto one engine.  Weighted
        // by pending prefill tokens, the next request goes to "b".
        assert_eq!(router.pick(), 1);
        // Retire everything quickly and drain.
        for t in &tickets {
            t.cancel.cancel();
        }
        for (name, m) in router.join().unwrap() {
            assert_eq!(m.completed + m.cancelled, 2, "{name}");
        }
    }

    #[test]
    fn speculative_pair_costs_two_engines() {
        use crate::serve::SpecConfig;
        use crate::server::gateway::DraftSource;
        // Same target everywhere; gateway "pair" carries a rank-4 draft on
        // top.  At equal (zero) queue depth the plain engine must win —
        // the pair pins target + draft cache per resident token.
        let target = StubSpec { rank: 8, ..Default::default() };
        let draft = StubSpec { rank: 4, ..target.clone() };
        let pair_spec = EngineSpec::stub(target.clone())
            .with_speculative(DraftSource::Stub(draft), SpecConfig::default());
        let router = Router::new(vec![
            Gateway::spawn("pair", GatewayConfig::default(), pair_spec).unwrap(),
            Gateway::spawn("plain", GatewayConfig::default(), EngineSpec::stub(target)).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        assert!(g[0].speculative() && !g[1].speculative());
        assert_eq!(
            g[0].kv_bytes_per_token(),
            g[1].kv_bytes_per_token() * 3 / 2,
            "rank-4 draft adds half a rank-8 target's bytes"
        );
        // "pair" is listed first, so only its higher KV cost can explain
        // the router preferring "plain".
        assert_eq!(router.pick(), 1);
        router.join().unwrap();
    }

    #[test]
    fn factored_codec_engine_attracts_traffic_like_a_lower_rank() {
        use crate::serve::KvCodecSpec;
        // Two engines at the same compiled rank; "fact" stores its cache
        // through the factored codec at half budgets.  The router's
        // codec-aware per-token cost makes it the cheaper target at equal
        // depth, exactly as if it had been compiled one rank down.
        let target = StubSpec { rank: 8, ..Default::default() };
        let fact_spec = EngineSpec::stub(target.clone())
            .with_kv_codec(KvCodecSpec::Factored { layer_budgets: None });
        let router = Router::new(vec![
            Gateway::spawn("plain", GatewayConfig::default(), EngineSpec::stub(target)).unwrap(),
            Gateway::spawn("fact", GatewayConfig::default(), fact_spec).unwrap(),
        ])
        .unwrap();
        let g = router.gateways();
        assert_eq!(g[0].rank(), g[1].rank(), "same compiled rank");
        assert_eq!(g[1].kv_bytes_per_token() * 2, g[0].kv_bytes_per_token());
        // "plain" is listed first and ties resolve to it, so only the
        // compressed cost can explain the router preferring "fact".
        assert_eq!(router.pick(), 1);
        router.join().unwrap();
    }

    #[test]
    fn export_metrics_publishes_per_rank_gauges() {
        let target = StubSpec { rank: 8, ..Default::default() };
        let low = StubSpec { rank: 4, ..target.clone() };
        let router = Router::new(vec![
            Gateway::spawn("r8", GatewayConfig::default(), EngineSpec::stub(target)).unwrap(),
            Gateway::spawn("r4", GatewayConfig::default(), EngineSpec::stub(low)).unwrap(),
        ])
        .unwrap();
        let reg = crate::obs::Registry::new();
        router.export_metrics(&reg);
        assert_eq!(reg.get("clover_router_in_flight{gateway=\"r8\",rank=\"8\"}"), Some(0.0));
        assert_eq!(
            reg.get("clover_router_kv_bytes_per_token{gateway=\"r4\",rank=\"4\"}"),
            Some(router.gateways()[1].kv_bytes_per_token() as f64),
        );
        let text = router.prometheus_text();
        assert!(text.contains("# TYPE clover_router_score gauge\n"));
        assert!(text.contains("clover_router_score{gateway=\"r8\",rank=\"8\"}"));
        router.join().unwrap();
    }
}
