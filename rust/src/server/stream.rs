//! Per-request event streams: the client half of the gateway.
//!
//! Each submission hands back a [`RequestStream`] — an mpsc receiver the
//! gateway worker feeds as the engine's step hook fires.  The lifecycle is
//!
//! ```text
//! Queued → Started → Token{pos,id} … → Done{completion}
//!                  ├──────────────────▶ Cancelled{reason, partial tokens}
//!                  └──────────────────▶ Failed{reason, partial tokens}
//! ```
//!
//! `Queued` is sent at submission time (before the worker ever sees the
//! request), `Token` events arrive as tokens are sampled — *not* at wave
//! end — and exactly one terminal event (`Done`, `Cancelled`, or
//! `Failed`) closes every stream the gateway accepted.  `Failed` is rare
//! by design: a request on a dying engine is *replayed* by the gateway
//! supervisor (its stream simply resumes), so `Failed` only reaches a
//! client when the failure is unrecoverable — a poisoned lane, or a
//! supervisor out of restart budget.  A stream that ends without a
//! terminal event means the gateway itself died; [`RequestStream::wait`]
//! surfaces that as an error instead of hanging.

use anyhow::{bail, Result};
use std::sync::mpsc;
use std::time::Duration;

use crate::serve::{CancelReason, Completion, FailReason};

/// One moment in a request's lifecycle.  `step` fields carry the engine's
/// global decode-step counter at the event, which is what the bench uses
/// to show a cancelled lane being re-admitted within one decode step.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// Accepted by the gateway handle; not yet seen by the engine thread.
    Queued { id: u64 },
    /// Admitted into a KV lane after `step` decode steps.
    Started { id: u64, lane: usize, step: usize },
    /// A token was sampled at absolute row position `pos` (the prompt
    /// occupies `[0, prompt_len)`, so the k-th generated token sits at
    /// `prompt_len + k`).
    Token { id: u64, pos: usize, token: i32, step: usize },
    /// Terminal: the request finished; full row + latencies inside.
    Done { completion: Completion },
    /// Terminal: retired early; `tokens` is the partial row (prompt +
    /// whatever was generated before retirement).
    Cancelled { id: u64, reason: CancelReason, tokens: Vec<i32>, step: usize },
    /// Terminal: the request failed unrecoverably; `tokens` is the
    /// partial row, like `Cancelled`.  Replayable failures (a backend
    /// death under a live supervisor) never reach the stream — the
    /// request resumes on the rebuilt or sibling engine instead.
    Failed { id: u64, reason: FailReason, tokens: Vec<i32>, step: usize },
}

impl StreamEvent {
    pub fn id(&self) -> u64 {
        match self {
            StreamEvent::Queued { id }
            | StreamEvent::Started { id, .. }
            | StreamEvent::Token { id, .. }
            | StreamEvent::Cancelled { id, .. }
            | StreamEvent::Failed { id, .. } => *id,
            StreamEvent::Done { completion } => completion.id,
        }
    }

    /// `Done`, `Cancelled`, or `Failed` — the stream carries nothing
    /// after these.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            StreamEvent::Done { .. } | StreamEvent::Cancelled { .. } | StreamEvent::Failed { .. }
        )
    }
}

/// How a request ended: the terminal event, minus stream plumbing.
#[derive(Clone, Debug)]
pub enum StreamOutcome {
    Done(Completion),
    Cancelled { id: u64, reason: CancelReason, tokens: Vec<i32> },
    Failed { id: u64, reason: FailReason, tokens: Vec<i32> },
}

impl StreamOutcome {
    pub fn is_done(&self) -> bool {
        matches!(self, StreamOutcome::Done(_))
    }

    /// The token row this request produced (full on `Done`, partial on
    /// `Cancelled` / `Failed`).
    pub fn tokens(&self) -> &[i32] {
        match self {
            StreamOutcome::Done(c) => &c.tokens,
            StreamOutcome::Cancelled { tokens, .. } | StreamOutcome::Failed { tokens, .. } => {
                tokens
            }
        }
    }

    /// Unwrap the completion, erroring on a cancelled or failed request.
    pub fn completion(self) -> Result<Completion> {
        match self {
            StreamOutcome::Done(c) => Ok(c),
            StreamOutcome::Cancelled { id, reason, .. } => {
                bail!("request {id} was cancelled ({reason:?})")
            }
            StreamOutcome::Failed { id, reason, .. } => {
                bail!("request {id} failed ({reason:?})")
            }
        }
    }
}

/// Result of a non-blocking poll.
#[derive(Clone, Debug)]
pub enum TryNext {
    Event(StreamEvent),
    /// Nothing buffered right now; the stream is still live.
    Empty,
    /// The gateway dropped its sender — no further events will arrive.
    Closed,
}

/// The receiving end of one request's event stream.
pub struct RequestStream {
    id: u64,
    rx: mpsc::Receiver<StreamEvent>,
}

impl RequestStream {
    pub(crate) fn new(id: u64, rx: mpsc::Receiver<StreamEvent>) -> Self {
        Self { id, rx }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next event; `None` once the stream is closed.
    pub fn next_event(&self) -> Option<StreamEvent> {
        self.rx.recv().ok()
    }

    /// Block up to `timeout` for the next event.
    pub fn next_timeout(&self, timeout: Duration) -> Option<StreamEvent> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Non-blocking poll, distinguishing "nothing yet" from "gateway gone".
    pub fn try_next(&self) -> TryNext {
        match self.rx.try_recv() {
            Ok(ev) => TryNext::Event(ev),
            Err(mpsc::TryRecvError::Empty) => TryNext::Empty,
            Err(mpsc::TryRecvError::Disconnected) => TryNext::Closed,
        }
    }

    /// Drain to the terminal event.  Errors only if the gateway died
    /// before delivering one.
    pub fn wait(self) -> Result<StreamOutcome> {
        while let Some(ev) = self.next_event() {
            match ev {
                StreamEvent::Done { completion } => return Ok(StreamOutcome::Done(completion)),
                StreamEvent::Cancelled { id, reason, tokens, .. } => {
                    return Ok(StreamOutcome::Cancelled { id, reason, tokens })
                }
                StreamEvent::Failed { id, reason, tokens, .. } => {
                    return Ok(StreamOutcome::Failed { id, reason, tokens })
                }
                _ => {}
            }
        }
        bail!(
            "request {}: event stream closed before a terminal event (gateway gone)",
            self.id
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_all(evs: Vec<StreamEvent>) -> RequestStream {
        let (tx, rx) = mpsc::channel();
        for ev in evs {
            tx.send(ev).unwrap();
        }
        RequestStream::new(7, rx)
    }

    fn done(id: u64) -> StreamEvent {
        StreamEvent::Done {
            completion: Completion {
                id,
                tokens: vec![1, 2, 3],
                latency_s: 0.5,
                ttft_s: 0.1,
                queue_wait_s: 0.0,
                steps: 2,
                prefill_steps: 1,
                finished_step: 2,
            },
        }
    }

    #[test]
    fn wait_surfaces_failure() {
        let s = push_all(vec![
            StreamEvent::Queued { id: 7 },
            StreamEvent::Failed {
                id: 7,
                reason: FailReason::Poisoned,
                tokens: vec![1, 2],
                step: 3,
            },
        ]);
        match s.wait().unwrap() {
            StreamOutcome::Failed { id, reason, tokens } => {
                assert_eq!((id, reason), (7, FailReason::Poisoned));
                assert_eq!(tokens, vec![1, 2]);
            }
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn wait_drains_to_done() {
        let s = push_all(vec![
            StreamEvent::Queued { id: 7 },
            StreamEvent::Started { id: 7, lane: 0, step: 0 },
            StreamEvent::Token { id: 7, pos: 1, token: 2, step: 1 },
            done(7),
        ]);
        let out = s.wait().unwrap();
        assert!(out.is_done());
        assert_eq!(out.tokens(), &[1, 2, 3]);
        assert_eq!(out.completion().unwrap().id, 7);
    }

    #[test]
    fn wait_surfaces_cancellation() {
        let s = push_all(vec![
            StreamEvent::Queued { id: 7 },
            StreamEvent::Cancelled {
                id: 7,
                reason: CancelReason::Deadline,
                tokens: vec![1],
                step: 3,
            },
        ]);
        match s.wait().unwrap() {
            StreamOutcome::Cancelled { id, reason, tokens } => {
                assert_eq!((id, reason), (7, CancelReason::Deadline));
                assert_eq!(tokens, vec![1]);
            }
            other => panic!("expected cancellation, got {other:?}"),
        }
    }

    #[test]
    fn wait_errors_when_gateway_dies_mid_stream() {
        let s = push_all(vec![StreamEvent::Queued { id: 7 }]); // sender dropped
        assert!(s.wait().is_err());
    }

    #[test]
    fn try_next_distinguishes_empty_from_closed() {
        let (tx, rx) = mpsc::channel();
        let s = RequestStream::new(1, rx);
        assert!(matches!(s.try_next(), TryNext::Empty));
        tx.send(StreamEvent::Queued { id: 1 }).unwrap();
        match s.try_next() {
            TryNext::Event(ev) => {
                assert_eq!(ev.id(), 1);
                assert!(!ev.is_terminal());
            }
            other => panic!("expected event, got {other:?}"),
        }
        drop(tx);
        assert!(matches!(s.try_next(), TryNext::Closed));
    }
}
