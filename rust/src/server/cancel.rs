//! Cancellation tokens, per-request deadlines, and the registry that turns
//! them into engine [`Cancellation`] orders.
//!
//! A [`CancelToken`] travels with every accepted submission; firing it
//! sends a control message to the gateway worker, which applies it
//! *between decode steps*: the session retires, its partial tokens go out
//! as a `Cancelled` stream event, and its KV lane frees in time for the
//! same iteration's admission pass.  Deadlines are absolute instants fixed
//! at submission; the registry surfaces them through the same path with
//! [`CancelReason::Deadline`].
//!
//! Cancels ride an *unbounded* channel separate from the bounded ingress,
//! so a client can always cancel even while submitters are blocked on
//! backpressure — and because the two channels are unordered relative to
//! each other, a cancel can arrive before its own submission.  The
//! registry keeps such pre-cancels in its `cancelled` set until the id is
//! tracked; ids are tracked only at the moment the gateway hands them to
//! the engine, so every cancellation [`CancelRegistry::due`] surfaces
//! targets a request the engine actually knows about (in a lane or in its
//! batcher) and the engine's metrics count every retirement.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};
use std::sync::mpsc;
use std::time::Instant;

use super::gateway::Ctrl;
use crate::serve::{CancelReason, Cancellation};

/// Client-side handle to cancel one request.  Cloneable; firing it more
/// than once is harmless (the first application wins, later ones find the
/// id already retired).
#[derive(Clone, Debug)]
pub struct CancelToken {
    id: u64,
    ctrl: mpsc::Sender<Ctrl>,
}

impl CancelToken {
    pub(crate) fn new(id: u64, ctrl: mpsc::Sender<Ctrl>) -> Self {
        Self { id, ctrl }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Fire the cancellation.  Returns `false` when the gateway worker is
    /// already gone (the request ended one way or another regardless).
    pub fn cancel(&self) -> bool {
        self.ctrl.send(Ctrl::Cancel(self.id)).is_ok()
    }
}

/// Worker-side bookkeeping: which ids are live, which have user cancels
/// pending, and when deadlines expire.  Pure data structure — unit
/// testable without an engine.
#[derive(Debug, Default)]
pub struct CancelRegistry {
    /// Ids the gateway accepted and has not yet seen a terminal event for.
    live: HashSet<u64>,
    /// User cancels seen.  Kept until the id retires so a cancel that beat
    /// its own submission across the two channels still lands.  (A cancel
    /// for an id that already retired leaves a stale u64 here — bounded by
    /// the number of post-terminal cancels, which a client has no reason
    /// to send twice.)
    cancelled: HashSet<u64>,
    /// Deadline min-heap: earliest expiry first.
    deadlines: BinaryHeap<Reverse<(Instant, u64)>>,
}

impl CancelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of tracked (non-terminal) requests.
    pub fn live(&self) -> usize {
        self.live.len()
    }

    /// Track a request at the moment it is handed to the engine (not
    /// before: a cancellation surfaced for an id the engine cannot see in
    /// a lane or its batcher would be silently dropped there).  A cancel
    /// that arrived earlier is already waiting in the `cancelled` set and
    /// fires on the next [`CancelRegistry::due`] call.
    pub fn track(&mut self, id: u64, deadline: Option<Instant>) {
        self.live.insert(id);
        if let Some(d) = deadline {
            self.deadlines.push(Reverse((d, id)));
        }
    }

    /// Record a user cancel (idempotent).
    pub fn cancel(&mut self, id: u64) {
        self.cancelled.insert(id);
    }

    /// The id reached a terminal event; drop all state for it.
    pub fn retire(&mut self, id: u64) {
        self.live.remove(&id);
        self.cancelled.remove(&id);
    }

    /// Cancellations due now: user cancels for live ids, then deadlines
    /// that expired at or before `now`.  Ids leave `live` here so each is
    /// surfaced at most once; stale heap entries for retired ids are
    /// skipped lazily.
    pub fn due(&mut self, now: Instant) -> Vec<Cancellation> {
        let mut out = Vec::new();
        if !self.cancelled.is_empty() {
            let fired: Vec<u64> = self
                .cancelled
                .iter()
                .copied()
                .filter(|id| self.live.contains(id))
                .collect();
            for id in fired {
                self.cancelled.remove(&id);
                self.live.remove(&id);
                out.push(Cancellation { id, reason: CancelReason::User });
            }
        }
        while let Some(&Reverse((t, id))) = self.deadlines.peek() {
            if t > now {
                break;
            }
            self.deadlines.pop();
            if self.live.remove(&id) {
                out.push(Cancellation { id, reason: CancelReason::Deadline });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn user_cancel_fires_once_for_live_id() {
        let mut r = CancelRegistry::new();
        r.track(1, None);
        r.cancel(1);
        r.cancel(1); // idempotent
        let due = r.due(Instant::now());
        assert_eq!(due, vec![Cancellation { id: 1, reason: CancelReason::User }]);
        assert!(r.due(Instant::now()).is_empty(), "surfaced at most once");
        assert_eq!(r.live(), 0);
    }

    #[test]
    fn pre_cancel_waits_for_tracking_then_fires() {
        let mut r = CancelRegistry::new();
        r.cancel(5); // cancel beats submission across channels
        assert!(r.due(Instant::now()).is_empty(), "untracked ids never fire");
        r.track(5, None); // handed to the engine
        let due = r.due(Instant::now());
        assert_eq!(due, vec![Cancellation { id: 5, reason: CancelReason::User }]);
        assert_eq!(r.live(), 0);
    }

    #[test]
    fn deadlines_expire_in_order_and_skip_retired() {
        let mut r = CancelRegistry::new();
        let now = Instant::now();
        r.track(1, Some(now)); // already due
        r.track(2, Some(now + Duration::from_secs(60)));
        r.track(3, Some(now));
        r.retire(3); // finished before its deadline
        let due = r.due(now);
        assert_eq!(due, vec![Cancellation { id: 1, reason: CancelReason::Deadline }]);
        assert_eq!(r.live(), 1, "id 2 still live");
        assert!(r.due(now).is_empty(), "id 2 not due for a minute");
    }

    #[test]
    fn retire_beats_late_cancel() {
        let mut r = CancelRegistry::new();
        r.track(9, None);
        r.retire(9); // Done event won the race
        r.cancel(9); // late cancel
        assert!(r.due(Instant::now()).is_empty(), "terminal ids never cancel");
    }
}
