//! # CLOVER — Cross-Layer Orthogonal Vectors, as a Rust/JAX/Pallas stack
//!
//! Reproduction of *"CLOVER: Cross-Layer Orthogonal Vectors Pruning and
//! Fine-Tuning"* (Meng et al., 2024) as a three-layer system:
//!
//! * **Layer 3 (this crate)** — coordinator/framework: config system, data
//!   pipeline, tokenizer, training & eval loops, the CLOVER checkpoint
//!   transform + pruning engine (with its own linalg substrate), PEFT
//!   adapter initialization/accounting, a continuous-batching serving
//!   subsystem (slot-level scheduler, per-request sampling and latency
//!   accounting, paged KV bookkeeping — see [`serve`]), a thread-owning
//!   streaming server front-end above it (channel-fed gateway, per-token
//!   event streams, cancellation, rank-aware routing — see [`server`]),
//!   and the experiment runners that regenerate every table and figure.
//! * **Layer 2** — JAX programs (`python/compile/`), AOT-lowered once to
//!   HLO text under `artifacts/`.
//! * **Layer 1** — Pallas kernels for the fused factorized-attention hot
//!   path, lowered inside the same artifacts.
//!
//! Python never runs at runtime: the [`runtime`] module loads the HLO text
//! through the PJRT C API (`xla` crate) and the coordinator drives the
//! compiled executables with host-owned state.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! recorded paper-vs-measured results.

// CI runs clippy with `-D warnings`; these style lints are allowed
// crate-wide where the "idiomatic" rewrite would obscure the
// indexing-heavy numeric code (lane/slot loops over fixed-shape tensors).
#![allow(clippy::needless_range_loop)]

pub mod check;
pub mod clover;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod model;
// The serving spine must never panic a worker thread on a poisoned lock
// or a sloppy parse: `unwrap` is denied outright in the four modules a
// gateway worker executes — `runtime` (stub + PJRT backends), `serve`
// (engine), `server` (gateway/router), and `obs` (metrics/trace sinks
// shared across worker threads).  Tests are exempted via
// `allow-unwrap-in-tests` in `clippy.toml`.
#[deny(clippy::unwrap_used)]
pub mod obs;
pub mod peft;
pub mod report;
#[deny(clippy::unwrap_used)]
pub mod runtime;
#[deny(clippy::unwrap_used)]
pub mod serve;
#[deny(clippy::unwrap_used)]
pub mod server;
pub mod tensor;
pub mod testing;
pub mod util;
