//! Serving layer: dynamic batching + paged KV-cache management + the
//! batched greedy-decode engine over the KV-cache artifacts.
//!
//! This realizes the paper's motivation end-to-end: after CLOVER pruning to
//! rank r, the decode path caches rank-r factor projections instead of
//! full head dimensions, cutting KV memory by exactly r/d — measured and
//! reported by [`engine::ServeMetrics`].

pub mod batcher;
pub mod engine;
pub mod kv;

pub use batcher::{BatchPolicy, Batcher, Request};
pub use engine::{Completion, Engine, ServeMetrics};
pub use kv::{KvConfig, KvManager, PAGE_TOKENS};
