//! Serving layer: a continuous-batching scheduler over the fixed-shape
//! KV-cache decode artifacts.
//!
//! Architecture (one request's path through the subsystem):
//!
//! * [`batcher`] — FIFO queue + admission rule.  The engine pulls one
//!   request per freed KV lane *between decode steps*
//!   ([`Batcher::pop_admissible`]), so slots never idle waiting for a
//!   wave boundary.
//! * [`session`] — per-request decode state: prompt cursor, generated
//!   row, stop condition, KV slot, and latency bookkeeping (queue wait,
//!   TTFT, per-request completion step).
//! * [`sampling`] — per-request decode policy (greedy / temperature /
//!   top-k / stop token), deterministic per `(seed, request id)`.
//! * [`kv`] — paged KV slot manager: allocation inside the fixed batch,
//!   page-granular position accounting, live/peak bytes.
//! * [`engine`] — the step loop.  Each fused decode step runs all `B`
//!   lanes with *per-lane* positions; finished sessions retire and their
//!   lanes are zeroed and re-assigned immediately.  The KV cache values
//!   themselves stay literal-side across steps
//!   ([`crate::runtime::DecodeSession`]) — host↔device traffic per token
//!   is just the token/position vectors and the logits.
//!
//! This realizes the paper's motivation end-to-end: after CLOVER pruning
//! to rank r, the decode path caches rank-r factor projections instead of
//! full head dimensions, cutting KV memory by exactly r/d — and the
//! slot-level scheduler turns those freed bytes into admitted requests,
//! measured by [`engine::ServeMetrics`] (tokens/s, TTFT, p50/p99 latency,
//! peak KV bytes).

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod sampling;
pub mod session;

pub use batcher::{BatchPolicy, Batcher, Request};
pub use engine::{Admission, Completion, Engine, ServeMetrics};
pub use kv::{KvConfig, KvManager, PAGE_TOKENS};
pub use sampling::{Sampler, SamplingParams};
pub use session::Session;
