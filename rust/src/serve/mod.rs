//! Serving layer: a continuous-batching scheduler over the fixed-shape
//! KV-cache decode artifacts.
//!
//! Architecture (one request's path through the subsystem):
//!
//! * [`batcher`] — FIFO queue + admission rule.  The engine pulls one
//!   request per freed KV lane *between decode steps*
//!   ([`Batcher::pop_admissible`]), so slots never idle waiting for a
//!   wave boundary.
//! * [`session`] — per-request decode state: prompt cursor, generated
//!   row, stop condition, KV slot, and latency bookkeeping (queue wait,
//!   TTFT, per-request completion step).
//! * [`sampling`] — per-request decode policy (greedy / temperature /
//!   top-k / stop token), deterministic per `(seed, request id)`.
//! * [`kv`] — paged KV slot manager: allocation inside the fixed batch,
//!   page-granular position accounting, live/peak bytes.
//! * [`engine`] — the step loop.  Each fused decode step runs all `B`
//!   lanes with *per-lane* positions; finished sessions retire and their
//!   lanes are zeroed and re-assigned immediately.  The KV cache values
//!   themselves stay literal-side across steps
//!   ([`crate::runtime::DecodeSession`]) — host↔device traffic per token
//!   is just the token/position vectors and the logits.
//!
//! This realizes the paper's motivation end-to-end: after CLOVER pruning
//! to rank r, the decode path caches rank-r factor projections instead of
//! full head dimensions, cutting KV memory by exactly r/d — and the
//! slot-level scheduler turns those freed bytes into admitted requests,
//! measured by [`engine::ServeMetrics`] (tokens/s, TTFT, p50/p99 latency,
//! peak KV bytes).
//!
//! ## The step hook and the `server::` layer above
//!
//! The engine's step loop is observable and steerable through
//! [`engine::StepHook`]: between decode steps it polls the hook for new
//! requests ([`Engine::serve_open`] blocks there when idle) and for
//! cancellation orders (fired cancel tokens, expired deadlines — the
//! session retires and its KV lane frees *before* the same iteration's
//! admission pass, so a waiter reclaims it without skipping a step), and
//! during the step it reports admissions, every sampled token, and every
//! completion as they happen.
//!
//! [`crate::server`] is the thread-owning front-end built on that hook.
//! One request's lifecycle through the full stack:
//!
//! ```text
//!  client        gateway thread (owns Runtime + Engine)
//!  ------        --------------------------------------
//!  submit ──────▶ bounded ingress channel ──▶ poll_ingress ──▶ batcher
//!    │ Queued                                        admission │
//!    ◀─────────── Started ◀── on_started ◀───────────────────┘
//!    ◀─────────── Token{pos,id} ◀── on_token   (per sampled token)
//!    ◀─────────── Done{completion} | Cancelled ◀── on_done/on_cancelled
//!  cancel token ─▶ control channel ──▶ take_cancellations (between steps)
//! ```
//!
//! Every submitted request receives exactly one terminal event — `Done`
//! on completion (graceful shutdown drains accepted work to completion),
//! `Cancelled` on token fire or deadline expiry.  `server::Router`
//! multiplexes this across several
//! gateways whose engines were compiled at different CLOVER pruning ranks,
//! routing each request by queue depth × per-rank KV cost
//! ([`KvConfig::bytes_per_token`]).

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod sampling;
pub mod session;

pub use batcher::{BatchPolicy, Batcher, Request};
pub use engine::{
    Admission, Cancellation, CancelReason, Completion, Engine, NoHook, ServeMetrics, StepHook,
};
pub use kv::{KvConfig, KvManager, PAGE_TOKENS};
pub use sampling::{Sampler, SamplingParams};
pub use session::Session;
