//! Serving layer: a continuous-batching scheduler over the fixed-shape
//! KV-cache step artifacts, with chunked prefill as the API default.
//!
//! Architecture (one request's path through the subsystem):
//!
//! * [`batcher`] — FIFO queue + admission rule.  The engine pulls one
//!   request per freed KV lane *between fused steps*
//!   ([`Batcher::pop_admissible`]), so slots never idle waiting for a
//!   wave boundary.  Empty-prompt requests are rejected at admission.
//! * [`session`] — per-request decode state: row cursor, generated row,
//!   stop condition, KV slot, and latency bookkeeping (queue wait, TTFT,
//!   per-request completion and prefill step counts).  A session's unit of
//!   work is a *token slab* ([`Session::next_slab`]): a K-token prompt
//!   chunk during prefill, the single fed-back token during decode.
//! * [`sampling`] — per-request decode policy (greedy / temperature /
//!   top-k / stop token), deterministic per `(seed, request id)`.
//! * [`kv`] — paged KV slot manager and page codecs: allocation inside
//!   the fixed batch, page-granular position accounting per slab
//!   ([`KvManager::advance_by`]), live/peak/freed bytes — all at the
//!   *codec's* stored page size (see the page-codec lifecycle below).
//! * [`engine`] — the step loop, organized around [`engine::StepPlan`].
//!
//! ## The page-codec lifecycle
//!
//! KV pages travel through a pluggable [`kv::PageCodec`]
//! ([`KvCodecSpec`]: `identity` or `factored`, CLI `--kv-codec` /
//! `--kv-layer-budgets`), resolved against the model geometry at every
//! construction boundary ([`Engine::with_kv_codec`], the gateway worker,
//! the CLI):
//!
//! ```text
//!   write (slab step)          at rest                 read (next steps)
//!   rank-r coeff vector ──▶ encode_vec ──▶ [H, 16, stored_rank(l)] page
//!                                             │  bytes_per_page =
//!                                             │  2·H·4·Σ_l stored_rank(l)·16
//!   rank-r coeff vector ◀── decode_vec ◀──────┘  (truncated tail reads 0.0)
//! ```
//!
//! The cache rows are CLOVER coefficients against spectrum-ordered
//! orthogonal vectors, so the factored codec's truncation to per-layer
//! rank budgets (DepthKV-style `Vec<usize>`) is the paper's pruning
//! applied at rest.  [`KvManager`] accounts live/peak/freed bytes at the
//! encoded page size, [`kv::PagedKvStore`] *stores* stub pages at that
//! size (compression exercised, not just counted), and the engine's
//! admission gate ([`Engine::with_kv_memory_budget`]) turns the smaller
//! pages into proportionally more concurrent lanes at a fixed byte
//! budget — for a draft+verify pair, both engines' codecs are accounted.
//!
//! ## The StepPlan lifecycle
//!
//! Every iteration of the engine loop runs the same four stages:
//!
//! ```text
//!        ┌──────────────────────────────────────────────────────────┐
//!        │ 1 SLAB BUILD   each live session offers its next slab:   │
//!        │                prefill lane → widest admissible prompt   │
//!        │                chunk from the ladder {1, 8, 32, ...};    │
//!        │                decode lane → its one fed-back token      │
//!        └───────────────┬──────────────────────────────────────────┘
//!                        ▼  StepPlan { width = max over lanes, slabs }
//!        ┌──────────────────────────────────────────────────────────┐
//!        │ 2 DISPATCH     one fused step through the width-W        │
//!        │                artifact (decode_* at W=1, prefill_k{W}_* │
//!        │                above); narrow slabs pad by repeating     │
//!        │                their last (token, position) pair — an    │
//!        │                idempotent cache rewrite                  │
//!        └───────────────┬──────────────────────────────────────────┘
//!                        ▼  logits [B, V] at each lane's last slab index
//!        ┌──────────────────────────────────────────────────────────┐
//!        │ 3 SAMPLE       lanes whose slab crossed the prompt       │
//!        │                boundary (or that were decoding) sample   │
//!        │                one token; finished sessions retire and   │
//!        │                free their KV lane immediately            │
//!        └───────────────┬──────────────────────────────────────────┘
//!                        ▼  freed lanes, streamed tokens (StepHook)
//!        ┌──────────────────────────────────────────────────────────┐
//!        │ 4 ADMIT        between steps: cancellations retire lanes,│
//!        │                queued requests fill every free lane      │
//!        │                (zeroed first), and the next iteration    │
//!        │                plans over the new lane set               │
//!        └───────────────┬──────────────────────────────────────────┘
//!                        ▼  StepEvent (obs tap: one fused-step record —
//!                           width, lane census, prefill/decode/draft/
//!                           verify token split, live/freed KV bytes)
//! ```
//!
//! A 512-token prompt therefore reaches its first sampled token in
//! `ceil(512/K)` fused steps instead of 512, while neighbouring lanes
//! keep decoding inside the same steps — prefill and decode are one loop,
//! one plan, one artifact family.  The KV cache values stay literal-side
//! across steps *and across widths* ([`crate::runtime::DecodeSession`]
//! carries one cache set for the whole ladder), so host↔device traffic
//! per step is just the token/position slabs and the logits.  A per-step
//! token budget ([`Engine::with_max_step_tokens`], `--max-step-tokens`)
//! caps stage 1's summed slab width: decode lanes always run in full,
//! prefill chunks shrink into the remainder, so one giant prompt cannot
//! inflate every shared step to the widest slab and starve decode-lane
//! latency.
//!
//! ## The radix prefix cache: share → COW → donate → evict
//!
//! With [`Engine::with_prefix_cache`] (stub backing only, CLI
//! `--prefix-cache-block`), prompts that share a prefix prefill it
//! **once**.  A trie keyed on token-id blocks ([`prefix::PrefixCache`],
//! block = a multiple of [`PAGE_TOKENS`] on the prefill-chunk ladder)
//! maps cached prefixes to refcounted columns in the copy-on-write page
//! store ([`kv::PagedKvStore`]); bit-identity to a cold prefill is the
//! correctness bar, property-tested across chunk widths and codecs.
//! One cached block's lifecycle:
//!
//! ```text
//!            ADMIT (stage 4)                       lane lifetime
//!   prompt ─▶ trie.lookup ── hit ──▶ attach_prefix: lane's leading
//!    │           │ pin(path)         pages point at the cached columns
//!    │          miss                 (refcount++, zero bytes copied);
//!    │           │                   prefill resumes at the first
//!    ▼           ▼                   uncached token — never the last
//!   cold: full prefill               prompt token, so the logits step
//!    │                               always runs.  A pad rewrite of a
//!    ▼                               shared column copies first (COW).
//!   RETIRE/CANCEL: trie.unpin(path); store.zero_lane drops the lane's
//!    │             references — shared columns survive, refcount--.
//!    ▼
//!   DONATE: a finished cold prefill offers its prompt-aligned columns
//!    │      (trie.insert + store.share_pages) — contiguity-guarded, so
//!    │      a racing registration never donates a torn prefix.
//!    ▼
//!   EVICT: under a KV memory budget the admission gate asks the trie
//!          for unpinned leaves in ascending attention mass
//!          (block_tokens × (1 + hits), LRU tie-break) until the new
//!          request fits; `ServeMetrics::prefix_evicted_bytes` counts
//!          the sacrifice.
//! ```
//!
//! The gateway/router layer above adds **queue migration**: a saturated
//! engine surrenders *queued* (never admitted) requests from the back of
//! its batcher ([`Batcher::reclaim_newest`], `StepHook::reclaim_requests`
//! / `on_reclaimed`), and the router re-places them on an idle
//! rank-variant — `ServeMetrics::migrated` keeps the conservation
//! invariant `completed + cancelled + migrated + failed == enqueued`, and the
//! receiving gateway stamps `SpanPoint::Migrated` on the request's
//! timeline.  Beyond a configured in-flight depth the gateway sheds load
//! instead (`SubmitError::Overloaded`) — refused before any state is
//! allocated, so there is nothing to reclaim and in-flight requests are
//! untouched.
//!
//! ## Self-speculative decoding: draft → verify → accept/rollback
//!
//! An engine carrying a *draft* model one CLOVER rank down
//! ([`Engine::with_speculative`] / [`Engine::with_speculative_stub`])
//! runs opted-in greedy sessions through a second cycle nested in the
//! same loop, between stages 4 and 1:
//!
//! ```text
//!        ┌──────────────────────────────────────────────────────────┐
//!        │ D DRAFT        decode-ready speculative lanes open a     │
//!        │                round: K cheap width-1 steps on the       │
//!        │                rank-r draft model propose d1..dK         │
//!        │                (target lanes idle; cancels still land    │
//!        │                between draft steps)                      │
//!        └───────────────┬──────────────────────────────────────────┘
//!                        ▼  SpecState::Verify { d1..dK }
//!        ┌──────────────────────────────────────────────────────────┐
//!        │ V VERIFY       the next fused target step carries the    │
//!        │                slab [last, d1..dK-1]; its all-position   │
//!        │                logits [B, K, V] score the whole draft    │
//!        │                in ONE dense step                         │
//!        └───────────────┬──────────────────────────────────────────┘
//!                        ▼  longest greedy-matching prefix m
//!        ┌──────────────────────────────────────────────────────────┐
//!        │ A ACCEPT/      append d1..dm + the target's corrected    │
//!        │   ROLLBACK     token; roll KV accounting back to the     │
//!        │                kept prefix (KvManager::rollback_to,      │
//!        │                page-granular).  Rejected cache entries   │
//!        │                need no scrubbing: the causal mask only   │
//!        │                exposes a position after the step that    │
//!        │                rewrites it                               │
//!        └──────────────────────────────────────────────────────────┘
//! ```
//!
//! Up to K tokens land per dense step, and greedy speculative output is
//! **bit-identical** to vanilla greedy decode (every appended token is
//! the target's own greedy choice given the true prefix), so dense
//! steps-per-token dropping below 1.0 is a pure throughput win — the
//! paper's low-rank models drafting for their own dense parent.  An
//! adaptive controller shrinks K when acceptance drops and regrows it on
//! full acceptance ([`engine::SpecConfig`]).
//!
//! This realizes the paper's motivation end-to-end: after CLOVER pruning
//! to rank r, the decode path caches rank-r factor projections instead of
//! full head dimensions, cutting KV memory by exactly r/d — the
//! slot-level scheduler turns those freed bytes into admitted requests,
//! and the slab API turns the prefill compute-density the pruning spared
//! into TTFT ([`engine::Completion::prefill_steps`],
//! [`engine::ServeMetrics`]).
//!
//! Engines run against the compiled artifacts ([`Engine::new`]) or
//! against the deterministic host-side stub backend
//! ([`Engine::new_stub`], [`crate::runtime::stub`]) — same scheduler,
//! same plans, no PJRT dependency — which is how all of the above is
//! exercised on CI and how step-count benches run on a bare checkout.
//!
//! ## The step hook and the `server::` layer above
//!
//! The engine's step loop is observable and steerable through
//! [`engine::StepHook`]: between fused steps it polls the hook for new
//! requests ([`Engine::serve_open`] blocks there when idle) and for
//! cancellation orders (fired cancel tokens, expired deadlines — the
//! session retires and its KV lane frees *before* the same iteration's
//! admission pass, so a waiter reclaims it without skipping a step, even
//! mid-prefill), and during the step it reports admissions, every sampled
//! token, and every completion as they happen.
//!
//! [`crate::server`] is the thread-owning front-end built on that hook.
//! One request's lifecycle through the full stack:
//!
//! ```text
//!  client        gateway thread (owns Runtime + Engine)        obs taps
//!  ------        --------------------------------------        --------
//!  submit ──────▶ bounded ingress channel ──▶ poll_ingress ──▶ batcher
//!    │ Queued                                        admission │  Span: Queued
//!    ◀─────────── Started ◀── on_started ◀───────────────────┘  Span: Admitted
//!                            (prefill chunks consume prompt)     Span: PrefillChunk*
//!    ◀─────────── Token{pos,id} ◀── on_token   (per sampled      Span: FirstToken
//!                                               token)           Span: SpecRound*
//!                 ·· step fault ─▶ Retry (backoff, ≤ budget) ··  StepEvent.retries
//!    ◀─────────── Done{completion} | Cancelled ◀── on_done/      Span: Done |
//!                                        on_cancelled            Span: Cancelled
//!    ◀─────────── Failed{reason} ◀── on_failed  (poisoned lane   Span: Failed
//!                    │               or backend death)
//!                    └─▶ Backend failures replay on the rebuilt
//!                        engine or FAIL OVER to a sibling rank
//!                        (supervisor + router breaker — no event
//!                        reaches the client until the replay's
//!                        own terminal)
//!  cancel token ─▶ control channel ──▶ take_cancellations (between steps)
//! ```
//!
//! The right-hand column is the observability layer ([`crate::obs`]):
//! [`crate::obs::TraceSink`] is itself a [`engine::StepHook`], so the
//! same hook surface that streams tokens also feeds per-request
//! `SpanEvent` timelines (`Queued → Admitted → PrefillChunk* →
//! FirstToken → SpecRound* → Done | Cancelled`) and the per-step
//! `StepEvent` ring — a bounded flight recorder dumped on overload,
//! cancel storms, and shutdown, exportable as Chrome trace-event JSON.
//! `crate::obs::TeeHook` composes the sink with a primary control hook
//! (the gateway worker runs one), and the gateway publishes aggregate
//! counters/gauges into a shared `crate::obs::Registry`
//! (`server::gateway::Obs`), rendered as Prometheus text or JSON; the
//! router re-exports the same registry per rank.
//!
//! Every submitted request receives exactly one terminal event — `Done`
//! on completion (graceful shutdown drains accepted work to completion),
//! `Cancelled` on token fire or deadline expiry, including cancels that
//! land while the request is still prefilling (partial row = prompt, no
//! tokens), or `Failed` when a poisoned lane retires it individually.
//! Transient step faults never surface at all: the engine retries the
//! identical fused step under [`engine::RetryPolicy`] (a failed step
//! committed nothing — KV cursors and sessions only advance after Ok),
//! and a backend death fails every held request with
//! `FailReason::Backend`, whose partial rows the gateway supervisor
//! replays losslessly on the rebuilt engine — the conservation invariant
//! is `completed + cancelled + migrated + failed == enqueued` at every
//! level.  `server::Router` multiplexes this across several gateways
//! whose engines were compiled at different CLOVER pruning ranks, routing
//! each request by (queue depth + pending prefill tokens) × per-rank KV
//! cost ([`KvConfig::bytes_per_token`]), tracking per-engine health with
//! a fault-rate circuit breaker (Healthy/Degraded/Open, probe-driven
//! half-open) and failing a dead engine's queued + replayable requests
//! over to sibling ranks — see `docs/ROBUSTNESS.md`.

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod prefix;
pub mod sampling;
pub mod session;

pub use batcher::{BatchPolicy, Batcher, Request};
pub use engine::{
    chunk_width, Admission, Cancellation, CancelReason, Completion, Engine, FailReason, LaneSlab,
    NoHook, RetryPolicy, ServeMetrics, SpecConfig, StepError, StepHook, StepPlan,
};
pub use kv::{
    FactoredCodec, IdentityCodec, KvCodecSpec, KvConfig, KvManager, KvSpecError, PageCodec,
    PagedKvStore, PAGE_TOKENS,
};
pub use prefix::{chain_hashes, PrefixCache, PrefixMatch, DEFAULT_PREFIX_BLOCK};
pub use sampling::{Sampler, SamplingParams};
pub use session::{Session, SpecState, VerifyOutcome};
