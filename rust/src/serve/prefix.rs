//! Radix prefix cache over the copy-on-write page store.
//!
//! A trie keyed on *token-id blocks* — fixed-size chunks of the prompt,
//! sized to a multiple of [`PAGE_TOKENS`] and aligned to the engine's
//! prefill-chunk ladder — maps each cached block to the page-store
//! columns holding its KV pages.  A shared system prompt prefills once:
//! the first request's lane donates its prefix columns to the trie
//! ([`PrefixCache::insert`] + [`crate::serve::PagedKvStore::share_pages`]),
//! and later requests whose prompts walk the same path attach to those
//! columns with zero bytes copied ([`PrefixCache::lookup`] +
//! [`crate::serve::PagedKvStore::attach_prefix`]), diverging privately via
//! copy-on-write only if they ever rewrite a shared page.
//!
//! ## Eviction: LRU by attention mass
//!
//! Under memory pressure the engine asks the trie to give pages back
//! ([`PrefixCache::evict`]).  Candidates are *unpinned leaves* — nodes no
//! live lane is attached to ([`PrefixCache::pin`] guards the rest) and
//! with no cached children (a child's pages are useless without its
//! prefix, so interior nodes only fall after their subtree).  Victims go
//! in ascending **attention mass** — `block_tokens × (1 + hits)`, the
//! KVzap-style proxy for how much attention the cached pages absorb
//! across the request mix — with the logical touch clock as the LRU
//! tie-break.  Every evicted block releases its column references; the
//! store frees columns whose last reference that was, and the manager's
//! cache pool shrinks by the released page count.
//!
//! The trie never stores a *partial* block: prompts cache
//! `floor(len / block)` blocks, and lookups are capped by the caller (the
//! engine attaches at most `prompt_len − 1` tokens so at least one real
//! token always prefills — the step that produces the first logits).

use anyhow::{bail, Result};

use super::kv::PAGE_TOKENS;

/// Default block width (tokens per trie node): the widest rung of the
/// default prefill-chunk ladder, so one cached block is exactly one
/// fused prefill step skipped.
pub const DEFAULT_PREFIX_BLOCK: usize = 32;

/// Rolling FNV-1a hashes of each successive `block`-sized chunk of
/// `prompt` — hash `i` covers tokens `0..(i + 1) · block`.  The router's
/// shadow placement directory stores these per gateway, so "which engine
/// holds my longest cached prefix" is a set probe, not an RPC.
pub fn chain_hashes(prompt: &[i32], block: usize) -> Vec<u64> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut out = Vec::new();
    for chunk in prompt.chunks_exact(block) {
        for &t in chunk {
            h ^= t as u32 as u64;
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
        out.push(h);
    }
    out
}

struct Node {
    parent: Option<usize>,
    /// Exactly `block` token ids — the edge label from the parent.
    tokens: Vec<i32>,
    children: Vec<usize>,
    /// Column ids in the page store, `block / PAGE_TOKENS` of them.
    cols: Vec<usize>,
    /// Live lanes attached at or below this node; pinned nodes never
    /// evict.
    pins: usize,
    hits: usize,
    last_touch: u64,
}

impl Node {
    /// KVzap-style eviction key: tokens held × popularity.
    fn mass(&self) -> usize {
        self.tokens.len() * (1 + self.hits)
    }
}

/// Result of a trie walk: the matched path (root-first node ids, for
/// pinning), its length in tokens, and the concatenated column ids to
/// attach.
pub struct PrefixMatch {
    pub path: Vec<usize>,
    pub tokens: usize,
    pub cols: Vec<usize>,
}

/// The radix prefix cache.  Pure bookkeeping: column references and page
/// budgets live in [`crate::serve::PagedKvStore`] / `KvManager`; the trie
/// decides *which* columns to attach, donate, and sacrifice.
pub struct PrefixCache {
    block: usize,
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    roots: Vec<usize>,
    /// Logical clock for LRU tie-breaks (bumped per lookup/insert).
    clock: u64,
    hits: usize,
    misses: usize,
}

impl PrefixCache {
    pub fn new(block: usize) -> Result<Self> {
        if block == 0 || block % PAGE_TOKENS != 0 {
            bail!("prefix block {block} must be a positive multiple of {PAGE_TOKENS}");
        }
        Ok(Self {
            block,
            nodes: Vec::new(),
            free: Vec::new(),
            roots: Vec::new(),
            clock: 0,
            hits: 0,
            misses: 0,
        })
    }

    pub fn block(&self) -> usize {
        self.block
    }

    pub fn pages_per_block(&self) -> usize {
        self.block / PAGE_TOKENS
    }

    /// (hits, misses) across lookups — the hit-rate numerator/denominator
    /// the obs layer exports.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits, self.misses)
    }

    /// Cached blocks (trie nodes).
    pub fn len(&self) -> usize {
        self.nodes.iter().flatten().count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pages the trie holds — the page-count twin of
    /// `KvManager::cache_pages` (they agree by construction: every
    /// donation and eviction updates both).
    pub fn cached_pages(&self) -> usize {
        self.len() * self.pages_per_block()
    }

    pub fn cached_tokens(&self) -> usize {
        self.len() * self.block
    }

    fn child_matching(&self, children: &[usize], chunk: &[i32]) -> Option<usize> {
        children
            .iter()
            .copied()
            .find(|&c| self.nodes[c].as_ref().is_some_and(|n| n.tokens == chunk))
    }

    /// Walk `prompt` block by block, stopping at the first miss or at
    /// `max_tokens` (the engine passes `prompt_len − 1` so one token
    /// always prefills).  Counts one hit (and bumps path stats) when
    /// anything matched, one miss otherwise.
    pub fn lookup(&mut self, prompt: &[i32], max_tokens: usize) -> PrefixMatch {
        self.clock += 1;
        let clock = self.clock;
        let mut path = Vec::new();
        let mut cols = Vec::new();
        let mut children: Vec<usize> = self.roots.clone();
        for chunk in prompt.chunks_exact(self.block) {
            if (path.len() + 1) * self.block > max_tokens {
                break;
            }
            let Some(c) = self.child_matching(&children, chunk) else { break };
            let node = self.nodes[c].as_mut().unwrap();
            node.hits += 1;
            node.last_touch = clock;
            children = node.children.clone();
            cols.extend_from_slice(&self.nodes[c].as_ref().unwrap().cols);
            path.push(c);
        }
        if path.is_empty() {
            self.misses += 1;
        } else {
            self.hits += 1;
        }
        PrefixMatch { tokens: path.len() * self.block, path, cols }
    }

    /// Longest cached prefix of `prompt` in tokens, without touching hit
    /// stats or the LRU clock — budget math and placement probes.
    pub fn peek_match(&self, prompt: &[i32], max_tokens: usize) -> usize {
        let mut matched = 0;
        let mut children: Vec<usize> = self.roots.clone();
        for chunk in prompt.chunks_exact(self.block) {
            if matched + self.block > max_tokens {
                break;
            }
            let Some(c) = self.child_matching(&children, chunk) else { break };
            children = self.nodes[c].as_ref().unwrap().children.clone();
            matched += self.block;
        }
        matched
    }

    /// Register `blocks` leading blocks of `prompt` after its prefill
    /// completed.  Blocks already cached are reused (a concurrent
    /// duplicate prefill donates nothing twice); for each genuinely new
    /// block, `make_cols(block_index)` must pin and return its column
    /// ids (the engine shares the lane's page range).  Returns the full
    /// path and how many blocks were newly created — the page-donation
    /// count the caller forwards to `KvManager::donate_to_cache`.
    pub fn insert(
        &mut self,
        prompt: &[i32],
        blocks: usize,
        mut make_cols: impl FnMut(usize) -> Vec<usize>,
    ) -> (Vec<usize>, usize) {
        self.clock += 1;
        let clock = self.clock;
        let mut path = Vec::new();
        let mut created = 0;
        let mut parent: Option<usize> = None;
        for (i, chunk) in prompt.chunks_exact(self.block).take(blocks).enumerate() {
            let siblings = match parent {
                Some(p) => self.nodes[p].as_ref().unwrap().children.clone(),
                None => self.roots.clone(),
            };
            let id = match self.child_matching(&siblings, chunk) {
                Some(c) => {
                    self.nodes[c].as_mut().unwrap().last_touch = clock;
                    c
                }
                None => {
                    let cols = make_cols(i);
                    debug_assert_eq!(cols.len(), self.pages_per_block());
                    let node = Node {
                        parent,
                        tokens: chunk.to_vec(),
                        children: Vec::new(),
                        cols,
                        pins: 0,
                        hits: 0,
                        last_touch: clock,
                    };
                    let id = match self.free.pop() {
                        Some(slot) => {
                            self.nodes[slot] = Some(node);
                            slot
                        }
                        None => {
                            self.nodes.push(Some(node));
                            self.nodes.len() - 1
                        }
                    };
                    match parent {
                        Some(p) => self.nodes[p].as_mut().unwrap().children.push(id),
                        None => self.roots.push(id),
                    }
                    created += 1;
                    id
                }
            };
            path.push(id);
            parent = Some(id);
        }
        (path, created)
    }

    /// Pin every node on `path` (a lane is attached at or registered
    /// below them): pinned nodes never evict, so pages a live lane reads
    /// stay resident without any ownership juggling.
    pub fn pin(&mut self, path: &[usize]) {
        for &id in path {
            self.nodes[id].as_mut().expect("pin of an evicted node").pins += 1;
        }
    }

    /// Drop a lane's pins (on retirement or cancellation).
    pub fn unpin(&mut self, path: &[usize]) {
        for &id in path {
            let n = self.nodes[id].as_mut().expect("unpin of an evicted node");
            debug_assert!(n.pins > 0);
            n.pins -= 1;
        }
    }

    /// Give back at least `min_pages` pages (or everything evictable):
    /// repeatedly remove the unpinned *leaf* with the smallest
    /// (attention mass, last touch), collecting its column ids.  Returns
    /// the released columns — the caller forwards them to
    /// `PagedKvStore::release_cols` and shrinks `KvManager::cache_pages`
    /// by `cols.len()` (one page per column).
    pub fn evict(&mut self, min_pages: usize) -> Vec<usize> {
        let mut released = Vec::new();
        while released.len() < min_pages {
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .filter_map(|(id, n)| n.as_ref().map(|n| (id, n)))
                .filter(|(_, n)| n.pins == 0 && n.children.is_empty())
                .min_by_key(|(_, n)| (n.mass(), n.last_touch))
                .map(|(id, _)| id);
            let Some(id) = victim else { break };
            let node = self.nodes[id].take().unwrap();
            self.free.push(id);
            match node.parent {
                Some(p) => {
                    if let Some(parent) = self.nodes[p].as_mut() {
                        parent.children.retain(|&c| c != id);
                    }
                }
                None => self.roots.retain(|&c| c != id),
            }
            released.extend(node.cols);
        }
        released
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cols_for(block_idx: usize, ppb: usize) -> Vec<usize> {
        (0..ppb).map(|p| block_idx * ppb + p + 100).collect()
    }

    #[test]
    fn block_must_align_to_pages() {
        assert!(PrefixCache::new(0).is_err());
        assert!(PrefixCache::new(20).is_err());
        assert!(PrefixCache::new(PAGE_TOKENS).is_ok());
        assert!(PrefixCache::new(2 * PAGE_TOKENS).is_ok());
    }

    #[test]
    fn insert_then_lookup_walks_shared_path() {
        let mut trie = PrefixCache::new(16).unwrap();
        let ppb = trie.pages_per_block();
        let prompt: Vec<i32> = (0..40).collect();
        // 40 tokens cache floor(40/16) = 2 blocks.
        let (path, created) = trie.insert(&prompt, 2, |i| cols_for(i, ppb));
        assert_eq!((path.len(), created), (2, 2));
        assert_eq!(trie.cached_pages(), 2 * ppb);
        // Same prompt again: nothing new is created.
        let (path2, created2) = trie.insert(&prompt, 2, |_| unreachable!("no new blocks"));
        assert_eq!((path2, created2), (path.clone(), 0));
        // A prompt sharing one block diverges after it.
        let mut other = prompt.clone();
        other[20] = 999;
        let (path3, created3) = trie.insert(&other, 2, |i| cols_for(10 + i, ppb));
        assert_eq!(created3, 1);
        assert_eq!(path3[0], path[0], "first block shared");
        assert_ne!(path3[1], path[1]);
        // Lookup returns the concatenated columns, capped by max_tokens.
        let m = trie.lookup(&prompt, 39);
        assert_eq!(m.tokens, 32);
        assert_eq!(m.path, path);
        assert_eq!(m.cols, [cols_for(0, ppb), cols_for(1, ppb)].concat());
        let capped = trie.lookup(&prompt, 20);
        assert_eq!(capped.tokens, 16, "cap keeps at least one block un-attached");
        assert_eq!(trie.peek_match(&prompt, 39), 32);
        assert_eq!(trie.stats(), (2, 0));
        let miss = trie.lookup(&[7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7], 15);
        assert_eq!(miss.tokens, 0);
        assert_eq!(trie.stats(), (2, 1));
    }

    #[test]
    fn eviction_takes_cold_unpinned_leaves_first() {
        let mut trie = PrefixCache::new(16).unwrap();
        let ppb = trie.pages_per_block();
        let hot: Vec<i32> = (0..32).collect();
        let cold: Vec<i32> = (1000..1032).collect();
        trie.insert(&hot, 2, |i| cols_for(i, ppb));
        trie.insert(&cold, 2, |i| cols_for(10 + i, ppb));
        // Heat up the full hot path: its mass grows with hits.
        for _ in 0..3 {
            trie.lookup(&hot, 32);
        }
        // Pin the hot path like an attached lane would.
        let m = trie.lookup(&hot, 32);
        assert_eq!(m.tokens, 32);
        let hot_path = m.path.clone();
        trie.pin(&hot_path);
        // Ask for one page: the cold *leaf* goes first (deepest block of
        // the cold chain), never the pinned hot chain.
        let out = trie.evict(1);
        assert_eq!(out, cols_for(11, ppb));
        assert_eq!(trie.cached_pages(), 3 * ppb);
        // Asking for everything evictable spares only the pinned chain.
        let out = trie.evict(usize::MAX);
        assert_eq!(out, cols_for(10, ppb));
        assert_eq!(trie.cached_pages(), 2 * ppb);
        // Unpin: now the interior block falls only after its child.
        trie.unpin(&hot_path);
        let out = trie.evict(usize::MAX);
        assert_eq!(out, [cols_for(1, ppb), cols_for(0, ppb)].concat());
        assert!(trie.is_empty());
        // Evicting an empty trie yields nothing (and does not loop).
        assert!(trie.evict(1).is_empty());
    }

    #[test]
    fn chain_hashes_are_prefix_stable() {
        let a: Vec<i32> = (0..64).collect();
        let mut b = a.clone();
        b[40] = -1;
        let (ha, hb) = (chain_hashes(&a, 16), chain_hashes(&b, 16));
        assert_eq!(ha.len(), 4);
        assert_eq!(ha[..2], hb[..2], "shared prefix hashes agree");
        assert_ne!(ha[2], hb[2], "divergence changes every later hash");
        assert_ne!(ha[3], hb[3]);
        // Truncation is a prefix of the full chain.
        assert_eq!(chain_hashes(&a[..32], 16), ha[..2]);
        assert!(chain_hashes(&a[..15], 16).is_empty());
    }
}
