//! Paged KV-cache slot manager.
//!
//! The decode artifacts carry caches shaped `[L, B, H, C, r]` for a fixed
//! micro-batch B; this manager owns slot allocation inside that batch,
//! page-granular position accounting, and the bytes bookkeeping that
//! demonstrates the paper's motivating claim: pruning head rank r shrinks
//! KV memory proportionally.

use anyhow::{bail, Result};

/// Page size in token positions (allocation granularity).
pub const PAGE_TOKENS: usize = 16;

#[derive(Clone, Debug)]
pub struct KvConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub rank: usize,
    pub max_positions: usize,
    pub batch_slots: usize,
}

impl KvConfig {
    /// Bytes per token position across all layers/heads (K + VO caches).
    pub fn bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_heads * self.rank * 4
    }

    pub fn bytes_per_page(&self) -> usize {
        self.bytes_per_token() * PAGE_TOKENS
    }
}

#[derive(Clone, Debug, PartialEq)]
struct Slot {
    id: u64,
    pages: usize,
    positions: usize,
}

/// Allocates batch slots + pages; tracks live KV bytes.
pub struct KvManager {
    cfg: KvConfig,
    slots: Vec<Option<Slot>>,
    peak_bytes: usize,
}

impl KvManager {
    pub fn new(cfg: KvConfig) -> Self {
        let slots = vec![None; cfg.batch_slots];
        Self { cfg, slots, peak_bytes: 0 }
    }

    pub fn config(&self) -> &KvConfig {
        &self.cfg
    }

    /// Claim a slot for request `id`. Errors when the batch is full.
    ///
    /// Contract: returns the *lowest* free slot index.  Slot indices are
    /// batch-lane indices — the engine zeroes exactly this lane of the
    /// `[L, B, H, C, r]` caches on re-assignment, so the mapping must be
    /// stable and dense.
    pub fn allocate(&mut self, id: u64) -> Result<usize> {
        if self.slots.iter().flatten().any(|s| s.id == id) {
            bail!("request {id} already has a slot");
        }
        for (i, s) in self.slots.iter_mut().enumerate() {
            if s.is_none() {
                *s = Some(Slot { id, pages: 0, positions: 0 });
                return Ok(i);
            }
        }
        bail!("KV batch full ({} slots)", self.slots.len())
    }

    /// Record one generated position for slot `slot`; grows pages on
    /// boundary crossings. Errors past `max_positions`.
    pub fn advance(&mut self, slot: usize) -> Result<()> {
        self.advance_by(slot, 1)
    }

    /// Record `n` positions at once — one token slab.  Page accounting is
    /// slab-granular: an 8-token chunk crossing a page boundary allocates
    /// the new page in the same call, so live/peak bytes are exact no
    /// matter how wide the step was.  Errors when the slab would escape
    /// `max_positions`, charging nothing.
    pub fn advance_by(&mut self, slot: usize, n: usize) -> Result<()> {
        let cfg_max = self.cfg.max_positions;
        let s = self.slots.get_mut(slot).and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow::anyhow!("slot {slot} not allocated"))?;
        if s.positions + n > cfg_max {
            bail!(
                "slot {slot}: {} + {n} positions would exceed max {cfg_max}",
                s.positions
            );
        }
        s.positions += n;
        let need = s.positions.div_ceil(PAGE_TOKENS);
        if need > s.pages {
            s.pages = need;
        }
        let live = self.live_bytes();
        if live > self.peak_bytes {
            self.peak_bytes = live;
        }
        Ok(())
    }

    /// Roll slot `slot` back to exactly `positions` recorded positions —
    /// the accounting half of speculative rollback: a verify step advances
    /// by the whole written slab, then rolls back to the accepted prefix.
    /// Page reclaim is page-granular (pages above the new high-water mark
    /// free immediately; `peak_bytes` keeps the high tide).  Errors when
    /// `positions` is *ahead* of the recorded count — rollback never
    /// invents progress — charging nothing.
    pub fn rollback_to(&mut self, slot: usize, positions: usize) -> Result<()> {
        let s = self.slots.get_mut(slot).and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow::anyhow!("slot {slot} not allocated"))?;
        if positions > s.positions {
            bail!(
                "slot {slot}: rollback_to {positions} is ahead of the {} recorded positions",
                s.positions
            );
        }
        s.positions = positions;
        s.pages = positions.div_ceil(PAGE_TOKENS);
        Ok(())
    }

    /// Free a slot (request finished / evicted).
    pub fn free(&mut self, slot: usize) -> Result<u64> {
        match self.slots.get_mut(slot).and_then(|s| s.take()) {
            Some(s) => Ok(s.id),
            None => bail!("double free of slot {slot}"),
        }
    }

    pub fn live_bytes(&self) -> usize {
        self.slots.iter().flatten()
            .map(|s| s.pages * self.cfg.bytes_per_page())
            .sum()
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// Positions recorded for `slot`; 0 for a free slot *or* an
    /// out-of-range index, matching the other accessors' no-panic contract.
    pub fn positions(&self, slot: usize) -> usize {
        self.slots.get(slot).and_then(|s| s.as_ref()).map_or(0, |s| s.positions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn cfg(rank: usize) -> KvConfig {
        KvConfig { n_layers: 2, n_heads: 4, rank, max_positions: 64, batch_slots: 4 }
    }

    #[test]
    fn rank_halves_bytes() {
        assert_eq!(cfg(8).bytes_per_token() * 2, cfg(16).bytes_per_token());
    }

    #[test]
    fn allocate_free_cycle() {
        let mut kv = KvManager::new(cfg(8));
        let a = kv.allocate(1).unwrap();
        let b = kv.allocate(2).unwrap();
        assert_ne!(a, b);
        assert_eq!(kv.free_slots(), 2);
        assert_eq!(kv.free(a).unwrap(), 1);
        assert_eq!(kv.free_slots(), 3);
        assert!(kv.free(a).is_err(), "double free must fail");
    }

    #[test]
    fn allocate_returns_lowest_free_slot() {
        // The engine uses slot indices as batch-lane indices; re-assignment
        // must hand back the lowest freed lane.
        let mut kv = KvManager::new(cfg(8));
        for i in 0..4 {
            assert_eq!(kv.allocate(i).unwrap(), i as usize);
        }
        kv.free(1).unwrap();
        kv.free(3).unwrap();
        assert_eq!(kv.allocate(10).unwrap(), 1);
        assert_eq!(kv.allocate(11).unwrap(), 3);
    }

    #[test]
    fn duplicate_request_rejected() {
        let mut kv = KvManager::new(cfg(8));
        kv.allocate(7).unwrap();
        assert!(kv.allocate(7).is_err());
    }

    #[test]
    fn batch_full() {
        let mut kv = KvManager::new(cfg(8));
        for i in 0..4 {
            kv.allocate(i).unwrap();
        }
        assert!(kv.allocate(99).is_err());
    }

    #[test]
    fn pages_grow_with_positions() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        for _ in 0..PAGE_TOKENS {
            kv.advance(s).unwrap();
        }
        assert_eq!(kv.live_bytes(), kv.config().bytes_per_page());
        kv.advance(s).unwrap();
        assert_eq!(kv.live_bytes(), 2 * kv.config().bytes_per_page());
    }

    #[test]
    fn advance_by_slab_accounts_pages() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        // One slab crossing a page boundary allocates the new page in the
        // same call.
        kv.advance_by(s, PAGE_TOKENS + 1).unwrap();
        assert_eq!(kv.live_bytes(), 2 * kv.config().bytes_per_page());
        assert_eq!(kv.positions(s), PAGE_TOKENS + 1);
        // A slab that would escape the window is refused atomically.
        assert!(kv.advance_by(s, 64).is_err());
        assert_eq!(kv.positions(s), PAGE_TOKENS + 1, "failed slab charges nothing");
        kv.advance_by(s, 64 - PAGE_TOKENS - 1).unwrap();
        assert!(kv.advance(s).is_err(), "window exactly full");
    }

    #[test]
    fn advance_by_failure_is_atomic_at_page_boundary() {
        // Satellite regression: a capacity-refused slab charges *nothing*
        // — positions, pages, and live bytes are all untouched, even when
        // the refused slab would have crossed a page boundary.
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        // Park exactly at a page boundary (one page, completely full).
        kv.advance_by(s, PAGE_TOKENS).unwrap();
        let (pos0, live0, peak0) =
            (kv.positions(s), kv.live_bytes(), kv.peak_bytes());
        assert_eq!(pos0, PAGE_TOKENS);
        assert_eq!(live0, kv.config().bytes_per_page());
        // 64 - PAGE_TOKENS positions remain; asking for one more than that
        // must fail without touching anything — no partial advance, no
        // page allocated for the boundary the slab would have crossed.
        let over = 64 - PAGE_TOKENS + 1;
        assert!(kv.advance_by(s, over).is_err());
        assert_eq!(kv.positions(s), pos0, "positions untouched on failure");
        assert_eq!(kv.live_bytes(), live0, "pages untouched on failure");
        assert_eq!(kv.peak_bytes(), peak0, "peak untouched on failure");
        // The exact remaining capacity still fits afterwards.
        kv.advance_by(s, over - 1).unwrap();
        assert_eq!(kv.positions(s), 64);
    }

    #[test]
    fn rollback_to_reclaims_pages() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        // A verify slab crossing into a second page...
        kv.advance_by(s, PAGE_TOKENS + 4).unwrap();
        assert_eq!(kv.live_bytes(), 2 * kv.config().bytes_per_page());
        let peak = kv.peak_bytes();
        // ...rolled back to the accepted prefix: the second page frees.
        kv.rollback_to(s, PAGE_TOKENS - 2).unwrap();
        assert_eq!(kv.positions(s), PAGE_TOKENS - 2);
        assert_eq!(kv.live_bytes(), kv.config().bytes_per_page());
        assert_eq!(kv.peak_bytes(), peak, "peak keeps the high tide");
        // Rollback to the current count is a no-op; going forward errors
        // without charging anything.
        kv.rollback_to(s, PAGE_TOKENS - 2).unwrap();
        assert!(kv.rollback_to(s, PAGE_TOKENS).is_err());
        assert_eq!(kv.positions(s), PAGE_TOKENS - 2);
        // Rollback to zero frees every page but keeps the slot.
        kv.rollback_to(s, 0).unwrap();
        assert_eq!(kv.live_bytes(), 0);
        assert_eq!(kv.free_slots(), 3, "slot itself stays allocated");
        // Unallocated slots are refused.
        assert!(kv.rollback_to(s + 1, 0).is_err());
    }

    #[test]
    fn max_positions_enforced() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        for _ in 0..64 {
            kv.advance(s).unwrap();
        }
        assert!(kv.advance(s).is_err());
    }

    #[test]
    fn positions_out_of_range_is_zero_not_panic() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        kv.advance(s).unwrap();
        assert_eq!(kv.positions(s), 1);
        // Free slot and out-of-range index both read as 0.
        assert_eq!(kv.positions(s + 1), 0);
        assert_eq!(kv.positions(1000), 0);
    }

    #[test]
    fn allocator_never_leaks_property() {
        prop("kv allocator conservation", 30, |rng| {
            let mut kv = KvManager::new(cfg(8));
            let mut live: Vec<usize> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..200 {
                if rng.uniform() < 0.5 && kv.free_slots() > 0 {
                    live.push(kv.allocate(next_id).map_err(|e| e.to_string())?);
                    next_id += 1;
                } else if !live.is_empty() {
                    let i = rng.below(live.len());
                    let slot = live.swap_remove(i);
                    kv.free(slot).map_err(|e| e.to_string())?;
                }
                if kv.free_slots() + live.len() != 4 {
                    return Err("slot conservation violated".into());
                }
            }
            Ok(())
        });
    }
}
