//! Paged KV-cache slot manager and page codecs.
//!
//! The decode artifacts carry caches shaped `[L, B, H, C, r]` for a fixed
//! micro-batch B; this module owns slot allocation inside that batch,
//! page-granular position accounting, and the bytes bookkeeping that
//! demonstrates the paper's motivating claim: pruning head rank r shrinks
//! KV memory proportionally.
//!
//! ## Page codecs
//!
//! Bytes-per-page is no longer the hardcoded dense formula
//! `2·L·H·r·4·PAGE_TOKENS`: every page travels through a pluggable
//! [`PageCodec`] that encodes/decodes `[H, PAGE_TOKENS, r]` page blocks
//! and *defines* the stored footprint.
//!
//! * [`IdentityCodec`] stores rank-r coefficient vectors verbatim —
//!   bit-identical to the pre-codec path (property-tested here and end to
//!   end through the engine's chunked-prefill and speculative bit-identity
//!   suites).
//! * [`FactoredCodec`] stores pages *in CLOVER's factored basis at the
//!   pruned rank*: the cache rows are already coefficients against the
//!   per-head orthogonal vectors, ordered by the singular spectrum, so
//!   keeping the first `budget[l]` coefficients of each vector is exactly
//!   the paper's rank truncation applied at rest.  `bytes_per_token`
//!   shrinks by the rank ratio and `batch_slots` multiplies at fixed
//!   memory.  Budgets are per layer (DepthKV-style — shallow layers
//!   tolerate more pruning than deep ones), validated against the model
//!   geometry by [`KvCodecSpec::resolve`].
//!
//! [`PagedKvStore`] is the host-side storage behind the stub backend:
//! pages are allocated lazily at their *encoded* size, so compression is
//! exercised for real (decoded reads round-trip through the codec), not
//! just counted.  The accounting side ([`KvManager`]) derives
//! `bytes_per_page` from the same codec spec, so admission control, the
//! router's per-token cost, and the stored bytes all agree.
//!
//! ## Refcounted copy-on-write page columns
//!
//! Page ownership is no longer "one lane, one page chain": the store
//! keeps *columns* — one column per page position, holding that page's
//! buffers across every (cache, layer) — in a refcounted arena, and each
//! lane's page table maps page indices to column ids.  A prefix cache
//! pins columns ([`PagedKvStore::share_prefix`]), later lanes attach to
//! them ([`PagedKvStore::attach_prefix`]) with **zero copied bytes**, and
//! a write into a shared column copies it first (copy-on-write) so the
//! writer diverges privately.  A write that stores bit-identical content
//! (the engine's idempotent pad rewrites) is detected and skipped, so
//! pads never break sharing.  [`KvManager`] mirrors this with
//! `shared_pages` per slot and a `cache_pages` pool: attached pages are
//! charged once, to the cache, and a lane's retirement only frees the
//! pages it privately owns.

use anyhow::{bail, Result};

/// Page size in token positions (allocation granularity).
pub const PAGE_TOKENS: usize = 16;

/// Typed failure modes of the codec-spec surface (`--kv-codec`,
/// `--kv-layer-budgets`).  `clover check` matches on the variants to map
/// each to its own `CLV0xx` diagnostic; runtime callers keep their
/// `anyhow` contexts via the `std::error::Error` impl and `?`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvSpecError {
    /// `--kv-codec` value is not `identity`/`factored`.
    UnknownCodec { codec: String },
    /// `--kv-layer-budgets` passed alongside `--kv-codec identity`.
    BudgetsWithIdentity,
    /// Budget list length does not match the model's layer count.
    BudgetLen { got: usize, n_layers: usize },
    /// A per-layer budget falls outside `1..=rank`.
    BudgetRange { layer: usize, budget: usize, rank: usize },
}

impl std::fmt::Display for KvSpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::UnknownCodec { codec } => {
                write!(f, "unknown KV codec {codec:?} (expected identity|factored)")
            }
            Self::BudgetsWithIdentity => {
                write!(f, "--kv-layer-budgets requires --kv-codec factored")
            }
            Self::BudgetLen { got, n_layers } => {
                write!(f, "--kv-layer-budgets has {got} entries for a {n_layers}-layer model")
            }
            Self::BudgetRange { layer, budget, rank } => {
                write!(f, "layer {layer} budget {budget} outside 1..={rank}")
            }
        }
    }
}

impl std::error::Error for KvSpecError {}

/// Plain-data description of a page codec — travels through `KvConfig`,
/// `EngineSpec`, and the CLI (`--kv-codec`, `--kv-layer-budgets`), and is
/// resolved against a concrete model geometry at engine construction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvCodecSpec {
    /// Store rank-r pages verbatim (the pre-codec dense layout).
    Identity,
    /// Store pages truncated to per-layer rank budgets.  `None` budgets
    /// resolve to a uniform `max(1, r/2)` per layer.
    Factored { layer_budgets: Option<Vec<usize>> },
}

impl Default for KvCodecSpec {
    fn default() -> Self {
        Self::Identity
    }
}

impl KvCodecSpec {
    /// Parse the CLI surface: `--kv-codec identity|factored` plus an
    /// optional `--kv-layer-budgets r0,r1,...` list (factored only).
    pub fn parse(codec: &str, layer_budgets: Option<Vec<usize>>) -> Result<Self, KvSpecError> {
        match codec {
            "identity" => {
                if layer_budgets.is_some() {
                    return Err(KvSpecError::BudgetsWithIdentity);
                }
                Ok(Self::Identity)
            }
            "factored" => Ok(Self::Factored { layer_budgets }),
            other => Err(KvSpecError::UnknownCodec { codec: other.to_string() }),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Identity => "identity",
            Self::Factored { .. } => "factored",
        }
    }

    /// Resolve to per-layer stored ranks against a concrete geometry,
    /// validating DepthKV-style budgets: one entry per layer, each within
    /// `1..=rank`.  This is the validation gate every construction boundary
    /// (engine builder, gateway worker, CLI) goes through.
    pub fn resolve(&self, n_layers: usize, rank: usize) -> Result<Vec<usize>, KvSpecError> {
        match self {
            Self::Identity => Ok(vec![rank; n_layers]),
            Self::Factored { layer_budgets: None } => Ok(vec![(rank / 2).max(1); n_layers]),
            Self::Factored { layer_budgets: Some(b) } => {
                if b.len() != n_layers {
                    return Err(KvSpecError::BudgetLen { got: b.len(), n_layers });
                }
                for (l, &r) in b.iter().enumerate() {
                    if r == 0 || r > rank {
                        return Err(KvSpecError::BudgetRange { layer: l, budget: r, rank });
                    }
                }
                Ok(b.clone())
            }
        }
    }

    /// Build the codec object for a concrete geometry.
    pub fn build(&self, n_layers: usize, rank: usize) -> Result<Box<dyn PageCodec>, KvSpecError> {
        let budgets = self.resolve(n_layers, rank)?;
        Ok(match self {
            Self::Identity => Box::new(IdentityCodec { rank, n_layers }),
            Self::Factored { .. } => Box::new(FactoredCodec { rank, budgets }),
        })
    }
}

/// Encode/decode of KV pages.  The unit of storage is one page block
/// `[H, PAGE_TOKENS, r]` per (cache, layer, lane, page); the unit of
/// transcoding is one rank-r coefficient vector (one head × one token),
/// since slab writes scatter position-by-position.  `stored_rank(layer)`
/// defines the at-rest footprint — `bytes_per_page` is *derived from the
/// codec*, not hardcoded.
pub trait PageCodec: Send + Sync {
    fn name(&self) -> &'static str;

    /// The full (in-flight) rank r of the cache rows.
    fn full_rank(&self) -> usize;

    /// Coefficients kept at rest for `layer`'s pages.
    fn stored_rank(&self, layer: usize) -> usize;

    /// Encode one rank-r coefficient vector into `stored_rank(layer)`
    /// stored floats.  `coeffs.len() == full_rank()`,
    /// `out.len() == stored_rank(layer)`.
    fn encode_vec(&self, layer: usize, coeffs: &[f32], out: &mut [f32]);

    /// Decode `stored_rank(layer)` stored floats back to a full rank-r
    /// vector (truncated components reconstruct as 0.0 — absence in the
    /// factored basis).
    fn decode_vec(&self, layer: usize, stored: &[f32], out: &mut [f32]);

    /// Encode a `[H, PAGE_TOKENS, full_rank]` page block into a
    /// `[H, PAGE_TOKENS, stored_rank(layer)]` block.
    fn encode_page(&self, layer: usize, n_heads: usize, block: &[f32], out: &mut [f32]) {
        let (r, sr) = (self.full_rank(), self.stored_rank(layer));
        debug_assert_eq!(block.len(), n_heads * PAGE_TOKENS * r);
        debug_assert_eq!(out.len(), n_heads * PAGE_TOKENS * sr);
        for i in 0..n_heads * PAGE_TOKENS {
            self.encode_vec(layer, &block[i * r..(i + 1) * r], &mut out[i * sr..(i + 1) * sr]);
        }
    }

    /// Decode a stored page block back to `[H, PAGE_TOKENS, full_rank]`.
    fn decode_page(&self, layer: usize, n_heads: usize, stored: &[f32], out: &mut [f32]) {
        let (r, sr) = (self.full_rank(), self.stored_rank(layer));
        debug_assert_eq!(stored.len(), n_heads * PAGE_TOKENS * sr);
        debug_assert_eq!(out.len(), n_heads * PAGE_TOKENS * r);
        for i in 0..n_heads * PAGE_TOKENS {
            self.decode_vec(layer, &stored[i * sr..(i + 1) * sr], &mut out[i * r..(i + 1) * r]);
        }
    }
}

/// Stores rank-r vectors verbatim: `stored_rank == full_rank`, decode is
/// a bit-exact copy.  The reference codec every other codec's accounting
/// is compared against.
pub struct IdentityCodec {
    rank: usize,
    n_layers: usize,
}

impl PageCodec for IdentityCodec {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn full_rank(&self) -> usize {
        self.rank
    }

    fn stored_rank(&self, layer: usize) -> usize {
        debug_assert!(layer < self.n_layers);
        self.rank
    }

    fn encode_vec(&self, _layer: usize, coeffs: &[f32], out: &mut [f32]) {
        out.copy_from_slice(coeffs);
    }

    fn decode_vec(&self, _layer: usize, stored: &[f32], out: &mut [f32]) {
        out.copy_from_slice(stored);
    }
}

/// Stores each vector truncated to the layer's rank budget.  The cache
/// rows are CLOVER coefficients against spectrum-ordered orthogonal
/// vectors, so dropping the tail is the paper's pruning applied to the
/// cache at rest; decode reconstructs dropped components as 0.0.
pub struct FactoredCodec {
    rank: usize,
    budgets: Vec<usize>,
}

impl PageCodec for FactoredCodec {
    fn name(&self) -> &'static str {
        "factored"
    }

    fn full_rank(&self) -> usize {
        self.rank
    }

    fn stored_rank(&self, layer: usize) -> usize {
        self.budgets[layer]
    }

    fn encode_vec(&self, layer: usize, coeffs: &[f32], out: &mut [f32]) {
        out.copy_from_slice(&coeffs[..self.budgets[layer]]);
    }

    fn decode_vec(&self, layer: usize, stored: &[f32], out: &mut [f32]) {
        let b = self.budgets[layer];
        out[..b].copy_from_slice(stored);
        out[b..].fill(0.0);
    }
}

#[derive(Clone, Debug)]
pub struct KvConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub rank: usize,
    pub max_positions: usize,
    pub batch_slots: usize,
    /// Page codec the cache is stored through.  Must pass
    /// [`KvConfig::validate`] before any byte accounting — the engine
    /// builder, gateway worker, and CLI all check at construction.
    pub codec: KvCodecSpec,
}

impl KvConfig {
    /// Check the codec spec against this geometry (per-layer budgets have
    /// one entry per manifest layer, each within `1..=rank`).
    pub fn validate(&self) -> Result<()> {
        self.codec.resolve(self.n_layers, self.rank)?;
        Ok(())
    }

    /// Per-layer stored ranks under the configured codec.
    ///
    /// Panics on an invalid codec/geometry pair — [`KvConfig::validate`]
    /// runs at every construction boundary, so a panic here is a missed
    /// validation, not a runtime condition.
    pub fn stored_ranks(&self) -> Vec<usize> {
        self.codec
            .resolve(self.n_layers, self.rank)
            .expect("KvConfig::validate must pass before byte accounting")
    }

    /// Bytes per token position across all layers/heads (K + VO caches),
    /// at the codec's *stored* ranks: `2·H·4·Σ_l stored_rank(l)`.  Under
    /// [`KvCodecSpec::Identity`] this is the dense `2·L·H·r·4`.
    pub fn bytes_per_token(&self) -> usize {
        2 * self.n_heads * 4 * self.stored_ranks().iter().sum::<usize>()
    }

    pub fn bytes_per_page(&self) -> usize {
        self.bytes_per_token() * PAGE_TOKENS
    }
}

#[derive(Clone, Debug, PartialEq)]
struct Slot {
    id: u64,
    pages: usize,
    positions: usize,
    /// Leading pages held by the prefix cache rather than this lane: an
    /// attached prefix at admission, plus pages donated to the cache when
    /// this lane's prefill registered.  They are accounted once, in
    /// [`KvManager::cache_pages`], so `pages - shared_pages` is what this
    /// slot privately owns.
    shared_pages: usize,
}

/// Allocates batch slots + pages; tracks live/peak/freed KV bytes at the
/// codec's stored page size.
pub struct KvManager {
    cfg: KvConfig,
    /// `cfg.bytes_per_page()`, resolved once — accounting is on the hot
    /// admission/advance path.
    page_bytes: usize,
    slots: Vec<Option<Slot>>,
    /// Pages owned by the prefix cache: donated prefixes that outlive the
    /// lanes that prefilled them.  Counted once here no matter how many
    /// lanes are attached.
    cache_pages: usize,
    peak_bytes: usize,
    freed_bytes: usize,
    /// Lanes retired from the pool for the engine's lifetime (poisoned
    /// logits rows): their bytes are freed but [`KvManager::allocate`]
    /// never hands them out again.  See [`KvManager::quarantine`].
    quarantined: Vec<bool>,
}

impl KvManager {
    pub fn new(cfg: KvConfig) -> Self {
        let page_bytes = cfg.bytes_per_page();
        let slots = vec![None; cfg.batch_slots];
        let quarantined = vec![false; cfg.batch_slots];
        Self { cfg, page_bytes, slots, cache_pages: 0, peak_bytes: 0, freed_bytes: 0, quarantined }
    }

    pub fn config(&self) -> &KvConfig {
        &self.cfg
    }

    /// Claim a slot for request `id`. Errors when the batch is full.
    ///
    /// Contract: returns the *lowest* free slot index.  Slot indices are
    /// batch-lane indices — the engine zeroes exactly this lane of the
    /// `[L, B, H, C, r]` caches on re-assignment, so the mapping must be
    /// stable and dense.
    pub fn allocate(&mut self, id: u64) -> Result<usize> {
        if self.slots.iter().flatten().any(|s| s.id == id) {
            bail!("request {id} already has a slot");
        }
        for (i, s) in self.slots.iter_mut().enumerate() {
            if s.is_none() && !self.quarantined[i] {
                *s = Some(Slot { id, pages: 0, positions: 0, shared_pages: 0 });
                return Ok(i);
            }
        }
        bail!("KV batch full ({} slots)", self.slots.len())
    }

    /// Record one generated position for slot `slot`; grows pages on
    /// boundary crossings. Errors past `max_positions`.
    pub fn advance(&mut self, slot: usize) -> Result<()> {
        self.advance_by(slot, 1)
    }

    /// Record `n` positions at once — one token slab.  Page accounting is
    /// slab-granular: an 8-token chunk crossing a page boundary allocates
    /// the new page in the same call, so live/peak bytes are exact no
    /// matter how wide the step was.  Errors when the slab would escape
    /// `max_positions`, charging nothing.
    pub fn advance_by(&mut self, slot: usize, n: usize) -> Result<()> {
        let cfg_max = self.cfg.max_positions;
        let s = self.slots.get_mut(slot).and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow::anyhow!("slot {slot} not allocated"))?;
        if s.positions + n > cfg_max {
            bail!(
                "slot {slot}: {} + {n} positions would exceed max {cfg_max}",
                s.positions
            );
        }
        s.positions += n;
        let need = s.positions.div_ceil(PAGE_TOKENS);
        if need > s.pages {
            s.pages = need;
        }
        let live = self.live_bytes();
        if live > self.peak_bytes {
            self.peak_bytes = live;
        }
        Ok(())
    }

    /// Roll slot `slot` back to exactly `positions` recorded positions —
    /// the accounting half of speculative rollback: a verify step advances
    /// by the whole written slab, then rolls back to the accepted prefix.
    /// Page reclaim is page-granular (pages above the new high-water mark
    /// free immediately, counting toward [`KvManager::freed_bytes`];
    /// `peak_bytes` keeps the high tide).  Errors when `positions` is
    /// *ahead* of the recorded count — rollback never invents progress —
    /// charging nothing.
    pub fn rollback_to(&mut self, slot: usize, positions: usize) -> Result<()> {
        let page_bytes = self.page_bytes;
        let s = self.slots.get_mut(slot).and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow::anyhow!("slot {slot} not allocated"))?;
        if positions > s.positions {
            bail!(
                "slot {slot}: rollback_to {positions} is ahead of the {} recorded positions",
                s.positions
            );
        }
        if positions < s.shared_pages * PAGE_TOKENS {
            bail!(
                "slot {slot}: rollback_to {positions} crosses into the {}-page shared prefix",
                s.shared_pages
            );
        }
        s.positions = positions;
        let keep = positions.div_ceil(PAGE_TOKENS);
        self.freed_bytes += (s.pages - keep) * page_bytes;
        s.pages = keep;
        Ok(())
    }

    /// Free a slot (request finished / evicted), folding its *privately
    /// owned* pages into the cumulative [`KvManager::freed_bytes`] churn
    /// counter — pages below the shared-prefix boundary belong to the
    /// cache and stay live.  Returns the request id the slot carried.
    pub fn free(&mut self, slot: usize) -> Result<u64> {
        match self.slots.get_mut(slot).and_then(|s| s.take()) {
            Some(s) => {
                self.freed_bytes += (s.pages - s.shared_pages) * self.page_bytes;
                Ok(s.id)
            }
            None => bail!("double free of slot {slot}"),
        }
    }

    /// Retire slot `slot` from the pool for the manager's lifetime: its
    /// privately-owned bytes are freed exactly like [`KvManager::free`],
    /// but the lane is never allocated again — the containment move for a
    /// poisoned-logits lane, where the cache rows can no longer be
    /// trusted and a rollback cannot scrub what a later occupant would
    /// read.  Returns the request id the slot carried.  Conservation
    /// shifts from `free_slots() == B` to
    /// `free_slots() + quarantined() == B` at drain.
    pub fn quarantine(&mut self, slot: usize) -> Result<u64> {
        match self.slots.get_mut(slot).and_then(|s| s.take()) {
            Some(s) => {
                self.freed_bytes += (s.pages - s.shared_pages) * self.page_bytes;
                self.quarantined[slot] = true;
                Ok(s.id)
            }
            None => bail!("quarantine of unallocated slot {slot}"),
        }
    }

    /// Lanes retired by [`KvManager::quarantine`].
    pub fn quarantined(&self) -> usize {
        self.quarantined.iter().filter(|&&q| q).count()
    }

    /// Attach a cached prefix of `pages` pages to freshly-allocated slot
    /// `slot`: positions jump to `pages · PAGE_TOKENS` without charging
    /// this slot a byte — the pages are the cache's, counted once in
    /// [`KvManager::cache_pages`].  The slot must not have advanced yet.
    pub fn attach_prefix(&mut self, slot: usize, pages: usize) -> Result<()> {
        let cfg_max = self.cfg.max_positions;
        let s = self.slots.get_mut(slot).and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow::anyhow!("slot {slot} not allocated"))?;
        if s.positions != 0 || s.pages != 0 {
            bail!("slot {slot}: attach_prefix on a slot that already advanced");
        }
        if pages * PAGE_TOKENS > cfg_max {
            bail!("slot {slot}: attached prefix of {pages} pages exceeds max positions {cfg_max}");
        }
        s.pages = pages;
        s.shared_pages = pages;
        s.positions = pages * PAGE_TOKENS;
        Ok(())
    }

    /// Move ownership of slot `slot`'s first `pages` pages to the prefix
    /// cache: the slot keeps reading them, but they now outlive it —
    /// retirement frees only pages above the shared boundary.  `pages` is
    /// the slot's *total* shared prefix (≥ any previously attached or
    /// donated count); live bytes are unchanged because the pages move
    /// pools, they don't duplicate.
    pub fn donate_to_cache(&mut self, slot: usize, pages: usize) -> Result<()> {
        let s = self.slots.get_mut(slot).and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow::anyhow!("slot {slot} not allocated"))?;
        if pages > s.pages {
            bail!("slot {slot}: cannot donate {pages} pages, only {} allocated", s.pages);
        }
        if pages < s.shared_pages {
            bail!("slot {slot}: donation of {pages} pages below the {} already shared", s.shared_pages);
        }
        let add = pages - s.shared_pages;
        s.shared_pages = pages;
        self.cache_pages += add;
        Ok(())
    }

    /// Release `pages` cache-owned pages (prefix-cache eviction): they
    /// leave the live pool and count toward [`KvManager::freed_bytes`].
    pub fn cache_release(&mut self, pages: usize) -> Result<()> {
        if pages > self.cache_pages {
            bail!("cache_release of {pages} pages with only {} cached", self.cache_pages);
        }
        self.cache_pages -= pages;
        self.freed_bytes += pages * self.page_bytes;
        Ok(())
    }

    /// Pages currently owned by the prefix cache.
    pub fn cache_pages(&self) -> usize {
        self.cache_pages
    }

    pub fn live_bytes(&self) -> usize {
        self.live_pages() * self.page_bytes
    }

    /// Resident pages: each slot's privately-owned pages plus the prefix
    /// cache's pool — one number the engine can multiply by *any* codec's
    /// page size (its own, or a paired draft engine's) for budget
    /// admission.  Shared pages count once no matter how many lanes read
    /// them.
    pub fn live_pages(&self) -> usize {
        self.slots.iter().flatten().map(|s| s.pages - s.shared_pages).sum::<usize>()
            + self.cache_pages
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Cumulative bytes released over the manager's lifetime — slot frees
    /// plus speculative-rollback page reclaims.  Together with
    /// `peak_bytes` this is the KV churn picture: how much cache the
    /// workload cycled through, not just how much it held at once.
    pub fn freed_bytes(&self) -> usize {
        self.freed_bytes
    }

    /// Slots currently allocatable — quarantined lanes are *not* free;
    /// drain-time conservation is `free_slots() + quarantined() == B`.
    pub fn free_slots(&self) -> usize {
        self.slots
            .iter()
            .zip(&self.quarantined)
            .filter(|(s, &q)| s.is_none() && !q)
            .count()
    }

    /// Positions recorded for `slot`; 0 for a free slot *or* an
    /// out-of-range index, matching the other accessors' no-panic contract.
    pub fn positions(&self, slot: usize) -> usize {
        self.slots.get(slot).and_then(|s| s.as_ref()).map_or(0, |s| s.positions)
    }
}

/// Host-side paged page storage behind the stub backend: pages allocate
/// lazily at their **encoded** size, writes encode through the codec,
/// reads decode back — so a factored cache really holds fewer floats, and
/// bit-identity under [`IdentityCodec`] is a storage property, not an
/// accounting convention.
///
/// Layout: the unit of ownership is a *column* — one page position's
/// buffers across every `(cache, layer)`, each
/// `[H, PAGE_TOKENS, stored_rank(layer)]` and lazily allocated.  Columns
/// live in a refcounted arena; `table[lane · pages_per_lane + page]` maps
/// a lane's page index to its column, and the prefix cache holds extra
/// references on shared columns ([`PagedKvStore::share_prefix`] /
/// [`PagedKvStore::attach_prefix`] / [`PagedKvStore::release_cols`]).
/// `n_caches` is 2 for the K + VO factor caches the artifacts carry.
struct Column {
    refs: usize,
    /// One lazily-allocated buffer per `(cache, layer)`, indexed
    /// `cache · n_layers + layer`.
    bufs: Vec<Option<Box<[f32]>>>,
}

pub struct PagedKvStore {
    n_caches: usize,
    n_layers: usize,
    n_heads: usize,
    lanes: usize,
    pages_per_lane: usize,
    codec: Box<dyn PageCodec>,
    columns: Vec<Option<Column>>,
    free_cols: Vec<usize>,
    table: Vec<Option<usize>>,
}

impl PagedKvStore {
    pub fn new(
        n_caches: usize,
        n_layers: usize,
        n_heads: usize,
        max_positions: usize,
        lanes: usize,
        codec: Box<dyn PageCodec>,
    ) -> Self {
        let pages_per_lane = max_positions.div_ceil(PAGE_TOKENS);
        let table = (0..lanes * pages_per_lane).map(|_| None).collect();
        Self {
            n_caches,
            n_layers,
            n_heads,
            lanes,
            pages_per_lane,
            codec,
            columns: Vec::new(),
            free_cols: Vec::new(),
            table,
        }
    }

    pub fn codec(&self) -> &dyn PageCodec {
        &*self.codec
    }

    fn table_slot(&self, lane: usize, page: usize) -> usize {
        debug_assert!(lane < self.lanes && page < self.pages_per_lane);
        lane * self.pages_per_lane + page
    }

    /// Floats one of `layer`'s pages holds at rest.
    fn page_len(&self, layer: usize) -> usize {
        self.n_heads * PAGE_TOKENS * self.codec.stored_rank(layer)
    }

    /// Arena-allocate a fresh column with one reference and no buffers.
    fn alloc_column(&mut self) -> usize {
        let col = Column { refs: 1, bufs: vec![None; self.n_caches * self.n_layers] };
        match self.free_cols.pop() {
            Some(i) => {
                debug_assert!(self.columns[i].is_none());
                self.columns[i] = Some(col);
                i
            }
            None => {
                self.columns.push(Some(col));
                self.columns.len() - 1
            }
        }
    }

    /// Drop one reference; the column frees exactly when the last holder
    /// (lane table entry or prefix cache) lets go.
    fn decref(&mut self, col: usize) {
        let c = self.columns[col].as_mut().expect("decref of a freed column");
        debug_assert!(c.refs > 0);
        c.refs -= 1;
        if c.refs == 0 {
            self.columns[col] = None;
            self.free_cols.push(col);
        }
    }

    /// The column behind `(lane, page)`, allocating a fresh private one on
    /// first touch.
    fn column_for(&mut self, lane: usize, page: usize) -> usize {
        let slot = self.table_slot(lane, page);
        match self.table[slot] {
            Some(c) => c,
            None => {
                let c = self.alloc_column();
                self.table[slot] = Some(c);
                c
            }
        }
    }

    /// Encode one full-rank coefficient vector into the page holding
    /// `pos`, allocating buffers (zeroed) on first touch.  Writing into a
    /// *shared* column first checks whether the write stores exactly the
    /// bits already there — the engine's idempotent pad rewrites — and
    /// skips it; a genuinely diverging write copies the column
    /// (copy-on-write), leaving every other holder untouched.
    pub fn write_vec(
        &mut self,
        cache: usize,
        layer: usize,
        lane: usize,
        head: usize,
        pos: usize,
        coeffs: &[f32],
    ) {
        let (page, off) = (pos / PAGE_TOKENS, pos % PAGE_TOKENS);
        let sr = self.codec.stored_rank(layer);
        let at = (head * PAGE_TOKENS + off) * sr;
        let bi = cache * self.n_layers + layer;
        let slot = self.table_slot(lane, page);
        let mut col = self.column_for(lane, page);
        if self.columns[col].as_ref().expect("write into freed column").refs > 1 {
            let mut enc = vec![0.0f32; sr];
            self.codec.encode_vec(layer, coeffs, &mut enc);
            let same = match &self.columns[col].as_ref().unwrap().bufs[bi] {
                Some(buf) => {
                    buf[at..at + sr].iter().zip(&enc).all(|(a, b)| a.to_bits() == b.to_bits())
                }
                None => enc.iter().all(|x| x.to_bits() == 0.0f32.to_bits()),
            };
            if same {
                return;
            }
            let bufs = self.columns[col].as_ref().unwrap().bufs.clone();
            self.decref(col);
            let fresh = self.alloc_column();
            self.columns[fresh].as_mut().unwrap().bufs = bufs;
            self.table[slot] = Some(fresh);
            col = fresh;
        }
        let len = self.page_len(layer);
        let column = self.columns[col].as_mut().unwrap();
        let buf = column.bufs[bi].get_or_insert_with(|| vec![0.0; len].into_boxed_slice());
        self.codec.encode_vec(layer, coeffs, &mut buf[at..at + sr]);
    }

    /// Decode the full-rank vector at `pos` into `out`
    /// (`out.len() == full_rank()`); an untouched page reads as zeros.
    pub fn read_vec(
        &self,
        cache: usize,
        layer: usize,
        lane: usize,
        head: usize,
        pos: usize,
        out: &mut [f32],
    ) {
        let (page, off) = (pos / PAGE_TOKENS, pos % PAGE_TOKENS);
        let buf = self.table[self.table_slot(lane, page)]
            .and_then(|c| self.columns[c].as_ref())
            .and_then(|col| col.bufs[cache * self.n_layers + layer].as_ref());
        match buf {
            Some(buf) => {
                let sr = self.codec.stored_rank(layer);
                let at = (head * PAGE_TOKENS + off) * sr;
                self.codec.decode_vec(layer, &buf[at..at + sr], out);
            }
            None => out.fill(0.0),
        }
    }

    /// Decode one whole page back to a `[H, PAGE_TOKENS, full_rank]`
    /// block (zeros for an untouched page) — the block-granular read the
    /// cache materializer uses.
    pub fn decode_page(&self, cache: usize, layer: usize, lane: usize, page: usize, out: &mut [f32]) {
        let buf = self.table[self.table_slot(lane, page)]
            .and_then(|c| self.columns[c].as_ref())
            .and_then(|col| col.bufs[cache * self.n_layers + layer].as_ref());
        match buf {
            Some(buf) => self.codec.decode_page(layer, self.n_heads, buf, out),
            None => out.fill(0.0),
        }
    }

    /// Drop `lane`'s references on every page — the storage half of lane
    /// zeroing on slot churn.  Columns the prefix cache (or another lane)
    /// still references survive; purely private pages free immediately.
    pub fn zero_lane(&mut self, lane: usize) {
        for page in 0..self.pages_per_lane {
            if let Some(col) = self.table[lane * self.pages_per_lane + page].take() {
                self.decref(col);
            }
        }
    }

    /// Pin `lane`'s first `n_pages` columns for the prefix cache: each
    /// gains a reference and the returned ids stay valid until released
    /// ([`PagedKvStore::release_cols`]).  Pages the lane never touched are
    /// materialized as (empty) columns first, so attach boundaries stay
    /// page-exact.
    pub fn share_prefix(&mut self, lane: usize, n_pages: usize) -> Vec<usize> {
        self.share_pages(lane, 0, n_pages)
    }

    /// Range form of [`PagedKvStore::share_prefix`]: pin pages
    /// `start..start + n_pages` of `lane` — the donation path shares only
    /// the blocks the prefix trie did not already hold.
    pub fn share_pages(&mut self, lane: usize, start: usize, n_pages: usize) -> Vec<usize> {
        debug_assert!(start + n_pages <= self.pages_per_lane);
        (start..start + n_pages)
            .map(|page| {
                let col = self.column_for(lane, page);
                self.columns[col].as_mut().expect("sharing a freed column").refs += 1;
                col
            })
            .collect()
    }

    /// Map the cached columns `cols` into `lane`'s leading pages — zero
    /// bytes copied.  The lane must be clean (zeroed); every column must
    /// be live.  Fails atomically: on error no reference has moved.
    pub fn attach_prefix(&mut self, lane: usize, cols: &[usize]) -> Result<()> {
        if cols.len() > self.pages_per_lane {
            bail!("attach_prefix: {} pages exceed the {}-page lane", cols.len(), self.pages_per_lane);
        }
        for page in 0..cols.len() {
            if self.table[self.table_slot(lane, page)].is_some() {
                bail!("attach_prefix: lane {lane} page {page} is not clean");
            }
        }
        for &col in cols {
            if self.columns.get(col).map_or(true, |c| c.is_none()) {
                bail!("attach_prefix: column {col} is not live");
            }
        }
        for (page, &col) in cols.iter().enumerate() {
            self.columns[col].as_mut().unwrap().refs += 1;
            self.table[self.table_slot(lane, page)] = Some(col);
        }
        Ok(())
    }

    /// Drop the prefix cache's references on `cols` (eviction or cache
    /// teardown).  Columns still mapped by live lanes survive; fully
    /// unreferenced columns free immediately — and never resurrect, their
    /// arena index recycles only through fresh allocation.
    pub fn release_cols(&mut self, cols: &[usize]) {
        for &c in cols {
            self.decref(c);
        }
    }

    /// Current reference count of a column (0 for a freed id) — the test
    /// and model-checking surface for COW lifecycles.
    pub fn col_refs(&self, col: usize) -> usize {
        self.columns.get(col).and_then(|c| c.as_ref()).map_or(0, |c| c.refs)
    }

    /// Live (referenced) columns — distinct resident pages, shared or not.
    pub fn live_columns(&self) -> usize {
        self.columns.iter().flatten().count()
    }

    /// Bytes currently held by allocated buffers, counting each shared
    /// column **once** — the storage-side twin of
    /// [`KvManager::live_bytes`] (which counts *accounted* pages; the
    /// store also holds rolled-back pages until the lane is zeroed, so
    /// store ≥ accounting is the expected relation, not equality).
    pub fn stored_bytes(&self) -> usize {
        self.columns
            .iter()
            .flatten()
            .flat_map(|c| c.bufs.iter().flatten())
            .map(|b| b.len() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn cfg(rank: usize) -> KvConfig {
        KvConfig {
            n_layers: 2,
            n_heads: 4,
            rank,
            max_positions: 64,
            batch_slots: 4,
            codec: KvCodecSpec::Identity,
        }
    }

    #[test]
    fn rank_halves_bytes() {
        assert_eq!(cfg(8).bytes_per_token() * 2, cfg(16).bytes_per_token());
    }

    #[test]
    fn factored_codec_shrinks_bytes_by_rank_ratio() {
        // Default factored budgets (r/2 everywhere) halve the dense bytes;
        // explicit per-layer budgets meter exactly Σ_l budget[l].
        let dense = cfg(8);
        let half = KvConfig { codec: KvCodecSpec::Factored { layer_budgets: None }, ..cfg(8) };
        assert_eq!(half.bytes_per_token() * 2, dense.bytes_per_token());
        let depth = KvConfig {
            codec: KvCodecSpec::Factored { layer_budgets: Some(vec![2, 6]) },
            ..cfg(8)
        };
        // 2·H·4·(2+6) vs dense 2·H·4·(8+8).
        assert_eq!(depth.bytes_per_token() * 2, dense.bytes_per_token());
        assert_eq!(depth.stored_ranks(), vec![2, 6]);
        assert_eq!(depth.bytes_per_page(), depth.bytes_per_token() * PAGE_TOKENS);
    }

    #[test]
    fn layer_budgets_validated_against_geometry() {
        let ok = KvCodecSpec::Factored { layer_budgets: Some(vec![4, 8]) };
        assert_eq!(ok.resolve(2, 8).unwrap(), vec![4, 8]);
        // Wrong layer count, zero budget, budget above the rank: refused.
        let wrong_len = KvCodecSpec::Factored { layer_budgets: Some(vec![4]) };
        assert!(wrong_len.resolve(2, 8).is_err());
        let zero = KvCodecSpec::Factored { layer_budgets: Some(vec![4, 0]) };
        assert!(zero.resolve(2, 8).is_err());
        let over = KvCodecSpec::Factored { layer_budgets: Some(vec![4, 9]) };
        assert!(over.resolve(2, 8).is_err());
        assert!(KvConfig { codec: over, ..cfg(8) }.validate().is_err());
        // Identity resolves to the full rank everywhere.
        assert_eq!(KvCodecSpec::Identity.resolve(3, 4).unwrap(), vec![4, 4, 4]);
    }

    #[test]
    fn codec_spec_parse_matches_cli_surface() {
        assert_eq!(KvCodecSpec::parse("identity", None).unwrap(), KvCodecSpec::Identity);
        assert_eq!(
            KvCodecSpec::parse("factored", Some(vec![2, 4])).unwrap(),
            KvCodecSpec::Factored { layer_budgets: Some(vec![2, 4]) }
        );
        assert!(KvCodecSpec::parse("identity", Some(vec![2])).is_err());
        assert!(KvCodecSpec::parse("zstd", None).is_err());
    }

    #[test]
    fn identity_codec_page_roundtrip_is_bit_exact_property() {
        prop("identity page roundtrip", 20, |rng| {
            let (layers, heads, rank) = (2, 3, 1 + rng.below(8));
            let codec = KvCodecSpec::Identity.build(layers, rank).map_err(|e| e.to_string())?;
            let block: Vec<f32> = (0..heads * PAGE_TOKENS * rank)
                .map(|_| (rng.uniform() as f32 - 0.5) * 8.0)
                .collect();
            for l in 0..layers {
                let mut stored = vec![0.0; heads * PAGE_TOKENS * codec.stored_rank(l)];
                let mut back = vec![0.0; block.len()];
                codec.encode_page(l, heads, &block, &mut stored);
                codec.decode_page(l, heads, &stored, &mut back);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                if bits(&back) != bits(&block) {
                    return Err(format!("layer {l}: identity roundtrip not bit-exact"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn factored_codec_roundtrip_truncates_spectrum_property() {
        prop("factored page roundtrip", 20, |rng| {
            let (layers, heads, rank) = (2, 2, 2 + rng.below(7));
            let budgets: Vec<usize> = (0..layers).map(|_| 1 + rng.below(rank)).collect();
            let spec = KvCodecSpec::Factored { layer_budgets: Some(budgets.clone()) };
            let codec = spec.build(layers, rank).map_err(|e| e.to_string())?;
            let vec_in: Vec<f32> =
                (0..rank).map(|_| (rng.uniform() as f32 - 0.5) * 8.0).collect();
            for (l, &b) in budgets.iter().enumerate() {
                let mut stored = vec![0.0; b];
                let mut back = vec![f32::NAN; rank];
                codec.encode_vec(l, &vec_in, &mut stored);
                codec.decode_vec(l, &stored, &mut back);
                // Kept coefficients are bit-exact, dropped ones read 0.0 —
                // absence in the factored basis, which the stub readout
                // skips exactly like an unwritten cache row.
                for k in 0..rank {
                    let want = if k < b { vec_in[k].to_bits() } else { 0.0f32.to_bits() };
                    if back[k].to_bits() != want {
                        return Err(format!("layer {l} coeff {k} wrong after roundtrip"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn paged_store_roundtrips_and_zeroes_lanes() {
        let rank = 8;
        let codec = KvCodecSpec::Identity.build(2, rank).unwrap();
        let mut store = PagedKvStore::new(2, 2, 2, 64, 2, codec);
        assert_eq!(store.stored_bytes(), 0, "pages allocate lazily");
        let v: Vec<f32> = (0..rank).map(|k| k as f32 + 0.25).collect();
        store.write_vec(1, 0, 1, 1, 17, &v);
        let mut out = vec![0.0; rank];
        store.read_vec(1, 0, 1, 1, 17, &mut out);
        assert_eq!(out, v, "identity storage is bit-exact");
        // One page allocated: H × PAGE_TOKENS × r floats.
        assert_eq!(store.stored_bytes(), 2 * PAGE_TOKENS * rank * 4);
        // Untouched coordinates — even in the allocated page — read zeros.
        store.read_vec(1, 0, 1, 0, 17, &mut out);
        assert_eq!(out, vec![0.0; rank]);
        store.read_vec(0, 1, 0, 1, 17, &mut out);
        assert_eq!(out, vec![0.0; rank]);
        // Zeroing the lane drops its pages entirely.
        store.zero_lane(1);
        assert_eq!(store.stored_bytes(), 0);
        store.read_vec(1, 0, 1, 1, 17, &mut out);
        assert_eq!(out, vec![0.0; rank]);
    }

    #[test]
    fn factored_store_holds_fewer_floats() {
        // Same write, two codecs: the factored store's allocated page is
        // budget/rank the size — compression exercised in storage, not
        // just accounted.
        let rank = 8;
        let v: Vec<f32> = (0..rank).map(|k| (k as f32).sin()).collect();
        let mut dense = PagedKvStore::new(2, 2, 2, 64, 1, KvCodecSpec::Identity.build(2, rank).unwrap());
        let spec = KvCodecSpec::Factored { layer_budgets: Some(vec![2, 4]) };
        let mut fact = PagedKvStore::new(2, 2, 2, 64, 1, spec.build(2, rank).unwrap());
        for (l, s) in [(0usize, 2usize), (1, 4)] {
            dense.write_vec(0, l, 0, 0, 3, &v);
            fact.write_vec(0, l, 0, 0, 3, &v);
            let mut out = vec![f32::NAN; rank];
            fact.read_vec(0, l, 0, 0, 3, &mut out);
            assert_eq!(&out[..s], &v[..s], "kept coefficients round-trip");
            assert!(out[s..].iter().all(|&x| x == 0.0), "dropped coefficients read 0");
        }
        // Dense pages: 2 layers × H·P·8; factored: H·P·(2+4).
        assert_eq!(dense.stored_bytes(), 2 * 2 * PAGE_TOKENS * 8 * 4);
        assert_eq!(fact.stored_bytes(), 2 * PAGE_TOKENS * (2 + 4) * 4);
    }

    #[test]
    fn cow_store_shares_and_diverges() {
        let rank = 4;
        let codec = KvCodecSpec::Identity.build(1, rank).unwrap();
        let mut store = PagedKvStore::new(2, 1, 2, 64, 2, codec);
        let v: Vec<f32> = (0..rank).map(|k| k as f32 + 0.5).collect();
        // Lane 0 prefills one head row across two pages.
        for pos in 0..2 * PAGE_TOKENS {
            store.write_vec(0, 0, 0, 0, pos, &v);
        }
        let one_page = store.stored_bytes() / 2;
        // The cache pins both columns; lane 1 attaches — zero new bytes.
        let cols = store.share_prefix(0, 2);
        assert_eq!(cols.len(), 2);
        assert!(cols.iter().all(|&c| store.col_refs(c) == 2));
        let before = store.stored_bytes();
        store.attach_prefix(1, &cols).unwrap();
        assert_eq!(store.stored_bytes(), before, "attach copies nothing");
        assert!(cols.iter().all(|&c| store.col_refs(c) == 3));
        let mut out = vec![0.0; rank];
        store.read_vec(0, 0, 1, 0, 17, &mut out);
        assert_eq!(out, v, "attached lane reads the shared pages");
        // An identical rewrite into a shared page (the engine's pad
        // rewrite) is skipped, not cloned.
        store.write_vec(0, 0, 1, 0, 17, &v);
        assert_eq!(store.stored_bytes(), before, "idempotent rewrite keeps sharing");
        assert_eq!(store.col_refs(cols[1]), 3);
        // A genuinely diverging write copies the column; lane 0 and the
        // cache keep the original bits.
        let w: Vec<f32> = v.iter().map(|x| x + 10.0).collect();
        store.write_vec(0, 0, 1, 0, 17, &w);
        assert_eq!(store.col_refs(cols[1]), 2, "writer left the shared column");
        store.read_vec(0, 0, 1, 0, 17, &mut out);
        assert_eq!(out, w);
        store.read_vec(0, 0, 0, 0, 17, &mut out);
        assert_eq!(out, v, "donor lane unchanged after COW");
        assert_eq!(store.stored_bytes(), before + one_page, "exactly one cloned column");
        // Lane teardown + cache release drop every reference exactly once.
        store.zero_lane(1);
        store.zero_lane(0);
        assert!(cols.iter().all(|&c| store.col_refs(c) == 1), "cache still pins");
        assert_eq!(store.stored_bytes(), before, "pinned pages survive lane churn");
        store.release_cols(&cols);
        assert!(cols.iter().all(|&c| store.col_refs(c) == 0));
        assert_eq!(store.stored_bytes(), 0, "no page resurrection");
        assert_eq!(store.live_columns(), 0);
    }

    #[test]
    fn attach_refuses_dirty_lane_and_dead_columns() {
        let codec = KvCodecSpec::Identity.build(1, 2).unwrap();
        let mut store = PagedKvStore::new(1, 1, 1, 64, 2, codec);
        store.write_vec(0, 0, 0, 0, 0, &[1.0, 2.0]);
        let cols = store.share_prefix(0, 1);
        // Lane 1 already holds a page at index 0: attach is refused and no
        // reference moves.
        store.write_vec(0, 0, 1, 0, 3, &[3.0, 4.0]);
        assert!(store.attach_prefix(1, &cols).is_err());
        assert_eq!(store.col_refs(cols[0]), 2);
        store.zero_lane(1);
        // A released (dead) column id is refused before any ref moves.
        store.release_cols(&cols);
        store.zero_lane(0);
        assert_eq!(store.col_refs(cols[0]), 0);
        assert!(store.attach_prefix(1, &cols).is_err());
        assert_eq!(store.live_columns(), 0);
    }

    #[test]
    fn manager_attach_donate_and_cache_release_accounting() {
        let mut kv = KvManager::new(cfg(8));
        let bpp = kv.config().bytes_per_page();
        // Donor prefills 2 pages + 4 decode positions, then donates the
        // 2-page prefix to the cache: live bytes are unchanged — the pages
        // moved pools, they did not duplicate.
        let a = kv.allocate(1).unwrap();
        kv.advance_by(a, 2 * PAGE_TOKENS + 4).unwrap();
        assert_eq!(kv.live_bytes(), 3 * bpp);
        kv.donate_to_cache(a, 2).unwrap();
        assert_eq!(kv.live_bytes(), 3 * bpp, "donation moves pages, not bytes");
        assert_eq!(kv.cache_pages(), 2);
        // An attached lane starts at the prefix boundary for free.
        let b = kv.allocate(2).unwrap();
        kv.attach_prefix(b, 2).unwrap();
        assert_eq!(kv.positions(b), 2 * PAGE_TOKENS);
        assert_eq!(kv.live_bytes(), 3 * bpp, "attach charges nothing");
        // Its own positions past the boundary are charged normally.
        kv.advance_by(b, 1).unwrap();
        assert_eq!(kv.live_bytes(), 4 * bpp);
        // Retirement frees only privately-owned pages.
        let freed0 = kv.freed_bytes();
        kv.free(a).unwrap();
        assert_eq!(kv.freed_bytes(), freed0 + bpp, "donor frees its decode page only");
        assert_eq!(kv.live_bytes(), 3 * bpp);
        kv.free(b).unwrap();
        assert_eq!(kv.live_bytes(), 2 * bpp, "cache still holds the prefix");
        // Eviction returns the cached pages (and no more than exist).
        assert!(kv.cache_release(3).is_err());
        kv.cache_release(2).unwrap();
        assert_eq!(kv.cache_pages(), 0);
        assert_eq!(kv.live_bytes(), 0);
        // Guards: attach after advancing, rollback below the boundary.
        let c = kv.allocate(3).unwrap();
        kv.advance(c).unwrap();
        assert!(kv.attach_prefix(c, 1).is_err());
        kv.free(c).unwrap();
        let d = kv.allocate(4).unwrap();
        kv.attach_prefix(d, 2).unwrap();
        kv.advance_by(d, 4).unwrap();
        assert!(kv.rollback_to(d, PAGE_TOKENS).is_err(), "rollback below shared prefix refused");
        kv.rollback_to(d, 2 * PAGE_TOKENS + 1).unwrap();
        assert_eq!(kv.positions(d), 2 * PAGE_TOKENS + 1);
    }

    #[test]
    fn allocate_free_cycle() {
        let mut kv = KvManager::new(cfg(8));
        let a = kv.allocate(1).unwrap();
        let b = kv.allocate(2).unwrap();
        assert_ne!(a, b);
        assert_eq!(kv.free_slots(), 2);
        assert_eq!(kv.free(a).unwrap(), 1);
        assert_eq!(kv.free_slots(), 3);
        assert!(kv.free(a).is_err(), "double free must fail");
    }

    #[test]
    fn allocate_returns_lowest_free_slot() {
        // The engine uses slot indices as batch-lane indices; re-assignment
        // must hand back the lowest freed lane.
        let mut kv = KvManager::new(cfg(8));
        for i in 0..4 {
            assert_eq!(kv.allocate(i).unwrap(), i as usize);
        }
        kv.free(1).unwrap();
        kv.free(3).unwrap();
        assert_eq!(kv.allocate(10).unwrap(), 1);
        assert_eq!(kv.allocate(11).unwrap(), 3);
    }

    #[test]
    fn duplicate_request_rejected() {
        let mut kv = KvManager::new(cfg(8));
        kv.allocate(7).unwrap();
        assert!(kv.allocate(7).is_err());
    }

    #[test]
    fn batch_full() {
        let mut kv = KvManager::new(cfg(8));
        for i in 0..4 {
            kv.allocate(i).unwrap();
        }
        assert!(kv.allocate(99).is_err());
    }

    #[test]
    fn quarantine_retires_lane_and_frees_bytes() {
        let mut kv = KvManager::new(cfg(8));
        let a = kv.allocate(1).unwrap();
        let b = kv.allocate(2).unwrap();
        assert_eq!((a, b), (0, 1));
        kv.advance_by(a, PAGE_TOKENS + 1).unwrap();
        let freed0 = kv.freed_bytes();
        // Quarantine frees the bytes like `free`...
        assert_eq!(kv.quarantine(a).unwrap(), 1);
        assert_eq!(kv.freed_bytes(), freed0 + 2 * kv.config().bytes_per_page());
        assert_eq!(kv.quarantined(), 1);
        // ...but the lane never returns to the pool: the next allocate
        // skips it, and conservation is free + quarantined + live == B.
        let c = kv.allocate(3).unwrap();
        assert_ne!(c, a, "quarantined lane must not be reallocated");
        assert!(kv.quarantine(a).is_err(), "double quarantine rejected");
        kv.free(b).unwrap();
        kv.free(c).unwrap();
        assert_eq!(kv.free_slots() + kv.quarantined(), 4);
        // Quarantining every lane exhausts the batch.
        for lane in [b, c, 3] {
            let s = kv.allocate(10 + lane as u64).unwrap();
            kv.quarantine(s).unwrap();
        }
        assert_eq!(kv.quarantined(), 4);
        assert!(kv.allocate(99).is_err(), "all lanes quarantined: batch full");
    }

    #[test]
    fn pages_grow_with_positions() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        for _ in 0..PAGE_TOKENS {
            kv.advance(s).unwrap();
        }
        assert_eq!(kv.live_bytes(), kv.config().bytes_per_page());
        kv.advance(s).unwrap();
        assert_eq!(kv.live_bytes(), 2 * kv.config().bytes_per_page());
    }

    #[test]
    fn advance_by_slab_accounts_pages() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        // One slab crossing a page boundary allocates the new page in the
        // same call.
        kv.advance_by(s, PAGE_TOKENS + 1).unwrap();
        assert_eq!(kv.live_bytes(), 2 * kv.config().bytes_per_page());
        assert_eq!(kv.positions(s), PAGE_TOKENS + 1);
        // A slab that would escape the window is refused atomically.
        assert!(kv.advance_by(s, 64).is_err());
        assert_eq!(kv.positions(s), PAGE_TOKENS + 1, "failed slab charges nothing");
        kv.advance_by(s, 64 - PAGE_TOKENS - 1).unwrap();
        assert!(kv.advance(s).is_err(), "window exactly full");
    }

    #[test]
    fn advance_by_failure_is_atomic_at_page_boundary() {
        // Satellite regression: a capacity-refused slab charges *nothing*
        // — positions, pages, and live bytes are all untouched, even when
        // the refused slab would have crossed a page boundary.
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        // Park exactly at a page boundary (one page, completely full).
        kv.advance_by(s, PAGE_TOKENS).unwrap();
        let (pos0, live0, peak0) =
            (kv.positions(s), kv.live_bytes(), kv.peak_bytes());
        assert_eq!(pos0, PAGE_TOKENS);
        assert_eq!(live0, kv.config().bytes_per_page());
        // 64 - PAGE_TOKENS positions remain; asking for one more than that
        // must fail without touching anything — no partial advance, no
        // page allocated for the boundary the slab would have crossed.
        let over = 64 - PAGE_TOKENS + 1;
        assert!(kv.advance_by(s, over).is_err());
        assert_eq!(kv.positions(s), pos0, "positions untouched on failure");
        assert_eq!(kv.live_bytes(), live0, "pages untouched on failure");
        assert_eq!(kv.peak_bytes(), peak0, "peak untouched on failure");
        // The exact remaining capacity still fits afterwards.
        kv.advance_by(s, over - 1).unwrap();
        assert_eq!(kv.positions(s), 64);
    }

    #[test]
    fn rollback_to_reclaims_pages() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        // A verify slab crossing into a second page...
        kv.advance_by(s, PAGE_TOKENS + 4).unwrap();
        assert_eq!(kv.live_bytes(), 2 * kv.config().bytes_per_page());
        let peak = kv.peak_bytes();
        // ...rolled back to the accepted prefix: the second page frees.
        kv.rollback_to(s, PAGE_TOKENS - 2).unwrap();
        assert_eq!(kv.positions(s), PAGE_TOKENS - 2);
        assert_eq!(kv.live_bytes(), kv.config().bytes_per_page());
        assert_eq!(kv.peak_bytes(), peak, "peak keeps the high tide");
        // Rollback to the current count is a no-op; going forward errors
        // without charging anything.
        kv.rollback_to(s, PAGE_TOKENS - 2).unwrap();
        assert!(kv.rollback_to(s, PAGE_TOKENS).is_err());
        assert_eq!(kv.positions(s), PAGE_TOKENS - 2);
        // Rollback to zero frees every page but keeps the slot.
        kv.rollback_to(s, 0).unwrap();
        assert_eq!(kv.live_bytes(), 0);
        assert_eq!(kv.free_slots(), 3, "slot itself stays allocated");
        // Unallocated slots are refused.
        assert!(kv.rollback_to(s + 1, 0).is_err());
    }

    #[test]
    fn freed_bytes_counts_slot_frees_and_rollback_reclaims() {
        // The satellite churn counter: everything released — retired
        // slots and speculative rollback reclaims — accumulates.
        let mut kv = KvManager::new(cfg(8));
        let bpp = kv.config().bytes_per_page();
        assert_eq!(kv.freed_bytes(), 0);
        let s = kv.allocate(1).unwrap();
        kv.advance_by(s, PAGE_TOKENS + 4).unwrap();
        // Rollback reclaims the second page.
        kv.rollback_to(s, 4).unwrap();
        assert_eq!(kv.freed_bytes(), bpp);
        // Rollback with no page crossing reclaims nothing.
        kv.rollback_to(s, 2).unwrap();
        assert_eq!(kv.freed_bytes(), bpp);
        // Freeing the slot folds its remaining page in.
        kv.free(s).unwrap();
        assert_eq!(kv.freed_bytes(), 2 * bpp);
        // A fresh slot freed while empty adds nothing.
        let s2 = kv.allocate(2).unwrap();
        kv.free(s2).unwrap();
        assert_eq!(kv.freed_bytes(), 2 * bpp);
    }

    #[test]
    fn max_positions_enforced() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        for _ in 0..64 {
            kv.advance(s).unwrap();
        }
        assert!(kv.advance(s).is_err());
    }

    #[test]
    fn positions_out_of_range_is_zero_not_panic() {
        let mut kv = KvManager::new(cfg(8));
        let s = kv.allocate(1).unwrap();
        kv.advance(s).unwrap();
        assert_eq!(kv.positions(s), 1);
        // Free slot and out-of-range index both read as 0.
        assert_eq!(kv.positions(s + 1), 0);
        assert_eq!(kv.positions(1000), 0);
    }

    #[test]
    fn allocator_never_leaks_property() {
        prop("kv allocator conservation", 30, |rng| {
            let mut kv = KvManager::new(cfg(8));
            let mut live: Vec<usize> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..200 {
                if rng.uniform() < 0.5 && kv.free_slots() > 0 {
                    live.push(kv.allocate(next_id).map_err(|e| e.to_string())?);
                    next_id += 1;
                } else if !live.is_empty() {
                    let i = rng.below(live.len());
                    let slot = live.swap_remove(i);
                    kv.free(slot).map_err(|e| e.to_string())?;
                }
                if kv.free_slots() + live.len() != 4 {
                    return Err("slot conservation violated".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn interleaved_accounting_matches_model_property() {
        // Satellite: random interleavings of allocate / advance_by /
        // rollback_to / free against a trivial reference model.  At every
        // step: live_bytes == Σ_slots ceil(positions/PAGE_TOKENS) × bpp,
        // peak never decreases and always dominates live, and freed_bytes
        // only grows.
        prop("kv interleaved accounting", 20, |rng| {
            // Mix codecs so the invariant is checked at several page sizes.
            let codec = match rng.below(3) {
                0 => KvCodecSpec::Identity,
                1 => KvCodecSpec::Factored { layer_budgets: None },
                _ => KvCodecSpec::Factored { layer_budgets: Some(vec![2, 5]) },
            };
            let mut kv = KvManager::new(KvConfig { codec, ..cfg(8) });
            let bpp = kv.config().bytes_per_page();
            let max = kv.config().max_positions;
            // slot index -> positions, for currently-live slots.
            let mut model: Vec<(usize, usize)> = Vec::new();
            let (mut next_id, mut last_peak, mut last_freed) = (0u64, 0usize, 0usize);
            for _ in 0..300 {
                match rng.below(4) {
                    0 => {
                        if kv.free_slots() > 0 {
                            let s = kv.allocate(next_id).map_err(|e| e.to_string())?;
                            next_id += 1;
                            model.push((s, 0));
                        }
                    }
                    1 => {
                        if !model.is_empty() {
                            let i = rng.below(model.len());
                            let (s, pos) = model[i];
                            let n = 1 + rng.below(24);
                            if pos + n <= max {
                                kv.advance_by(s, n).map_err(|e| e.to_string())?;
                                model[i].1 = pos + n;
                            } else if kv.advance_by(s, n).is_ok() {
                                return Err("advance past max_positions accepted".into());
                            }
                        }
                    }
                    2 => {
                        if !model.is_empty() {
                            let i = rng.below(model.len());
                            let (s, pos) = model[i];
                            let back = rng.below(pos + 1);
                            kv.rollback_to(s, back).map_err(|e| e.to_string())?;
                            model[i].1 = back;
                        }
                    }
                    _ => {
                        if !model.is_empty() {
                            let i = rng.below(model.len());
                            let (s, _) = model.swap_remove(i);
                            kv.free(s).map_err(|e| e.to_string())?;
                        }
                    }
                }
                let want: usize =
                    model.iter().map(|&(_, p)| p.div_ceil(PAGE_TOKENS) * bpp).sum();
                if kv.live_bytes() != want {
                    return Err(format!("live {} != model {want}", kv.live_bytes()));
                }
                if kv.peak_bytes() < last_peak {
                    return Err("peak decreased".into());
                }
                if kv.peak_bytes() < kv.live_bytes() {
                    return Err("peak below live".into());
                }
                if kv.freed_bytes() < last_freed {
                    return Err("freed_bytes decreased".into());
                }
                last_peak = kv.peak_bytes();
                last_freed = kv.freed_bytes();
            }
            Ok(())
        });
    }
}
