//! Per-request decode state for the continuous-batching engine.
//!
//! A [`Session`] owns everything about one in-flight request: the token
//! row (prompt + generated), the row cursor, the KV slot it occupies,
//! its sampling policy and stop condition, and the latency bookkeeping
//! (queue wait, time-to-first-token, per-request completion).  The engine
//! loop is then thin: ask each live session for its next *token slab*
//! ([`Session::next_slab`] — a K-token prompt chunk during prefill, the
//! single fed-back token during decode), run one fused step over all
//! lanes, hand each lane's logits row back through
//! [`Session::observe_slab`], and retire sessions the moment they finish
//! — freeing their batch lane for the next queued request.
//!
//! Invariant: a session's prompt is non-empty — empty-prompt requests are
//! rejected at admission (the engine bails, the gateway refuses the
//! submit), so the cursor always has a real token to feed.

use std::time::Instant;

use super::batcher::Request;
use super::engine::Completion;
use super::sampling::Sampler;

/// One in-flight request's decode state.
#[derive(Clone, Debug)]
pub struct Session {
    id: u64,
    prompt_len: usize,
    /// Prompt + generated tokens — the full row so far.
    row: Vec<i32>,
    /// Next model position to feed.  This is the per-lane position counter
    /// that restarts at 0 every time a lane is re-assigned.
    cursor: usize,
    /// Hard stop: `min(prompt + max_new, context_window)` positions.
    target_len: usize,
    slot: usize,
    sampler: Sampler,
    arrived: Instant,
    admitted: Instant,
    ttft_s: Option<f64>,
    stopped: bool,
    steps: usize,
    /// Fused steps that consumed at least one prompt token — how many
    /// engine steps this request's prefill occupied (the TTFT driver
    /// chunked prefill exists to shrink).
    prefill_steps: usize,
    /// `(row position, token)` sampled by the most recent [`Session::observe`]
    /// call, or `None` when that step only consumed prompt.  This is what the
    /// engine's per-step hook streams out as tokens are sampled, rather than
    /// waiting for the completion at wave end.
    last_sampled: Option<(usize, i32)>,
}

impl Session {
    /// Build the decode state for `req`, bound to KV slot/lane `slot`.
    /// The prompt must be non-empty (enforced at admission by the engine
    /// and at submit by the gateway).
    pub fn new(req: Request, slot: usize, max_positions: usize, admitted: Instant) -> Self {
        debug_assert!(!req.prompt.is_empty(), "empty prompts are rejected at admission");
        let target_len = (req.prompt.len() + req.max_new).min(max_positions);
        let sampler = Sampler::for_request(req.sampling.clone(), req.id);
        Self {
            id: req.id,
            prompt_len: req.prompt.len(),
            row: req.prompt,
            cursor: 0,
            target_len,
            slot,
            sampler,
            arrived: req.arrived,
            admitted,
            ttft_s: None,
            stopped: false,
            steps: 0,
            prefill_steps: 0,
            last_sampled: None,
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// KV slot / batch lane this session occupies.
    pub fn slot(&self) -> usize {
        self.slot
    }

    /// Token to feed this step: the prompt token under the cursor during
    /// prefill, else the last generated token.  (The trailing `0` fallback
    /// is unreachable under the non-empty-prompt invariant; it survives
    /// only so this accessor stays total.)
    pub fn next_token(&self) -> i32 {
        self.row
            .get(self.cursor)
            .copied()
            .or_else(|| self.row.last().copied())
            .unwrap_or(0)
    }

    /// Model position for this step.
    pub fn position(&self) -> usize {
        self.cursor
    }

    /// Unconsumed row tokens: the prompt remainder during prefill, exactly
    /// 1 during decode (the fed-back last sample).
    pub fn pending(&self) -> usize {
        self.row.len() - self.cursor
    }

    /// The token slab this session would feed into a step of at most
    /// `max_width` tokens: `(tokens, start position)`.  During prefill
    /// this is the next chunk of unconsumed prompt; during decode it is
    /// the single fed-back token.  Never empty for a live session.
    pub fn next_slab(&self, max_width: usize) -> (&[i32], usize) {
        debug_assert!(max_width >= 1);
        let take = self.pending().min(max_width);
        (&self.row[self.cursor..self.cursor + take], self.cursor)
    }

    /// Still consuming prompt tokens (no token generated yet)?
    pub fn in_prefill(&self) -> bool {
        self.row.len() == self.prompt_len
    }

    /// Number of generated (non-prompt) tokens so far.
    pub fn generated(&self) -> usize {
        self.row.len() - self.prompt_len
    }

    /// True when the request needs no further decode steps: target length
    /// reached, context window exhausted, or stop token emitted.  Can be
    /// true at admission (e.g. `max_new == 0`, or a prompt that already
    /// fills the context window) — such requests complete without ever
    /// occupying a decode step.
    pub fn is_done(&self) -> bool {
        self.stopped || self.row.len() >= self.target_len || self.cursor >= self.target_len
    }

    /// Consume this step's logits row for this lane after a width-1 slab —
    /// [`Session::observe_slab`] with `taken == 1`.
    pub fn observe(&mut self, logits: &[f32], now: Instant) -> bool {
        self.observe_slab(1, logits, now)
    }

    /// Consume this step's logits row for this lane, having fed a
    /// `taken`-token slab.  Advances the cursor by the whole slab, samples
    /// a token iff the row is exhausted (prefill just ended or we're
    /// generating — the logits are at the slab's *last* index, which is
    /// exactly the last consumed position), and returns `true` when the
    /// request finished on this step.
    pub fn observe_slab(&mut self, taken: usize, logits: &[f32], now: Instant) -> bool {
        debug_assert!(!self.is_done(), "observe on a finished session");
        debug_assert!(
            taken >= 1 && self.cursor + taken <= self.row.len(),
            "slab of {taken} escapes the row ({} of {})",
            self.cursor,
            self.row.len()
        );
        self.steps += 1;
        if self.cursor < self.prompt_len {
            self.prefill_steps += 1;
        }
        self.cursor += taken;
        self.last_sampled = None;
        if self.cursor >= self.row.len() && self.row.len() < self.target_len {
            let tok = self.sampler.sample(logits);
            if self.ttft_s.is_none() {
                self.ttft_s = Some(now.duration_since(self.arrived).as_secs_f64());
            }
            self.row.push(tok);
            self.last_sampled = Some((self.row.len() - 1, tok));
            if self.sampler.is_stop(tok) {
                self.stopped = true;
            }
        }
        self.is_done()
    }

    /// `(row position, token)` sampled by the most recent observe, if any.
    /// Positions are absolute row indices: the prompt occupies
    /// `[0, prompt_len)`, so the k-th generated token sits at `prompt_len + k`.
    pub fn last_sampled(&self) -> Option<(usize, i32)> {
        self.last_sampled
    }

    /// The token row so far (prompt + generated) — partial output handed to
    /// the cancellation path when a session retires early.
    pub fn tokens(&self) -> &[i32] {
        &self.row
    }

    /// Consume the session into its token row (cancellation retirement).
    pub fn into_tokens(self) -> Vec<i32> {
        self.row
    }

    /// Retire into a [`Completion`].  `finished_step` is the engine's
    /// global decode-step counter at retirement; latency is measured from
    /// this request's own arrival to its own last token — not to the end
    /// of whatever batch it happened to share lanes with.
    pub fn finish(self, now: Instant, finished_step: usize) -> Completion {
        let latency_s = now.duration_since(self.arrived).as_secs_f64();
        Completion {
            id: self.id,
            tokens: self.row,
            latency_s,
            ttft_s: self.ttft_s.unwrap_or(latency_s),
            queue_wait_s: self.admitted.duration_since(self.arrived).as_secs_f64(),
            steps: self.steps,
            prefill_steps: self.prefill_steps,
            finished_step,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sampling::SamplingParams;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    const V: usize = 16;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize, sampling: SamplingParams) -> Request {
        Request { id, prompt, max_new, arrived: Instant::now(), sampling }
    }

    fn logits_from(rng: &mut Rng) -> Vec<f32> {
        (0..V).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn prefill_then_generate_counts() {
        let now = Instant::now();
        let mut s = Session::new(req(1, vec![5, 6, 7], 4, SamplingParams::greedy()), 0, 64, now);
        let mut rng = Rng::new(1);
        // Prefill: positions 0..2 feed the prompt verbatim.
        assert!(s.in_prefill());
        assert_eq!((s.next_token(), s.position()), (5, 0));
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert_eq!((s.next_token(), s.position()), (6, 1));
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert_eq!((s.next_token(), s.position()), (7, 2));
        // Third observe ends prefill and generates the first token: TTFT.
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert!(!s.in_prefill());
        assert_eq!(s.generated(), 1);
        // Generated token is fed back at the next position.
        assert_eq!(s.position(), 3);
        assert_eq!(s.next_token(), *s_row_last(&s));
        // Run to completion: 3 prompt + 4 new = 7 positions, 6 steps.
        let mut steps = 3;
        while !s.observe(&logits_from(&mut rng), now) {
            steps += 1;
        }
        steps += 1;
        assert_eq!(steps, 6, "last generated token is never fed back");
        let c = s.finish(now, steps);
        assert_eq!(c.tokens.len(), 7);
        assert_eq!(&c.tokens[..3], &[5, 6, 7]);
        assert_eq!(c.steps, 6);
    }

    fn s_row_last(s: &Session) -> &i32 {
        s.row.last().unwrap()
    }

    #[test]
    fn last_sampled_tracks_generated_tokens_only() {
        let now = Instant::now();
        let mut s = Session::new(req(1, vec![5, 6], 2, SamplingParams::greedy()), 0, 64, now);
        let mut rng = Rng::new(3);
        // First observe consumes prompt: nothing sampled.
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert_eq!(s.last_sampled(), None);
        // Second observe ends prefill: first generated token at row index 2.
        assert!(!s.observe(&logits_from(&mut rng), now));
        let (pos, tok) = s.last_sampled().expect("token sampled");
        assert_eq!(pos, 2);
        assert_eq!(s.tokens()[pos], tok);
        // Final observe samples the last token at row index 3 and finishes.
        assert!(s.observe(&logits_from(&mut rng), now));
        assert_eq!(s.last_sampled().map(|(p, _)| p), Some(3));
        assert_eq!(s.into_tokens().len(), 4);
    }

    #[test]
    fn next_slab_chunks_prompt_then_feeds_back() {
        let now = Instant::now();
        let mut s =
            Session::new(req(1, vec![5, 6, 7, 8, 9], 3, SamplingParams::greedy()), 0, 64, now);
        let mut rng = Rng::new(4);
        assert_eq!(s.pending(), 5);
        let (slab, start) = s.next_slab(4);
        assert_eq!((slab, start), (&[5, 6, 7, 8][..], 0));
        assert!(!s.observe_slab(4, &logits_from(&mut rng), now));
        assert_eq!(s.last_sampled(), None, "mid-prefill slab samples nothing");
        // Remainder narrower than the width: take what's left; the step
        // that exhausts the prompt samples the first token.
        let (slab, start) = s.next_slab(4);
        assert_eq!((slab.len(), start), (1, 4));
        assert!(!s.observe_slab(1, &logits_from(&mut rng), now));
        assert_eq!(s.last_sampled().map(|(p, _)| p), Some(5));
        // Decode: pending is exactly 1 no matter the width on offer.
        assert_eq!(s.pending(), 1);
        let (slab, start) = s.next_slab(8);
        assert_eq!((slab.len(), start), (1, 5));
        let mut steps = 2;
        while !s.observe_slab(1, &logits_from(&mut rng), now) {
            steps += 1;
        }
        let c = s.finish(now, steps + 1);
        assert_eq!(c.tokens.len(), 8);
        assert_eq!(c.prefill_steps, 2, "5-token prompt over a 4-wide slab: 2 prefill steps");
    }

    #[test]
    fn slab_and_single_token_prefill_sample_identically() {
        // The sampled token depends only on the logits at the prompt's
        // last position and the per-request sampler state — not on how
        // many steps the prompt took to consume.
        let now = Instant::now();
        let sampling =
            SamplingParams { temperature: 0.8, top_k: 3, seed: 5, stop_token: None };
        let mk = || Session::new(req(9, vec![1, 2, 3, 4], 2, sampling.clone()), 0, 64, now);
        let mut rng = Rng::new(11);
        let sample_logits = logits_from(&mut rng);
        let junk = logits_from(&mut rng);
        let mut a = mk();
        a.observe_slab(4, &sample_logits, now);
        let mut b = mk();
        for _ in 0..3 {
            b.observe(&junk, now); // prompt-consuming steps ignore logits
        }
        b.observe(&sample_logits, now);
        assert_eq!(a.last_sampled(), b.last_sampled());
        assert_eq!(a.tokens(), b.tokens());
        assert_eq!(a.prefill_steps, 1);
        assert_eq!(b.prefill_steps, 4);
    }

    #[test]
    fn stop_token_ends_early() {
        let now = Instant::now();
        let mut sampling = SamplingParams::greedy();
        sampling.stop_token = Some(3);
        let mut s = Session::new(req(1, vec![1], 10, sampling), 0, 64, now);
        // Logits rigged so argmax is always token 3 → stops on first sample.
        let mut logits = vec![0.0f32; V];
        logits[3] = 5.0;
        assert!(s.observe(&logits, now), "stop token must finish the session");
        let c = s.finish(now, 1);
        assert_eq!(c.tokens, vec![1, 3]);
    }

    #[test]
    fn degenerate_requests_are_done_at_admission() {
        let now = Instant::now();
        // max_new == 0: nothing to generate.
        let s = Session::new(req(1, vec![1, 2], 0, SamplingParams::greedy()), 0, 64, now);
        assert!(s.is_done());
        // Prompt already fills the context window.
        let s = Session::new(req(2, (0..64).collect(), 8, SamplingParams::greedy()), 0, 64, now);
        assert!(s.is_done());
    }

    #[test]
    fn session_invariants_property() {
        prop("session decode invariants", 40, |rng| {
            let now = Instant::now();
            // Prompts are non-empty by the admission contract.
            let p = 1 + rng.below(4);
            let prompt: Vec<i32> = (0..p).map(|_| rng.below(V) as i32).collect();
            let max_new = rng.below(8);
            let cwin = 16;
            let sampling = SamplingParams {
                temperature: if rng.uniform() < 0.5 { 0.0 } else { 0.9 },
                top_k: rng.below(4),
                seed: rng.next_u64(),
                stop_token: None,
            };
            let target = (p + max_new).min(cwin);
            let mut s = Session::new(req(7, prompt.clone(), max_new, sampling), 0, cwin, now);
            let mut steps = 0usize;
            while !s.is_done() {
                if s.position() >= cwin {
                    return Err(format!("position {} escaped the window", s.position()));
                }
                // Random slab widths: the invariants hold whether the
                // prompt is consumed token-by-token or in chunks.
                let width = 1 + rng.below(4);
                let (slab, start) = s.next_slab(width);
                if start != s.position() || slab.is_empty() || slab.len() > width {
                    return Err(format!("bad slab {}@{start} for width {width}", slab.len()));
                }
                let taken = slab.len();
                s.observe_slab(taken, &logits_from(rng), now);
                steps += 1;
                if steps > 2 * cwin {
                    return Err("session failed to terminate".into());
                }
            }
            let c = s.finish(now, steps);
            if c.tokens.len() > target.max(p) {
                return Err(format!("row {} exceeds target {target}", c.tokens.len()));
            }
            if c.tokens.len() >= p && c.tokens[..p] != prompt[..] {
                return Err("prompt prefix mutated".into());
            }
            if c.tokens.len() - p > max_new {
                return Err("generated more than max_new".into());
            }
            // The final generated token is never re-fed: at most target - 1
            // single-token steps; slab consumption can only reduce that.
            if steps > target.saturating_sub(1) {
                return Err(format!("{steps} steps for target {target} (prompt {p})"));
            }
            if c.prefill_steps > p {
                return Err(format!("{} prefill steps for a {p}-token prompt", c.prefill_steps));
            }
            Ok(())
        });
    }
}
