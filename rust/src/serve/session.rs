//! Per-request decode state for the continuous-batching engine.
//!
//! A [`Session`] owns everything about one in-flight request: the token
//! row (prompt + generated), the row cursor, the KV slot it occupies,
//! its sampling policy and stop condition, and the latency bookkeeping
//! (queue wait, time-to-first-token, per-request completion).  The engine
//! loop is then thin: ask each live session for its next *token slab*
//! ([`Session::next_slab`] — a K-token prompt chunk during prefill, the
//! single fed-back token during decode), run one fused step over all
//! lanes, hand each lane's logits row back through
//! [`Session::observe_slab`], and retire sessions the moment they finish
//! — freeing their batch lane for the next queued request.
//!
//! Invariant: a session's prompt is non-empty — empty-prompt requests are
//! rejected at admission (the engine bails, the gateway refuses the
//! submit), so the cursor always has a real token to feed.

use std::time::Instant;

use super::batcher::Request;
use super::engine::Completion;
use super::sampling::Sampler;

/// Where a session is in its self-speculative decode cycle.
///
/// A speculating session loops `Idle → Drafting → Verify → Idle`: between
/// target steps the engine starts a round ([`Session::begin_draft`]), the
/// *draft* model autoregressively proposes up to K tokens over K cheap
/// width-1 steps ([`Session::push_draft`]), and the next *target* step
/// scores the whole draft as one K-wide slab
/// ([`Session::observe_verify`]), accepting the longest greedy-matching
/// prefix plus one corrected token and rolling the rest back.  Sessions
/// that never opted in (or are non-greedy) stay `Idle` forever.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecState {
    /// Not mid-round: vanilla slab scheduling applies.
    Idle,
    /// Draft model is proposing; `drafted` grows one token per draft step
    /// until it reaches `k`.
    Drafting { k: usize, drafted: Vec<i32> },
    /// Draft complete: the next target step this lane joins is a verify
    /// step over `[row[cursor], drafted[..k-1]]`.
    Verify { drafted: Vec<i32> },
}

/// What a verify step did to the session —the engine uses `appended` to
/// roll the KV accounting back to the accepted prefix
/// ([`crate::serve::KvManager::rollback_to`]) and the counters for
/// metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VerifyOutcome {
    /// Draft tokens the target confirmed *and* the session kept (a stop
    /// token can cut acceptance short).
    pub accepted: usize,
    /// Row tokens appended by this step: accepted drafts plus the
    /// target's corrected token at the first divergence (or nothing extra
    /// when the whole draft matched).  `cursor` advanced by exactly this.
    pub appended: usize,
    /// Draft tokens rejected (drafted − accepted): the rolled-back
    /// suffix.
    pub rejected: usize,
    pub finished: bool,
}

/// One in-flight request's decode state.
#[derive(Clone, Debug)]
pub struct Session {
    id: u64,
    prompt_len: usize,
    /// Prompt + generated tokens — the full row so far.
    row: Vec<i32>,
    /// Next model position to feed.  This is the per-lane position counter
    /// that restarts at 0 every time a lane is re-assigned.
    cursor: usize,
    /// Hard stop: `min(prompt + max_new, context_window)` positions.
    target_len: usize,
    slot: usize,
    sampler: Sampler,
    arrived: Instant,
    admitted: Instant,
    ttft_s: Option<f64>,
    stopped: bool,
    steps: usize,
    /// Fused steps that consumed at least one prompt token — how many
    /// engine steps this request's prefill occupied (the TTFT driver
    /// chunked prefill exists to shrink).
    prefill_steps: usize,
    /// Prompt tokens attached from the prefix cache at admission (the
    /// cursor started there instead of 0).
    attached: usize,
    /// `(row position, token)` pairs sampled by the most recent observe
    /// call — empty when that step only consumed prompt, one pair for a
    /// vanilla decode step, up to K pairs for a verify step that accepted
    /// a draft.  This is what the engine's per-step hook streams out as
    /// tokens are sampled, rather than waiting for the completion at wave
    /// end.
    sampled: Vec<(usize, i32)>,
    /// Self-speculative round state ([`SpecState::Idle`] unless the
    /// engine enabled speculation for this request).
    spec: SpecState,
    /// Current draft length K for the next round; 0 = speculation off.
    /// The adaptive controller moves it within `[2, draft_max]`.
    draft_len: usize,
    draft_max: usize,
    spec_adaptive: bool,
}

impl Session {
    /// Build the decode state for `req`, bound to KV slot/lane `slot`.
    /// The prompt must be non-empty (enforced at admission by the engine
    /// and at submit by the gateway).
    pub fn new(req: Request, slot: usize, max_positions: usize, admitted: Instant) -> Self {
        debug_assert!(!req.prompt.is_empty(), "empty prompts are rejected at admission");
        let target_len = (req.prompt.len() + req.max_new).min(max_positions);
        let sampler = Sampler::for_request(req.sampling.clone(), req.id);
        Self {
            id: req.id,
            prompt_len: req.prompt.len(),
            row: req.prompt,
            cursor: 0,
            target_len,
            slot,
            sampler,
            arrived: req.arrived,
            admitted,
            ttft_s: None,
            stopped: false,
            steps: 0,
            prefill_steps: 0,
            attached: 0,
            sampled: Vec::new(),
            spec: SpecState::Idle,
            draft_len: 0,
            draft_max: 0,
            spec_adaptive: false,
        }
    }

    /// Attach a cached prefix: the first `tokens` prompt positions are
    /// already in this lane's KV pages (mapped from the prefix cache), so
    /// the cursor jumps past them — prefill starts at the first uncached
    /// token.  Must run before any step (`cursor == 0`) and must leave at
    /// least one prompt token to feed: the step that consumes the last
    /// prompt token is the one that produces the first logits, so a fully
    /// cached prompt still prefills its final token.
    pub fn attach_prefix(&mut self, tokens: usize) {
        debug_assert_eq!(self.cursor, 0, "attach_prefix after stepping");
        debug_assert!(tokens < self.prompt_len, "at least one prompt token must prefill");
        self.cursor = tokens;
        self.attached = tokens;
    }

    /// Prompt tokens attached from the prefix cache (0 = cold prefill).
    pub fn attached(&self) -> usize {
        self.attached
    }

    /// Turn on self-speculative decoding for this session: rounds start at
    /// draft length `draft_len` and the adaptive controller (when
    /// `adaptive`) halves K after a fully-rejected round and doubles it
    /// after a fully-accepted one, within `[2, draft_len]`.  The engine
    /// calls this at admission for opted-in greedy requests only — the
    /// greedy invariant is what makes speculative output bit-identical to
    /// vanilla decode.
    pub fn enable_spec(&mut self, draft_len: usize, adaptive: bool) {
        debug_assert!(draft_len >= 2, "a draft of < 2 tokens cannot win a step");
        self.draft_len = draft_len;
        self.draft_max = draft_len;
        self.spec_adaptive = adaptive;
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// KV slot / batch lane this session occupies.
    pub fn slot(&self) -> usize {
        self.slot
    }

    /// Token to feed this step: the prompt token under the cursor during
    /// prefill, else the last generated token.  (The trailing `0` fallback
    /// is unreachable under the non-empty-prompt invariant; it survives
    /// only so this accessor stays total.)
    pub fn next_token(&self) -> i32 {
        self.row
            .get(self.cursor)
            .copied()
            .or_else(|| self.row.last().copied())
            .unwrap_or(0)
    }

    /// Model position for this step.
    pub fn position(&self) -> usize {
        self.cursor
    }

    /// Unconsumed row tokens: the prompt remainder during prefill, exactly
    /// 1 during decode (the fed-back last sample).
    pub fn pending(&self) -> usize {
        self.row.len() - self.cursor
    }

    /// The token slab this session would feed into a step of at most
    /// `max_width` tokens: `(tokens, start position)`.  During prefill
    /// this is the next chunk of unconsumed prompt; during decode it is
    /// the single fed-back token.  Never empty for a live session.
    pub fn next_slab(&self, max_width: usize) -> (&[i32], usize) {
        debug_assert!(max_width >= 1);
        let take = self.pending().min(max_width);
        (&self.row[self.cursor..self.cursor + take], self.cursor)
    }

    /// Still consuming prompt tokens (no token generated yet)?
    pub fn in_prefill(&self) -> bool {
        self.row.len() == self.prompt_len
    }

    /// Prompt length — the row prefix that was never sampled.  The
    /// observability taps split a slab into prefill vs decode tokens at
    /// this boundary.
    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// An idempotent `(token, position)` pair for steps this lane sits out
    /// of (a draft step it is not drafting in, or a budget-deferred slab):
    /// re-feeding the last consumed pair rewrites an identical cache entry,
    /// and a fresh lane (nothing consumed yet) pre-writes its first prompt
    /// token at position 0 — the exact value the real first slab will
    /// write there anyway.
    pub fn pad_pair(&self) -> (i32, usize) {
        if self.cursor > 0 {
            (self.row[self.cursor - 1], self.cursor - 1)
        } else {
            (self.row[0], 0)
        }
    }

    // ---- self-speculative round state --------------------------------

    /// Speculation enabled for this session (regardless of round phase)?
    pub fn spec_enabled(&self) -> bool {
        self.draft_len >= 2
    }

    /// The draft length the next round should use, when a round can start
    /// right now: session speculative, between rounds, decode-phase
    /// (prompt consumed, one fed-back token pending), and at least two
    /// tokens still wanted (a 1-token round could never beat a vanilla
    /// step).  `max_k` caps at the engine's widest verify slab.
    pub fn spec_round_len(&self, max_k: usize) -> Option<usize> {
        if !self.spec_enabled()
            || self.spec != SpecState::Idle
            || self.is_done()
            || self.in_prefill()
            || self.pending() != 1
        {
            return None;
        }
        let want = self.target_len - self.row.len();
        let k = self.draft_len.min(want).min(max_k);
        (k >= 2).then_some(k)
    }

    /// Begin a draft round of `k` proposed tokens.
    pub fn begin_draft(&mut self, k: usize) {
        debug_assert!(self.spec == SpecState::Idle);
        self.spec = SpecState::Drafting { k, drafted: Vec::with_capacity(k) };
    }

    /// Mid-round with an incomplete draft — the engine runs draft steps
    /// until no live session reports true.
    pub fn drafting(&self) -> bool {
        matches!(&self.spec, SpecState::Drafting { k, drafted } if drafted.len() < *k)
    }

    /// The `(token, position)` this session feeds the *draft* model next:
    /// the fed-back row token to open the round, then each proposed token
    /// autoregressively.
    pub fn draft_feed(&self) -> (i32, usize) {
        match &self.spec {
            SpecState::Drafting { drafted, .. } => match drafted.last() {
                Some(&d) => (d, self.cursor + drafted.len()),
                None => (self.row[self.cursor], self.cursor),
            },
            _ => self.pad_pair(),
        }
    }

    /// Record one draft-model proposal; flips to [`SpecState::Verify`]
    /// when the round's K tokens are in.
    pub fn push_draft(&mut self, tok: i32) {
        let SpecState::Drafting { k, drafted } = &mut self.spec else {
            unreachable!("push_draft outside a draft round");
        };
        drafted.push(tok);
        if drafted.len() == *k {
            let drafted = std::mem::take(drafted);
            self.spec = SpecState::Verify { drafted };
        }
    }

    /// Length of the verify slab the next target step must carry for this
    /// lane (`None` when not in the verify phase).
    pub fn verify_len(&self) -> Option<usize> {
        match &self.spec {
            SpecState::Verify { drafted } => Some(drafted.len()),
            _ => None,
        }
    }

    /// The `(token, position)` at verify-slab index `j`: the fed-back row
    /// token at the cursor, then the drafted tokens at the following
    /// positions — the slab whose all-position logits score the draft.
    fn verify_pair(&self, j: usize) -> (i32, usize) {
        let SpecState::Verify { drafted } = &self.spec else {
            unreachable!("verify_pair outside the verify phase")
        };
        if j == 0 {
            (self.row[self.cursor], self.cursor)
        } else {
            (drafted[j - 1], self.cursor + j)
        }
    }

    /// The `(token, position)` this lane contributes at index `j` of a
    /// planned slab (`start`/`len` from its [`crate::serve::LaneSlab`]):
    /// verify tokens when mid-verify, row tokens otherwise, the pad pair
    /// for a zero-length (sat-out) slab, and pad-by-repeat of the last
    /// valid index beyond `len`.
    pub fn step_pair(&self, start: usize, len: usize, j: usize) -> (i32, usize) {
        if len == 0 {
            return self.pad_pair();
        }
        let jj = j.min(len - 1);
        if self.verify_len().is_some() {
            self.verify_pair(jj)
        } else {
            (self.row[start + jj], start + jj)
        }
    }

    /// Number of generated (non-prompt) tokens so far.
    pub fn generated(&self) -> usize {
        self.row.len() - self.prompt_len
    }

    /// True when the request needs no further decode steps: target length
    /// reached, context window exhausted, or stop token emitted.  Can be
    /// true at admission (e.g. `max_new == 0`, or a prompt that already
    /// fills the context window) — such requests complete without ever
    /// occupying a decode step.
    pub fn is_done(&self) -> bool {
        self.stopped || self.row.len() >= self.target_len || self.cursor >= self.target_len
    }

    /// Consume this step's logits row for this lane after a width-1 slab —
    /// [`Session::observe_slab`] with `taken == 1`.
    pub fn observe(&mut self, logits: &[f32], now: Instant) -> bool {
        self.observe_slab(1, logits, now)
    }

    /// Consume this step's logits row for this lane, having fed a
    /// `taken`-token slab.  Advances the cursor by the whole slab, samples
    /// a token iff the row is exhausted (prefill just ended or we're
    /// generating — the logits are at the slab's *last* index, which is
    /// exactly the last consumed position), and returns `true` when the
    /// request finished on this step.
    pub fn observe_slab(&mut self, taken: usize, logits: &[f32], now: Instant) -> bool {
        debug_assert!(!self.is_done(), "observe on a finished session");
        debug_assert!(
            taken >= 1 && self.cursor + taken <= self.row.len(),
            "slab of {taken} escapes the row ({} of {})",
            self.cursor,
            self.row.len()
        );
        self.steps += 1;
        if self.cursor < self.prompt_len {
            self.prefill_steps += 1;
        }
        self.cursor += taken;
        self.sampled.clear();
        if self.cursor >= self.row.len() && self.row.len() < self.target_len {
            let tok = self.sampler.sample(logits);
            if self.ttft_s.is_none() {
                self.ttft_s = Some(now.duration_since(self.arrived).as_secs_f64());
            }
            self.row.push(tok);
            self.sampled.push((self.row.len() - 1, tok));
            if self.sampler.is_stop(tok) {
                self.stopped = true;
            }
        }
        self.is_done()
    }

    /// Consume one *verify* step's all-position logits for this lane.
    /// `targets[j]` is the target model's greedy token at verify-slab
    /// index `j` (the successor of the j-th fed token).  Because the
    /// drafted prefix that matches `targets` *is* what vanilla greedy
    /// decode would have emitted, accepting `targets[0 ..= m]` (m = the
    /// longest matching prefix; index m is the correction at the first
    /// divergence, or the final bonus comparison when everything matched)
    /// appends exactly the vanilla token sequence — bit-identity by
    /// construction, whatever the draft proposed.
    pub fn observe_verify(&mut self, targets: &[i32], now: Instant) -> VerifyOutcome {
        debug_assert!(!self.is_done(), "verify on a finished session");
        let SpecState::Verify { drafted } = std::mem::replace(&mut self.spec, SpecState::Idle)
        else {
            unreachable!("observe_verify outside the verify phase")
        };
        let k = drafted.len();
        debug_assert_eq!(targets.len(), k, "one target token per verify index");
        self.steps += 1;
        self.sampled.clear();
        // Longest prefix of the draft the target agrees with.
        let mut m = 0;
        while m < k && targets[m] == drafted[m] {
            m += 1;
        }
        // targets[j] == drafted[j] for j < m, and targets[m] (when m < k)
        // is the target's own correction — so the appended tokens are
        // simply targets[0..take].
        let take = (m + 1).min(k);
        let mut appended = 0;
        for &tok in &targets[..take] {
            debug_assert!(self.row.len() < self.target_len, "round drafted past target_len");
            self.cursor += 1;
            self.row.push(tok);
            self.sampled.push((self.row.len() - 1, tok));
            appended += 1;
            if self.ttft_s.is_none() {
                self.ttft_s = Some(now.duration_since(self.arrived).as_secs_f64());
            }
            if self.sampler.is_stop(tok) {
                self.stopped = true;
                break;
            }
        }
        // Adaptive draft length: a fully-accepted round earns a longer
        // draft next time, a fully-rejected one halves it (floor 2).
        if self.spec_adaptive {
            if m == k {
                self.draft_len = (self.draft_len * 2).min(self.draft_max);
            } else if m == 0 {
                self.draft_len = (self.draft_len / 2).max(2);
            }
        }
        let accepted = appended.min(m);
        VerifyOutcome { accepted, appended, rejected: k - accepted, finished: self.is_done() }
    }

    /// `(row position, token)` pairs sampled by the most recent observe —
    /// one for a vanilla step, up to K for a verify step.  Positions are
    /// absolute row indices: the prompt occupies `[0, prompt_len)`, so the
    /// k-th generated token sits at `prompt_len + k`.
    pub fn sampled(&self) -> &[(usize, i32)] {
        &self.sampled
    }

    /// The last `(row position, token)` sampled by the most recent
    /// observe, if any.
    pub fn last_sampled(&self) -> Option<(usize, i32)> {
        self.sampled.last().copied()
    }

    /// The token row so far (prompt + generated) — partial output handed to
    /// the cancellation path when a session retires early.
    pub fn tokens(&self) -> &[i32] {
        &self.row
    }

    /// Consume the session into its token row (cancellation retirement).
    pub fn into_tokens(self) -> Vec<i32> {
        self.row
    }

    /// Retire into a [`Completion`].  `finished_step` is the engine's
    /// global decode-step counter at retirement; latency is measured from
    /// this request's own arrival to its own last token — not to the end
    /// of whatever batch it happened to share lanes with.
    pub fn finish(self, now: Instant, finished_step: usize) -> Completion {
        let latency_s = now.duration_since(self.arrived).as_secs_f64();
        Completion {
            id: self.id,
            tokens: self.row,
            latency_s,
            ttft_s: self.ttft_s.unwrap_or(latency_s),
            queue_wait_s: self.admitted.duration_since(self.arrived).as_secs_f64(),
            steps: self.steps,
            prefill_steps: self.prefill_steps,
            finished_step,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sampling::SamplingParams;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    const V: usize = 16;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize, sampling: SamplingParams) -> Request {
        Request { id, prompt, max_new, arrived: Instant::now(), sampling }
    }

    fn logits_from(rng: &mut Rng) -> Vec<f32> {
        (0..V).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn prefill_then_generate_counts() {
        let now = Instant::now();
        let mut s = Session::new(req(1, vec![5, 6, 7], 4, SamplingParams::greedy()), 0, 64, now);
        let mut rng = Rng::new(1);
        // Prefill: positions 0..2 feed the prompt verbatim.
        assert!(s.in_prefill());
        assert_eq!((s.next_token(), s.position()), (5, 0));
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert_eq!((s.next_token(), s.position()), (6, 1));
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert_eq!((s.next_token(), s.position()), (7, 2));
        // Third observe ends prefill and generates the first token: TTFT.
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert!(!s.in_prefill());
        assert_eq!(s.generated(), 1);
        // Generated token is fed back at the next position.
        assert_eq!(s.position(), 3);
        assert_eq!(s.next_token(), *s_row_last(&s));
        // Run to completion: 3 prompt + 4 new = 7 positions, 6 steps.
        let mut steps = 3;
        while !s.observe(&logits_from(&mut rng), now) {
            steps += 1;
        }
        steps += 1;
        assert_eq!(steps, 6, "last generated token is never fed back");
        let c = s.finish(now, steps);
        assert_eq!(c.tokens.len(), 7);
        assert_eq!(&c.tokens[..3], &[5, 6, 7]);
        assert_eq!(c.steps, 6);
    }

    fn s_row_last(s: &Session) -> &i32 {
        s.row.last().unwrap()
    }

    #[test]
    fn last_sampled_tracks_generated_tokens_only() {
        let now = Instant::now();
        let mut s = Session::new(req(1, vec![5, 6], 2, SamplingParams::greedy()), 0, 64, now);
        let mut rng = Rng::new(3);
        // First observe consumes prompt: nothing sampled.
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert_eq!(s.last_sampled(), None);
        // Second observe ends prefill: first generated token at row index 2.
        assert!(!s.observe(&logits_from(&mut rng), now));
        let (pos, tok) = s.last_sampled().expect("token sampled");
        assert_eq!(pos, 2);
        assert_eq!(s.tokens()[pos], tok);
        // Final observe samples the last token at row index 3 and finishes.
        assert!(s.observe(&logits_from(&mut rng), now));
        assert_eq!(s.last_sampled().map(|(p, _)| p), Some(3));
        assert_eq!(s.into_tokens().len(), 4);
    }

    #[test]
    fn next_slab_chunks_prompt_then_feeds_back() {
        let now = Instant::now();
        let mut s =
            Session::new(req(1, vec![5, 6, 7, 8, 9], 3, SamplingParams::greedy()), 0, 64, now);
        let mut rng = Rng::new(4);
        assert_eq!(s.pending(), 5);
        let (slab, start) = s.next_slab(4);
        assert_eq!((slab, start), (&[5, 6, 7, 8][..], 0));
        assert!(!s.observe_slab(4, &logits_from(&mut rng), now));
        assert_eq!(s.last_sampled(), None, "mid-prefill slab samples nothing");
        // Remainder narrower than the width: take what's left; the step
        // that exhausts the prompt samples the first token.
        let (slab, start) = s.next_slab(4);
        assert_eq!((slab.len(), start), (1, 4));
        assert!(!s.observe_slab(1, &logits_from(&mut rng), now));
        assert_eq!(s.last_sampled().map(|(p, _)| p), Some(5));
        // Decode: pending is exactly 1 no matter the width on offer.
        assert_eq!(s.pending(), 1);
        let (slab, start) = s.next_slab(8);
        assert_eq!((slab.len(), start), (1, 5));
        let mut steps = 2;
        while !s.observe_slab(1, &logits_from(&mut rng), now) {
            steps += 1;
        }
        let c = s.finish(now, steps + 1);
        assert_eq!(c.tokens.len(), 8);
        assert_eq!(c.prefill_steps, 2, "5-token prompt over a 4-wide slab: 2 prefill steps");
    }

    #[test]
    fn slab_and_single_token_prefill_sample_identically() {
        // The sampled token depends only on the logits at the prompt's
        // last position and the per-request sampler state — not on how
        // many steps the prompt took to consume.
        let now = Instant::now();
        let sampling =
            SamplingParams { temperature: 0.8, top_k: 3, seed: 5, ..Default::default() };
        let mk = || Session::new(req(9, vec![1, 2, 3, 4], 2, sampling.clone()), 0, 64, now);
        let mut rng = Rng::new(11);
        let sample_logits = logits_from(&mut rng);
        let junk = logits_from(&mut rng);
        let mut a = mk();
        a.observe_slab(4, &sample_logits, now);
        let mut b = mk();
        for _ in 0..3 {
            b.observe(&junk, now); // prompt-consuming steps ignore logits
        }
        b.observe(&sample_logits, now);
        assert_eq!(a.last_sampled(), b.last_sampled());
        assert_eq!(a.tokens(), b.tokens());
        assert_eq!(a.prefill_steps, 1);
        assert_eq!(b.prefill_steps, 4);
    }

    #[test]
    fn stop_token_ends_early() {
        let now = Instant::now();
        let mut sampling = SamplingParams::greedy();
        sampling.stop_token = Some(3);
        let mut s = Session::new(req(1, vec![1], 10, sampling), 0, 64, now);
        // Logits rigged so argmax is always token 3 → stops on first sample.
        let mut logits = vec![0.0f32; V];
        logits[3] = 5.0;
        assert!(s.observe(&logits, now), "stop token must finish the session");
        let c = s.finish(now, 1);
        assert_eq!(c.tokens, vec![1, 3]);
    }

    #[test]
    fn degenerate_requests_are_done_at_admission() {
        let now = Instant::now();
        // max_new == 0: nothing to generate.
        let s = Session::new(req(1, vec![1, 2], 0, SamplingParams::greedy()), 0, 64, now);
        assert!(s.is_done());
        // Prompt already fills the context window.
        let s = Session::new(req(2, (0..64).collect(), 8, SamplingParams::greedy()), 0, 64, now);
        assert!(s.is_done());
    }

    #[test]
    fn pad_pair_is_idempotent_rewrite() {
        let now = Instant::now();
        let mut s = Session::new(req(1, vec![5, 6, 7], 4, SamplingParams::greedy()), 0, 64, now);
        // Fresh lane: pre-writes its own first prompt token at position 0.
        assert_eq!(s.pad_pair(), (5, 0));
        let mut rng = Rng::new(2);
        s.observe_slab(2, &logits_from(&mut rng), now);
        // Mid-row: re-feeds the last consumed pair.
        assert_eq!(s.pad_pair(), (6, 1));
    }

    #[test]
    fn attach_prefix_skips_cached_prompt_positions() {
        let now = Instant::now();
        let prompt: Vec<i32> = (0..40).collect();
        let mut s = Session::new(req(1, prompt.clone(), 2, SamplingParams::greedy()), 0, 64, now);
        s.attach_prefix(32);
        assert_eq!(s.attached(), 32);
        assert!(s.in_prefill());
        // The next slab starts at the first uncached token.
        let (slab, start) = s.next_slab(32);
        assert_eq!((slab, start), (&prompt[32..], 32));
        // Mid-prefill the pad pair points into the attached region — the
        // COW store skips it as an idempotent rewrite.
        assert_eq!(s.pad_pair(), (prompt[0], 0));
        let mut rng = Rng::new(12);
        assert!(!s.observe_slab(8, &logits_from(&mut rng), now));
        assert_eq!(s.last_sampled().map(|(p, _)| p), Some(40), "one step to first token");
        let c = {
            let mut steps = 1;
            while !s.observe(&logits_from(&mut rng), now) {
                steps += 1;
            }
            s.finish(now, steps + 1)
        };
        assert_eq!(c.prefill_steps, 1, "attached prefix never occupies a step");
        assert_eq!(&c.tokens[..40], &prompt[..]);
    }

    #[test]
    fn draft_verify_cycle_accepts_matching_prefix() {
        let now = Instant::now();
        let mut s = Session::new(
            req(1, vec![5, 6], 8, SamplingParams::speculative_greedy()),
            0,
            64,
            now,
        );
        s.enable_spec(4, false);
        let mut rng = Rng::new(6);
        // No round during prefill.
        assert_eq!(s.spec_round_len(32), None);
        s.observe_slab(2, &logits_from(&mut rng), now);
        let first = s.last_sampled().expect("prefill end samples").1;
        // Decode-ready: a 4-token round fits (8 - 1 = 7 wanted ≥ 4).
        assert_eq!(s.spec_round_len(32), Some(4));
        s.begin_draft(4);
        assert!(s.drafting());
        // The draft feed walks [row[c], d1, d2, d3] at positions c, c+1, …
        assert_eq!(s.draft_feed(), (first, 2));
        for (i, d) in [21, 22, 23, 24].into_iter().enumerate() {
            s.push_draft(d);
            if i < 3 {
                assert_eq!(s.draft_feed(), (d, 3 + i));
            }
        }
        assert!(!s.drafting(), "round of 4 is complete");
        assert_eq!(s.verify_len(), Some(4));
        // Slab pairs: fed-back token first, then the draft.
        assert_eq!(s.step_pair(2, 4, 0), (first, 2));
        assert_eq!(s.step_pair(2, 4, 1), (21, 3));
        // The slab is [row[c], d1, d2, d3] — d4 is never fed, only compared
        // against the target's token at the last index.  Pads repeat the
        // last slab pair.
        assert_eq!(s.step_pair(2, 4, 5), (23, 5), "pads repeat the last pair");
        // Target agrees with d1, d2, diverges at d3: accept 2 + correction.
        let out = s.observe_verify(&[21, 22, 99, 0], now);
        assert_eq!(out, VerifyOutcome { accepted: 2, appended: 3, rejected: 2, finished: false });
        assert_eq!(s.tokens(), &[5, 6, first, 21, 22, 99]);
        assert_eq!(
            s.sampled(),
            &[(3, 21), (4, 22), (5, 99)],
            "every appended token streams out with its row position"
        );
        assert_eq!(s.pending(), 1, "decode invariant restored after a round");
        assert_eq!(s.verify_len(), None);
    }

    #[test]
    fn verify_full_acceptance_and_stop_token() {
        let now = Instant::now();
        // Full acceptance appends exactly k tokens (the last comparison is
        // the bonus: target's own token at the final index).
        let mut s = Session::new(
            req(1, vec![5], 8, SamplingParams::speculative_greedy()),
            0,
            64,
            now,
        );
        s.enable_spec(3, false);
        let mut rng = Rng::new(8);
        s.observe_slab(1, &logits_from(&mut rng), now);
        s.begin_draft(3);
        for d in [11, 12, 13] {
            s.push_draft(d);
        }
        let out = s.observe_verify(&[11, 12, 13], now);
        assert_eq!(out, VerifyOutcome { accepted: 3, appended: 3, rejected: 0, finished: false });
        assert_eq!(s.generated(), 4);

        // A stop token inside the accepted prefix cuts the round short,
        // exactly as vanilla decode would have stopped there.
        let mut stop_params = SamplingParams::speculative_greedy();
        stop_params.stop_token = Some(12);
        let mut s = Session::new(req(2, vec![5], 8, stop_params), 0, 64, now);
        s.enable_spec(3, false);
        // Rigged logits so the prefill-end sample is deterministic and
        // not the stop token.
        let mut first = vec![0.0f32; V];
        first[3] = 5.0;
        s.observe_slab(1, &first, now);
        s.begin_draft(3);
        for d in [11, 12, 13] {
            s.push_draft(d);
        }
        let out = s.observe_verify(&[11, 12, 13], now);
        assert!(out.finished, "stop token finishes the session");
        assert_eq!(out.appended, 2, "nothing after the stop token");
        assert_eq!(&s.into_tokens()[2..], &[11, 12]);
    }

    #[test]
    fn adaptive_draft_length_shrinks_and_regrows() {
        let now = Instant::now();
        let mut s = Session::new(
            req(1, vec![5], 64, SamplingParams::speculative_greedy()),
            0,
            128,
            now,
        );
        s.enable_spec(8, true);
        let mut rng = Rng::new(9);
        s.observe_slab(1, &logits_from(&mut rng), now);
        // Fully-rejected rounds halve K: 8 → 4 → 2 → floor at 2.
        for want in [4usize, 2, 2] {
            let k = s.spec_round_len(32).unwrap();
            s.begin_draft(k);
            for _ in 0..k {
                s.push_draft(-1); // a token greedy decode can never emit
            }
            let last = s.tokens().len();
            let targets: Vec<i32> = (0..k as i32).map(|j| 1 + j + last as i32).collect();
            let out = s.observe_verify(&targets, now);
            assert_eq!(out.accepted, 0);
            assert_eq!(out.appended, 1, "a failed round still yields the corrected token");
            assert_eq!(s.spec_round_len(32), Some(want));
        }
        // Fully-accepted rounds double it back, capped at the initial K.
        for want in [4usize, 8, 8] {
            let k = s.spec_round_len(32).unwrap();
            s.begin_draft(k);
            let base = 30 + s.tokens().len() as i32;
            for j in 0..k as i32 {
                s.push_draft(base + j);
            }
            let targets: Vec<i32> = (0..k as i32).map(|j| base + j).collect();
            let out = s.observe_verify(&targets, now);
            assert_eq!(out.accepted, k);
            assert_eq!(s.spec_round_len(32), Some(want));
        }
    }

    #[test]
    fn spec_round_len_respects_remaining_budget() {
        let now = Instant::now();
        let mut s = Session::new(
            req(1, vec![5], 4, SamplingParams::speculative_greedy()),
            0,
            64,
            now,
        );
        s.enable_spec(8, false);
        let mut rng = Rng::new(10);
        s.observe_slab(1, &logits_from(&mut rng), now);
        // 1 prompt + 4 new = target 5; row is 2 → 3 tokens wanted < 8.
        assert_eq!(s.spec_round_len(32), Some(3));
        // The engine's verify-width cap applies too.
        assert_eq!(s.spec_round_len(2), Some(2));
        // One token wanted: speculation cannot win — vanilla step instead.
        while s.generated() < 3 {
            s.observe_slab(1, &logits_from(&mut rng), now);
        }
        assert_eq!(s.spec_round_len(32), None);
    }

    #[test]
    fn session_invariants_property() {
        prop("session decode invariants", 40, |rng| {
            let now = Instant::now();
            // Prompts are non-empty by the admission contract.
            let p = 1 + rng.below(4);
            let prompt: Vec<i32> = (0..p).map(|_| rng.below(V) as i32).collect();
            let max_new = rng.below(8);
            let cwin = 16;
            let sampling = SamplingParams {
                temperature: if rng.uniform() < 0.5 { 0.0 } else { 0.9 },
                top_k: rng.below(4),
                seed: rng.next_u64(),
                stop_token: None,
                speculative: false,
            };
            let target = (p + max_new).min(cwin);
            let mut s = Session::new(req(7, prompt.clone(), max_new, sampling), 0, cwin, now);
            let mut steps = 0usize;
            while !s.is_done() {
                if s.position() >= cwin {
                    return Err(format!("position {} escaped the window", s.position()));
                }
                // Random slab widths: the invariants hold whether the
                // prompt is consumed token-by-token or in chunks.
                let width = 1 + rng.below(4);
                let (slab, start) = s.next_slab(width);
                if start != s.position() || slab.is_empty() || slab.len() > width {
                    return Err(format!("bad slab {}@{start} for width {width}", slab.len()));
                }
                let taken = slab.len();
                s.observe_slab(taken, &logits_from(rng), now);
                steps += 1;
                if steps > 2 * cwin {
                    return Err("session failed to terminate".into());
                }
            }
            let c = s.finish(now, steps);
            if c.tokens.len() > target.max(p) {
                return Err(format!("row {} exceeds target {target}", c.tokens.len()));
            }
            if c.tokens.len() >= p && c.tokens[..p] != prompt[..] {
                return Err("prompt prefix mutated".into());
            }
            if c.tokens.len() - p > max_new {
                return Err("generated more than max_new".into());
            }
            // The final generated token is never re-fed: at most target - 1
            // single-token steps; slab consumption can only reduce that.
            if steps > target.saturating_sub(1) {
                return Err(format!("{steps} steps for target {target} (prompt {p})"));
            }
            if c.prefill_steps > p {
                return Err(format!("{} prefill steps for a {p}-token prompt", c.prefill_steps));
            }
            Ok(())
        });
    }
}
