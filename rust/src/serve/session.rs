//! Per-request decode state for the continuous-batching engine.
//!
//! A [`Session`] owns everything about one in-flight request: the token
//! row (prompt + generated), the prompt cursor, the KV slot it occupies,
//! its sampling policy and stop condition, and the latency bookkeeping
//! (queue wait, time-to-first-token, per-request completion).  The engine
//! loop is then thin: feed each live session's `(next_token, position)`
//! into one fused decode step, hand each lane's logits row back through
//! [`Session::observe`], and retire sessions the moment they finish —
//! freeing their batch lane for the next queued request.

use std::time::Instant;

use super::batcher::Request;
use super::engine::Completion;
use super::sampling::Sampler;

/// One in-flight request's decode state.
#[derive(Clone, Debug)]
pub struct Session {
    id: u64,
    prompt_len: usize,
    /// Prompt + generated tokens — the full row so far.
    row: Vec<i32>,
    /// Next model position to feed.  This is the per-lane position counter
    /// that restarts at 0 every time a lane is re-assigned.
    cursor: usize,
    /// Hard stop: `min(prompt + max_new, context_window)` positions.
    target_len: usize,
    slot: usize,
    sampler: Sampler,
    arrived: Instant,
    admitted: Instant,
    ttft_s: Option<f64>,
    stopped: bool,
    steps: usize,
    /// `(row position, token)` sampled by the most recent [`Session::observe`]
    /// call, or `None` when that step only consumed prompt.  This is what the
    /// engine's per-step hook streams out as tokens are sampled, rather than
    /// waiting for the completion at wave end.
    last_sampled: Option<(usize, i32)>,
}

impl Session {
    /// Build the decode state for `req`, bound to KV slot/lane `slot`.
    pub fn new(req: Request, slot: usize, max_positions: usize, admitted: Instant) -> Self {
        let target_len = (req.prompt.len() + req.max_new).min(max_positions);
        let sampler = Sampler::for_request(req.sampling.clone(), req.id);
        Self {
            id: req.id,
            prompt_len: req.prompt.len(),
            row: req.prompt,
            cursor: 0,
            target_len,
            slot,
            sampler,
            arrived: req.arrived,
            admitted,
            ttft_s: None,
            stopped: false,
            steps: 0,
            last_sampled: None,
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// KV slot / batch lane this session occupies.
    pub fn slot(&self) -> usize {
        self.slot
    }

    /// Token to feed this step: the prompt token under the cursor during
    /// prefill, else the last generated token (0 for an empty prompt).
    pub fn next_token(&self) -> i32 {
        self.row
            .get(self.cursor)
            .copied()
            .or_else(|| self.row.last().copied())
            .unwrap_or(0)
    }

    /// Model position for this step.
    pub fn position(&self) -> usize {
        self.cursor
    }

    /// Still consuming prompt tokens (no token generated yet)?
    pub fn in_prefill(&self) -> bool {
        self.row.len() == self.prompt_len
    }

    /// Number of generated (non-prompt) tokens so far.
    pub fn generated(&self) -> usize {
        self.row.len() - self.prompt_len
    }

    /// True when the request needs no further decode steps: target length
    /// reached, context window exhausted, or stop token emitted.  Can be
    /// true at admission (e.g. `max_new == 0`, or a prompt that already
    /// fills the context window) — such requests complete without ever
    /// occupying a decode step.
    pub fn is_done(&self) -> bool {
        self.stopped || self.row.len() >= self.target_len || self.cursor >= self.target_len
    }

    /// Consume this step's logits row for this lane.  Advances the cursor,
    /// samples a token iff the row is exhausted (prefill just ended or
    /// we're generating), and returns `true` when the request finished on
    /// this step.
    pub fn observe(&mut self, logits: &[f32], now: Instant) -> bool {
        debug_assert!(!self.is_done(), "observe on a finished session");
        self.steps += 1;
        self.cursor += 1;
        self.last_sampled = None;
        if self.cursor >= self.row.len() && self.row.len() < self.target_len {
            let tok = self.sampler.sample(logits);
            if self.ttft_s.is_none() {
                self.ttft_s = Some(now.duration_since(self.arrived).as_secs_f64());
            }
            self.row.push(tok);
            self.last_sampled = Some((self.row.len() - 1, tok));
            if self.sampler.is_stop(tok) {
                self.stopped = true;
            }
        }
        self.is_done()
    }

    /// `(row position, token)` sampled by the most recent observe, if any.
    /// Positions are absolute row indices: the prompt occupies
    /// `[0, prompt_len)`, so the k-th generated token sits at `prompt_len + k`.
    pub fn last_sampled(&self) -> Option<(usize, i32)> {
        self.last_sampled
    }

    /// The token row so far (prompt + generated) — partial output handed to
    /// the cancellation path when a session retires early.
    pub fn tokens(&self) -> &[i32] {
        &self.row
    }

    /// Consume the session into its token row (cancellation retirement).
    pub fn into_tokens(self) -> Vec<i32> {
        self.row
    }

    /// Retire into a [`Completion`].  `finished_step` is the engine's
    /// global decode-step counter at retirement; latency is measured from
    /// this request's own arrival to its own last token — not to the end
    /// of whatever batch it happened to share lanes with.
    pub fn finish(self, now: Instant, finished_step: usize) -> Completion {
        let latency_s = now.duration_since(self.arrived).as_secs_f64();
        Completion {
            id: self.id,
            tokens: self.row,
            latency_s,
            ttft_s: self.ttft_s.unwrap_or(latency_s),
            queue_wait_s: self.admitted.duration_since(self.arrived).as_secs_f64(),
            steps: self.steps,
            finished_step,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sampling::SamplingParams;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    const V: usize = 16;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize, sampling: SamplingParams) -> Request {
        Request { id, prompt, max_new, arrived: Instant::now(), sampling }
    }

    fn logits_from(rng: &mut Rng) -> Vec<f32> {
        (0..V).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn prefill_then_generate_counts() {
        let now = Instant::now();
        let mut s = Session::new(req(1, vec![5, 6, 7], 4, SamplingParams::greedy()), 0, 64, now);
        let mut rng = Rng::new(1);
        // Prefill: positions 0..2 feed the prompt verbatim.
        assert!(s.in_prefill());
        assert_eq!((s.next_token(), s.position()), (5, 0));
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert_eq!((s.next_token(), s.position()), (6, 1));
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert_eq!((s.next_token(), s.position()), (7, 2));
        // Third observe ends prefill and generates the first token: TTFT.
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert!(!s.in_prefill());
        assert_eq!(s.generated(), 1);
        // Generated token is fed back at the next position.
        assert_eq!(s.position(), 3);
        assert_eq!(s.next_token(), *s_row_last(&s));
        // Run to completion: 3 prompt + 4 new = 7 positions, 6 steps.
        let mut steps = 3;
        while !s.observe(&logits_from(&mut rng), now) {
            steps += 1;
        }
        steps += 1;
        assert_eq!(steps, 6, "last generated token is never fed back");
        let c = s.finish(now, steps);
        assert_eq!(c.tokens.len(), 7);
        assert_eq!(&c.tokens[..3], &[5, 6, 7]);
        assert_eq!(c.steps, 6);
    }

    fn s_row_last(s: &Session) -> &i32 {
        s.row.last().unwrap()
    }

    #[test]
    fn last_sampled_tracks_generated_tokens_only() {
        let now = Instant::now();
        let mut s = Session::new(req(1, vec![5, 6], 2, SamplingParams::greedy()), 0, 64, now);
        let mut rng = Rng::new(3);
        // First observe consumes prompt: nothing sampled.
        assert!(!s.observe(&logits_from(&mut rng), now));
        assert_eq!(s.last_sampled(), None);
        // Second observe ends prefill: first generated token at row index 2.
        assert!(!s.observe(&logits_from(&mut rng), now));
        let (pos, tok) = s.last_sampled().expect("token sampled");
        assert_eq!(pos, 2);
        assert_eq!(s.tokens()[pos], tok);
        // Final observe samples the last token at row index 3 and finishes.
        assert!(s.observe(&logits_from(&mut rng), now));
        assert_eq!(s.last_sampled().map(|(p, _)| p), Some(3));
        assert_eq!(s.into_tokens().len(), 4);
    }

    #[test]
    fn stop_token_ends_early() {
        let now = Instant::now();
        let mut sampling = SamplingParams::greedy();
        sampling.stop_token = Some(3);
        let mut s = Session::new(req(1, vec![1], 10, sampling), 0, 64, now);
        // Logits rigged so argmax is always token 3 → stops on first sample.
        let mut logits = vec![0.0f32; V];
        logits[3] = 5.0;
        assert!(s.observe(&logits, now), "stop token must finish the session");
        let c = s.finish(now, 1);
        assert_eq!(c.tokens, vec![1, 3]);
    }

    #[test]
    fn degenerate_requests_are_done_at_admission() {
        let now = Instant::now();
        // max_new == 0: nothing to generate.
        let s = Session::new(req(1, vec![1, 2], 0, SamplingParams::greedy()), 0, 64, now);
        assert!(s.is_done());
        // Prompt already fills the context window.
        let s = Session::new(req(2, (0..64).collect(), 8, SamplingParams::greedy()), 0, 64, now);
        assert!(s.is_done());
    }

    #[test]
    fn session_invariants_property() {
        prop("session decode invariants", 40, |rng| {
            let now = Instant::now();
            let p = rng.below(5);
            let prompt: Vec<i32> = (0..p).map(|_| rng.below(V) as i32).collect();
            let max_new = rng.below(8);
            let cwin = 16;
            let sampling = SamplingParams {
                temperature: if rng.uniform() < 0.5 { 0.0 } else { 0.9 },
                top_k: rng.below(4),
                seed: rng.next_u64(),
                stop_token: None,
            };
            let target = (p + max_new).min(cwin);
            let mut s = Session::new(req(7, prompt.clone(), max_new, sampling), 0, cwin, now);
            let mut steps = 0usize;
            while !s.is_done() {
                if s.position() >= cwin {
                    return Err(format!("position {} escaped the window", s.position()));
                }
                s.observe(&logits_from(rng), now);
                steps += 1;
                if steps > 2 * cwin {
                    return Err("session failed to terminate".into());
                }
            }
            let c = s.finish(now, steps);
            if c.tokens.len() > target.max(p) {
                return Err(format!("row {} exceeds target {target}", c.tokens.len()));
            }
            if c.tokens.len() >= p && c.tokens[..p] != prompt[..] {
                return Err("prompt prefix mutated".into());
            }
            if c.tokens.len() - p > max_new {
                return Err("generated more than max_new".into());
            }
            // The final generated token is never re-fed: at most target - 1
            // steps for a real prompt (degenerate requests take zero).  An
            // empty prompt burns one extra step on the position-0 dummy.
            let max_steps = if p == 0 { target } else { target.saturating_sub(1) };
            if steps > max_steps {
                return Err(format!("{steps} steps for target {target} (prompt {p})"));
            }
            Ok(())
        });
    }
}
