//! Per-request decode policy: greedy argmax, temperature softmax, and
//! top-k truncation over a logits row.
//!
//! Each in-flight request owns a [`Sampler`] seeded from its
//! [`SamplingParams`] and request id, so a request's output stream is a
//! pure function of `(policy, prompt)` no matter how the continuous-batch
//! scheduler interleaves it with other traffic — replaying a request in
//! isolation reproduces exactly what it got under load.

use crate::util::rng::Rng;

pub use crate::util::argmax;

/// Decode policy carried by each [`super::Request`].
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `<= 0.0` selects greedy argmax.
    pub temperature: f32,
    /// Keep only the k highest logits before sampling; `0` disables the
    /// cut.  Logits tied with the k-th largest are all kept.
    pub top_k: usize,
    /// Policy seed, mixed with the request id (see [`Sampler::for_request`]).
    pub seed: u64,
    /// Generation stops early when this token is emitted.
    pub stop_token: Option<i32>,
    /// Per-request opt-in to self-speculative decoding: when the engine
    /// has a draft model attached, this request's decode phase runs
    /// draft → verify → accept/rollback rounds instead of one token per
    /// fused step.  Only meaningful for greedy policies (speculative
    /// greedy is bit-identical to vanilla greedy, which is what makes it
    /// a pure perf win); the engine silently serves non-greedy opt-ins
    /// the vanilla way.
    pub speculative: bool,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, seed: 0, stop_token: None, speculative: false }
    }
}

impl SamplingParams {
    /// The policy the old engine hard-coded: plain argmax, no stop token.
    pub fn greedy() -> Self {
        Self::default()
    }

    /// Greedy with speculative decoding opted in — the draft/verify fast
    /// path when the engine carries a draft model, plain greedy otherwise.
    pub fn speculative_greedy() -> Self {
        Self { speculative: true, ..Self::default() }
    }

    /// Greedy either explicitly (temperature off) or degenerately (top-1).
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0 || self.top_k == 1
    }
}

/// Sampling state owned by one in-flight request.
#[derive(Clone, Debug)]
pub struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Self {
        let rng = Rng::new(params.seed);
        Self { params, rng }
    }

    /// Decorrelate the stream per request id so identical default policies
    /// on different requests don't emit identical token streams.  (`Rng`
    /// seeds through SplitMix64, so even consecutive mixed seeds diverge.)
    pub fn for_request(params: SamplingParams, id: u64) -> Self {
        let rng = Rng::new(params.seed.wrapping_add(id.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
        Self { params, rng }
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    pub fn is_stop(&self, tok: i32) -> bool {
        self.params.stop_token == Some(tok)
    }

    /// Draw the next token id from a logits row.
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        assert!(!logits.is_empty(), "empty logits row");
        if self.params.is_greedy() {
            return argmax(logits) as i32;
        }
        // Top-k cut: zero out everything strictly below the k-th largest.
        // O(V) selection, not a sort — this runs once per sampled token.
        let cut = if self.params.top_k > 0 && self.params.top_k < logits.len() {
            let mut scratch = logits.to_vec();
            let (_, kth, _) = scratch.select_nth_unstable_by(self.params.top_k - 1, |a, b| {
                b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal)
            });
            *kth
        } else {
            f32::NEG_INFINITY
        };
        // Softmax weights at temperature, max-shifted for stability; the
        // argmax always survives the cut, so the weights never all vanish.
        let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let inv_t = 1.0 / self.params.temperature as f64;
        let weights: Vec<f64> = logits
            .iter()
            .map(|&x| if x < cut { 0.0 } else { ((x - m) as f64 * inv_t).exp() })
            .collect();
        self.rng.weighted(&weights) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(SamplingParams::greedy());
        assert_eq!(s.sample(&[0.1, 2.0, -1.0, 1.9]), 1);
        assert_eq!(argmax(&[3.0, 3.0, 1.0]), 0, "ties go to the lowest index");
    }

    #[test]
    fn top1_is_greedy_at_any_temperature() {
        let p = SamplingParams { temperature: 5.0, top_k: 1, seed: 9, ..Default::default() };
        let mut s = Sampler::new(p);
        for _ in 0..20 {
            assert_eq!(s.sample(&[0.0, 4.0, 3.9]), 1);
        }
    }

    #[test]
    fn topk_never_samples_below_cut() {
        let mut s = Sampler::new(SamplingParams {
            temperature: 10.0,
            top_k: 2,
            seed: 3,
            ..Default::default()
        });
        // With huge temperature everything inside the cut is near-uniform;
        // indices 0 and 3 are outside the top-2 and must never appear.
        for _ in 0..200 {
            let t = s.sample(&[-5.0, 1.0, 2.0, -4.0]);
            assert!(t == 1 || t == 2, "sampled {t} outside top-k");
        }
    }

    #[test]
    fn temperature_prefers_heavy_logit() {
        let p = SamplingParams { temperature: 1.0, top_k: 0, seed: 4, ..Default::default() };
        let mut s = Sampler::new(p);
        let mut counts = [0usize; 2];
        for _ in 0..2000 {
            counts[s.sample(&[0.0, 2.5]) as usize] += 1;
        }
        assert!(counts[1] > counts[0] * 4, "counts {counts:?}");
    }

    #[test]
    fn deterministic_per_seed_and_id() {
        let p = SamplingParams { temperature: 0.8, top_k: 3, seed: 11, ..Default::default() };
        let logits = [0.3, 1.0, -0.2, 0.9, 0.0];
        let mut a = Sampler::for_request(p.clone(), 42);
        let mut b = Sampler::for_request(p.clone(), 42);
        let seq_a: Vec<i32> = (0..32).map(|_| a.sample(&logits)).collect();
        let seq_b: Vec<i32> = (0..32).map(|_| b.sample(&logits)).collect();
        assert_eq!(seq_a, seq_b, "same (seed, id) must replay identically");
        let mut c = Sampler::for_request(p, 43);
        let seq_c: Vec<i32> = (0..32).map(|_| c.sample(&logits)).collect();
        assert_ne!(seq_a, seq_c, "different ids must decorrelate");
    }

    #[test]
    fn stop_token_recognized() {
        let s = Sampler::new(SamplingParams { stop_token: Some(7), ..Default::default() });
        assert!(s.is_stop(7));
        assert!(!s.is_stop(8));
    }
}
