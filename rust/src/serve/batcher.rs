//! Dynamic request batcher: FIFO queue + admission policy in front of the
//! continuous-batching engine.
//!
//! Two admission granularities share one rule ([`Batcher::ready`]):
//! * [`Batcher::take_batch`] — wave admission, used by micro-benches and
//!   any caller that wants the classic batch-to-completion shape;
//! * [`Batcher::pop_admissible`] — slot-level admission, the continuous
//!   path: the engine pulls one request per freed KV lane *between decode
//!   steps*, so a request that finishes at step 10 hands its lane to the
//!   next waiter at step 11.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::sampling::SamplingParams;

#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub arrived: Instant,
    /// Per-request decode policy (greedy / temperature / top-k / stop).
    pub sampling: SamplingParams,
}

impl Request {
    /// A greedy-decode request — the policy every request had before
    /// sampling became per-request.
    pub fn greedy(id: u64, prompt: Vec<i32>, max_new: usize, arrived: Instant) -> Self {
        Self { id, prompt, max_new, arrived, sampling: SamplingParams::greedy() }
    }
}

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Wave size for `take_batch`; concurrency cap for slot-level admission.
    pub max_batch: usize,
    /// How long the oldest waiter may sit before admission fires anyway.
    pub max_wait: Duration,
}

/// FIFO queue + admission policy.  Thread-safe wrapper lives in the engine;
/// this core is synchronous and unit-testable.
pub struct Batcher {
    queue: VecDeque<Request>,
    policy: BatchPolicy,
    admitted: u64,
    enqueued: u64,
    removed: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { queue: VecDeque::new(), policy, admitted: 0, enqueued: 0, removed: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// The admission rule: release now when the queue is saturated
    /// (≥ max_batch waiting), when the oldest waiter exceeded max_wait, or
    /// when `drain` (closed request set / shutdown) is set.
    pub fn ready(&self, now: Instant, drain: bool) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.policy.max_batch || drain {
            return true;
        }
        now.duration_since(self.queue[0].arrived) >= self.policy.max_wait
    }

    /// The head-of-line request, without admitting it.  The engine's KV
    /// memory budget sizes the head's worst-case footprint before popping;
    /// when it doesn't fit, admission stops for the round (strict FIFO —
    /// no smaller request skips ahead, so a big prompt cannot starve).
    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// Slot-level admission: pop the head request iff the admission rule
    /// says it should run *now*.  The engine calls this once per free KV
    /// lane between decode steps.
    pub fn pop_admissible(&mut self, now: Instant, drain: bool) -> Option<Request> {
        if !self.ready(now, drain) {
            return None;
        }
        let req = self.queue.pop_front()?;
        self.admitted += 1;
        Some(req)
    }

    /// Wave admission: pop up to max_batch requests.
    pub fn take_batch(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<Request> = self.queue.drain(..n).collect();
        self.admitted += batch.len() as u64;
        batch
    }

    /// Pull a request out of the queue by id (cancellation of a waiter that
    /// never reached a KV lane).  Counted separately from admissions so the
    /// conservation invariant becomes `enqueued == admitted + removed`.
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        let i = self.queue.iter().position(|r| r.id == id)?;
        self.removed += 1;
        self.queue.remove(i)
    }

    /// Pull the *newest* waiter off the back of the queue — the migration
    /// path: when another engine drains this one's backlog, it takes the
    /// requests that have waited least (the head keeps its FIFO claim on
    /// the next local lane).  Counts as removed, like any other exit that
    /// is not a local admission.
    pub fn reclaim_newest(&mut self) -> Option<Request> {
        let req = self.queue.pop_back()?;
        self.removed += 1;
        Some(req)
    }

    /// (enqueued, admitted) — conservation check: nothing lost or duplicated.
    pub fn counters(&self) -> (u64, u64) {
        (self.enqueued, self.admitted)
    }

    /// Requests cancelled out of the queue before admission.
    pub fn removed(&self) -> u64 {
        self.removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn req(id: u64, t: Instant) -> Request {
        Request::greedy(id, vec![1], 4, t)
    }

    fn policy(b: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch: b, max_wait: Duration::from_millis(ms) }
    }

    #[test]
    fn releases_when_full() {
        let mut b = Batcher::new(policy(2, 1000));
        let now = Instant::now();
        b.push(req(1, now));
        assert!(!b.ready(now, false));
        b.push(req(2, now));
        assert!(b.ready(now, false));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn releases_on_timeout() {
        let mut b = Batcher::new(policy(8, 5));
        let past = Instant::now() - Duration::from_millis(50);
        b.push(req(1, past));
        assert!(b.ready(Instant::now(), false));
    }

    #[test]
    fn drain_releases_partial() {
        let mut b = Batcher::new(policy(8, 10_000));
        b.push(req(1, Instant::now()));
        assert!(b.ready(Instant::now(), true));
    }

    #[test]
    fn batch_caps_at_max() {
        let mut b = Batcher::new(policy(3, 0));
        let now = Instant::now();
        for i in 0..7 {
            b.push(req(i, now));
        }
        assert_eq!(b.take_batch().len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn pop_admissible_respects_policy() {
        let mut b = Batcher::new(policy(4, 10_000));
        let now = Instant::now();
        assert!(b.pop_admissible(now, true).is_none(), "empty queue never admits");
        b.push(req(1, now));
        // One fresh request, queue unsaturated, no drain: hold it back.
        assert!(b.pop_admissible(now, false).is_none());
        // Drain overrides the wait.
        let r = b.pop_admissible(now, true).unwrap();
        assert_eq!(r.id, 1);
        // Saturation admits without drain.
        for i in 2..6 {
            b.push(req(i, now));
        }
        assert_eq!(b.pop_admissible(now, false).unwrap().id, 2);
        // Timeout admits the aged head.
        let mut b2 = Batcher::new(policy(8, 5));
        b2.push(req(9, now - Duration::from_millis(50)));
        assert_eq!(b2.pop_admissible(now, false).unwrap().id, 9);
        let (enq, adm) = b2.counters();
        assert_eq!((enq, adm), (1, 1));
    }

    #[test]
    fn remove_cancels_waiters_and_counts() {
        let mut b = Batcher::new(policy(8, 0));
        let now = Instant::now();
        for i in 0..4 {
            b.push(req(i, now));
        }
        assert_eq!(b.remove(2).map(|r| r.id), Some(2));
        assert!(b.remove(2).is_none(), "already removed");
        assert!(b.remove(99).is_none(), "never enqueued");
        assert_eq!(b.len(), 3);
        // FIFO order of the survivors is preserved.
        let ids: Vec<u64> = std::iter::from_fn(|| b.pop_admissible(now, true)).map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 3]);
        let (enq, adm) = b.counters();
        assert_eq!(enq, adm + b.removed());
    }

    #[test]
    fn reclaim_newest_takes_the_back_and_counts_removed() {
        let mut b = Batcher::new(policy(8, 0));
        let now = Instant::now();
        for i in 0..3 {
            b.push(req(i, now));
        }
        // Migration drains from the back: newest waiters leave first,
        // the head keeps its FIFO claim.
        assert_eq!(b.reclaim_newest().map(|r| r.id), Some(2));
        assert_eq!(b.reclaim_newest().map(|r| r.id), Some(1));
        assert_eq!(b.pop_admissible(now, true).map(|r| r.id), Some(0));
        assert!(b.reclaim_newest().is_none(), "empty queue reclaims nothing");
        let (enq, adm) = b.counters();
        assert_eq!(enq, adm + b.removed());
        assert_eq!((enq, adm, b.removed()), (3, 1, 2));
    }

    #[test]
    fn conservation_property() {
        prop("batcher conserves requests", 20, |rng: &mut Rng| {
            let mut b = Batcher::new(policy(1 + rng.below(4), 0));
            let now = Instant::now();
            let mut seen = Vec::new();
            let mut next = 0u64;
            for _ in 0..100 {
                let u = rng.uniform();
                if u < 0.5 {
                    b.push(req(next, now));
                    next += 1;
                } else if u < 0.75 {
                    // Mix slot-level pops with wave takes.
                    if let Some(r) = b.pop_admissible(now, true) {
                        seen.push(r.id);
                    }
                } else if b.ready(now, true) {
                    for r in b.take_batch() {
                        seen.push(r.id);
                    }
                }
            }
            while let Some(r) = b.pop_admissible(now, true) {
                seen.push(r.id);
            }
            let (enq, adm) = b.counters();
            if enq != adm || seen.len() as u64 != enq {
                return Err(format!("enq {enq} adm {adm} seen {}", seen.len()));
            }
            // FIFO order, no duplicates
            for (i, w) in seen.windows(2).enumerate() {
                if w[1] <= w[0] {
                    return Err(format!("order violated at {i}"));
                }
            }
            Ok(())
        });
    }
}
