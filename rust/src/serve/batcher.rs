//! Dynamic request batcher: collects incoming generation requests into
//! micro-batches under a (max_batch, max_wait) policy — the standard
//! continuous-batching admission rule, scoped to the fixed-B decode
//! artifacts this runtime executes.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub arrived: Instant,
}

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

/// FIFO queue + admission policy.  Thread-safe wrapper lives in the engine;
/// this core is synchronous and unit-testable.
pub struct Batcher {
    queue: VecDeque<Request>,
    policy: BatchPolicy,
    admitted: u64,
    enqueued: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { queue: VecDeque::new(), policy, admitted: 0, enqueued: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.enqueued += 1;
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be released now?  Yes when full, or when the oldest
    /// waiter exceeded max_wait, or when `drain` (shutdown) is set.
    pub fn ready(&self, now: Instant, drain: bool) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.policy.max_batch || drain {
            return true;
        }
        now.duration_since(self.queue[0].arrived) >= self.policy.max_wait
    }

    /// Pop up to max_batch requests.
    pub fn take_batch(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<Request> = self.queue.drain(..n).collect();
        self.admitted += batch.len() as u64;
        batch
    }

    /// (enqueued, admitted) — conservation check: nothing lost or duplicated.
    pub fn counters(&self) -> (u64, u64) {
        (self.enqueued, self.admitted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn req(id: u64, t: Instant) -> Request {
        Request { id, prompt: vec![1], max_new: 4, arrived: t }
    }

    fn policy(b: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch: b, max_wait: Duration::from_millis(ms) }
    }

    #[test]
    fn releases_when_full() {
        let mut b = Batcher::new(policy(2, 1000));
        let now = Instant::now();
        b.push(req(1, now));
        assert!(!b.ready(now, false));
        b.push(req(2, now));
        assert!(b.ready(now, false));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn releases_on_timeout() {
        let mut b = Batcher::new(policy(8, 5));
        let past = Instant::now() - Duration::from_millis(50);
        b.push(req(1, past));
        assert!(b.ready(Instant::now(), false));
    }

    #[test]
    fn drain_releases_partial() {
        let mut b = Batcher::new(policy(8, 10_000));
        b.push(req(1, Instant::now()));
        assert!(b.ready(Instant::now(), true));
    }

    #[test]
    fn batch_caps_at_max() {
        let mut b = Batcher::new(policy(3, 0));
        let now = Instant::now();
        for i in 0..7 {
            b.push(req(i, now));
        }
        assert_eq!(b.take_batch().len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn conservation_property() {
        prop("batcher conserves requests", 20, |rng: &mut Rng| {
            let mut b = Batcher::new(policy(1 + rng.below(4), 0));
            let now = Instant::now();
            let mut seen = Vec::new();
            let mut next = 0u64;
            for _ in 0..100 {
                if rng.uniform() < 0.6 {
                    b.push(req(next, now));
                    next += 1;
                } else if b.ready(now, true) {
                    for r in b.take_batch() {
                        seen.push(r.id);
                    }
                }
            }
            while b.ready(now, true) {
                for r in b.take_batch() {
                    seen.push(r.id);
                }
            }
            let (enq, adm) = b.counters();
            if enq != adm || seen.len() as u64 != enq {
                return Err(format!("enq {enq} adm {adm} seen {}", seen.len()));
            }
            // FIFO order, no duplicates
            for (i, w) in seen.windows(2).enumerate() {
                if w[1] <= w[0] {
                    return Err(format!("order violated at {i}"));
                }
            }
            Ok(())
        });
    }
}
