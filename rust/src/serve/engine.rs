//! Serving engine: batched greedy generation over the KV-cache decode
//! artifacts, with the dynamic batcher + paged KV accounting in front.
//!
//! Single-threaded executor by design: the PJRT handles are not Sync, and
//! this box has one core — concurrency is expressed by the request queue,
//! not OS threads.  `serve_all` is the synchronous core the CLI demo,
//! example, and bench drive; a thread-owning wrapper would feed it from
//! channels without changing any of this logic.

use anyhow::{Context, Result};
use std::time::Instant;

use crate::model::params::ParamSet;
use crate::runtime::Runtime;
use crate::tensor::{Tensor, TensorI, Value};
use crate::util::Stopwatch;

use super::batcher::{BatchPolicy, Batcher, Request};
use super::kv::{KvConfig, KvManager};

#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_s: f64,
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub completed: usize,
    pub generated_tokens: usize,
    pub wall_s: f64,
    pub kv_peak_bytes: usize,
    pub batches: usize,
}

impl ServeMetrics {
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.generated_tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

pub struct Engine<'rt> {
    rt: &'rt Runtime,
    config: String,
    program: String,
    params: ParamSet,
    kv_cfg: KvConfig,
    batch_slots: usize,
}

impl<'rt> Engine<'rt> {
    /// `program` is a decode artifact (e.g. "decode_b8" or
    /// "decode_fac_r8_b8"); its cache input fixes batch size and rank.
    pub fn new(rt: &'rt Runtime, config: &str, program: &str, params: ParamSet) -> Result<Self> {
        let sig = rt.manifest().config(config)?.program(program)?.clone();
        let cache = sig.inputs.iter().find(|a| a.name.ends_with("_cache"))
            .context("decode program lacks a cache input")?;
        let (l, b, h, c, r) = (
            cache.shape[0], cache.shape[1], cache.shape[2], cache.shape[3], cache.shape[4],
        );
        Ok(Self {
            rt,
            config: config.into(),
            program: program.into(),
            params,
            kv_cfg: KvConfig {
                n_layers: l,
                n_heads: h,
                rank: r,
                max_positions: c,
                batch_slots: b,
            },
            batch_slots: b,
        })
    }

    pub fn kv_config(&self) -> &KvConfig {
        &self.kv_cfg
    }

    /// Serve a closed set of requests to completion through the batcher.
    /// Returns completions (same order as input) and aggregate metrics.
    pub fn serve_all(
        &self,
        requests: Vec<Request>,
        policy: BatchPolicy,
    ) -> Result<(Vec<Completion>, ServeMetrics)> {
        let sw = Stopwatch::new();
        let mut batcher = Batcher::new(policy);
        let n = requests.len();
        for r in requests {
            batcher.push(r);
        }
        let mut completions: Vec<Option<Completion>> = (0..n).map(|_| None).collect();
        let mut metrics = ServeMetrics::default();
        let mut kv = KvManager::new(self.kv_cfg.clone());

        while !batcher.is_empty() {
            if !batcher.ready(Instant::now(), true) {
                continue;
            }
            let batch = batcher.take_batch();
            metrics.batches += 1;
            let started = Instant::now();
            // Allocate KV slots for the micro-batch.
            let mut slots = Vec::with_capacity(batch.len());
            for r in &batch {
                slots.push(kv.allocate(r.id)?);
            }
            let rows = self.decode_batch(&batch, &mut kv, &slots)?;
            for ((req, row), slot) in batch.iter().zip(rows).zip(&slots) {
                metrics.generated_tokens += row.len().saturating_sub(req.prompt.len());
                completions[req.id as usize] = Some(Completion {
                    id: req.id,
                    tokens: row,
                    latency_s: started.elapsed().as_secs_f64()
                        + started.duration_since(req.arrived).as_secs_f64(),
                });
                kv.free(*slot)?;
            }
            metrics.completed += batch.len();
        }
        metrics.wall_s = sw.elapsed_s();
        metrics.kv_peak_bytes = kv.peak_bytes();
        let out = completions.into_iter().map(|c| c.expect("request lost")).collect();
        Ok((out, metrics))
    }

    /// One micro-batch of greedy decoding (prompt prefill token-by-token,
    /// then generation).  Returns full token rows per request.
    fn decode_batch(
        &self,
        batch: &[Request],
        kv: &mut KvManager,
        slots: &[usize],
    ) -> Result<Vec<Vec<i32>>> {
        let b = self.batch_slots;
        let c = self.kv_cfg.max_positions;
        let v = self.rt.manifest().config(&self.config)?.dim("vocab")?;
        let cache_shape = [
            self.kv_cfg.n_layers, b, self.kv_cfg.n_heads, c, self.kv_cfg.rank,
        ];
        let mut kc = Tensor::zeros(&cache_shape);
        let mut vc = Tensor::zeros(&cache_shape);
        let mut rows: Vec<Vec<i32>> = (0..b)
            .map(|i| batch.get(i).map(|r| r.prompt.clone()).unwrap_or_else(|| vec![0]))
            .collect();
        let want: Vec<usize> = (0..b)
            .map(|i| batch.get(i).map(|r| (r.prompt.len() + r.max_new).min(c)).unwrap_or(1))
            .collect();
        let total = want.iter().copied().max().unwrap_or(1);

        // §Perf: params are constant over the whole decode session — pay
        // the host→literal marshal once instead of per step.
        let param_values: Vec<Value> =
            self.params.flat().iter().map(|&t| Value::F32(t.clone())).collect();
        let prepared = self.rt.prepare(&param_values.iter().collect::<Vec<_>>())?;
        drop(param_values);

        for pos in 0..total {
            let toks: Vec<i32> = rows.iter()
                .map(|r| *r.get(pos).unwrap_or_else(|| r.last().unwrap_or(&0)))
                .collect();
            let args = vec![
                Value::F32(kc),
                Value::F32(vc),
                Value::I32(TensorI::new(vec![b], toks)),
                Value::I32(TensorI::scalar(pos as i32)),
            ];
            let mut outs = self.rt.run_prepared(&self.config, &self.program, &prepared, &args)?;
            vc = outs.pop().unwrap().into_f32()?;
            kc = outs.pop().unwrap().into_f32()?;
            let logits = outs.pop().unwrap().into_f32()?;
            for (i, row) in rows.iter_mut().enumerate() {
                if i < batch.len() && pos < want[i] {
                    kv.advance(slots[i])?;
                }
                if pos + 1 >= row.len() && row.len() < want[i] {
                    let base = i * v;
                    let mut best = 0usize;
                    let mut bestv = f32::NEG_INFINITY;
                    for j in 0..v {
                        let x = logits.data()[base + j];
                        if x > bestv {
                            bestv = x;
                            best = j;
                        }
                    }
                    row.push(best as i32);
                }
            }
        }
        rows.truncate(batch.len());
        Ok(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ops::init_params;
    use std::time::Duration;

    fn art() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn serves_batch_of_requests() {
        let rt = Runtime::new(&art()).expect("runtime");
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request {
                id: i,
                prompt: vec![1, 2, 3 + i as i32],
                max_new: 5,
                arrived: now,
            })
            .collect();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let (completions, metrics) = engine.serve_all(reqs, policy).unwrap();
        assert_eq!(completions.len(), 3);
        for (i, c) in completions.iter().enumerate() {
            assert_eq!(c.id, i as u64);
            assert_eq!(c.tokens.len(), 8); // 3 prompt + 5 new
            assert_eq!(&c.tokens[..2], &[1, 2]);
        }
        assert_eq!(metrics.completed, 3);
        assert_eq!(metrics.generated_tokens, 15);
        assert!(metrics.kv_peak_bytes > 0);
        assert!(metrics.tokens_per_s() > 0.0);
    }

    #[test]
    fn factorized_engine_kv_smaller() {
        let rt = Runtime::new(&art()).expect("runtime");
        let entry = rt.manifest().config("tiny").unwrap().clone();
        let dense = init_params(&rt, "tiny", 9).unwrap();
        let (fac, r) = crate::coordinator::ops::prune_to_ratio(&entry, &dense, 0.5, "clover")
            .unwrap();
        let dense_engine = Engine::new(&rt, "tiny", "decode_b8", dense).unwrap();
        let fac_engine =
            Engine::new(&rt, "tiny", &format!("decode_fac_r{r}_b8"), fac).unwrap();
        let d = dense_engine.kv_config().bytes_per_token();
        let f = fac_engine.kv_config().bytes_per_token();
        assert_eq!(f * 2, d, "rank-8 cache should be half of rank-16");
    }
}
