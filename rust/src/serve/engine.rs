//! Continuous-batching serving engine over a token-slab step API.
//!
//! The scheduler is slot-granular: every fused step runs all `B` batch
//! lanes of the fixed-shape step artifacts at once, and *between* steps
//! the engine retires finished sessions and admits queued requests into
//! the freed lanes (zero the lane, restart its position counter at 0).  A
//! request that finishes at step 10 hands its KV lane to the next waiter
//! at step 11 — no lane idles while the longest request in a wave drains,
//! which is exactly how pruned-rank KV savings turn into served traffic.
//!
//! Each iteration the engine builds a [`StepPlan`]: every live lane
//! contributes a *token slab* — the widest admissible chunk of unconsumed
//! prompt during prefill, the single fed-back token during decode — and
//! the plan dispatches to the artifact for the step's width (lanes with
//! narrower slabs pad by repeating their last `(token, position)` pair,
//! an idempotent rewrite).  A 64-token prompt therefore reaches its first
//! sampled token in `ceil(64/K)` steps instead of 64, *while its
//! neighbours keep decoding in the same fused steps* — chunked prefill is
//! the API default, not a special mode.
//!
//! Single-threaded executor by design: the PJRT handles are not Sync, and
//! this box has one core — concurrency is expressed by the request queue,
//! not OS threads.  `serve_all` is the synchronous closed-set core the CLI
//! demo, example, and bench drive.  The step loop is additionally
//! observable and steerable through [`StepHook`]: per-token/lifecycle
//! callbacks fire as they happen, cancellation orders retire sessions
//! between steps, and [`Engine::serve_open`] runs the same loop
//! open-ended, fed from channels by the thread-owning
//! [`crate::server`] gateway.
//!
//! An engine can additionally carry a *draft* backend at a lower CLOVER
//! rank ([`Engine::with_speculative`] / [`Engine::with_speculative_stub`])
//! for **self-speculative decoding**: opted-in greedy sessions run
//! draft → verify → accept/rollback rounds — the cheap rank-4 model
//! proposes up to K tokens over K width-1 draft steps, then one fused
//! target step scores the whole draft through the all-position logits of
//! the `prefill_k{K}` slab programs, accepting the longest greedy-matching
//! prefix plus one corrected token and rolling the rejected suffix back
//! ([`KvManager::rollback_to`]; the cache entries themselves need no
//! scrubbing — the per-position causal mask means a rejected position is
//! always rewritten before any later position can attend to it).  Greedy
//! speculative output is **bit-identical** to vanilla greedy decode, so
//! the dense steps-per-token drop below 1.0 is a pure perf win.
//!
//! Engines run on one of two backings: the compiled HLO artifacts through
//! [`crate::runtime::DecodeSession`] (production), or the deterministic
//! host-side [`crate::runtime::stub::StubModel`] ([`Engine::new_stub`]) so
//! every scheduling property — including the K=1 vs K=8 bit-identity of
//! chunked prefill and the speculative == vanilla greedy bit-identity —
//! is testable without a live PJRT backend.

use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use crate::model::params::ParamSet;
use crate::obs::{Clock, SpanEvent, SpanPoint, StepEvent};
use crate::runtime::stub::{FaultPlan, StepFault, StubModel, StubSpec};
use crate::runtime::{DecodeSession, Runtime};
use crate::tensor::{Tensor, Value};
use crate::util::argmax;

use super::batcher::{BatchPolicy, Batcher, Request};
use super::kv::{KvCodecSpec, KvConfig, KvManager, PagedKvStore, PAGE_TOKENS};
use super::prefix::PrefixCache;
use super::session::Session;

/// One finished request, with its own latency accounting: every duration
/// is measured against *this* request's arrival and completion, not the
/// wall time of whatever batch it shared lanes with.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    /// Prompt + generated tokens.
    pub tokens: Vec<i32>,
    /// Arrival → this request's own last token.
    pub latency_s: f64,
    /// Arrival → first *generated* token (== latency_s when nothing was
    /// generated).
    pub ttft_s: f64,
    /// Arrival → admission into a KV lane.
    pub queue_wait_s: f64,
    /// Fused steps this request occupied a lane for.
    pub steps: usize,
    /// Fused steps that consumed prompt tokens — `ceil(prompt/K)` under a
    /// K-wide chunk ladder vs `prompt` under single-token prefill.
    pub prefill_steps: usize,
    /// Engine-global decode-step counter at completion.
    pub finished_step: usize,
}

/// One lane's slab within a [`StepPlan`]: `len` row tokens starting at row
/// position `start` (positions `start..start+len` of the request).  `len <
/// plan.width` means the lane pads by repeating its last pair.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LaneSlab {
    pub id: u64,
    pub start: usize,
    pub len: usize,
}

/// The work order for one fused step: the slab width to dispatch (which
/// selects the artifact — `decode_*` at width 1, `prefill_k{W}_*` above)
/// and each lane's slab.  Built fresh every iteration from the live
/// sessions; prefill and decode lanes mix freely in one plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepPlan {
    pub width: usize,
    pub slabs: Vec<Option<LaneSlab>>,
}

impl StepPlan {
    /// Plan the next fused step: each live session asks for the widest
    /// admissible chunk of its pending row ([`chunk_width`]) — or its
    /// verify slab, when a speculative draft is ready — and the step
    /// dispatches at the maximum over lanes so nobody waits an extra step.
    ///
    /// `max_step_tokens` is the prefill-aware admission budget
    /// (`--max-step-tokens`): a cap on the summed slab tokens of one fused
    /// step.  Decode and verify lanes are latency-critical and always
    /// scheduled in full; prefill lanes share what remains in lane order,
    /// shrinking their chunks (down to a single token, and then to
    /// sitting the step out entirely on an idempotent pad pair) — so one
    /// giant prompt can no longer force every step to the widest slab and
    /// starve decode-lane latency.  At least one lane always makes
    /// progress, whatever the budget.
    pub fn build(
        widths: &[usize],
        lanes: &[Option<Session>],
        max_step_tokens: Option<usize>,
    ) -> StepPlan {
        let Some(budget) = max_step_tokens else {
            // Unbudgeted: the pre-budget planner, bit-for-bit.
            let mut width = 1;
            for s in lanes.iter().flatten() {
                width = width.max(match s.verify_len() {
                    Some(k) => fit_width(widths, k),
                    None => chunk_width(widths, s.pending()),
                });
            }
            let slabs = lanes
                .iter()
                .map(|l| {
                    l.as_ref().map(|s| match s.verify_len() {
                        Some(k) => LaneSlab { id: s.id(), start: s.position(), len: k },
                        None => {
                            let (slab, start) = s.next_slab(width);
                            LaneSlab { id: s.id(), start, len: slab.len() }
                        }
                    })
                })
                .collect();
            return StepPlan { width, slabs };
        };

        // Pass 1: the non-shrinkable contributions.
        let fixed: usize = lanes
            .iter()
            .flatten()
            .map(|s| match s.verify_len() {
                Some(k) => k,
                None if s.pending() == 1 => 1,
                None => 0,
            })
            .sum();
        let mut remaining = budget.max(1).saturating_sub(fixed);
        let mut progressed = fixed > 0;
        // Pass 2: prefill lanes shrink into the remainder, lane order.
        let slabs: Vec<Option<LaneSlab>> = lanes
            .iter()
            .map(|l| {
                l.as_ref().map(|s| {
                    let len = match s.verify_len() {
                        Some(k) => k,
                        None if s.pending() == 1 => 1,
                        None => {
                            // As much pending prompt as the remaining
                            // budget and the widest ladder step allow — a
                            // slab len need not be a ladder width (short
                            // slabs pad by repeat; [`fit_width`] picks the
                            // step width afterwards), so a prompt tail of
                            // 5 under a {1, 8} ladder still lands in one
                            // padded step, exactly like the unbudgeted
                            // planner.  A sit-out (len 0) only when the
                            // budget is spent — unless nothing else
                            // progresses this step.
                            let widest = widths.last().copied().unwrap_or(1);
                            let mut take = s.pending().min(remaining).min(widest);
                            if take == 0 && !progressed {
                                take = 1;
                            }
                            remaining = remaining.saturating_sub(take);
                            take
                        }
                    };
                    if len > 0 {
                        progressed = true;
                    }
                    LaneSlab { id: s.id(), start: s.position(), len }
                })
            })
            .collect();
        let widest = slabs.iter().flatten().map(|s| s.len).max().unwrap_or(1);
        StepPlan { width: fit_width(widths, widest.max(1)), slabs }
    }

    /// Total row tokens this plan consumes (pads excluded; a verify slab
    /// counts its full width — its accepted share is only known after the
    /// step).
    pub fn tokens(&self) -> usize {
        self.slabs.iter().flatten().map(|s| s.len).sum()
    }
}

/// The slab width a lane with `remaining` unconsumed row tokens asks for,
/// given the engine's width ladder (ascending, containing 1):
///
/// * the **widest** ladder width that fits entirely (`w <= remaining`) —
///   no padding waste when a big chunk fits;
/// * else the **narrowest** width above 1, padding the remainder in one
///   step rather than single-stepping it (`remaining = 5` under a
///   `{1, 8, 32}` ladder takes one padded 8-wide step, not five steps);
/// * 1 when the lane is decoding (`remaining == 1`) or the ladder has no
///   chunks.
pub fn chunk_width(widths: &[usize], remaining: usize) -> usize {
    debug_assert!(remaining >= 1);
    let mut best = 1;
    for &w in widths {
        if w <= remaining && w > best {
            best = w;
        }
    }
    if best == 1 && remaining > 1 {
        if let Some(&w) = widths.iter().filter(|&&w| w > 1).min() {
            best = w;
        }
    }
    best
}

/// The narrowest ladder width that fits a slab of `len` tokens in one
/// step (a verify slab must not be split across steps).  The engine caps
/// draft rounds at [`Engine::max_chunk`], so a fit always exists; the
/// widest-ladder fallback is defensive.
fn fit_width(widths: &[usize], len: usize) -> usize {
    widths
        .iter()
        .copied()
        .filter(|&w| w >= len)
        .min()
        .unwrap_or_else(|| widths.last().copied().unwrap_or(1))
}

/// Policy for self-speculative decode rounds (engine-level; requests opt
/// in per-request via [`super::SamplingParams::speculative`], greedy
/// only).
#[derive(Clone, Debug)]
pub struct SpecConfig {
    /// Initial (and maximum) draft length K: tokens the draft model
    /// proposes per round, scored by one fused target step.  Clamped to
    /// the engine's widest slab width at round start.
    pub draft_len: usize,
    /// Adaptive controller: halve K after a fully-rejected round (floor
    /// 2), double it back after a fully-accepted one (cap `draft_len`) —
    /// "shrink K when acceptance drops".
    pub adaptive: bool,
}

impl Default for SpecConfig {
    fn default() -> Self {
        Self { draft_len: 4, adaptive: true }
    }
}

/// Where an engine's draft (speculative proposal) steps execute.  Always
/// the same shape of backend as the target, one CLOVER rank down.
enum DraftBacking {
    /// Factored decode + slab programs at the draft rank, sharing the
    /// target's Runtime.
    Pjrt {
        /// `(width, program name)` — width 1 plus every target ladder
        /// width.
        programs: Vec<(usize, String)>,
        params: ParamSet,
    },
    Stub(StubSpec),
}

/// Draft backing + policy + the draft model's own KV geometry (its cache
/// is real memory too — the router charges a speculative engine for both
/// halves of the pair).
struct Speculative {
    draft: DraftBacking,
    cfg: SpecConfig,
    draft_kv: KvConfig,
}

/// How freed lanes are refilled.  [`Admission::Continuous`] is the engine's
/// normal mode; [`Admission::WaveToCompletion`] reproduces the old
/// batch-to-completion behavior (admit only when *all* lanes are free) and
/// exists so benches can measure exactly what slot-level scheduling buys.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Continuous,
    WaveToCompletion,
}

/// Why a request was retired without completing.  (Graceful shutdown is
/// deliberately *not* a reason: the gateway drains accepted work to
/// completion instead of cancelling it.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// Explicit client cancellation (a cancel token fired).
    User,
    /// The request's deadline expired before it finished.
    Deadline,
}

/// A cancellation order, applied by the step loop *between* decode steps:
/// the session retires, its partial tokens go out through the hook, and its
/// KV lane frees immediately — the next admission pass (same iteration,
/// before the next decode step) can hand the lane to a waiting request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cancellation {
    pub id: u64,
    pub reason: CancelReason,
}

/// Why a request reached the `Failed` terminal.  The distinction matters
/// to the supervisor above: a [`FailReason::Backend`] request died with
/// the engine and is *losslessly replayable* on a rebuilt one, while a
/// [`FailReason::Poisoned`] request failed individually on a healthy
/// engine — replaying it would just poison another lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailReason {
    /// The step backend died (fatal step error, or a transient fault that
    /// outlived the retry budget) and took every in-flight request with
    /// it.
    Backend,
    /// This lane's logits came back non-finite; the lane is quarantined
    /// ([`KvManager::quarantine`]) and only this request fails.
    Poisoned,
}

/// A failed backend step, classified for the retry layer: transient
/// faults are retried with exponential backoff under [`RetryPolicy`];
/// fatal errors (and transient ones that exhaust the budget) kill the
/// serve — every in-flight request fails with [`FailReason::Backend`]
/// and `serve_*` returns the underlying error for the supervisor.
///
/// Classification is by downcast: a
/// [`StepFault::Transient`](crate::runtime::stub::StepFault) anywhere in
/// the chain is transient; everything else — [`StepFault::Fatal`], PJRT
/// execution errors, shape mismatches — is fatal, because a step
/// executor gives no general way to tell a blip from a dead device, and
/// retrying an unknown error against a corrupt backend is worse than
/// failing over.
#[derive(Debug)]
pub enum StepError {
    /// Worth retrying: the backend is believed alive.
    Transient(anyhow::Error),
    /// The backend is gone (or the retry budget is spent).
    Fatal(anyhow::Error),
}

impl StepError {
    /// Classify a raw step error (see the type docs).
    pub fn classify(e: anyhow::Error) -> Self {
        match e.downcast_ref::<StepFault>() {
            Some(StepFault::Transient { .. }) => Self::Transient(e),
            _ => Self::Fatal(e),
        }
    }

    /// Unwrap the underlying error.
    pub fn into_inner(self) -> anyhow::Error {
        match self {
            Self::Transient(e) | Self::Fatal(e) => e,
        }
    }
}

/// Per-step retry policy for transient backend faults (`clover serve
/// --retry-budget N`): attempt `1 + budget` times total, sleeping
/// `backoff × 2^attempt` on the engine clock between attempts — on a
/// manual clock the backoff burns *virtual* time, so recovery tests and
/// benches are deterministic and instant.  Retrying a step is safe by
/// the same idempotence contract padding relies on: a failed step wrote
/// either nothing (the stub's fault model) or the same pure-function
/// values a retry rewrites, and session/KV state only advances after a
/// step succeeds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = fail fast).
    pub budget: usize,
    /// Initial backoff, doubled each retry.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { budget: 3, backoff: Duration::from_millis(1) }
    }
}

/// Per-step observer and control surface threaded through the engine loop.
///
/// The engine only *returns* finished [`Completion`]s; everything live —
/// admissions, per-token sampling, retirements — is invisible to a
/// `serve_all` caller until the drain ends.  A `StepHook` sees each of
/// those moments as it happens, which is what the `server::` layer turns
/// into per-request event streams, and feeds control back in: new requests
/// between steps (`poll_ingress`) and cancellation orders
/// (`take_cancellations`).  All methods default to no-ops so closed-set
/// serving pays nothing.
pub trait StepHook {
    /// New requests to enqueue, polled between decode steps (open-loop
    /// serving only).  `idle` is true when the engine has no live lanes and
    /// an empty queue — the hook may block until traffic arrives instead of
    /// spinning.  Return `None` once the ingress is closed for good: the
    /// engine drains what it has and returns.
    fn poll_ingress(&mut self, _idle: bool) -> Option<Vec<Request>> {
        None
    }

    /// Cancellation orders (fired cancel tokens + expired deadlines) to
    /// apply before the next decode step.
    fn take_cancellations(&mut self, _now: Instant) -> Vec<Cancellation> {
        Vec::new()
    }

    /// A request was admitted into KV lane `lane` after `step` fused
    /// steps — it contributes its first slab to the very next plan.
    fn on_started(&mut self, _id: u64, _lane: usize, _step: usize) {}

    /// A token was sampled for `id` at row position `pos` — delivered as it
    /// is sampled, not at wave end.
    fn on_token(&mut self, _id: u64, _pos: usize, _token: i32, _step: usize) {}

    /// A request finished; `completion` carries its full row + latencies.
    fn on_done(&mut self, _completion: &Completion) {}

    /// A request was cancelled; `tokens` is the partial row (prompt +
    /// whatever was generated before retirement).
    fn on_cancelled(&mut self, _id: u64, _tokens: Vec<i32>, _reason: CancelReason, _step: usize) {}

    /// A request failed terminally: the backend died under it
    /// ([`FailReason::Backend`] — the serve is about to return an error,
    /// and a supervisor may replay the request losslessly on a rebuilt
    /// engine) or its lane was quarantined after poisoned logits
    /// ([`FailReason::Poisoned`] — the engine keeps serving).  `tokens`
    /// is the partial row, like `on_cancelled`.
    fn on_failed(&mut self, _id: u64, _tokens: Vec<i32>, _reason: FailReason, _step: usize) {}

    /// Opt in to the observability taps below.  The engine only assembles
    /// [`StepEvent`]/[`SpanEvent`] payloads (lane census, token mix, KV
    /// accounting) when this returns true, so hooks that don't trace —
    /// including [`NoHook`] — pay nothing beyond this one call per step.
    fn wants_step_events(&self) -> bool {
        false
    }

    /// One fused (or draft) step executed; fires only when
    /// [`StepHook::wants_step_events`] is true.
    fn on_step(&mut self, _ev: &StepEvent) {}

    /// A request-span timeline point (queued/admitted/prefill chunk/first
    /// token/spec round/done/cancelled); fires only when
    /// [`StepHook::wants_step_events`] is true.
    fn on_span(&mut self, _ev: &SpanEvent) {}

    /// How many queued requests this engine may surrender to a
    /// coordinating scheduler right now (cross-engine queue migration).
    /// Polled between decode steps; `None` means keep everything.  The
    /// engine pops that many of its *newest* waiters
    /// ([`Batcher::reclaim_newest`] — the head keeps its FIFO claim on
    /// the next local lane) and hands each to [`StepHook::on_reclaimed`].
    fn reclaim_requests(&mut self) -> Option<usize> {
        None
    }

    /// A queued request was surrendered for migration.  The hook owns it
    /// now — re-submit it to another engine or fail it; the source
    /// engine counts it as migrated, neither completed nor cancelled.
    fn on_reclaimed(&mut self, _req: Request) {}
}

/// The no-op hook closed-set serving runs with.
pub struct NoHook;

impl StepHook for NoHook {}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub completed: usize,
    /// Requests retired early (cancel token or deadline expiry).
    pub cancelled: usize,
    /// Generated (non-prompt) tokens, including those streamed out by
    /// requests that were later cancelled mid-decode.
    pub generated_tokens: usize,
    pub wall_s: f64,
    pub kv_peak_bytes: usize,
    /// Cumulative KV bytes released over the serve — retired slots plus
    /// speculative-rollback page reclaims ([`KvManager::freed_bytes`]).
    /// With `kv_peak_bytes` this is the cache churn picture: how much KV
    /// the workload cycled through, not just how much it held at once.
    pub kv_freed_bytes: usize,
    /// Fused steps executed (each runs all batch lanes, at whatever slab
    /// width the step's plan selected).
    pub decode_steps: usize,
    /// Row tokens consumed across all fused steps (prompt chunks + fed-back
    /// tokens, padding excluded).  `slab_tokens / decode_steps` is the
    /// effective tokens-per-step the chunk ladder buys.
    pub slab_tokens: usize,
    /// Requests admitted into a lane (== completed after a full drain when
    /// nothing was cancelled).
    pub admissions: usize,
    /// Fused steps on the *draft* model (speculative rounds only; these
    /// run the cheap low-rank engine, not the dense target).
    pub draft_steps: usize,
    /// Draft → verify rounds completed.
    pub spec_rounds: usize,
    /// Tokens proposed by the draft model across all rounds.
    pub drafted_tokens: usize,
    /// Drafted tokens the target confirmed and the row kept.
    pub accepted_draft_tokens: usize,
    /// Drafted tokens rejected by a verify step and rolled back
    /// (KV positions reclaimed page-granularly).
    pub rollback_tokens: usize,
    /// Requests surrendered from the queue to a coordinating scheduler
    /// (cross-engine migration) — neither completed nor cancelled here.
    pub migrated: usize,
    /// Requests that reached the `Failed` terminal: lanes quarantined
    /// after poisoned logits, plus every request the backend's death took
    /// down.  Conserved alongside completed/cancelled/migrated:
    /// `completed + cancelled + migrated + failed == enqueued`.
    pub failed: usize,
    /// Step attempts that returned a backend fault (transient or fatal,
    /// target and draft alike).
    pub step_faults: usize,
    /// Transient-fault retries dispatched under the [`RetryPolicy`]
    /// (successful or not).
    pub step_retries: usize,
    /// KV lanes retired for the serve's lifetime after poisoned logits
    /// ([`KvManager::quarantine`]).
    pub quarantined_lanes: usize,
    /// Admissions that attached cached prefix blocks instead of
    /// prefilling them.
    pub prefix_hits: usize,
    /// Prompt tokens served from the prefix cache across all hits.
    pub prefix_hit_tokens: usize,
    /// Cumulative bytes released by prefix-cache eviction under the KV
    /// memory budget.
    pub prefix_evicted_bytes: usize,
    /// Bytes the prefix cache held at drain end.
    pub prefix_cached_bytes: usize,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
}

impl ServeMetrics {
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.generated_tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Fraction of drafted tokens the target accepted (0.0 when nothing
    /// was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted_tokens > 0 {
            self.accepted_draft_tokens as f64 / self.drafted_tokens as f64
        } else {
            0.0
        }
    }

    fn observe_latencies(&mut self, mut lat: Vec<f64>, mut ttft: Vec<f64>) {
        lat.sort_by(f64::total_cmp);
        ttft.sort_by(f64::total_cmp);
        self.latency_p50_s = percentile(&lat, 0.50);
        self.latency_p99_s = percentile(&lat, 0.99);
        self.ttft_p50_s = percentile(&ttft, 0.50);
        self.ttft_p99_s = percentile(&ttft, 0.99);
    }
}

/// Percentile by rounded linear index over an ascending-sorted slice
/// (`round((n-1)·q)`; 0.0 for empty) — so p50 of `[1,2,3,4]` is 3.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Where an engine's fused steps execute.
enum Backing<'rt> {
    /// Compiled HLO artifacts through PJRT: the width-1 decode program
    /// plus every `prefill_k{K}` sibling discovered in the manifest.
    Pjrt {
        rt: &'rt Runtime,
        config: String,
        /// `(width, program name)`, width 1 always present.
        programs: Vec<(usize, String)>,
        params: ParamSet,
    },
    /// Deterministic host-side stub model — the same step contract with
    /// no PJRT dependency (scheduling tests, step-count benches).
    Stub(StubSpec),
}

pub struct Engine<'rt> {
    backing: Backing<'rt>,
    kv_cfg: KvConfig,
    batch_slots: usize,
    vocab: usize,
    /// Slab-width ladder, ascending, always containing 1.
    widths: Vec<usize>,
    /// Draft model + policy for self-speculative decoding (None = vanilla
    /// engine).
    spec: Option<Speculative>,
    /// Prefill-aware admission budget: cap on one fused step's summed
    /// slab tokens (see [`StepPlan::build`]).
    max_step_tokens: Option<usize>,
    /// KV memory budget in bytes for admission: a request is only
    /// admitted when its worst-case page footprint — at the *codec's*
    /// compressed page size, target plus draft for a speculative pair —
    /// fits alongside the live pages (see [`Engine::with_kv_memory_budget`]).
    kv_memory_budget: Option<usize>,
    /// Radix prefix-cache block width in tokens (None = caching off; see
    /// [`Engine::with_prefix_cache`]).  Stub backing only.
    prefix_cache_block: Option<usize>,
    /// Transient-fault retry policy for every step dispatch (target,
    /// draft, and mirror steps alike); see [`RetryPolicy`].
    retry: RetryPolicy,
    /// Time source for every `now` the step loop takes (cancellation
    /// sweeps, TTFT/latency stamps, wall_s) and for trace timestamps.
    /// Wall by default; [`Engine::new_stub`] adopts the spec's clock so a
    /// manual clock shared with the stub's simulated delays puts the
    /// whole serve on one virtual timeline.
    clock: Clock,
}

impl<'rt> Engine<'rt> {
    /// `program` is a decode artifact (e.g. "decode_b8" or
    /// "decode_fac_r8_b8"); its cache input fixes batch size and rank.
    /// Chunked-prefill siblings (`prefill_k{K}_b{B}` /
    /// `prefill_fac_r{r}_k{K}_b{B}`) are discovered through the manifest's
    /// `prefill_chunks` and join the step ladder automatically — cap or
    /// disable them with [`Engine::with_prefill_chunk`].
    pub fn new(rt: &'rt Runtime, config: &str, program: &str, params: ParamSet) -> Result<Self> {
        let entry = rt.manifest().config(config)?;
        let sig = entry.program(program)?.clone();
        let vocab = entry.dim("vocab")?;
        let cache = sig.inputs.iter().find(|a| a.name.ends_with("_cache"))
            .context("decode program lacks a cache input")?;
        let (l, b, h, c, r) = (
            cache.shape[0], cache.shape[1], cache.shape[2], cache.shape[3], cache.shape[4],
        );
        // Discover the chunk ladder: "decode{mid}_b{B}" has prefill
        // siblings "prefill{mid}_k{K}_b{B}" sharing its cache block.
        let mut programs = vec![(1usize, program.to_string())];
        let mut widths = vec![1usize];
        if let Some(mid) = program
            .strip_prefix("decode")
            .and_then(|rest| rest.strip_suffix(&format!("_b{b}")))
        {
            for &ck in &entry.prefill_chunks {
                let name = format!("prefill{mid}_k{ck}_b{b}");
                if entry.programs.contains_key(&name) {
                    programs.push((ck, name));
                    widths.push(ck);
                }
            }
        }
        widths.sort_unstable();
        Ok(Self {
            backing: Backing::Pjrt {
                rt,
                config: config.into(),
                programs,
                params,
            },
            kv_cfg: KvConfig {
                n_layers: l,
                n_heads: h,
                rank: r,
                max_positions: c,
                batch_slots: b,
                codec: KvCodecSpec::Identity,
            },
            batch_slots: b,
            vocab,
            widths,
            spec: None,
            max_step_tokens: None,
            kv_memory_budget: None,
            prefix_cache_block: None,
            retry: RetryPolicy::default(),
            clock: Clock::wall(),
        })
    }

    /// An engine over the deterministic host-side stub model: identical
    /// scheduling (plans, admission, cancellation, KV accounting) with the
    /// step math replaced by [`StubModel`].  This is how the serving
    /// stack's behaviour — including chunked-prefill bit-identity — is
    /// exercised on machines and CI runners without a PJRT backend.
    pub fn new_stub(spec: StubSpec) -> Engine<'static> {
        let kv_cfg = KvConfig {
            n_layers: spec.n_layers,
            n_heads: spec.n_heads,
            rank: spec.rank,
            max_positions: spec.max_positions,
            batch_slots: spec.batch_slots,
            codec: KvCodecSpec::Identity,
        };
        let widths = spec.widths();
        let clock = spec.clock.clone();
        Engine {
            kv_cfg,
            batch_slots: spec.batch_slots,
            vocab: spec.vocab,
            widths,
            backing: Backing::Stub(spec),
            spec: None,
            max_step_tokens: None,
            kv_memory_budget: None,
            prefix_cache_block: None,
            retry: RetryPolicy::default(),
            clock,
        }
    }

    /// Replace the engine's time source (see the `clock` field).  Also
    /// rebinds any stub backings — target and attached draft — so their
    /// simulated delays burn the same timeline; call order relative to
    /// [`Engine::with_speculative_stub`] doesn't matter.
    pub fn with_clock(mut self, clock: Clock) -> Self {
        if let Backing::Stub(spec) = &mut self.backing {
            spec.clock = clock.clone();
        }
        if let Some(sp) = &mut self.spec {
            if let DraftBacking::Stub(spec) = &mut sp.draft {
                spec.clock = clock.clone();
            }
        }
        self.clock = clock;
        self
    }

    /// The engine's time source (shared with spawned traces and tests).
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Cap the slab ladder at `cap` tokens (`Some(1)` disables chunked
    /// prefill entirely; `None` keeps every discovered width).  The CLI
    /// exposes this as `clover serve --prefill-chunk N`.
    pub fn with_prefill_chunk(mut self, cap: Option<usize>) -> Self {
        if let Some(cap) = cap {
            let cap = cap.max(1);
            self.widths.retain(|&w| w <= cap);
            if let Backing::Pjrt { programs, .. } = &mut self.backing {
                programs.retain(|(w, _)| *w <= cap);
            }
        }
        self
    }

    /// Cap one fused step's summed slab tokens (prefill-aware admission,
    /// `clover serve --max-step-tokens N`): decode/verify lanes always
    /// run in full, prefill chunks shrink into the remainder — so a giant
    /// prompt cannot starve decode-lane latency.  `None` removes the cap;
    /// values are clamped to >= 1.
    pub fn with_max_step_tokens(mut self, cap: Option<usize>) -> Self {
        self.max_step_tokens = cap.map(|c| c.max(1));
        self
    }

    /// Store the KV cache through `codec` (`clover serve --kv-codec`,
    /// `--kv-layer-budgets`).  Per-layer rank budgets are validated here
    /// against the manifest-derived geometry (`n_layers` layers, budgets
    /// within `1..=rank`) — the same numbers the decode artifact's cache
    /// shape pinned at compile time.
    ///
    /// The codec governs byte accounting everywhere (admission, the
    /// router's per-token cost, peak/freed metrics), and on the stub
    /// backing it also governs *storage*: pages really hold
    /// `stored_rank(l)` floats ([`crate::runtime::stub::StubModel::with_codec`]).
    /// On a PJRT backing the device caches stay rank-r — compressed
    /// residency there lands with the factored at-rest layout in a later
    /// PR, so for compiled engines this is accounting-only today.
    pub fn with_kv_codec(mut self, codec: KvCodecSpec) -> Result<Self> {
        codec.resolve(self.kv_cfg.n_layers, self.kv_cfg.rank)?;
        self.kv_cfg.codec = codec;
        Ok(self)
    }

    /// Cap resident KV memory for admission (`clover serve
    /// --kv-memory-budget BYTES`): a queued request is only admitted when
    /// its worst-case footprint — `ceil(min(prompt+max_new, C) /
    /// PAGE_TOKENS)` pages at the codec's compressed page size, target
    /// plus draft for a speculative pair — fits next to the live pages.
    /// Admission is strict FIFO (head-of-line: when the head doesn't fit,
    /// nothing smaller skips ahead).  This is the lanes-at-fixed-memory
    /// lever: at a fixed budget, a factored codec admits proportionally
    /// more concurrent lanes.  `None` (the default) means batch slots are
    /// the only concurrency cap.
    pub fn with_kv_memory_budget(mut self, budget: Option<usize>) -> Self {
        self.kv_memory_budget = budget;
        self
    }

    /// Enable the radix prefix cache over the copy-on-write page store
    /// (`clover serve --prefix-cache-block N`): a completed prefill
    /// donates its leading `block`-token chunks to a trie, and later
    /// requests sharing that prompt prefix attach the cached KV pages at
    /// admission instead of prefilling them — bit-identical to a cold
    /// prefill, with zero bytes copied.  `block` must be a positive
    /// multiple of [`PAGE_TOKENS`]; under a `--kv-memory-budget` the
    /// cache's pages count against the budget and evict LRU-by-attention-
    /// mass before any admission is refused.
    ///
    /// Stub backing only today: compiled engines keep their caches
    /// device-side, where cross-lane page sharing lands together with
    /// the factored at-rest layout.  Mutually exclusive with speculative
    /// decoding (the draft cache has no shared pages to attach).
    pub fn with_prefix_cache(mut self, block: Option<usize>) -> Result<Self> {
        let Some(block) = block else {
            self.prefix_cache_block = None;
            return Ok(self);
        };
        if !matches!(self.backing, Backing::Stub(_)) {
            bail!(
                "--prefix-cache-block requires the stub backing — compiled engines \
                 keep their KV caches device-side, where cross-lane page sharing \
                 lands with the factored at-rest layout"
            );
        }
        if self.spec.is_some() {
            bail!(
                "prefix cache and speculative decoding are mutually exclusive on \
                 one engine (the draft cache has no shared pages to attach)"
            );
        }
        PrefixCache::new(block)?; // validates the PAGE_TOKENS alignment
        self.prefix_cache_block = Some(block);
        Ok(self)
    }

    /// The configured prefix-cache block width (None = caching off).
    pub fn prefix_cache_block(&self) -> Option<usize> {
        self.prefix_cache_block
    }

    /// Set the transient-fault retry policy (`clover serve
    /// --retry-budget N`): up to `retry.budget` re-dispatches of a
    /// failed step with exponential backoff starting at
    /// `retry.backoff`.  A failed step committed nothing — the KV
    /// cursor only advances and sessions only observe logits after a
    /// step returns Ok — so a retry re-runs the identical fused step.
    pub fn with_retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Arm a deterministic fault schedule on the stub target backing
    /// (`clover serve --fault-plan SPEC`): transient step errors,
    /// fatal backend death, latency spikes, and poisoned-logits rows,
    /// every one a pure function of `(plan.seed, step)` — see
    /// [`FaultPlan`].  Stub backing only: compiled engines fail on
    /// their own schedule.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Result<Self> {
        let Backing::Stub(spec) = &mut self.backing else {
            bail!("--fault-plan requires the stub backing — fault injection drives chaos tests, not devices");
        };
        spec.fault_plan = plan;
        Ok(self)
    }

    /// The retry policy in force (budget + base backoff).
    pub fn retry_policy(&self) -> RetryPolicy {
        self.retry
    }

    /// Batch lanes of the fixed-shape step artifacts — the fleet
    /// scheduler's saturation denominator.
    pub fn batch_slots(&self) -> usize {
        self.batch_slots
    }

    /// Attach a stub draft model for self-speculative decoding: opted-in
    /// greedy requests draft up to `cfg.draft_len` tokens per round on
    /// `draft` (typically the same seed at a lower rank — a spectrum
    /// truncation of the target) and the target verifies each round in
    /// one fused slab step.  Call after [`Engine::with_prefill_chunk`] so
    /// the ladder validation sees the final widths.
    pub fn with_speculative_stub(mut self, draft: StubSpec, cfg: SpecConfig) -> Result<Self> {
        if !matches!(self.backing, Backing::Stub(_)) {
            bail!("with_speculative_stub on a PJRT engine — use with_speculative");
        }
        self.validate_spec_cfg(&cfg)?;
        if draft.batch_slots != self.batch_slots {
            bail!(
                "draft has {} batch lanes, target has {} — lanes must mirror 1:1",
                draft.batch_slots,
                self.batch_slots
            );
        }
        if draft.max_positions != self.kv_cfg.max_positions {
            bail!("draft context window differs from the target's");
        }
        let dw = draft.widths();
        for w in &self.widths {
            if !dw.contains(w) {
                bail!("draft ladder {dw:?} lacks the target step width {w}");
            }
        }
        let draft_kv = KvConfig {
            n_layers: draft.n_layers,
            n_heads: draft.n_heads,
            rank: draft.rank,
            max_positions: draft.max_positions,
            batch_slots: draft.batch_slots,
            // The draft cache already sits at the pruned rank (it *is* the
            // truncated model) — it stores identity pages.
            codec: KvCodecSpec::Identity,
        };
        let mut draft = draft;
        draft.clock = self.clock.clone();
        self.spec = Some(Speculative { draft: DraftBacking::Stub(draft), cfg, draft_kv });
        Ok(self)
    }

    /// Attach a compiled draft engine (PJRT backing): `draft_program` is
    /// the draft's width-1 decode artifact at the lower rank (e.g.
    /// "decode_fac_r4_b8"); its `prefill_fac_*` slab siblings are resolved
    /// for every target ladder width.  Requires the target's slab
    /// programs to emit all-position logits (manifests exported with
    /// `verify_widths`) — last-position-only artifacts cannot score a
    /// draft.  Call after [`Engine::with_prefill_chunk`].
    pub fn with_speculative(
        mut self,
        draft_program: &str,
        draft_params: ParamSet,
        cfg: SpecConfig,
    ) -> Result<Self> {
        self.validate_spec_cfg(&cfg)?;
        let (programs, draft_kv) = {
            let Backing::Pjrt { rt, config, programs: target_programs, .. } = &self.backing
            else {
                bail!("with_speculative on a stub engine — use with_speculative_stub");
            };
            let entry = rt.manifest().config(config)?;
            // The verify contract: every chunked target width must be
            // advertised in the manifest's `verify_widths` (exported
            // alongside the all-position logits change) AND actually emit
            // logits at all K slab positions ([B, K, V]) — the advertised
            // list gates cleanly on old manifests, the shape check guards
            // against a stale or hand-edited manifest disagreeing with
            // its artifacts.
            for (w, name) in target_programs {
                if *w == 1 {
                    continue;
                }
                if !entry.verify_widths.contains(w) {
                    bail!(
                        "{config}: width {w} is not in the manifest's verify_widths \
                         {:?} — re-export the artifacts to enable speculation",
                        entry.verify_widths
                    );
                }
                let lg = &entry.program(name)?.outputs[0];
                if lg.shape.len() != 3 {
                    bail!(
                        "{config}/{name}: logits {:?} are last-position only despite \
                         verify_widths — the manifest disagrees with its artifacts",
                        lg.shape
                    );
                }
            }
            let dsig = entry.program(draft_program)?;
            let cache = dsig
                .inputs
                .iter()
                .find(|a| a.name.ends_with("_cache"))
                .context("draft decode program lacks a cache input")?;
            let (l, b, h, c, r) = (
                cache.shape[0],
                cache.shape[1],
                cache.shape[2],
                cache.shape[3],
                cache.shape[4],
            );
            if b != self.batch_slots {
                bail!("draft has {b} batch lanes, target has {}", self.batch_slots);
            }
            if c != self.kv_cfg.max_positions {
                bail!("draft context window {c} differs from the target's");
            }
            let mid = draft_program
                .strip_prefix("decode")
                .and_then(|rest| rest.strip_suffix(&format!("_b{b}")))
                .with_context(|| format!("{draft_program:?} is not a decode_*_b{b} program"))?;
            let mut programs = vec![(1usize, draft_program.to_string())];
            for &w in &self.widths {
                if w == 1 {
                    continue;
                }
                let name = format!("prefill{mid}_k{w}_b{b}");
                if !entry.programs.contains_key(&name) {
                    bail!("draft lacks the width-{w} slab program {name:?}");
                }
                programs.push((w, name));
            }
            let draft_kv = KvConfig {
                n_layers: l,
                n_heads: h,
                rank: r,
                max_positions: c,
                batch_slots: b,
                codec: KvCodecSpec::Identity,
            };
            (programs, draft_kv)
        };
        let draft = DraftBacking::Pjrt { programs, params: draft_params };
        self.spec = Some(Speculative { draft, cfg, draft_kv });
        Ok(self)
    }

    fn validate_spec_cfg(&self, cfg: &SpecConfig) -> Result<()> {
        if self.prefix_cache_block.is_some() {
            bail!(
                "prefix cache and speculative decoding are mutually exclusive on \
                 one engine (the draft cache has no shared pages to attach)"
            );
        }
        if cfg.draft_len < 2 {
            bail!("SpecConfig.draft_len must be >= 2 (a 1-token draft cannot beat a step)");
        }
        if self.max_chunk() < 2 {
            bail!(
                "speculative decoding needs a chunked slab ladder to verify with \
                 (widths {:?} have no width >= 2 — check --prefill-chunk)",
                self.widths
            );
        }
        Ok(())
    }

    /// Does this engine carry a draft model (speculative pair)?
    pub fn speculative(&self) -> bool {
        self.spec.is_some()
    }

    /// The draft model's KV geometry, when speculative.
    pub fn draft_kv_config(&self) -> Option<&KvConfig> {
        self.spec.as_ref().map(|s| &s.draft_kv)
    }

    /// Per-token KV cost of everything this engine keeps resident: the
    /// target cache plus, for a speculative pair, the draft cache — the
    /// router's weight ("a draft+verify pair consumes two engines").
    pub fn kv_bytes_per_token_total(&self) -> usize {
        self.kv_cfg.bytes_per_token()
            + self.spec.as_ref().map_or(0, |s| s.draft_kv.bytes_per_token())
    }

    /// The slab-width ladder this engine plans over (ascending, starts
    /// at 1).
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// Widest slab a single step can consume (1 = chunking disabled).
    pub fn max_chunk(&self) -> usize {
        self.widths.last().copied().unwrap_or(1)
    }

    pub fn kv_config(&self) -> &KvConfig {
        &self.kv_cfg
    }

    /// Serve a closed set of requests to completion with continuous
    /// (slot-level) batching.  Completions come back in input order, keyed
    /// by id — ids may be arbitrary u64s, but must be unique within a call.
    pub fn serve_all(
        &self,
        requests: Vec<Request>,
        policy: BatchPolicy,
    ) -> Result<(Vec<Completion>, ServeMetrics)> {
        self.serve_with(requests, policy, Admission::Continuous)
    }

    /// [`Engine::serve_all`] with an explicit admission mode (benches use
    /// [`Admission::WaveToCompletion`] as the before-refactor baseline).
    pub fn serve_with(
        &self,
        requests: Vec<Request>,
        policy: BatchPolicy,
        admission: Admission,
    ) -> Result<(Vec<Completion>, ServeMetrics)> {
        self.serve_hooked(requests, policy, admission, &mut NoHook)
    }

    /// Closed-set serving with a per-step observer: identical scheduling to
    /// [`Engine::serve_with`] (a [`NoHook`] hook reproduces it bit-for-bit),
    /// plus streamed `on_token`/`on_done` callbacks and cancellation orders
    /// applied between decode steps.
    pub fn serve_hooked(
        &self,
        requests: Vec<Request>,
        policy: BatchPolicy,
        admission: Admission,
        hook: &mut dyn StepHook,
    ) -> Result<(Vec<Completion>, ServeMetrics)> {
        self.serve_core(requests, policy, admission, hook, false)
    }

    /// Open-loop serving: the thread-owning `server::` gateway's entry
    /// point.  Requests arrive through `hook.poll_ingress` between decode
    /// steps (blocking when the engine is idle) until the hook closes the
    /// ingress, after which the engine drains and returns its metrics.
    /// Completions are delivered exclusively through `hook.on_done` /
    /// `hook.on_cancelled` — no per-request rows are retained (only the
    /// id-uniqueness set and per-completion latency samples for the final
    /// percentiles grow with traffic).
    pub fn serve_open(&self, policy: BatchPolicy, hook: &mut dyn StepHook) -> Result<ServeMetrics> {
        let (_, metrics) = self.serve_core(Vec::new(), policy, Admission::Continuous, hook, true)?;
        Ok(metrics)
    }

    fn serve_core(
        &self,
        initial: Vec<Request>,
        policy: BatchPolicy,
        admission: Admission,
        hook: &mut dyn StepHook,
        open: bool,
    ) -> Result<(Vec<Completion>, ServeMetrics)> {
        if policy.max_batch == 0 {
            bail!("BatchPolicy.max_batch must be >= 1");
        }
        let order: Vec<u64> = initial.iter().map(|r| r.id).collect();
        let mut uniq = HashSet::new();
        for id in &order {
            if !uniq.insert(*id) {
                bail!("duplicate request id {id}");
            }
        }

        let t_origin = self.clock.now();
        // Observability taps are assembled only when the hook asks
        // (TraceSink and friends); NoHook serving skips every payload.
        let wants_obs = hook.wants_step_events();
        let b = self.batch_slots;
        let cap = policy.max_batch.min(b);
        let cwin = self.kv_cfg.max_positions;
        let mut batcher = Batcher::new(policy);
        for r in initial {
            if r.prompt.is_empty() {
                bail!("request {}: empty prompt — rejected at admission", r.id);
            }
            batcher.push(r);
        }
        let mut kv = KvManager::new(self.kv_cfg.clone());
        // Resident bytes per KV page under the configured codec(s): the
        // target's compressed pages plus, for a draft+verify pair, the
        // draft's — both caches pin pages for every resident position, so
        // budget admission accounts both codecs.
        let resident_page_bytes = self.kv_cfg.bytes_per_page()
            + self.spec.as_ref().map_or(0, |s| s.draft_kv.bytes_per_page());
        // Worst-case page reservations per resident request id.  Budget
        // admission checks reservations, not current live pages: a freshly
        // admitted session holds zero pages until its first step, and its
        // claim on the budget must already be visible to the next waiter.
        let mut kv_reservations: HashMap<u64, usize> = HashMap::new();
        // The radix prefix cache and its per-lane bookkeeping: the trie
        // path each lane pinned (kept resident until the lane retires)
        // and the store-side column attaches deferred until after lane
        // zeroing.
        let mut prefix = match self.prefix_cache_block {
            Some(block) => Some(PrefixCache::new(block)?),
            None => None,
        };
        let mut lane_pins: Vec<Vec<usize>> = vec![Vec::new(); b];
        let mut pending_attach: Vec<(usize, Vec<usize>)> = Vec::new();
        let target_page_bytes = self.kv_cfg.bytes_per_page();
        let mut lanes: Vec<Option<Session>> = (0..b).map(|_| None).collect();
        let mut done: HashMap<u64, Completion> = HashMap::new();
        let mut metrics = ServeMetrics::default();
        let (mut lat, mut ttfts): (Vec<f64>, Vec<f64>) = (Vec::new(), Vec::new());
        let mut ingress_open = open;

        // Build the step backend.  PJRT: params marshalled once, KV caches
        // literal-side across the whole loop (host round-trips only on
        // lane churn), every ladder width sharing that one cache set.
        let mut backend = match &self.backing {
            Backing::Pjrt { rt, config, programs, params } => {
                let param_values: Vec<Value> =
                    params.flat().iter().map(|&t| Value::F32(t.clone())).collect();
                StepBackend::Pjrt(DecodeSession::new_planned(rt, config, programs, &param_values)?)
            }
            // The stub holds real host-side page storage through the
            // engine's codec — compression is exercised, not just counted.
            Backing::Stub(spec) => {
                StepBackend::Stub(StubModel::with_codec(spec.clone(), self.kv_cfg.codec.clone())?)
            }
        };
        // The draft backend for self-speculative decoding: same step
        // contract, one rank down, its own carried cache set.  Every
        // target step a speculating session participates in is mirrored
        // here so the draft's KV stays a replica of the target's.
        let mut draft_backend = match &self.spec {
            None => None,
            Some(sp) => Some(match &sp.draft {
                DraftBacking::Stub(spec) => StepBackend::Stub(StubModel::new(spec.clone())),
                DraftBacking::Pjrt { programs, params } => {
                    let Backing::Pjrt { rt, config, .. } = &self.backing else {
                        bail!("PJRT draft attached to a stub engine");
                    };
                    let vals: Vec<Value> =
                        params.flat().iter().map(|&t| Value::F32(t.clone())).collect();
                    StepBackend::Pjrt(DecodeSession::new_planned(rt, config, programs, &vals)?)
                }
            }),
        };

        loop {
            // ---- ingress: accept new work between decode steps ----
            if ingress_open {
                let idle = batcher.is_empty() && lanes.iter().all(|l| l.is_none());
                match hook.poll_ingress(idle) {
                    None => ingress_open = false,
                    Some(reqs) => {
                        for r in reqs {
                            if !uniq.insert(r.id) {
                                bail!("duplicate request id {}", r.id);
                            }
                            if r.prompt.is_empty() {
                                bail!("request {}: empty prompt — rejected at admission", r.id);
                            }
                            batcher.push(r);
                        }
                    }
                }
            }
            if !ingress_open && batcher.is_empty() && lanes.iter().all(|l| l.is_none()) {
                break; // drained
            }

            let now = self.clock.now();
            // ---- cancellation: retire sessions between decode steps ----
            // A cancelled lane frees *before* this iteration's admission
            // pass, so a waiting request reclaims it without skipping a
            // decode step.
            for c in hook.take_cancellations(now) {
                let lane = lanes
                    .iter()
                    .position(|l| l.as_ref().is_some_and(|s| s.id() == c.id));
                if let Some(lane) = lane {
                    let sess = lanes[lane].take().expect("lane occupied");
                    // A cache-attached lane releases its column
                    // references right here: the trie keeps its own refs
                    // (shared pages survive), the lane's pins drop so
                    // eviction may take unpinned blocks, and a cancelled
                    // mid-prefill attach leaves no dangling claim.
                    if let Some(trie) = prefix.as_mut() {
                        trie.unpin(&lane_pins[lane]);
                        lane_pins[lane].clear();
                        if let Some(store) = backend.stub_store_mut() {
                            store.zero_lane(lane);
                        }
                    }
                    kv.free(sess.slot())?;
                    kv_reservations.remove(&c.id);
                    metrics.cancelled += 1;
                    let gen = sess.generated();
                    metrics.generated_tokens += gen;
                    hook.on_cancelled(c.id, sess.into_tokens(), c.reason, metrics.decode_steps);
                    if wants_obs {
                        hook.on_span(&SpanEvent {
                            id: c.id,
                            t_s: self.clock.secs_since_epoch(now),
                            point: SpanPoint::Cancelled { generated: gen },
                        });
                    }
                } else if let Some(req) = batcher.remove(c.id) {
                    metrics.cancelled += 1;
                    let arrived = req.arrived;
                    hook.on_cancelled(c.id, req.prompt, c.reason, metrics.decode_steps);
                    if wants_obs {
                        // Cancelled while still queued: open the span at
                        // its arrival stamp so the timeline still shows
                        // the queue wait the request paid.
                        hook.on_span(&SpanEvent {
                            id: c.id,
                            t_s: self.clock.secs_since_epoch(arrived),
                            point: SpanPoint::Queued,
                        });
                        hook.on_span(&SpanEvent {
                            id: c.id,
                            t_s: self.clock.secs_since_epoch(now),
                            point: SpanPoint::Cancelled { generated: 0 },
                        });
                    }
                }
                // Unknown or already-finished id: completion won the race.
            }

            // ---- migration: surrender queued work between decode steps ----
            // A coordinating hook (the fleet scheduler) may drain this
            // engine's backlog for an idle rank-variant engine.  Waiters
            // leave from the *back* of the queue — the head keeps its
            // FIFO claim on the next local lane — and count as migrated:
            // conserved, but neither completed nor cancelled here.
            if let Some(max) = hook.reclaim_requests() {
                for _ in 0..max {
                    let Some(req) = batcher.reclaim_newest() else { break };
                    metrics.migrated += 1;
                    hook.on_reclaimed(req);
                }
            }

            // ---- admission: refill freed lanes between decode steps ----
            let mut live = lanes.iter().filter(|l| l.is_some()).count();
            let gate_open = match admission {
                Admission::Continuous => true,
                Admission::WaveToCompletion => live == 0,
            };
            let mut fresh: Vec<usize> = Vec::new();
            if gate_open {
                while live < cap && kv.free_slots() > 0 {
                    // Admit whenever capacity exists: a fused decode step
                    // runs all B lanes whether occupied or not, so holding a
                    // waiter back never helps (max_wait is a wave-admission
                    // knob; slot-level admission ignores it).
                    //
                    // Under a KV memory budget, capacity additionally means
                    // the head request's worst-case page footprint — at the
                    // codec's compressed page size, target + draft — fits
                    // next to the live pages.  Head-of-line on purpose: a
                    // too-big head stops the round, nothing skips it.
                    if let Some(budget) = self.kv_memory_budget {
                        let Some(head) = batcher.peek() else { break };
                        let worst = (head.prompt.len() + head.max_new).min(cwin);
                        let need = worst.div_ceil(PAGE_TOKENS) * resident_page_bytes;
                        let head_id = head.id;
                        let reserved: usize = kv_reservations.values().sum();
                        // Prefix-cache pages share the budget with the
                        // live reservations; the cache yields first — it
                        // is a performance opportunist, never a reason
                        // to keep a request queued.
                        let mut in_use =
                            reserved * resident_page_bytes + kv.cache_pages() * target_page_bytes;
                        if in_use + need > budget {
                            if let Some(trie) = prefix.as_mut() {
                                let short = (in_use + need - budget).div_ceil(target_page_bytes);
                                let cols = trie.evict(short);
                                if !cols.is_empty() {
                                    if let Some(store) = backend.stub_store_mut() {
                                        store.release_cols(&cols);
                                    }
                                    kv.cache_release(cols.len())?;
                                    metrics.prefix_evicted_bytes +=
                                        cols.len() * target_page_bytes;
                                }
                                in_use = reserved * resident_page_bytes
                                    + kv.cache_pages() * target_page_bytes;
                            }
                        }
                        if in_use + need > budget {
                            if live == 0 && kv.cache_pages() == 0 {
                                bail!(
                                    "request {head_id} needs {need} KV bytes worst-case — over \
                                     the {budget}-byte budget even on an empty cache"
                                );
                            }
                            break;
                        }
                    }
                    let Some(req) = batcher.pop_admissible(now, true) else { break };
                    kv_reservations.insert(
                        req.id,
                        (req.prompt.len() + req.max_new).min(cwin).div_ceil(PAGE_TOKENS),
                    );
                    let slot = kv.allocate(req.id)?;
                    // Per-request speculative opt-in: greedy + flagged +
                    // an engine that carries a draft model.  Non-greedy
                    // opt-ins serve the vanilla way (speculative greedy is
                    // bit-identical to vanilla greedy; sampled decode has
                    // no such identity to preserve).
                    let wants_spec = req.sampling.speculative && req.sampling.is_greedy();
                    let arrived = req.arrived;
                    let mut sess = Session::new(req, slot, cwin, now);
                    if let (true, Some(sp)) = (wants_spec, &self.spec) {
                        sess.enable_spec(sp.cfg.draft_len, sp.cfg.adaptive);
                    }
                    metrics.admissions += 1;
                    hook.on_started(sess.id(), slot, metrics.decode_steps);
                    if wants_obs {
                        hook.on_span(&SpanEvent {
                            id: sess.id(),
                            t_s: self.clock.secs_since_epoch(arrived),
                            point: SpanPoint::Queued,
                        });
                        hook.on_span(&SpanEvent {
                            id: sess.id(),
                            t_s: self.clock.secs_since_epoch(now),
                            point: SpanPoint::Admitted { lane: slot },
                        });
                    }
                    if sess.is_done() {
                        // Nothing to decode (max_new == 0 or the prompt
                        // already fills the window): complete immediately.
                        kv.free(slot)?;
                        kv_reservations.remove(&sess.id());
                        metrics.completed += 1;
                        let c = sess.finish(now, metrics.decode_steps);
                        lat.push(c.latency_s);
                        ttfts.push(c.ttft_s);
                        hook.on_done(&c);
                        if wants_obs {
                            hook.on_span(&SpanEvent {
                                id: c.id,
                                t_s: self.clock.secs_since_epoch(now),
                                point: SpanPoint::Done { generated: 0 },
                            });
                        }
                        if !open {
                            done.insert(c.id, c);
                        }
                        continue;
                    }
                    // Prefix-cache attach: walk the trie over the prompt,
                    // capped one token short — the last prompt token must
                    // prefill, that step produces the first logits.  The
                    // manager charges zero live pages for the shared
                    // prefix; the store-side column attach is deferred
                    // until after lane zeroing below.
                    if let Some(trie) = prefix.as_mut() {
                        let m = trie.lookup(sess.tokens(), sess.prompt_len() - 1);
                        if m.tokens > 0 {
                            kv.attach_prefix(slot, m.tokens / PAGE_TOKENS)?;
                            trie.pin(&m.path);
                            lane_pins[slot] = m.path;
                            sess.attach_prefix(m.tokens);
                            pending_attach.push((slot, m.cols));
                            metrics.prefix_hits += 1;
                            metrics.prefix_hit_tokens += m.tokens;
                            if wants_obs {
                                hook.on_span(&SpanEvent {
                                    id: sess.id(),
                                    t_s: self.clock.secs_since_epoch(now),
                                    point: SpanPoint::PrefixHit { tokens: m.tokens },
                                });
                            }
                        }
                    }
                    lanes[slot] = Some(sess);
                    fresh.push(slot);
                    live += 1;
                }
            }
            if lanes.iter().all(|l| l.is_none()) {
                if batcher.is_empty() {
                    if ingress_open {
                        continue; // back to a blocking ingress poll
                    }
                    break; // everything completed at admission time
                }
                // Every lane retired poisoned: nothing queued can ever be
                // admitted again.  Fail the backlog (each request gets
                // its terminal event) before reporting the engine dead.
                if kv.quarantined() == b {
                    fail_all(
                        &mut lanes,
                        &mut batcher,
                        &mut kv,
                        &mut kv_reservations,
                        &mut prefix,
                        &mut lane_pins,
                        &mut metrics,
                        hook,
                        &self.clock,
                        wants_obs,
                    );
                    bail!("all {b} KV lanes quarantined — backend unusable");
                }
                bail!("scheduler stalled: free lanes but nothing admissible");
            }
            // Zero re-assigned lanes so no stale KV rows survive a slot
            // handoff — in the draft caches too, which a previous
            // occupant's drafting or mirroring may have written.  Skipped
            // before the first step (caches are zeros), and costs one host
            // round-trip per churn event — not per token.
            if metrics.decode_steps + metrics.draft_steps > 0 && !fresh.is_empty() {
                backend.zero_lanes(&fresh)?;
                if let Some(draft) = draft_backend.as_mut() {
                    draft.zero_lanes(&fresh)?;
                }
            }
            // Store-side prefix attach, strictly after lane zeroing so a
            // re-used lane's stale columns never leak into the shared
            // mapping (the manager/session bookkeeping above is
            // ordering-free; the store attach is what the stub reads).
            if !pending_attach.is_empty() {
                if let Some(store) = backend.stub_store_mut() {
                    for (lane, cols) in pending_attach.drain(..) {
                        store.attach_prefix(lane, &cols)?;
                    }
                }
                pending_attach.clear();
            }

            // ---- speculative rounds: open drafts, run draft micro-steps ----
            // Decode-ready opted-in sessions open a round; while any lane
            // is mid-draft, iterations dispatch width-1 steps on the cheap
            // draft model only (the loop re-polls ingress and applies
            // cancellations between draft steps, so a cancel or deadline
            // landing mid-draft retires the lane exactly like mid-prefill).
            if self.spec.is_some() {
                let max_k = self.max_chunk();
                for sess in lanes.iter_mut().flatten() {
                    if let Some(k) = sess.spec_round_len(max_k) {
                        sess.begin_draft(k);
                    }
                }
                if lanes.iter().flatten().any(|s| s.drafting()) {
                    let draft = draft_backend.as_mut().expect("spec engines carry a draft");
                    let step_t0 = self.clock.now();
                    let mut toks = vec![0i32; b];
                    let mut poss = vec![0i32; b];
                    for (lane, slot) in lanes.iter().enumerate() {
                        // Non-drafting occupied lanes re-feed their pad
                        // pair (idempotent rewrite); free lanes write junk
                        // that lane zeroing clears before reuse.
                        if let Some(sess) = slot {
                            let (t, p) =
                                if sess.drafting() { sess.draft_feed() } else { sess.pad_pair() };
                            toks[lane] = t;
                            poss[lane] = p as i32;
                        }
                    }
                    let retries0 = metrics.step_retries;
                    let logits = match step_with_retry(
                        draft,
                        1,
                        &toks,
                        &poss,
                        &self.retry,
                        &self.clock,
                        &mut metrics,
                    ) {
                        Ok(logits) => logits,
                        Err(e) => {
                            fail_all(
                                &mut lanes,
                                &mut batcher,
                                &mut kv,
                                &mut kv_reservations,
                                &mut prefix,
                                &mut lane_pins,
                                &mut metrics,
                                hook,
                                &self.clock,
                                wants_obs,
                            );
                            return Err(e.into_inner().context("draft backend died mid-serve"));
                        }
                    };
                    let mut drafted_now = 0usize;
                    for (lane, slot) in lanes.iter_mut().enumerate() {
                        let Some(sess) = slot else { continue };
                        if sess.drafting() {
                            let d = argmax(logits_row(&logits, lane, 0, self.vocab)) as i32;
                            sess.push_draft(d);
                            metrics.drafted_tokens += 1;
                            drafted_now += 1;
                        }
                    }
                    metrics.draft_steps += 1;
                    if wants_obs {
                        let end = self.clock.now();
                        hook.on_step(&StepEvent {
                            seq: metrics.decode_steps + metrics.draft_steps,
                            decode_step: metrics.decode_steps,
                            width: 1,
                            draft: true,
                            t_s: self.clock.secs_since_epoch(step_t0),
                            dur_s: end.duration_since(step_t0).as_secs_f64(),
                            lanes_live: lanes.iter().flatten().count(),
                            lanes_total: b,
                            prefill_tokens: 0,
                            decode_tokens: 0,
                            draft_tokens: drafted_now,
                            verify_tokens: 0,
                            retries: metrics.step_retries - retries0,
                            kv_live_bytes: kv.live_bytes(),
                            kv_freed_bytes: kv.freed_bytes(),
                            kv_cached_bytes: kv.cache_pages() * target_page_bytes,
                            prefix_evicted_bytes: metrics.prefix_evicted_bytes,
                        });
                    }
                    continue;
                }
            }

            // ---- one fused step over all lanes: slab build → dispatch ----
            // Every live lane contributes a slab (prompt chunk, fed-back
            // token, or a ready verify slab); the plan's width picks the
            // artifact; short slabs pad by repeating their last (token,
            // position) pair — an idempotent rewrite the slab programs
            // guarantee.  Budget-deferred lanes (len 0) feed only their
            // pad pair and consume nothing.
            let plan = StepPlan::build(&self.widths, &lanes, self.max_step_tokens);
            let step_t0 = self.clock.now();
            let w = plan.width;
            let mut toks = vec![0i32; b * w];
            let mut poss = vec![0i32; b * w];
            for (lane, slab) in plan.slabs.iter().enumerate() {
                let Some(slab) = slab else { continue };
                let sess = lanes[lane].as_ref().expect("slab for occupied lane");
                for j in 0..w {
                    let (t, p) = sess.step_pair(slab.start, slab.len, j);
                    toks[lane * w + j] = t;
                    poss[lane * w + j] = p as i32;
                }
            }
            // Mirror the step into the draft backend when any live session
            // speculates, so the draft cache replays the target's token
            // history (verify slabs rewrite what drafting already wrote —
            // idempotent by the pad-by-repeat contract).
            let mirror =
                draft_backend.is_some() && lanes.iter().flatten().any(|s| s.spec_enabled());
            let retries0 = metrics.step_retries;
            let logits = match step_with_retry(
                &mut backend,
                w,
                &toks,
                &poss,
                &self.retry,
                &self.clock,
                &mut metrics,
            ) {
                Ok(logits) => logits,
                Err(e) => {
                    fail_all(
                        &mut lanes,
                        &mut batcher,
                        &mut kv,
                        &mut kv_reservations,
                        &mut prefix,
                        &mut lane_pins,
                        &mut metrics,
                        hook,
                        &self.clock,
                        wants_obs,
                    );
                    return Err(e.into_inner().context("backend died mid-serve"));
                }
            };
            if mirror {
                let draft = draft_backend.as_mut().expect("mirror implies a draft");
                if let Err(e) =
                    step_with_retry(draft, w, &toks, &poss, &self.retry, &self.clock, &mut metrics)
                {
                    fail_all(
                        &mut lanes,
                        &mut batcher,
                        &mut kv,
                        &mut kv_reservations,
                        &mut prefix,
                        &mut lane_pins,
                        &mut metrics,
                        hook,
                        &self.clock,
                        wants_obs,
                    );
                    return Err(e.into_inner().context("draft backend died mid-serve"));
                }
            }
            metrics.decode_steps += 1;

            // ---- sample / verify / retire; finished lanes free here ----
            let now = self.clock.now();
            // Token mix of this step's slabs, split at each session's
            // prompt boundary (tap payload only).
            let (mut mix_prefill, mut mix_decode, mut mix_verify) = (0usize, 0usize, 0usize);
            let lanes_live = plan.slabs.iter().flatten().count();
            for lane in 0..b {
                if lanes[lane].is_none() {
                    continue;
                }
                let slab = plan.slabs[lane].as_ref().expect("occupied lane planned");
                let taken = slab.len;
                if taken == 0 {
                    continue; // budget-deferred: fed a pad, consumed nothing
                }
                // ---- poisoned-logits quarantine ----
                // A non-finite readout row means the backend corrupted
                // this lane (the stub's poison fault; a NaN storm on a
                // real device).  The KV append already happened — only
                // the readout blew up — so the accounting stays honest
                // (advance, then quarantine: the lane's private bytes
                // free, the slot never reallocates) and the request
                // fails *individually* with [`FailReason::Poisoned`]:
                // unlike a backend death, replaying it verbatim would
                // just poison another lane.
                if logits_row(&logits, lane, taken - 1, self.vocab)
                    .iter()
                    .any(|v| !v.is_finite())
                {
                    let sess = lanes[lane].take().expect("lane occupied");
                    if let Some(trie) = prefix.as_mut() {
                        trie.unpin(&lane_pins[lane]);
                        lane_pins[lane].clear();
                        if let Some(store) = backend.stub_store_mut() {
                            store.zero_lane(lane);
                        }
                    }
                    kv.advance_by(sess.slot(), taken)?;
                    kv.quarantine(sess.slot())?;
                    kv_reservations.remove(&sess.id());
                    metrics.failed += 1;
                    metrics.quarantined_lanes += 1;
                    let gen = sess.generated();
                    metrics.generated_tokens += gen;
                    let id = sess.id();
                    hook.on_failed(
                        id,
                        sess.into_tokens(),
                        FailReason::Poisoned,
                        metrics.decode_steps,
                    );
                    if wants_obs {
                        hook.on_span(&SpanEvent {
                            id,
                            t_s: self.clock.secs_since_epoch(now),
                            point: SpanPoint::Failed { generated: gen },
                        });
                    }
                    continue;
                }
                let sess = lanes[lane].as_mut().expect("lane occupied");
                let prefill_part = if sess.verify_len().is_some() {
                    0
                } else {
                    sess.prompt_len().saturating_sub(slab.start).min(taken)
                };
                let finished = if sess.verify_len().is_some() {
                    // Accept the longest greedy-matching prefix of the
                    // draft plus the target's corrected token; roll the KV
                    // accounting back to what the row actually kept.  The
                    // rejected cache entries need no scrubbing: the causal
                    // mask only ever exposes a position after the step
                    // that rewrites it.
                    let before = sess.position();
                    kv.advance_by(sess.slot(), taken)?;
                    let mut targets = Vec::with_capacity(taken);
                    for j in 0..taken {
                        targets.push(argmax(logits_row(&logits, lane, j, self.vocab)) as i32);
                    }
                    let out = sess.observe_verify(&targets, now);
                    kv.rollback_to(sess.slot(), before + out.appended)?;
                    metrics.spec_rounds += 1;
                    metrics.accepted_draft_tokens += out.accepted;
                    metrics.rollback_tokens += out.rejected;
                    metrics.slab_tokens += out.appended;
                    mix_verify += taken;
                    if wants_obs {
                        hook.on_span(&SpanEvent {
                            id: sess.id(),
                            t_s: self.clock.secs_since_epoch(now),
                            point: SpanPoint::SpecRound {
                                drafted: taken,
                                accepted: out.accepted,
                            },
                        });
                    }
                    out.finished
                } else {
                    kv.advance_by(sess.slot(), taken)?;
                    let row = logits_row(&logits, lane, taken - 1, self.vocab);
                    metrics.slab_tokens += taken;
                    mix_prefill += prefill_part;
                    mix_decode += taken - prefill_part;
                    if wants_obs && prefill_part > 0 {
                        hook.on_span(&SpanEvent {
                            id: sess.id(),
                            t_s: self.clock.secs_since_epoch(now),
                            point: SpanPoint::PrefillChunk { tokens: prefill_part },
                        });
                    }
                    sess.observe_slab(taken, row, now)
                };
                let id = sess.id();
                let sampled: Vec<(usize, i32)> = sess.sampled().to_vec();
                // First generated token this step ⇔ everything generated so
                // far was sampled just now.
                if wants_obs && !sampled.is_empty() && sess.generated() == sampled.len() {
                    hook.on_span(&SpanEvent {
                        id,
                        t_s: self.clock.secs_since_epoch(now),
                        point: SpanPoint::FirstToken,
                    });
                }
                for (pos, tok) in sampled {
                    hook.on_token(id, pos, tok, metrics.decode_steps);
                }
                // ---- prefix registration: a completed prefill donates
                // its leading blocks to the trie.  The donated pages move
                // from the lane's private pool to the cache pool (the
                // lane keeps reading them; its byte-count claim transfers)
                // and the store increfs the shared columns. ----
                if prefill_part > 0 && slab.start + taken >= sess.prompt_len() {
                    if let Some(trie) = prefix.as_mut() {
                        let block = trie.block();
                        let blocks = sess.prompt_len() / block;
                        let attached_blocks = sess.attached() / block;
                        let prompt = &sess.tokens()[..sess.prompt_len()];
                        // Donate only when the trie's existing path is
                        // exactly what this lane attached: a concurrent
                        // prefill that registered *more* blocks meanwhile
                        // left this lane's middle pages private, and the
                        // slot model keeps shared pages contiguous.
                        let reused = trie.peek_match(prompt, blocks * block) / block;
                        if blocks > attached_blocks && reused == attached_blocks {
                            let ppb = trie.pages_per_block();
                            let store = backend
                                .stub_store_mut()
                                .expect("the prefix cache is stub-backed");
                            let (path, created) = trie.insert(prompt, blocks, |i| {
                                store.share_pages(lane, i * ppb, ppb)
                            });
                            if created > 0 {
                                kv.donate_to_cache(sess.slot(), blocks * ppb)?;
                            }
                            trie.unpin(&lane_pins[lane]);
                            trie.pin(&path);
                            lane_pins[lane] = path;
                        }
                    }
                }
                if finished {
                    let sess = lanes[lane].take().expect("lane occupied");
                    if let Some(trie) = prefix.as_mut() {
                        trie.unpin(&lane_pins[lane]);
                        lane_pins[lane].clear();
                        if let Some(store) = backend.stub_store_mut() {
                            store.zero_lane(lane);
                        }
                    }
                    kv.free(sess.slot())?;
                    kv_reservations.remove(&id);
                    metrics.completed += 1;
                    let gen = sess.generated();
                    metrics.generated_tokens += gen;
                    let c = sess.finish(now, metrics.decode_steps);
                    lat.push(c.latency_s);
                    ttfts.push(c.ttft_s);
                    hook.on_done(&c);
                    if wants_obs {
                        hook.on_span(&SpanEvent {
                            id: c.id,
                            t_s: self.clock.secs_since_epoch(now),
                            point: SpanPoint::Done { generated: gen },
                        });
                    }
                    if !open {
                        done.insert(c.id, c);
                    }
                }
            }
            if wants_obs {
                hook.on_step(&StepEvent {
                    seq: metrics.decode_steps + metrics.draft_steps,
                    decode_step: metrics.decode_steps,
                    width: w,
                    draft: false,
                    t_s: self.clock.secs_since_epoch(step_t0),
                    dur_s: now.duration_since(step_t0).as_secs_f64(),
                    lanes_live,
                    lanes_total: b,
                    prefill_tokens: mix_prefill,
                    decode_tokens: mix_decode,
                    draft_tokens: 0,
                    verify_tokens: mix_verify,
                    retries: metrics.step_retries - retries0,
                    kv_live_bytes: kv.live_bytes(),
                    kv_freed_bytes: kv.freed_bytes(),
                    kv_cached_bytes: kv.cache_pages() * target_page_bytes,
                    prefix_evicted_bytes: metrics.prefix_evicted_bytes,
                });
            }
        }

        // Conservation: every slot returned, every request accounted for —
        // completed or cancelled, never lost.
        if kv.free_slots() + kv.quarantined() != b {
            bail!(
                "KV slot leak: {}/{} free ({} quarantined) after drain",
                kv.free_slots(),
                b,
                kv.quarantined()
            );
        }
        let (enq, adm) = batcher.counters();
        if enq != adm + batcher.removed()
            || metrics.completed + metrics.cancelled + metrics.migrated + metrics.failed
                != enq as usize
        {
            bail!(
                "request conservation violated: enqueued {enq}, admitted {adm}, \
                 removed {}, completed {}, cancelled {}, migrated {}, failed {}",
                batcher.removed(),
                metrics.completed,
                metrics.cancelled,
                metrics.migrated,
                metrics.failed
            );
        }

        metrics.wall_s = self.clock.now().duration_since(t_origin).as_secs_f64();
        metrics.kv_peak_bytes = kv.peak_bytes();
        metrics.kv_freed_bytes = kv.freed_bytes();
        metrics.prefix_cached_bytes = kv.cache_pages() * target_page_bytes;
        metrics.observe_latencies(lat, ttfts);
        let out: Vec<Completion> = if open {
            Vec::new()
        } else {
            // Input order, cancelled requests omitted (their partial rows
            // went out through the hook).
            order.iter().filter_map(|id| done.remove(id)).collect()
        };
        Ok((out, metrics))
    }
}

/// The per-serve step executor: dispatches a plan's fused step and zeroes
/// re-assigned lanes, over whichever backing the engine was built with.
enum StepBackend<'rt> {
    Pjrt(DecodeSession<'rt>),
    Stub(StubModel),
}

/// A lane's logits row out of a fused step's output: `[B, V]` (width-1
/// decode artifacts, and chunk artifacts from manifests that predate the
/// all-position export — there `idx` is ignored because only the last
/// slab index was ever emitted) or `[B, W, V]` (all-position slab
/// programs and the stub, where `idx` selects the slab index — what a
/// verify step reads a whole draft from).
fn logits_row(logits: &Tensor, lane: usize, idx: usize, vocab: usize) -> &[f32] {
    match logits.ndim() {
        2 => &logits.data()[lane * vocab..(lane + 1) * vocab],
        3 => {
            let w = logits.shape()[1];
            debug_assert!(idx < w, "slab index {idx} outside width {w}");
            let at = (lane * w + idx) * vocab;
            &logits.data()[at..at + vocab]
        }
        d => unreachable!("step logits must be [B, V] or [B, W, V], got rank {d}"),
    }
}

/// Dispatch one fused step through the transient-fault retry loop: a
/// [`StepError::Transient`] classification re-dispatches the identical
/// step after exponential backoff (base `retry.backoff`, doubling per
/// attempt) up to `retry.budget` retries; a [`StepError::Fatal`]
/// classification — or a transient fault that outlives the budget —
/// returns `Err` for the caller to fail the serve.  Re-dispatch is safe
/// because a failed step committed nothing: the stub injects transient
/// faults before its cache writes, and sessions / KV cursors only
/// observe a step after it returns Ok.
fn step_with_retry(
    backend: &mut StepBackend,
    width: usize,
    toks: &[i32],
    poss: &[i32],
    retry: &RetryPolicy,
    clock: &Clock,
    metrics: &mut ServeMetrics,
) -> std::result::Result<Tensor, StepError> {
    let mut attempt = 0usize;
    loop {
        match backend.step(width, toks.to_vec(), poss.to_vec()) {
            Ok(logits) => return Ok(logits),
            Err(e) => match StepError::classify(e) {
                StepError::Fatal(e) => return Err(StepError::Fatal(e)),
                StepError::Transient(e) => {
                    metrics.step_faults += 1;
                    if attempt >= retry.budget {
                        return Err(StepError::Fatal(e.context(format!(
                            "transient fault persisted past the {}-attempt retry budget",
                            retry.budget
                        ))));
                    }
                    clock.sleep(retry.backoff * (1u32 << attempt.min(16) as u32));
                    metrics.step_retries += 1;
                    attempt += 1;
                }
            },
        }
    }
}

/// Fail every live lane and every queued request with
/// [`FailReason::Backend`]: the backend died (fatal fault, exhausted
/// retry budget, or every lane quarantined), so nothing held here can
/// make progress.  Sessions hand their partial rows to
/// [`StepHook::on_failed`] — the gateway supervisor's replay book — and
/// count as `failed`, keeping the conservation invariant (`completed +
/// cancelled + migrated + failed == enqueued`) intact on the error
/// path.  Queued requests leave through `reclaim_newest`, so the
/// batcher's own `enqueued == admitted + removed` ledger stays
/// balanced too.
#[allow(clippy::too_many_arguments)]
fn fail_all(
    lanes: &mut [Option<Session>],
    batcher: &mut Batcher,
    kv: &mut KvManager,
    kv_reservations: &mut HashMap<u64, usize>,
    prefix: &mut Option<PrefixCache>,
    lane_pins: &mut [Vec<usize>],
    metrics: &mut ServeMetrics,
    hook: &mut dyn StepHook,
    clock: &Clock,
    wants_obs: bool,
) {
    let step = metrics.decode_steps;
    let now = clock.now();
    for lane in 0..lanes.len() {
        let Some(sess) = lanes[lane].take() else { continue };
        if let Some(trie) = prefix.as_mut() {
            trie.unpin(&lane_pins[lane]);
            lane_pins[lane].clear();
        }
        let _ = kv.free(sess.slot());
        kv_reservations.remove(&sess.id());
        metrics.failed += 1;
        let gen = sess.generated();
        metrics.generated_tokens += gen;
        let id = sess.id();
        hook.on_failed(id, sess.into_tokens(), FailReason::Backend, step);
        if wants_obs {
            hook.on_span(&SpanEvent {
                id,
                t_s: clock.secs_since_epoch(now),
                point: SpanPoint::Failed { generated: gen },
            });
        }
    }
    while let Some(req) = batcher.reclaim_newest() {
        metrics.failed += 1;
        let arrived = req.arrived;
        let id = req.id;
        hook.on_failed(id, req.prompt, FailReason::Backend, step);
        if wants_obs {
            // Failed while still queued: open the span at its arrival
            // stamp so the timeline shows the queue wait it paid.
            hook.on_span(&SpanEvent {
                id,
                t_s: clock.secs_since_epoch(arrived),
                point: SpanPoint::Queued,
            });
            hook.on_span(&SpanEvent {
                id,
                t_s: clock.secs_since_epoch(now),
                point: SpanPoint::Failed { generated: 0 },
            });
        }
    }
}

impl StepBackend<'_> {
    /// Run one `width`-wide fused step; `toks`/`poss` are row-major
    /// `[B, width]`.  Returns the logits — `[B, V]` at width 1, `[B,
    /// width, V]` (every slab position) from the all-position slab
    /// programs; read rows through [`logits_row`].
    fn step(&mut self, width: usize, toks: Vec<i32>, poss: Vec<i32>) -> Result<Tensor> {
        match self {
            StepBackend::Pjrt(dec) => dec
                .run_plan(width, toks, poss)?
                .into_iter()
                .next()
                .context("step returned no logits")?
                .into_f32(),
            StepBackend::Stub(m) => m.step(width, &toks, &poss),
        }
    }

    /// The stub backing's host-side page store (None on PJRT) — the
    /// prefix cache's sharing surface.
    fn stub_store_mut(&mut self) -> Option<&mut PagedKvStore> {
        match self {
            StepBackend::Stub(m) => Some(m.store_mut()),
            StepBackend::Pjrt(_) => None,
        }
    }

    fn zero_lanes(&mut self, lanes: &[usize]) -> Result<()> {
        match self {
            StepBackend::Pjrt(dec) => dec.update_caches(|caches| {
                for cache in caches.iter_mut() {
                    for &lane in lanes {
                        zero_lane(cache, lane);
                    }
                }
                Ok(())
            }),
            StepBackend::Stub(m) => {
                m.zero_lanes(lanes);
                Ok(())
            }
        }
    }
}

/// Zero batch lane `lane` of a `[L, B, H, C, r]` cache tensor.
fn zero_lane(cache: &mut Tensor, lane: usize) {
    let shape = cache.shape().to_vec();
    debug_assert_eq!(shape.len(), 5, "cache must be [L, B, H, C, r]");
    debug_assert!(lane < shape[1]);
    let b = shape[1];
    let inner: usize = shape[2..].iter().product();
    let data = cache.data_mut();
    for l in 0..shape[0] {
        let start = (l * b + lane) * inner;
        data[start..start + inner].fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ops::init_params;
    use crate::serve::sampling::SamplingParams;
    use crate::testing::prop;
    use std::time::Duration;

    fn art() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn policy() -> BatchPolicy {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) }
    }

    #[test]
    fn zero_lane_clears_only_that_lane() {
        let mut t = Tensor::full(&[2, 3, 2, 2, 2], 1.0);
        zero_lane(&mut t, 1);
        let inner = 8;
        for l in 0..2 {
            for lane in 0..3 {
                let start = (l * 3 + lane) * inner;
                let want = if lane == 1 { 0.0 } else { 1.0 };
                assert!(t.data()[start..start + inner].iter().all(|&x| x == want),
                        "layer {l} lane {lane}");
            }
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn serves_batch_of_requests() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request::greedy(i, vec![1, 2, 3 + i as i32], 5, now))
            .collect();
        let (completions, metrics) = engine.serve_all(reqs, policy()).unwrap();
        assert_eq!(completions.len(), 3);
        for (i, c) in completions.iter().enumerate() {
            assert_eq!(c.id, i as u64);
            assert_eq!(c.tokens.len(), 8); // 3 prompt + 5 new
            assert_eq!(&c.tokens[..2], &[1, 2]);
            assert!(c.ttft_s <= c.latency_s);
            assert!(c.queue_wait_s >= 0.0);
        }
        assert_eq!(metrics.completed, 3);
        assert_eq!(metrics.generated_tokens, 15);
        assert_eq!(metrics.admissions, 3);
        // 3 prompt + 5 generated = 8 positions.  With a chunk ladder the
        // prompt collapses into one padded slab step (then 4 decode
        // steps); without prefill artifacts it is 7 single-token steps.
        let expect = if engine.max_chunk() > 1 { 5 } else { 7 };
        assert_eq!(metrics.decode_steps, expect);
        assert!(metrics.kv_peak_bytes > 0);
        assert!(metrics.tokens_per_s() > 0.0);
        assert!(metrics.latency_p99_s >= metrics.latency_p50_s);
    }

    #[test]
    fn midflight_admission_beats_waves() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        // 2× the slot count, mixed lengths finishing at different steps.
        let mk = || -> Vec<Request> {
            (0..16u64)
                .map(|i| Request::greedy(i, vec![1, 2], 2 + (i as usize % 4) * 4, now))
                .collect()
        };
        let (cont_c, cont) = engine.serve_all(mk(), policy()).unwrap();
        let (wave_c, wave) = engine
            .serve_with(mk(), policy(), Admission::WaveToCompletion)
            .unwrap();
        assert_eq!(cont_c.len(), 16);
        assert_eq!(cont.completed, 16);
        assert_eq!(wave.completed, 16);
        // Same results, fewer steps: freed lanes were refilled mid-flight.
        for (a, b) in cont_c.iter().zip(&wave_c) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "schedule must not change tokens");
        }
        assert!(
            cont.decode_steps < wave.decode_steps,
            "continuous {} vs wave {} steps",
            cont.decode_steps, wave.decode_steps
        );
        // Mixed lengths really did finish at different steps.
        let steps: HashSet<usize> = cont_c.iter().map(|c| c.finished_step).collect();
        assert!(steps.len() > 1, "all requests finished at the same step");
    }

    #[test]
    fn non_contiguous_ids_in_input_order() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let ids = [503u64, 7, 1_000_000_009, 64];
        let reqs: Vec<Request> = ids
            .iter()
            .enumerate()
            .map(|(i, &id)| Request::greedy(id, vec![1 + i as i32], 3, now))
            .collect();
        let (completions, metrics) = engine.serve_all(reqs, policy()).unwrap();
        assert_eq!(completions.len(), 4);
        for (i, c) in completions.iter().enumerate() {
            assert_eq!(c.id, ids[i], "completions must come back in input order");
            assert_eq!(c.tokens[0], 1 + i as i32);
        }
        assert_eq!(metrics.completed, 4);

        // Duplicate ids are rejected up front, not mis-keyed.
        let dup = vec![
            Request::greedy(5, vec![1], 2, now),
            Request::greedy(5, vec![2], 2, now),
        ];
        assert!(engine.serve_all(dup, policy()).is_err());
    }

    #[test]
    fn per_request_latency_not_batch_latency() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let reqs = vec![
            Request::greedy(0, vec![1, 2], 2, now),
            Request::greedy(1, vec![1, 2], 20, now),
        ];
        let (c, _) = engine.serve_all(reqs, policy()).unwrap();
        assert!(c[0].finished_step < c[1].finished_step);
        assert!(
            c[0].latency_s <= c[1].latency_s,
            "the early finisher must not be charged the long request's wall time"
        );
        assert!(c[0].steps < c[1].steps);
        // Degenerate request: completes with zero steps and ttft == latency.
        let (c, m) = engine
            .serve_all(vec![Request::greedy(2, vec![1, 2], 0, now)], policy())
            .unwrap();
        assert_eq!(c[0].tokens, vec![1, 2]);
        assert_eq!(c[0].steps, 0);
        assert_eq!(c[0].ttft_s, c[0].latency_s);
        assert_eq!(m.decode_steps, 0);
    }

    #[test]
    fn sampled_decode_is_deterministic_and_in_vocab() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let vocab = rt.manifest().config("tiny").unwrap().dim("vocab").unwrap() as i32;
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let mk = || -> Vec<Request> {
            (0..4u64)
                .map(|i| Request {
                    id: i,
                    prompt: vec![3, 4],
                    max_new: 6,
                    arrived: now,
                    sampling: SamplingParams {
                        temperature: 0.9,
                        top_k: 8,
                        seed: 17,
                        stop_token: None,
                        speculative: false,
                    },
                })
                .collect()
        };
        let (a, _) = engine.serve_all(mk(), policy()).unwrap();
        let (b, _) = engine.serve_all(mk(), policy()).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "same seed must replay identically");
            assert!(x.tokens.iter().all(|&t| t >= 0 && t < vocab));
        }
        // Different request ids decorrelate even with identical prompts.
        assert!(a.windows(2).any(|w| w[0].tokens != w[1].tokens),
                "all sampled rows identical — per-request streams not decorrelated");
    }

    #[test]
    fn slot_conservation_under_churn_property() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        // serve_with itself bails on any slot leak / conservation breach;
        // this drives it with randomized churn shapes (the kv.rs property,
        // extended through the engine).
        prop("engine slot conservation", 5, |rng| {
            let now = Instant::now();
            let n = 1 + rng.below(12);
            let mut ids: Vec<u64> = Vec::new();
            while ids.len() < n {
                let id = rng.next_u64() % 1000;
                if !ids.contains(&id) {
                    ids.push(id);
                }
            }
            let reqs: Vec<Request> = ids
                .iter()
                .map(|&id| {
                    let p = 1 + rng.below(3);
                    let prompt = (0..p).map(|_| rng.below(64) as i32).collect();
                    Request::greedy(id, prompt, rng.below(7), now)
                })
                .collect();
            let (completions, metrics) = engine
                .serve_all(reqs, policy())
                .map_err(|e| e.to_string())?;
            if completions.len() != n {
                return Err(format!("{} of {n} completions", completions.len()));
            }
            for (c, &id) in completions.iter().zip(&ids) {
                if c.id != id {
                    return Err(format!("order violated: got {} want {id}", c.id));
                }
            }
            if metrics.completed != n || metrics.admissions != n {
                return Err(format!(
                    "metrics disagree: completed {} admitted {}", metrics.completed, metrics.admissions
                ));
            }
            Ok(())
        });
    }

    /// Records hook callbacks and fires one cancellation after the target
    /// request has streamed `fire_after` tokens.
    struct CancellingHook {
        target: u64,
        fire_after: usize,
        target_tokens: usize,
        fired: bool,
        started: Vec<u64>,
        tokens: Vec<(u64, usize, i32)>,
        done_ids: Vec<u64>,
        cancelled: Vec<(u64, Vec<i32>, CancelReason)>,
    }

    impl CancellingHook {
        fn new(target: u64, fire_after: usize) -> Self {
            Self {
                target,
                fire_after,
                target_tokens: 0,
                fired: false,
                started: Vec::new(),
                tokens: Vec::new(),
                done_ids: Vec::new(),
                cancelled: Vec::new(),
            }
        }
    }

    impl StepHook for CancellingHook {
        fn take_cancellations(&mut self, _now: Instant) -> Vec<Cancellation> {
            if !self.fired && self.target_tokens >= self.fire_after {
                self.fired = true;
                return vec![Cancellation { id: self.target, reason: CancelReason::User }];
            }
            Vec::new()
        }

        fn on_started(&mut self, id: u64, _lane: usize, _step: usize) {
            self.started.push(id);
        }

        fn on_token(&mut self, id: u64, pos: usize, token: i32, _step: usize) {
            if id == self.target {
                self.target_tokens += 1;
            }
            self.tokens.push((id, pos, token));
        }

        fn on_done(&mut self, completion: &Completion) {
            self.done_ids.push(completion.id);
        }

        fn on_cancelled(&mut self, id: u64, tokens: Vec<i32>, reason: CancelReason, _step: usize) {
            self.cancelled.push((id, tokens, reason));
        }
    }

    #[test]
    fn hooked_serve_streams_tokens_and_cancels_between_steps() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let prompt_len = 2;
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request::greedy(i, vec![1, 2 + i as i32], 6, now))
            .collect();
        let mut hook = CancellingHook::new(1, 2);
        let (completions, metrics) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut hook)
            .unwrap();

        // The cancelled request is gone from the completions; everyone
        // else finished in input order.
        assert_eq!(completions.iter().map(|c| c.id).collect::<Vec<_>>(), vec![0, 2, 3]);
        assert_eq!(metrics.completed, 3);
        assert_eq!(metrics.cancelled, 1);
        assert_eq!(hook.started.len(), 4, "all four admitted");
        assert_eq!(hook.done_ids.len(), 3);

        // Cancellation applied between decode steps, right after the
        // second generated token: the partial row is prompt + 2.
        assert_eq!(hook.cancelled.len(), 1);
        let (cid, partial, reason) = &hook.cancelled[0];
        assert_eq!((*cid, *reason), (1, CancelReason::User));
        assert_eq!(partial.len(), prompt_len + 2);
        assert_eq!(&partial[..prompt_len], &[1, 3]);

        // Streamed tokens reconstruct each completion's generated suffix
        // exactly — token-level delivery carries the same data wave-end
        // delivery would.
        for c in &completions {
            let streamed: Vec<i32> = hook
                .tokens
                .iter()
                .filter(|(id, _, _)| *id == c.id)
                .map(|&(_, _, t)| t)
                .collect();
            assert_eq!(streamed.as_slice(), &c.tokens[prompt_len..], "request {}", c.id);
            // Positions are the absolute row indices of the generated part.
            let positions: Vec<usize> = hook
                .tokens
                .iter()
                .filter(|(id, _, _)| *id == c.id)
                .map(|&(_, p, _)| p)
                .collect();
            let want: Vec<usize> = (prompt_len..c.tokens.len()).collect();
            assert_eq!(positions, want);
        }

        // A NoHook run of the same (uncancelled) trace is bit-identical to
        // serve_all — the hook plumbing itself changes nothing.
        let mk = |ids: &[u64]| -> Vec<Request> {
            ids.iter().map(|&i| Request::greedy(i, vec![1, 2 + i as i32], 6, now)).collect()
        };
        let (a, _) = engine.serve_all(mk(&[0, 1, 2, 3]), policy()).unwrap();
        let (b, _) = engine
            .serve_hooked(mk(&[0, 1, 2, 3]), policy(), Admission::Continuous, &mut NoHook)
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    // ---- stub-backed tests: the scheduling contract, runnable without a
    // PJRT backend (these are what CI exercises) ----

    /// Small dims keep the stub's O(V·L·H·r·C) logits cheap in debug
    /// builds; the ladder and window are what the scheduling cares about.
    fn stub_spec() -> StubSpec {
        StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 2,
            vocab: 16,
            max_positions: 128,
            ..Default::default()
        }
    }

    fn stub_engine(cap: Option<usize>) -> Engine<'static> {
        Engine::new_stub(stub_spec()).with_prefill_chunk(cap)
    }

    #[test]
    fn chunk_width_policy() {
        let ladder = [1, 8, 32];
        assert_eq!(chunk_width(&ladder, 1), 1, "decode lanes stay single-token");
        assert_eq!(chunk_width(&ladder, 2), 8, "short remainders pad into one chunk");
        assert_eq!(chunk_width(&ladder, 8), 8);
        assert_eq!(chunk_width(&ladder, 10), 8, "biggest exact fit wins over padding");
        assert_eq!(chunk_width(&ladder, 32), 32);
        assert_eq!(chunk_width(&ladder, 100), 32);
        assert_eq!(chunk_width(&[1], 100), 1, "no chunk artifacts: single-token");
    }

    #[test]
    fn step_plan_mixes_prefill_and_decode_lanes() {
        let now = Instant::now();
        let mut lanes: Vec<Option<Session>> = vec![None; 3];
        lanes[0] = Some(Session::new(Request::greedy(7, (0..20).collect(), 4, now), 0, 64, now));
        lanes[2] = Some(Session::new(Request::greedy(9, vec![5], 4, now), 2, 64, now));
        let plan = StepPlan::build(&[1, 8], &lanes, None);
        assert_eq!(plan.width, 8, "the prefilling lane sets the step width");
        assert_eq!(plan.slabs[0], Some(LaneSlab { id: 7, start: 0, len: 8 }));
        assert_eq!(plan.slabs[1], None);
        assert_eq!(plan.slabs[2], Some(LaneSlab { id: 9, start: 0, len: 1 }));
        assert_eq!(plan.tokens(), 9);
    }

    #[test]
    fn step_plan_budget_shrinks_prefill_keeps_decode() {
        let now = Instant::now();
        let mut lanes: Vec<Option<Session>> = vec![None; 3];
        lanes[0] = Some(Session::new(Request::greedy(1, (0..100).collect(), 4, now), 0, 256, now));
        lanes[2] = Some(Session::new(Request::greedy(2, vec![5], 4, now), 2, 256, now));
        let ladder = [1usize, 8, 32];
        // Unbudgeted: the 100-token prompt takes a 32-wide chunk.
        let plan = StepPlan::build(&ladder, &lanes, None);
        assert_eq!(plan.width, 32);
        assert_eq!(plan.slabs[0].as_ref().unwrap().len, 32);
        // Budget 9: the decode lane's token is reserved first, the prefill
        // lane shrinks to the widest chunk fitting the remaining 8.
        let plan = StepPlan::build(&ladder, &lanes, Some(9));
        assert_eq!(plan.slabs[2].as_ref().unwrap().len, 1, "decode always runs");
        assert_eq!(plan.slabs[0].as_ref().unwrap().len, 8);
        assert_eq!(plan.width, 8, "narrower chunks mean a cheaper fused step");
        // Budget 2: no ladder chunk fits the remaining 1, but the prefill
        // lane still single-steps rather than stalling forever.
        let plan = StepPlan::build(&ladder, &lanes, Some(2));
        assert_eq!(plan.slabs[0].as_ref().unwrap().len, 1);
        // Budget 1 with a decode lane present: the prefill lane sits the
        // step out on its pad pair (len 0) — the decode lane progresses.
        let plan = StepPlan::build(&ladder, &lanes, Some(1));
        assert_eq!(plan.slabs[0].as_ref().unwrap().len, 0, "deferred entirely");
        assert_eq!(plan.slabs[2].as_ref().unwrap().len, 1);
        assert_eq!(plan.tokens(), 1);
        // A lone prefill lane is never starved, whatever the budget.
        lanes[2] = None;
        let plan = StepPlan::build(&ladder, &lanes, Some(1));
        assert_eq!(plan.slabs[0].as_ref().unwrap().len, 1);
    }

    #[test]
    fn chunked_prefill_bit_identity_property() {
        // For any prompt set and any chunk ladder cap, chunked prefill
        // produces exactly the tokens the single-token path does — the
        // schedule changes, the results never do.  Request counts beyond
        // the 8 lanes force lane reuse, so slab-width-dependent admission
        // timing and lane zeroing are under test too.
        prop("chunked prefill bit-identity", 8, |rng| {
            let now = Instant::now();
            let n = 1 + rng.below(12);
            let reqs: Vec<Request> = (0..n as u64)
                .map(|id| {
                    let p = 1 + rng.below(40);
                    let prompt: Vec<i32> = (0..p).map(|_| rng.below(16) as i32).collect();
                    let sampling = SamplingParams {
                        temperature: if rng.uniform() < 0.5 { 0.0 } else { 0.9 },
                        top_k: rng.below(5),
                        seed: rng.next_u64(),
                        stop_token: None,
                        speculative: false,
                    };
                    Request { id, prompt, max_new: rng.below(9), arrived: now, sampling }
                })
                .collect();
            let mut runs = Vec::new();
            for cap in [Some(1), Some(8), None] {
                let engine = stub_engine(cap);
                let out = engine.serve_all(reqs.clone(), policy()).map_err(|e| e.to_string())?;
                runs.push((cap, out));
            }
            let (_, (base, base_m)) = &runs[0];
            for (cap, (c, m)) in &runs[1..] {
                if c.len() != base.len() {
                    return Err(format!("cap {cap:?}: {} vs {} completions", c.len(), base.len()));
                }
                for (x, y) in c.iter().zip(base) {
                    if x.tokens != y.tokens {
                        return Err(format!("cap {cap:?}: request {} diverged", x.id));
                    }
                }
                if m.decode_steps > base_m.decode_steps {
                    return Err(format!(
                        "cap {cap:?}: chunking took {} steps vs {} single-token",
                        m.decode_steps, base_m.decode_steps
                    ));
                }
                if m.slab_tokens != base_m.slab_tokens {
                    return Err("same trace must consume the same row tokens".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chunked_prefill_cuts_prefill_steps_4x() {
        // The acceptance bar: a 64-token prompt's prefill steps shrink
        // >= 4x at K=8 vs K=1 (8x here), with identical output tokens.
        let now = Instant::now();
        let mk = || vec![Request::greedy(0, (0..64).map(|i| i % 32).collect(), 8, now)];
        let (c1, m1) = stub_engine(Some(1)).serve_all(mk(), policy()).unwrap();
        let (c8, m8) = stub_engine(Some(8)).serve_all(mk(), policy()).unwrap();
        let (c32, m32) = stub_engine(None).serve_all(mk(), policy()).unwrap();
        assert_eq!(c1[0].tokens, c8[0].tokens);
        assert_eq!(c1[0].tokens, c32[0].tokens);
        assert_eq!(c1[0].prefill_steps, 64);
        assert_eq!(c8[0].prefill_steps, 8);
        assert_eq!(c32[0].prefill_steps, 2);
        assert!(c1[0].prefill_steps >= 4 * c8[0].prefill_steps);
        // Step totals shift by exactly the prefill saving.
        assert_eq!(m8.decode_steps, m1.decode_steps - 64 + 8);
        assert_eq!(m32.slab_tokens, m1.slab_tokens, "same tokens, fewer steps");
        assert!(m32.decode_steps < m8.decode_steps);
    }

    #[test]
    fn mixed_prefill_and_decode_share_steps() {
        // Lane 0 is generating from step 2 onward while lane 1 is still
        // prefilling its 40-token prompt — the same fused steps carry
        // both, and the tokens match the single-token schedule.
        let now = Instant::now();
        let mk = || {
            vec![
                Request::greedy(0, vec![1, 2], 12, now),
                Request::greedy(1, (0..40).map(|i| i % 32).collect(), 4, now),
            ]
        };
        let (cc, mc) = stub_engine(None).serve_all(mk(), policy()).unwrap();
        let (c1, m1) = stub_engine(Some(1)).serve_all(mk(), policy()).unwrap();
        for (a, b) in cc.iter().zip(&c1) {
            assert_eq!(a.tokens, b.tokens, "request {}", a.id);
        }
        assert!(mc.decode_steps < m1.decode_steps);
        assert_eq!(cc[1].prefill_steps, 2, "40 = 32 + 8: two chunk steps");
        assert_eq!(cc[0].prefill_steps, 1, "2-token prompt pads into one slab");
    }

    #[test]
    fn empty_prompt_rejected_at_admission() {
        let now = Instant::now();
        let engine = stub_engine(None);
        let err = engine
            .serve_all(vec![Request::greedy(0, vec![], 4, now)], policy())
            .unwrap_err();
        assert!(err.to_string().contains("empty prompt"), "{err:#}");
        // A mixed batch is rejected up front too — nothing is partially
        // served.
        let reqs = vec![
            Request::greedy(1, vec![3], 2, now),
            Request::greedy(2, vec![], 2, now),
        ];
        assert!(engine.serve_all(reqs, policy()).is_err());
    }

    /// Fires one cancellation for `target` as soon as it has been
    /// admitted — i.e. *during its prefill*, before any sampled token.
    struct PrefillCancelHook {
        target: u64,
        fired: bool,
        started: Vec<(u64, usize)>,
        target_tokens: usize,
        cancelled: Vec<(u64, Vec<i32>, CancelReason, usize)>,
    }

    impl StepHook for PrefillCancelHook {
        fn take_cancellations(&mut self, _now: Instant) -> Vec<Cancellation> {
            if !self.fired && self.started.iter().any(|&(id, _)| id == self.target) {
                self.fired = true;
                return vec![Cancellation { id: self.target, reason: CancelReason::User }];
            }
            Vec::new()
        }

        fn on_started(&mut self, id: u64, _lane: usize, step: usize) {
            self.started.push((id, step));
        }

        fn on_token(&mut self, id: u64, _pos: usize, _token: i32, _step: usize) {
            if id == self.target {
                self.target_tokens += 1;
            }
        }

        fn on_cancelled(&mut self, id: u64, tokens: Vec<i32>, reason: CancelReason, step: usize) {
            self.cancelled.push((id, tokens, reason, step));
        }
    }

    #[test]
    fn cancel_during_prefill_reclaims_lane_same_iteration() {
        // One lane, single-token ladder: the 16-token prompt needs 16
        // prefill steps, and the cancellation lands after the first one —
        // mid-prefill by construction, no timing involved.
        let spec = StubSpec { batch_slots: 1, chunk_widths: vec![1], ..Default::default() };
        let engine = Engine::new_stub(spec);
        let now = Instant::now();
        let prompt: Vec<i32> = (0..16).collect();
        let reqs = vec![
            Request::greedy(0, prompt.clone(), 4, now),
            Request::greedy(1, vec![7, 8], 2, now),
        ];
        let mut hook = PrefillCancelHook {
            target: 0,
            fired: false,
            started: Vec::new(),
            target_tokens: 0,
            cancelled: Vec::new(),
        };
        let (completions, metrics) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut hook)
            .unwrap();

        // Exactly one Cancelled, with the untouched prompt as the partial
        // row (zero generated tokens — the cancel beat the first sample).
        assert_eq!(hook.cancelled.len(), 1);
        let (cid, partial, reason, cancel_step) = &hook.cancelled[0];
        assert_eq!((*cid, *reason), (0, CancelReason::User));
        assert_eq!(partial, &prompt, "no tokens were generated during prefill");
        assert_eq!(hook.target_tokens, 0);

        // The waiter reclaimed the lane in the same iteration the victim
        // was retired: its Started step equals the cancellation step.
        let waiter_started = hook
            .started
            .iter()
            .find(|&&(id, _)| id == 1)
            .map(|&(_, step)| step)
            .expect("waiter admitted");
        assert_eq!(waiter_started, *cancel_step, "same-iteration lane reclaim");
        assert_eq!(completions.iter().map(|c| c.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!((metrics.completed, metrics.cancelled), (1, 1));
    }

    // ---- self-speculative decoding (stub target + stub draft) ----

    /// Target at rank 8 with a rank-4 draft sharing its seed: the draft is
    /// a spectrum truncation of the target, so acceptance is high but not
    /// total (see `runtime::stub::RANK_DECAY`).
    fn spec_target_spec() -> StubSpec {
        StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 8,
            vocab: 16,
            max_positions: 128,
            ..Default::default()
        }
    }

    fn spec_draft_spec(rank: usize) -> StubSpec {
        StubSpec { rank, ..spec_target_spec() }
    }

    fn spec_engine(draft_rank: usize, cfg: SpecConfig) -> Engine<'static> {
        Engine::new_stub(spec_target_spec())
            .with_speculative_stub(spec_draft_spec(draft_rank), cfg)
            .unwrap()
    }

    #[test]
    fn speculative_config_validation() {
        // Draft length 1 can never win a step.
        let err = Engine::new_stub(spec_target_spec())
            .with_speculative_stub(spec_draft_spec(4), SpecConfig { draft_len: 1, adaptive: true })
            .err()
            .expect("draft_len 1 must be refused");
        assert!(err.to_string().contains("draft_len"), "{err:#}");
        // A single-token ladder has nothing to verify with.
        let err = Engine::new_stub(spec_target_spec())
            .with_prefill_chunk(Some(1))
            .with_speculative_stub(spec_draft_spec(4), SpecConfig::default())
            .err()
            .expect("chunkless ladder must be refused");
        assert!(err.to_string().contains("chunked slab ladder"), "{err:#}");
        // Lane counts must mirror 1:1.
        let draft = StubSpec { batch_slots: 2, ..spec_draft_spec(4) };
        assert!(Engine::new_stub(spec_target_spec())
            .with_speculative_stub(draft, SpecConfig::default())
            .is_err());
        // The pair's KV cost is both caches.
        let engine = spec_engine(4, SpecConfig::default());
        assert!(engine.speculative());
        assert_eq!(engine.draft_kv_config().unwrap().rank, 4);
        assert_eq!(
            engine.kv_bytes_per_token_total(),
            engine.kv_config().bytes_per_token()
                + engine.draft_kv_config().unwrap().bytes_per_token()
        );
    }

    #[test]
    fn speculative_greedy_cuts_dense_steps_below_one_per_token() {
        // The acceptance bar: identical tokens, fewer target steps — the
        // decode phase runs at < 1 dense step per generated token.
        let now = Instant::now();
        let mk = |spec: bool| {
            let sampling =
                if spec { SamplingParams::speculative_greedy() } else { SamplingParams::greedy() };
            vec![Request { id: 0, prompt: vec![3, 7, 1, 5], max_new: 32, arrived: now, sampling }]
        };
        let vanilla = Engine::new_stub(spec_target_spec());
        let (vc, vm) = vanilla.serve_all(mk(false), policy()).unwrap();
        let engine = spec_engine(4, SpecConfig { draft_len: 4, adaptive: false });
        let (sc, sm) = engine.serve_all(mk(true), policy()).unwrap();
        assert_eq!(sc[0].tokens, vc[0].tokens, "speculative == vanilla greedy, bit for bit");
        assert_eq!(sm.generated_tokens, 32);
        assert!(sm.spec_rounds > 0);
        assert!(sm.accepted_draft_tokens > 0, "rank-4 draft must win some tokens");
        assert!(
            sm.decode_steps < vm.decode_steps,
            "speculation took {} target steps vs {} vanilla",
            sm.decode_steps,
            vm.decode_steps
        );
        // Dense decode steps per generated token < 1.0 (prefill excluded:
        // both runs spend the same ceil(4/8)=1 padded prefill step).
        let dense_decode = sm.decode_steps - sc[0].prefill_steps;
        assert!(
            (dense_decode as f64) < sm.generated_tokens as f64,
            "{dense_decode} dense decode steps for {} tokens",
            sm.generated_tokens
        );
        // Draft steps are extra, but on the cheap engine; the rolled-back
        // suffix is bounded by what was drafted.
        assert_eq!(sm.drafted_tokens, sm.accepted_draft_tokens + sm.rollback_tokens);
        // A same-rank draft (rank 8 == target) agrees everywhere: every
        // round is fully accepted and decode collapses toward K tokens
        // per dense step.
        let twin = spec_engine(8, SpecConfig { draft_len: 4, adaptive: false });
        let (tc, tm) = twin.serve_all(mk(true), policy()).unwrap();
        assert_eq!(tc[0].tokens, vc[0].tokens);
        assert_eq!(tm.rollback_tokens, 0, "a perfect draft is never rolled back");
        assert!(tm.decode_steps <= sm.decode_steps);
    }

    /// Satellite property: speculative greedy decode is bit-identical to
    /// vanilla greedy decode across draft ranks {4, 8} and draft lengths
    /// {2, 4, 8}, adaptive on and off, over randomized prompt sets with
    /// lane churn.
    #[test]
    fn speculative_bit_identity_property() {
        prop("speculative greedy bit-identity", 6, |rng| {
            let now = Instant::now();
            let n = 1 + rng.below(10);
            let mk = |spec: bool| -> Vec<Request> {
                let mut rr = crate::util::rng::Rng::new(99);
                (0..n as u64)
                    .map(|id| {
                        let p = 1 + rr.below(20);
                        let prompt: Vec<i32> = (0..p).map(|_| rr.below(16) as i32).collect();
                        let sampling = if spec {
                            SamplingParams::speculative_greedy()
                        } else {
                            SamplingParams::greedy()
                        };
                        Request { id, prompt, max_new: rr.below(20), arrived: now, sampling }
                    })
                    .collect()
            };
            let (base, base_m) =
                Engine::new_stub(spec_target_spec()).serve_all(mk(false), policy())
                    .map_err(|e| e.to_string())?;
            for draft_rank in [4usize, 8] {
                for draft_len in [2usize, 4, 8] {
                    let adaptive = rng.uniform() < 0.5;
                    let engine = spec_engine(draft_rank, SpecConfig { draft_len, adaptive });
                    let (c, m) =
                        engine.serve_all(mk(true), policy()).map_err(|e| e.to_string())?;
                    if c.len() != base.len() {
                        return Err(format!("{} vs {} completions", c.len(), base.len()));
                    }
                    for (x, y) in c.iter().zip(&base) {
                        if x.tokens != y.tokens {
                            return Err(format!(
                                "draft r{draft_rank} K{draft_len}: request {} diverged\n  spec    {:?}\n  vanilla {:?}",
                                x.id, x.tokens, y.tokens
                            ));
                        }
                    }
                    if m.generated_tokens != base_m.generated_tokens {
                        return Err("generated-token totals diverged".into());
                    }
                    if m.drafted_tokens != m.accepted_draft_tokens + m.rollback_tokens {
                        return Err("draft conservation violated".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn speculative_nongreedy_requests_serve_vanilla() {
        // A temperature request with the speculative flag set is served
        // the vanilla way (no rounds), and matches its non-spec twin.
        let now = Instant::now();
        let mk = |spec: bool| {
            let sampling = SamplingParams {
                temperature: 0.9,
                top_k: 4,
                seed: 11,
                stop_token: None,
                speculative: spec,
            };
            vec![Request { id: 0, prompt: vec![2, 4], max_new: 12, arrived: now, sampling }]
        };
        let engine = spec_engine(4, SpecConfig::default());
        let (a, am) = engine.serve_all(mk(true), policy()).unwrap();
        let (b, bm) = Engine::new_stub(spec_target_spec()).serve_all(mk(false), policy()).unwrap();
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_eq!(am.spec_rounds, 0, "non-greedy never opens a round");
        assert_eq!(am.draft_steps, 0);
        assert_eq!(am.decode_steps, bm.decode_steps);
    }

    /// Fires one cancellation for `target` mid-draft, by construction:
    /// `take_cancellations` is polled once per engine iteration, so after
    /// the target's first token (prefill end) the iteration sequence is
    /// [poll, open round + draft step], [poll, draft step], … — firing on
    /// the *second* poll after the token lands the cancel with the round
    /// one drafted token in (draft_len ≥ 2 keeps it incomplete).
    struct CountingCancelHook {
        target: u64,
        seen: usize,
        polls_after_token: usize,
        fired: bool,
        started: Vec<(u64, usize)>,
        cancelled: Vec<(u64, usize, usize)>,
    }

    impl StepHook for CountingCancelHook {
        fn take_cancellations(&mut self, _now: Instant) -> Vec<Cancellation> {
            if self.seen >= 1 && !self.fired {
                self.polls_after_token += 1;
                if self.polls_after_token == 2 {
                    self.fired = true;
                    return vec![Cancellation { id: self.target, reason: CancelReason::User }];
                }
            }
            Vec::new()
        }

        fn on_started(&mut self, id: u64, _lane: usize, step: usize) {
            self.started.push((id, step));
        }

        fn on_token(&mut self, id: u64, _pos: usize, _token: i32, _step: usize) {
            if id == self.target {
                self.seen += 1;
            }
        }

        fn on_cancelled(&mut self, id: u64, tokens: Vec<i32>, _reason: CancelReason, step: usize) {
            self.cancelled.push((id, tokens.len(), step));
        }
    }

    #[test]
    fn mid_draft_cancel_reclaims_lane_and_draft_lane_same_iteration() {
        // One lane, so the waiter can only run after the victim's lane —
        // and its draft-cache lane — are reclaimed.  The victim is
        // cancelled mid-decode, i.e. between the draft steps of its
        // current speculative round.
        let target = StubSpec { batch_slots: 1, ..spec_target_spec() };
        let draft = StubSpec { batch_slots: 1, ..spec_draft_spec(4) };
        let engine = Engine::new_stub(target.clone())
            .with_speculative_stub(draft, SpecConfig { draft_len: 8, adaptive: false })
            .unwrap();
        let now = Instant::now();
        let waiter_prompt = vec![9, 2, 6];
        let reqs = vec![
            Request {
                id: 0,
                prompt: vec![1, 2],
                max_new: 40,
                arrived: now,
                sampling: SamplingParams::speculative_greedy(),
            },
            Request {
                id: 1,
                prompt: waiter_prompt.clone(),
                max_new: 5,
                arrived: now,
                sampling: SamplingParams::speculative_greedy(),
            },
        ];
        let mut hook = CountingCancelHook {
            target: 0,
            seen: 0,
            polls_after_token: 0,
            fired: false,
            started: Vec::new(),
            cancelled: Vec::new(),
        };
        let (completions, metrics) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut hook)
            .unwrap();
        // Exactly one Cancelled; the victim had its first token and one
        // drafted (never-appended) proposal — the partial row is prompt +
        // exactly the streamed tokens, with the in-flight draft discarded.
        assert_eq!(metrics.cancelled, 1);
        assert_eq!(hook.cancelled.len(), 1);
        let (cid, partial_len, cancel_step) = hook.cancelled[0];
        assert_eq!(cid, 0);
        assert_eq!(partial_len, 2 + hook.seen, "partial row = prompt + streamed tokens only");
        // Same-iteration reclaim: the waiter starts at the cancel step.
        let waiter_started = hook
            .started
            .iter()
            .find(|&&(id, _)| id == 1)
            .map(|&(_, s)| s)
            .expect("waiter admitted");
        assert_eq!(waiter_started, cancel_step, "same-iteration lane reclaim");
        // Draft-lane reclaim: the waiter's tokens equal an isolated run on
        // a fresh pair — any stale draft or target rows from the victim
        // would change them (the stub reads the whole cache prefix).
        assert_eq!(completions.len(), 1);
        let engine2 = Engine::new_stub(target)
            .with_speculative_stub(spec_draft_spec(4), SpecConfig { draft_len: 8, adaptive: false })
            .unwrap();
        let solo = vec![Request {
            id: 1,
            prompt: waiter_prompt,
            max_new: 5,
            arrived: now,
            sampling: SamplingParams::speculative_greedy(),
        }];
        let (solo_c, _) = engine2.serve_all(solo, policy()).unwrap();
        assert_eq!(completions[0].tokens, solo_c[0].tokens, "draft lane was zeroed on reuse");
        assert!(metrics.draft_steps > 0, "the victim really was drafting");
    }

    #[test]
    fn max_step_tokens_bounds_decode_ttft_under_giant_prefill() {
        // Satellite: a 512-token prompt prefilling must not starve a
        // decode lane's latency.  Step cost scales with slab width
        // (width_delay), so capping the summed slab width caps the cost
        // of every step the decode lane shares.
        //
        // Each engine runs on its own *manual* clock: the simulated width
        // delays advance virtual time only, so the TTFT comparison is
        // exact and the test spends no wall time sleeping.
        let mk_spec = |clock: &Clock| StubSpec {
            n_layers: 1,
            n_heads: 1,
            rank: 2,
            vocab: 8,
            batch_slots: 2,
            max_positions: 600,
            width_delay: Duration::from_millis(2),
            clock: clock.clone(),
            ..Default::default()
        };
        let mk = |clock: &Clock| {
            let now = clock.now();
            vec![
                Request::greedy(0, (0..512).map(|i| i % 8).collect(), 2, now),
                Request::greedy(1, vec![1, 2], 6, now),
            ]
        };
        let uclock = Clock::manual();
        let unbounded = Engine::new_stub(mk_spec(&uclock));
        let (uc, um) = unbounded.serve_all(mk(&uclock), policy()).unwrap();
        let bclock = Clock::manual();
        let budgeted = Engine::new_stub(mk_spec(&bclock)).with_max_step_tokens(Some(9));
        let (bc, bm) = budgeted.serve_all(mk(&bclock), policy()).unwrap();
        // Same tokens either way — the budget only reshapes the schedule.
        for (a, b) in uc.iter().zip(&bc) {
            assert_eq!(a.tokens, b.tokens, "request {}", a.id);
        }
        // Unbudgeted: the giant prompt rides 32-wide steps (16 of them);
        // budgeted at 9 (1 decode + 8 prefill): 8-wide chunks, 64 steps.
        assert_eq!(uc[0].prefill_steps, 16);
        assert_eq!(bc[0].prefill_steps, 64);
        assert!(bm.decode_steps > um.decode_steps);
        // The decode request's TTFT: every shared step now costs ~8 width
        // units instead of ~32, so its first token lands sooner in wall
        // time even though the prompt takes more steps overall.
        assert!(
            bc[1].ttft_s < uc[1].ttft_s,
            "budgeted ttft {:.4}s must beat unbudgeted {:.4}s",
            bc[1].ttft_s,
            uc[1].ttft_s
        );
    }

    // ---- observability taps (stub-backed) ----

    /// The acceptance bar for the trace layer: span timelines alone must
    /// reconstruct the engine's own aggregates — completed / cancelled /
    /// generated tokens exactly, TTFT percentiles to float tolerance —
    /// and the step events' token mix must account for every slab token.
    #[test]
    fn trace_sink_reconstructs_serve_metrics() {
        use crate::obs::TraceSink;
        let clock = Clock::manual();
        let spec = StubSpec {
            step_delay: Duration::from_millis(1),
            clock: clock.clone(),
            ..stub_spec()
        };
        let engine = Engine::new_stub(spec);
        let now = clock.now();
        let reqs: Vec<Request> = (0..6u64)
            .map(|i| Request::greedy(i, vec![1, 2 + i as i32], 3 + (i as usize % 3), now))
            .collect();
        let mut sink = TraceSink::default();
        let (completions, metrics) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut sink)
            .unwrap();
        assert_eq!(completions.len(), 6);
        assert_eq!(sink.open_spans(), 0, "a drained serve closes every span");
        let recon = sink.reconstruct();
        assert_eq!(recon.completed, metrics.completed);
        assert_eq!(recon.cancelled, metrics.cancelled);
        assert_eq!(recon.generated_tokens, metrics.generated_tokens);
        assert!(
            (recon.ttft_p50_s - metrics.ttft_p50_s).abs() < 1e-9,
            "recon p50 {} vs engine {}",
            recon.ttft_p50_s,
            metrics.ttft_p50_s
        );
        assert!((recon.ttft_p99_s - metrics.ttft_p99_s).abs() < 1e-9);
        // Step-event token conservation: the per-step prefill/decode mix
        // sums to exactly the slab tokens the engine consumed.
        let (sum_p, sum_d) = sink
            .steps()
            .fold((0usize, 0usize), |(p, d), e| (p + e.prefill_tokens, d + e.decode_tokens));
        assert_eq!(sum_p + sum_d, metrics.slab_tokens);
        assert_eq!(sink.steps_seen(), metrics.decode_steps, "one event per fused step");
        // Monotonic timeline: virtual step delays give strictly ordered
        // starts on one engine thread.
        let starts: Vec<f64> = sink.steps().map(|e| e.t_s).collect();
        assert!(starts.windows(2).all(|w| w[0] <= w[1]));
    }

    /// Regression (observability): a cancel landing *mid-prefill* in a
    /// lane and a deadline expiring on a still-queued request both close
    /// their span timelines — nothing stays open after the drain, and the
    /// queue-cancelled request's span still shows its arrival stamp.
    #[test]
    fn spans_close_after_midprefill_cancel_and_queued_deadline() {
        use crate::obs::{TeeHook, TraceSink};
        struct TwoCancels {
            polls: usize,
        }
        impl StepHook for TwoCancels {
            fn take_cancellations(&mut self, _now: Instant) -> Vec<Cancellation> {
                self.polls += 1;
                if self.polls == 3 {
                    return vec![
                        Cancellation { id: 0, reason: CancelReason::User },
                        Cancellation { id: 1, reason: CancelReason::Deadline },
                    ];
                }
                Vec::new()
            }
        }
        let clock = Clock::manual();
        let spec = StubSpec {
            batch_slots: 1,
            chunk_widths: vec![1],
            step_delay: Duration::from_millis(5),
            clock: clock.clone(),
            ..stub_spec()
        };
        let engine = Engine::new_stub(spec);
        let now = clock.now();
        // id 0 holds the single lane with a long prefill; id 1 queues
        // behind it and expires before it is ever admitted.
        let reqs = vec![
            Request::greedy(0, (0..64).map(|i| i % 16).collect(), 8, now),
            Request::greedy(1, (0..16).map(|i| i % 16).collect(), 4, now),
        ];
        let mut primary = TwoCancels { polls: 0 };
        let mut sink = TraceSink::default();
        let mut tee = TeeHook { primary: &mut primary, observer: &mut sink };
        let (completions, m) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut tee)
            .unwrap();
        assert!(completions.is_empty());
        assert_eq!((m.completed, m.cancelled), (0, 2));
        assert_eq!(sink.open_spans(), 0, "cancelled spans are closed, not leaked");
        let lane_victim = sink.span(0).expect("lane victim traced");
        assert!(lane_victim.cancelled && lane_victim.closed());
        assert!(lane_victim.admitted_s.is_some());
        assert!(
            !lane_victim.prefill_chunks.is_empty(),
            "prefill chunks recorded before the mid-prefill cancel"
        );
        assert!(lane_victim.first_token_s.is_none(), "no token was ever sampled");
        let queued_victim = sink.span(1).expect("queued victim traced");
        assert!(queued_victim.cancelled && queued_victim.closed());
        assert!(queued_victim.admitted_s.is_none(), "never reached a lane");
        assert!(queued_victim.queued_s.is_some(), "span opens at its arrival stamp");
        let recon = sink.reconstruct();
        assert_eq!((recon.completed, recon.cancelled), (0, 2));
    }

    #[test]
    fn factorized_engine_kv_smaller() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let entry = rt.manifest().config("tiny").unwrap().clone();
        let dense = init_params(&rt, "tiny", 9).unwrap();
        let (fac, r) = crate::coordinator::ops::prune_to_ratio(&entry, &dense, 0.5, "clover")
            .unwrap();
        let dense_engine = Engine::new(&rt, "tiny", "decode_b8", dense).unwrap();
        let fac_engine =
            Engine::new(&rt, "tiny", &format!("decode_fac_r{r}_b8"), fac).unwrap();
        let d = dense_engine.kv_config().bytes_per_token();
        let f = fac_engine.kv_config().bytes_per_token();
        assert_eq!(f * 2, d, "rank-8 cache should be half of rank-16");
    }

    // ---- KV page codecs + memory-budget admission (stub-backed) ----

    /// A rank-8 spec so factored budgets have room to bite.
    fn codec_spec() -> StubSpec {
        StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 8,
            vocab: 16,
            max_positions: 128,
            ..Default::default()
        }
    }

    fn codec_reqs(n: u64) -> Vec<Request> {
        let now = Instant::now();
        (0..n)
            .map(|id| {
                let prompt: Vec<i32> = (0..8).map(|p| ((id as usize + p) % 16) as i32).collect();
                Request::greedy(id, prompt, 8, now)
            })
            .collect()
    }

    #[test]
    fn kv_codec_validation_against_manifest_geometry() {
        // Budgets must match the layer count…
        let err = Engine::new_stub(codec_spec())
            .with_kv_codec(KvCodecSpec::Factored { layer_budgets: Some(vec![4, 4]) })
            .err()
            .expect("2 budgets on a 1-layer model must be refused");
        assert!(err.to_string().contains("1-layer"), "{err:#}");
        // …and each sit in 1..=rank.
        for bad in [0usize, 9] {
            let err = Engine::new_stub(codec_spec())
                .with_kv_codec(KvCodecSpec::Factored { layer_budgets: Some(vec![bad]) })
                .err()
                .expect("out-of-range budget must be refused");
            assert!(err.to_string().contains("budget"), "{err:#}");
        }
        // Spec parsing guards the CLI surface: identity takes no budgets,
        // unknown codec names are refused.
        assert!(KvCodecSpec::parse("identity", Some(vec![4])).is_err());
        assert!(KvCodecSpec::parse("clover", None).is_err());
        // A half-rank budget halves the advertised per-token bytes.
        let identity = Engine::new_stub(codec_spec());
        let factored = Engine::new_stub(codec_spec())
            .with_kv_codec(KvCodecSpec::Factored { layer_budgets: Some(vec![4]) })
            .unwrap();
        assert_eq!(
            factored.kv_bytes_per_token_total() * 2,
            identity.kv_bytes_per_token_total(),
            "budget 4 of rank 8 must halve KV bytes"
        );
    }

    #[test]
    fn factored_full_budget_serves_bit_identical_to_identity() {
        // Budgets == rank make the factored codec a round-trip copy: the
        // whole serve — admission, chunked prefill, lane churn — must be
        // bit-identical to the identity codec.  A half budget is a real
        // truncation: the schedule still completes every request even
        // though the stored basis is pruned.
        let reqs = codec_reqs(12);
        let identity = Engine::new_stub(codec_spec());
        let (ic, im) = identity.serve_all(reqs.clone(), policy()).unwrap();
        let full = Engine::new_stub(codec_spec())
            .with_kv_codec(KvCodecSpec::Factored { layer_budgets: Some(vec![8]) })
            .unwrap();
        let (fc, fm) = full.serve_all(reqs.clone(), policy()).unwrap();
        assert_eq!(ic.len(), fc.len());
        for (a, b) in ic.iter().zip(&fc) {
            assert_eq!(a.tokens, b.tokens, "request {}", a.id);
        }
        assert_eq!(im.decode_steps, fm.decode_steps);
        let half = Engine::new_stub(codec_spec())
            .with_kv_codec(KvCodecSpec::Factored { layer_budgets: Some(vec![4]) })
            .unwrap();
        let (hc, hm) = half.serve_all(reqs, policy()).unwrap();
        assert_eq!(hc.len(), 12, "pruned storage still completes every request");
        assert_eq!(hm.completed, 12);
        for c in &hc {
            assert_eq!(c.tokens.len(), 16, "prompt 8 + max_new 8");
        }
    }

    /// Counts concurrently-live lanes over a serve — the budget's cap on
    /// admission shows up as the high-water mark of this census.
    #[derive(Default)]
    struct LaneCensusHook {
        live: usize,
        max_live: usize,
    }

    impl StepHook for LaneCensusHook {
        fn on_started(&mut self, _id: u64, _lane: usize, _step: usize) {
            self.live += 1;
            self.max_live = self.max_live.max(self.live);
        }

        fn on_done(&mut self, _completion: &Completion) {
            self.live -= 1;
        }

        fn on_cancelled(&mut self, _id: u64, _t: Vec<i32>, _r: CancelReason, _s: usize) {
            self.live -= 1;
        }
    }

    #[test]
    fn kv_memory_budget_caps_lanes_and_factored_codec_doubles_them() {
        // Every request worst-cases at 16 tokens = exactly one page.
        // Identity: 2 heads x 4 bytes x rank 8 x 16 tokens = 2048 bytes
        // per page, so a 4096-byte budget holds 2 lanes.  The factored
        // codec at budget 4 halves the page to 1024 bytes: same byte
        // budget, 4 lanes — the lanes-at-fixed-memory claim, observed on
        // a real schedule rather than computed from the config.
        let budget = 2 * 2048;
        let census = |codec: Option<KvCodecSpec>| {
            let mut engine = Engine::new_stub(codec_spec());
            if let Some(c) = codec {
                engine = engine.with_kv_codec(c).unwrap();
            }
            let engine = engine.with_kv_memory_budget(Some(budget));
            let mut hook = LaneCensusHook::default();
            let (c, m) = engine
                .serve_hooked(codec_reqs(8), policy(), Admission::Continuous, &mut hook)
                .unwrap();
            assert_eq!(c.len(), 8, "the budget delays admission, it drops nothing");
            assert_eq!(m.completed, 8);
            hook.max_live
        };
        assert_eq!(census(None), 2, "identity: floor(4096 / 2048) lanes");
        let factored = KvCodecSpec::Factored { layer_budgets: Some(vec![4]) };
        assert_eq!(census(Some(factored)), 4, "factored r4: floor(4096 / 1024) lanes");
        // The budget reshapes the schedule only — per-lane token streams
        // are untouched (the stub's rows are lane-independent).
        let unbudgeted = Engine::new_stub(codec_spec());
        let (uc, _) = unbudgeted.serve_all(codec_reqs(8), policy()).unwrap();
        let budgeted = Engine::new_stub(codec_spec()).with_kv_memory_budget(Some(budget));
        let (bc, _) = budgeted.serve_all(codec_reqs(8), policy()).unwrap();
        for (a, b) in uc.iter().zip(&bc) {
            assert_eq!(a.tokens, b.tokens, "request {}", a.id);
        }
    }

    #[test]
    fn kv_memory_budget_refuses_infeasible_head() {
        // A request whose worst case can never fit must fail loudly, not
        // deadlock the admission loop.
        let engine = Engine::new_stub(codec_spec()).with_kv_memory_budget(Some(1024));
        let err = engine.serve_all(codec_reqs(1), policy()).err().expect("must refuse");
        assert!(err.to_string().contains("budget"), "{err:#}");
    }

    #[test]
    fn serve_metrics_report_kv_churn() {
        // Satellite: freed bytes — every finished request hands its pages
        // back, so the churn counter is page-quantised and covers exactly
        // the pages the 16-token rows occupied.
        let engine = Engine::new_stub(codec_spec());
        let page = engine.kv_config().bytes_per_page();
        let (_, m) = engine.serve_all(codec_reqs(6), policy()).unwrap();
        assert_eq!(m.kv_freed_bytes, 6 * page, "6 one-page rows freed");
        assert!(m.kv_peak_bytes > 0);
    }

    // ---- radix prefix cache: COW sharing, eviction, migration ----

    /// One-lane engine: requests serve strictly FIFO, so every follower
    /// sees its predecessors' registered prefixes — the sharing path is
    /// deterministic, no admission races.
    fn serial_engine(cap: Option<usize>, factored: bool) -> Engine<'static> {
        let spec = StubSpec { batch_slots: 1, ..codec_spec() };
        let mut engine = Engine::new_stub(spec).with_prefill_chunk(cap);
        if factored {
            engine = engine
                .with_kv_codec(KvCodecSpec::Factored { layer_budgets: Some(vec![4]) })
                .unwrap();
        }
        engine
    }

    #[test]
    fn prefix_cache_bit_identity_property() {
        // The non-negotiable bar: a cache-hit serve emits exactly the
        // tokens a cold serve does, across chunk ladder caps {8, 32} and
        // both page codecs (identity, factored-with-truncation).  Random
        // follower mix: exact repeats (pure hits), extensions (hit +
        // fresh suffix), early divergence (miss or partial-block miss).
        prop("prefix cache hit bit-identity", 6, |rng| {
            let now = Instant::now();
            let base_len = 33 + rng.below(48); // crosses >= 1 cache block
            let base: Vec<i32> = (0..base_len).map(|_| rng.below(16) as i32).collect();
            let n = 2 + rng.below(4);
            let mut reqs = vec![Request::greedy(0, base.clone(), 1 + rng.below(6), now)];
            for id in 1..=n as u64 {
                let mut prompt = base.clone();
                match rng.below(3) {
                    0 => {} // exact repeat
                    1 => {
                        for _ in 0..1 + rng.below(40) {
                            prompt.push(rng.below(16) as i32);
                        }
                    }
                    _ => {
                        let at = rng.below(prompt.len());
                        prompt[at] = (prompt[at] + 1) % 16;
                    }
                }
                reqs.push(Request::greedy(id, prompt, 1 + rng.below(6), now));
            }
            for cap in [8usize, 32] {
                for factored in [false, true] {
                    let (cold, _) = serial_engine(Some(cap), factored)
                        .serve_all(reqs.clone(), policy())
                        .map_err(|e| e.to_string())?;
                    let warm_engine = serial_engine(Some(cap), factored)
                        .with_prefix_cache(Some(32))
                        .map_err(|e| e.to_string())?;
                    let (warm, wm) =
                        warm_engine.serve_all(reqs.clone(), policy()).map_err(|e| e.to_string())?;
                    if cold.len() != warm.len() {
                        return Err(format!(
                            "cap {cap} factored {factored}: {} vs {} completions",
                            warm.len(),
                            cold.len()
                        ));
                    }
                    for (a, b) in cold.iter().zip(&warm) {
                        if a.tokens != b.tokens {
                            return Err(format!(
                                "cap {cap} factored {factored}: request {} diverged on a cache hit",
                                a.id
                            ));
                        }
                    }
                    // Exact repeats of a >= 33-token base always hit.
                    if wm.prefix_hits == 0 && reqs.iter().skip(1).any(|r| r.prompt == base) {
                        return Err("an exact repeat never hit the cache".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prefix_cache_hits_skip_prefill_work() {
        // Deterministic acceptance shape: a 64-token prompt registers two
        // 32-token blocks; an exact repeat attaches one block (the last
        // prompt token must prefill — it produces the first logits), an
        // extension attaches both.  The warm serve spends strictly fewer
        // fused steps, on bit-identical outputs.
        let now = Instant::now();
        let base: Vec<i32> = (0..64).map(|i| (i % 16) as i32).collect();
        let mut extended = base.clone();
        extended.extend((0..8).map(|i| (i % 16) as i32));
        let mk = || {
            vec![
                Request::greedy(0, base.clone(), 4, now),
                Request::greedy(1, base.clone(), 4, now),
                Request::greedy(2, extended.clone(), 4, now),
            ]
        };
        let (cold, cm) = serial_engine(None, false).serve_all(mk(), policy()).unwrap();
        let warm_engine = serial_engine(None, false).with_prefix_cache(Some(32)).unwrap();
        let (warm, wm) = warm_engine.serve_all(mk(), policy()).unwrap();
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.tokens, b.tokens, "request {}", a.id);
        }
        assert_eq!(wm.prefix_hits, 2, "the repeat and the extension both hit");
        assert_eq!(wm.prefix_hit_tokens, 32 + 64);
        assert!(
            wm.decode_steps < cm.decode_steps,
            "cached prefixes must save fused steps: warm {} vs cold {}",
            wm.decode_steps,
            cm.decode_steps
        );
        // Request 0 donated its 64-token prompt: 2 blocks of 2 pages at
        // 1024 B/page stay resident in the cache pool after the drain.
        let page = warm_engine.kv_config().bytes_per_page();
        assert_eq!(wm.prefix_cached_bytes, 4 * page);
        assert_eq!(cm.prefix_hits, 0, "cache off: no hits, no cached bytes");
        assert_eq!(cm.prefix_cached_bytes, 0);
    }

    #[test]
    fn mid_prefill_cancel_on_attached_lane_leaves_cache_intact() {
        // A follower attaches a cached block, then is cancelled while its
        // remaining prompt is still prefilling.  The lane's column refs
        // must return to baseline — the cache keeps its pages, nothing is
        // freed twice, and a later identical request still hits and emits
        // the cold-path tokens (no resurrected or corrupted pages).
        let spec = StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 2,
            vocab: 16,
            max_positions: 128,
            batch_slots: 1,
            chunk_widths: vec![1],
            ..Default::default()
        };
        let engine = Engine::new_stub(spec.clone()).with_prefix_cache(Some(16)).unwrap();
        let now = Instant::now();
        let base: Vec<i32> = (0..32).map(|i| (i % 16) as i32).collect();
        let reqs = vec![
            Request::greedy(0, base.clone(), 2, now),
            Request::greedy(1, base.clone(), 4, now), // cancelled mid-prefill
            Request::greedy(2, base.clone(), 2, now),
        ];
        let mut hook = PrefillCancelHook {
            target: 1,
            fired: false,
            started: Vec::new(),
            target_tokens: 0,
            cancelled: Vec::new(),
        };
        let (out, m) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut hook)
            .unwrap();
        assert_eq!(hook.cancelled.len(), 1, "request 1 cancelled");
        assert_eq!(hook.target_tokens, 0, "cancel landed before its first token");
        assert_eq!(m.prefix_hits, 2, "the cancelled lane and the survivor both attached");
        assert_eq!(m.prefix_hit_tokens, 16 + 16);
        // Request 0's two 16-token blocks survive the cancel untouched.
        let page = engine.kv_config().bytes_per_page();
        assert_eq!(m.prefix_cached_bytes, 2 * page);
        // The survivor's cache-hit output matches a cold single-request
        // serve bit for bit.
        let cold = Engine::new_stub(spec);
        let (cc, _) = cold
            .serve_all(vec![Request::greedy(9, base, 2, now)], policy())
            .unwrap();
        let survivor = out.iter().find(|c| c.id == 2).expect("request 2 completed");
        assert_eq!(survivor.tokens, cc[0].tokens, "hit output == cold output");
    }

    #[test]
    fn prefix_cache_evicts_lru_under_memory_budget() {
        // Budget sized so a fresh request only fits once the cache yields:
        // rank 2 pages are 256 B; request 0's donated 2 pages (512 B) must
        // be evicted before request 1's 768-byte worst case is admitted.
        // The cache is an opportunist — it never keeps a request queued.
        let spec = StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 2,
            vocab: 16,
            max_positions: 128,
            batch_slots: 1,
            ..Default::default()
        };
        let engine = Engine::new_stub(spec)
            .with_prefix_cache(Some(32))
            .unwrap()
            .with_kv_memory_budget(Some(768));
        let page = engine.kv_config().bytes_per_page();
        assert_eq!(page, 256, "rank-2 identity page: 16 B/token x 16 tokens");
        let now = Instant::now();
        let a: Vec<i32> = (0..32).map(|i| (i % 16) as i32).collect();
        let b: Vec<i32> = (0..32).map(|i| ((i + 7) % 16) as i32).collect();
        let reqs = vec![Request::greedy(0, a, 4, now), Request::greedy(1, b, 4, now)];
        let (out, m) = engine.serve_all(reqs, policy()).unwrap();
        assert_eq!(out.len(), 2, "eviction admitted the second request");
        assert_eq!(m.prefix_hits, 0, "disjoint prompts never hit");
        assert_eq!(m.prefix_evicted_bytes, 2 * page, "request 0's blocks were evicted");
        assert_eq!(m.prefix_cached_bytes, 2 * page, "request 1's blocks replaced them");
    }

    /// Surrenders up to `max` queued requests once — the engine-side half
    /// of the fleet scheduler's queue-migration protocol.
    #[derive(Default)]
    struct ReclaimOnceHook {
        fired: bool,
        max: usize,
        reclaimed: Vec<Request>,
    }

    impl StepHook for ReclaimOnceHook {
        fn reclaim_requests(&mut self) -> Option<usize> {
            if self.fired {
                None
            } else {
                self.fired = true;
                Some(self.max)
            }
        }

        fn on_reclaimed(&mut self, req: Request) {
            self.reclaimed.push(req);
        }
    }

    #[test]
    fn reclaimed_requests_leave_from_the_back_and_stay_conserved() {
        // Four enqueued, two reclaimed before the first admission pass:
        // the *newest* waiters leave (the head keeps its FIFO claim), the
        // conservation check books them as migrated — neither completed
        // nor cancelled here — and the reclaimed requests come back out
        // intact for the coordinating scheduler to resubmit elsewhere.
        let engine = Engine::new_stub(codec_spec());
        let mut hook = ReclaimOnceHook { max: 2, ..Default::default() };
        let (out, m) = engine
            .serve_hooked(codec_reqs(4), policy(), Admission::Continuous, &mut hook)
            .unwrap();
        let ids: Vec<u64> = hook.reclaimed.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![3, 2], "back of the queue leaves first");
        assert_eq!(hook.reclaimed[0].prompt.len(), 8, "request returned intact");
        assert_eq!(m.migrated, 2);
        assert_eq!(m.completed, 2);
        let done: Vec<u64> = out.iter().map(|c| c.id).collect();
        assert_eq!(done, vec![0, 1], "survivors complete locally");
    }

    // ---- fault injection: retry, fail-all, quarantine (stub-backed) ----

    /// Collects `Failed` terminal events — the gateway supervisor's view
    /// of a dying engine.
    #[derive(Default)]
    struct FailHook {
        failed: Vec<(u64, Vec<i32>, FailReason, usize)>,
    }

    impl StepHook for FailHook {
        fn on_failed(&mut self, id: u64, tokens: Vec<i32>, reason: FailReason, step: usize) {
            self.failed.push((id, tokens, reason, step));
        }
    }

    #[test]
    fn transient_faults_retry_to_bit_identical_output() {
        // Seed 4 at rate 0.25 first faults at step 5 and never runs more
        // than 3 consecutive faults — inside the default 3-retry budget,
        // so every fault is absorbed by a retry.  A retried step commits
        // nothing twice (the stub faults before its cache writes; the
        // session only observes logits after Ok), so the output is
        // bit-identical to the fault-free run.
        let (base, bm) = Engine::new_stub(stub_spec()).serve_all(codec_reqs(4), policy()).unwrap();
        let plan = FaultPlan { seed: 4, transient_rate: 0.25, ..FaultPlan::default() };
        let engine = Engine::new_stub(stub_spec()).with_fault_plan(plan).unwrap();
        let (out, m) = engine.serve_all(codec_reqs(4), policy()).unwrap();
        assert_eq!(bm.completed, 4);
        assert_eq!(m.completed, 4);
        assert_eq!(m.failed, 0);
        assert!(m.step_faults > 0, "seed 4 must fault within this serve");
        assert_eq!(m.step_retries, m.step_faults, "every fault was retried, none fatal");
        for (a, b) in out.iter().zip(&base) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "request {}: retries must not change tokens", a.id);
        }
    }

    #[test]
    fn fatal_backend_death_fails_everything_with_terminal_events() {
        // The backend dies at step 4 (`fatal_after_steps: 3`): the serve
        // returns Err, and every request — holding a lane or still
        // queued — gets exactly one Failed(Backend) event carrying its
        // partial row, which is a prefix of the fault-free output: the
        // supervisor can replay it losslessly.
        let spec = StubSpec { batch_slots: 2, ..stub_spec() };
        let (base, _) = Engine::new_stub(spec.clone()).serve_all(codec_reqs(4), policy()).unwrap();
        let plan = FaultPlan { seed: 1, fatal_after_steps: Some(3), ..FaultPlan::default() };
        let engine = Engine::new_stub(spec).with_fault_plan(plan).unwrap();
        let mut hook = FailHook::default();
        let err = engine
            .serve_hooked(codec_reqs(4), policy(), Admission::Continuous, &mut hook)
            .unwrap_err();
        assert!(err.to_string().contains("died mid-serve"), "{err:#}");
        assert_eq!(hook.failed.len(), 4, "every request got a terminal event");
        let mut ids: Vec<u64> = hook.failed.iter().map(|f| f.0).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3], "each exactly once");
        for (id, partial, reason, _) in &hook.failed {
            assert_eq!(*reason, FailReason::Backend, "request {id}");
            let full = &base.iter().find(|c| c.id == *id).expect("in base").tokens;
            assert!(
                partial.len() <= full.len() && full[..partial.len()] == partial[..],
                "request {id}: partial row must be a replayable prefix of the \
                 fault-free output"
            );
        }
    }

    #[test]
    fn retry_budget_exhaustion_is_fatal() {
        // transient_rate 1.0: every attempt faults, the default 3-retry
        // budget exhausts, and the error names the budget — while the
        // in-flight requests still get their terminal events.
        let plan = FaultPlan { seed: 9, transient_rate: 1.0, ..FaultPlan::default() };
        let engine = Engine::new_stub(stub_spec()).with_fault_plan(plan).unwrap();
        let mut hook = FailHook::default();
        let err = engine
            .serve_hooked(codec_reqs(2), policy(), Admission::Continuous, &mut hook)
            .unwrap_err();
        assert!(err.to_string().contains("died mid-serve"), "{err:#}");
        assert!(format!("{err:#}").contains("retry budget"), "{err:#}");
        assert_eq!(hook.failed.len(), 2);
        assert!(hook.failed.iter().all(|f| f.2 == FailReason::Backend));
    }

    #[test]
    fn poisoned_lane_quarantines_and_backlog_fails() {
        // poison_rate 1.0 on a single-lane engine: step 1 NaNs lane 0's
        // readout.  The victim fails *individually* (Poisoned — replaying
        // it verbatim would poison another lane), the lane is quarantined
        // rather than freed, and with every lane quarantined the queued
        // request can never be admitted: it fails too (Backend — that one
        // *is* replayable) and the serve reports the engine unusable.
        let spec = StubSpec {
            batch_slots: 1,
            fault_plan: FaultPlan { seed: 7, poison_rate: 1.0, ..FaultPlan::default() },
            ..stub_spec()
        };
        let engine = Engine::new_stub(spec);
        let mut hook = FailHook::default();
        let err = engine
            .serve_hooked(codec_reqs(2), policy(), Admission::Continuous, &mut hook)
            .unwrap_err();
        assert!(err.to_string().contains("quarantined"), "{err:#}");
        assert_eq!(hook.failed.len(), 2);
        assert_eq!((hook.failed[0].0, hook.failed[0].2), (0, FailReason::Poisoned));
        assert_eq!((hook.failed[1].0, hook.failed[1].2), (1, FailReason::Backend));
    }

    /// Cancels `id` (reason Deadline) once the virtual clock passes
    /// `deadline` — modelling a deadline expiry that lands *inside* a
    /// retry backoff window, where the backoff sleep is what carries the
    /// clock past the deadline.
    struct DeadlineHook {
        id: u64,
        deadline: Instant,
        fired: bool,
        started: Vec<(u64, usize)>,
        cancelled: Vec<(u64, Vec<i32>, CancelReason, usize)>,
    }

    impl StepHook for DeadlineHook {
        fn take_cancellations(&mut self, now: Instant) -> Vec<Cancellation> {
            if !self.fired && now >= self.deadline {
                self.fired = true;
                return vec![Cancellation { id: self.id, reason: CancelReason::Deadline }];
            }
            Vec::new()
        }

        fn on_started(&mut self, id: u64, _lane: usize, step: usize) {
            self.started.push((id, step));
        }

        fn on_cancelled(&mut self, id: u64, tokens: Vec<i32>, reason: CancelReason, step: usize) {
            self.cancelled.push((id, tokens, reason, step));
        }
    }

    #[test]
    fn deadline_expiry_during_retry_backoff_cancels_exactly_once() {
        // Seed 15 at rate 0.4 faults the very first attempt, so the 1 ms
        // backoff sleep is the only thing that moves the manual clock
        // past the 500 µs deadline: the expiry lands during a retry
        // backoff window by construction.  The retried step still
        // completes (committing nothing twice), the cancel retires the
        // lane at the next poll — exactly one terminal event — and the
        // waiter reclaims the lane in the same iteration.
        let clock = Clock::manual();
        let spec = StubSpec {
            batch_slots: 1,
            chunk_widths: vec![1],
            clock: clock.clone(),
            fault_plan: FaultPlan { seed: 15, transient_rate: 0.4, ..FaultPlan::default() },
            ..stub_spec()
        };
        let engine = Engine::new_stub(spec)
            .with_retry_policy(RetryPolicy { budget: 8, backoff: Duration::from_millis(1) });
        let now = clock.now();
        let reqs = vec![
            Request::greedy(0, (0..8).collect(), 4, now),
            Request::greedy(1, vec![7], 2, now),
        ];
        let mut hook = DeadlineHook {
            id: 0,
            deadline: now + Duration::from_micros(500),
            fired: false,
            started: Vec::new(),
            cancelled: Vec::new(),
        };
        let (out, m) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut hook)
            .unwrap();
        assert!(m.step_retries >= 1, "the first attempt must have been retried");
        assert_eq!(hook.cancelled.len(), 1, "exactly one terminal event for id 0");
        let (cid, _, reason, cancel_step) = &hook.cancelled[0];
        assert_eq!((*cid, *reason), (0, CancelReason::Deadline));
        let waiter = hook
            .started
            .iter()
            .find(|&&(id, _)| id == 1)
            .map(|&(_, step)| step)
            .expect("waiter admitted");
        assert_eq!(waiter, *cancel_step, "same-iteration lane reclaim");
        assert_eq!(out.iter().map(|c| c.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!((m.completed, m.cancelled, m.failed), (1, 1, 0));
    }

    #[test]
    fn deadline_expiry_during_verify_slab_retry_cancels_exactly_once() {
        // Speculative pair on one lane, target fault seed 8 at rate 0.4:
        // target step 1 (prefill) is clean, target step 2 — the round's
        // *verify slab* — faults and retries, and that backoff is what
        // carries the manual clock past the deadline.  The cancel lands
        // at the next poll, mid-round: one terminal event, the waiter
        // reclaims the lane (and its mirrored draft lane) in the same
        // iteration, and the drain's KV + request conservation checks
        // pass (serve returns Ok).
        let clock = Clock::manual();
        let target = StubSpec {
            batch_slots: 1,
            fault_plan: FaultPlan { seed: 8, transient_rate: 0.4, ..FaultPlan::default() },
            ..spec_target_spec()
        };
        let draft = StubSpec { rank: 4, batch_slots: 1, ..spec_target_spec() };
        let engine = Engine::new_stub(target)
            .with_speculative_stub(draft, SpecConfig::default())
            .unwrap()
            .with_retry_policy(RetryPolicy { budget: 8, backoff: Duration::from_millis(1) })
            .with_clock(clock.clone());
        let now = clock.now();
        let spec_req = Request {
            id: 0,
            prompt: (0..8).collect(),
            max_new: 12,
            arrived: now,
            sampling: SamplingParams::speculative_greedy(),
        };
        let reqs = vec![spec_req, Request::greedy(1, vec![7], 2, now)];
        let mut hook = DeadlineHook {
            id: 0,
            deadline: now + Duration::from_micros(500),
            fired: false,
            started: Vec::new(),
            cancelled: Vec::new(),
        };
        let (out, m) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut hook)
            .unwrap();
        assert!(m.step_retries >= 1, "the verify slab must have been retried");
        assert!(m.spec_rounds >= 1, "the cancel landed after a verify round ran");
        assert_eq!(hook.cancelled.len(), 1, "exactly one terminal event for id 0");
        let (cid, partial, reason, cancel_step) = &hook.cancelled[0];
        assert_eq!((*cid, *reason), (0, CancelReason::Deadline));
        assert!(partial.len() > 8, "the round's accepted tokens are in the partial row");
        let waiter = hook
            .started
            .iter()
            .find(|&&(id, _)| id == 1)
            .map(|&(_, step)| step)
            .expect("waiter admitted");
        assert_eq!(waiter, *cancel_step, "same-iteration lane + draft-lane reclaim");
        assert_eq!((m.completed, m.cancelled, m.failed), (1, 1, 0));
        // The survivor's output matches a clean fault-free serve bit for
        // bit — no stale speculative or fault state leaked into its lane.
        let clean = Engine::new_stub(StubSpec { batch_slots: 1, ..spec_target_spec() });
        let (cc, _) = clean
            .serve_all(vec![Request::greedy(1, vec![7], 2, Instant::now())], policy())
            .unwrap();
        assert_eq!(out[0].tokens, cc[0].tokens);
    }
}
