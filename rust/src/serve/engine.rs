//! Continuous-batching serving engine over a token-slab step API.
//!
//! The scheduler is slot-granular: every fused step runs all `B` batch
//! lanes of the fixed-shape step artifacts at once, and *between* steps
//! the engine retires finished sessions and admits queued requests into
//! the freed lanes (zero the lane, restart its position counter at 0).  A
//! request that finishes at step 10 hands its KV lane to the next waiter
//! at step 11 — no lane idles while the longest request in a wave drains,
//! which is exactly how pruned-rank KV savings turn into served traffic.
//!
//! Each iteration the engine builds a [`StepPlan`]: every live lane
//! contributes a *token slab* — the widest admissible chunk of unconsumed
//! prompt during prefill, the single fed-back token during decode — and
//! the plan dispatches to the artifact for the step's width (lanes with
//! narrower slabs pad by repeating their last `(token, position)` pair,
//! an idempotent rewrite).  A 64-token prompt therefore reaches its first
//! sampled token in `ceil(64/K)` steps instead of 64, *while its
//! neighbours keep decoding in the same fused steps* — chunked prefill is
//! the API default, not a special mode.
//!
//! Single-threaded executor by design: the PJRT handles are not Sync, and
//! this box has one core — concurrency is expressed by the request queue,
//! not OS threads.  `serve_all` is the synchronous closed-set core the CLI
//! demo, example, and bench drive.  The step loop is additionally
//! observable and steerable through [`StepHook`]: per-token/lifecycle
//! callbacks fire as they happen, cancellation orders retire sessions
//! between steps, and [`Engine::serve_open`] runs the same loop
//! open-ended, fed from channels by the thread-owning
//! [`crate::server`] gateway.
//!
//! Engines run on one of two backings: the compiled HLO artifacts through
//! [`crate::runtime::DecodeSession`] (production), or the deterministic
//! host-side [`crate::runtime::stub::StubModel`] ([`Engine::new_stub`]) so
//! every scheduling property — including the K=1 vs K=8 bit-identity of
//! chunked prefill — is testable without a live PJRT backend.

use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::time::Instant;

use crate::model::params::ParamSet;
use crate::runtime::stub::{StubModel, StubSpec};
use crate::runtime::{DecodeSession, Runtime};
use crate::tensor::{Tensor, Value};
use crate::util::Stopwatch;

use super::batcher::{BatchPolicy, Batcher, Request};
use super::kv::{KvConfig, KvManager};
use super::session::Session;

/// One finished request, with its own latency accounting: every duration
/// is measured against *this* request's arrival and completion, not the
/// wall time of whatever batch it shared lanes with.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    /// Prompt + generated tokens.
    pub tokens: Vec<i32>,
    /// Arrival → this request's own last token.
    pub latency_s: f64,
    /// Arrival → first *generated* token (== latency_s when nothing was
    /// generated).
    pub ttft_s: f64,
    /// Arrival → admission into a KV lane.
    pub queue_wait_s: f64,
    /// Fused steps this request occupied a lane for.
    pub steps: usize,
    /// Fused steps that consumed prompt tokens — `ceil(prompt/K)` under a
    /// K-wide chunk ladder vs `prompt` under single-token prefill.
    pub prefill_steps: usize,
    /// Engine-global decode-step counter at completion.
    pub finished_step: usize,
}

/// One lane's slab within a [`StepPlan`]: `len` row tokens starting at row
/// position `start` (positions `start..start+len` of the request).  `len <
/// plan.width` means the lane pads by repeating its last pair.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LaneSlab {
    pub id: u64,
    pub start: usize,
    pub len: usize,
}

/// The work order for one fused step: the slab width to dispatch (which
/// selects the artifact — `decode_*` at width 1, `prefill_k{W}_*` above)
/// and each lane's slab.  Built fresh every iteration from the live
/// sessions; prefill and decode lanes mix freely in one plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepPlan {
    pub width: usize,
    pub slabs: Vec<Option<LaneSlab>>,
}

impl StepPlan {
    /// Plan the next fused step: each live session asks for the widest
    /// admissible chunk of its pending row ([`chunk_width`]), and the step
    /// dispatches at the maximum over lanes so nobody waits an extra step.
    pub fn build(widths: &[usize], lanes: &[Option<Session>]) -> StepPlan {
        let mut width = 1;
        for s in lanes.iter().flatten() {
            width = width.max(chunk_width(widths, s.pending()));
        }
        let slabs = lanes
            .iter()
            .map(|l| {
                l.as_ref().map(|s| {
                    let (slab, start) = s.next_slab(width);
                    LaneSlab { id: s.id(), start, len: slab.len() }
                })
            })
            .collect();
        StepPlan { width, slabs }
    }

    /// Total row tokens this plan consumes (pads excluded).
    pub fn tokens(&self) -> usize {
        self.slabs.iter().flatten().map(|s| s.len).sum()
    }
}

/// The slab width a lane with `remaining` unconsumed row tokens asks for,
/// given the engine's width ladder (ascending, containing 1):
///
/// * the **widest** ladder width that fits entirely (`w <= remaining`) —
///   no padding waste when a big chunk fits;
/// * else the **narrowest** width above 1, padding the remainder in one
///   step rather than single-stepping it (`remaining = 5` under a
///   `{1, 8, 32}` ladder takes one padded 8-wide step, not five steps);
/// * 1 when the lane is decoding (`remaining == 1`) or the ladder has no
///   chunks.
pub fn chunk_width(widths: &[usize], remaining: usize) -> usize {
    debug_assert!(remaining >= 1);
    let mut best = 1;
    for &w in widths {
        if w <= remaining && w > best {
            best = w;
        }
    }
    if best == 1 && remaining > 1 {
        if let Some(&w) = widths.iter().filter(|&&w| w > 1).min() {
            best = w;
        }
    }
    best
}

/// How freed lanes are refilled.  [`Admission::Continuous`] is the engine's
/// normal mode; [`Admission::WaveToCompletion`] reproduces the old
/// batch-to-completion behavior (admit only when *all* lanes are free) and
/// exists so benches can measure exactly what slot-level scheduling buys.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Continuous,
    WaveToCompletion,
}

/// Why a request was retired without completing.  (Graceful shutdown is
/// deliberately *not* a reason: the gateway drains accepted work to
/// completion instead of cancelling it.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// Explicit client cancellation (a cancel token fired).
    User,
    /// The request's deadline expired before it finished.
    Deadline,
}

/// A cancellation order, applied by the step loop *between* decode steps:
/// the session retires, its partial tokens go out through the hook, and its
/// KV lane frees immediately — the next admission pass (same iteration,
/// before the next decode step) can hand the lane to a waiting request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cancellation {
    pub id: u64,
    pub reason: CancelReason,
}

/// Per-step observer and control surface threaded through the engine loop.
///
/// The engine only *returns* finished [`Completion`]s; everything live —
/// admissions, per-token sampling, retirements — is invisible to a
/// `serve_all` caller until the drain ends.  A `StepHook` sees each of
/// those moments as it happens, which is what the `server::` layer turns
/// into per-request event streams, and feeds control back in: new requests
/// between steps (`poll_ingress`) and cancellation orders
/// (`take_cancellations`).  All methods default to no-ops so closed-set
/// serving pays nothing.
pub trait StepHook {
    /// New requests to enqueue, polled between decode steps (open-loop
    /// serving only).  `idle` is true when the engine has no live lanes and
    /// an empty queue — the hook may block until traffic arrives instead of
    /// spinning.  Return `None` once the ingress is closed for good: the
    /// engine drains what it has and returns.
    fn poll_ingress(&mut self, _idle: bool) -> Option<Vec<Request>> {
        None
    }

    /// Cancellation orders (fired cancel tokens + expired deadlines) to
    /// apply before the next decode step.
    fn take_cancellations(&mut self, _now: Instant) -> Vec<Cancellation> {
        Vec::new()
    }

    /// A request was admitted into KV lane `lane` after `step` fused
    /// steps — it contributes its first slab to the very next plan.
    fn on_started(&mut self, _id: u64, _lane: usize, _step: usize) {}

    /// A token was sampled for `id` at row position `pos` — delivered as it
    /// is sampled, not at wave end.
    fn on_token(&mut self, _id: u64, _pos: usize, _token: i32, _step: usize) {}

    /// A request finished; `completion` carries its full row + latencies.
    fn on_done(&mut self, _completion: &Completion) {}

    /// A request was cancelled; `tokens` is the partial row (prompt +
    /// whatever was generated before retirement).
    fn on_cancelled(&mut self, _id: u64, _tokens: Vec<i32>, _reason: CancelReason, _step: usize) {}
}

/// The no-op hook closed-set serving runs with.
pub struct NoHook;

impl StepHook for NoHook {}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub completed: usize,
    /// Requests retired early (cancel token or deadline expiry).
    pub cancelled: usize,
    /// Generated (non-prompt) tokens, including those streamed out by
    /// requests that were later cancelled mid-decode.
    pub generated_tokens: usize,
    pub wall_s: f64,
    pub kv_peak_bytes: usize,
    /// Fused steps executed (each runs all batch lanes, at whatever slab
    /// width the step's plan selected).
    pub decode_steps: usize,
    /// Row tokens consumed across all fused steps (prompt chunks + fed-back
    /// tokens, padding excluded).  `slab_tokens / decode_steps` is the
    /// effective tokens-per-step the chunk ladder buys.
    pub slab_tokens: usize,
    /// Requests admitted into a lane (== completed after a full drain when
    /// nothing was cancelled).
    pub admissions: usize,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
}

impl ServeMetrics {
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.generated_tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }

    fn observe_latencies(&mut self, mut lat: Vec<f64>, mut ttft: Vec<f64>) {
        lat.sort_by(f64::total_cmp);
        ttft.sort_by(f64::total_cmp);
        self.latency_p50_s = percentile(&lat, 0.50);
        self.latency_p99_s = percentile(&lat, 0.99);
        self.ttft_p50_s = percentile(&ttft, 0.50);
        self.ttft_p99_s = percentile(&ttft, 0.99);
    }
}

/// Percentile by rounded linear index over an ascending-sorted slice
/// (`round((n-1)·q)`; 0.0 for empty) — so p50 of `[1,2,3,4]` is 3.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Where an engine's fused steps execute.
enum Backing<'rt> {
    /// Compiled HLO artifacts through PJRT: the width-1 decode program
    /// plus every `prefill_k{K}` sibling discovered in the manifest.
    Pjrt {
        rt: &'rt Runtime,
        config: String,
        /// `(width, program name)`, width 1 always present.
        programs: Vec<(usize, String)>,
        params: ParamSet,
    },
    /// Deterministic host-side stub model — the same step contract with
    /// no PJRT dependency (scheduling tests, step-count benches).
    Stub(StubSpec),
}

pub struct Engine<'rt> {
    backing: Backing<'rt>,
    kv_cfg: KvConfig,
    batch_slots: usize,
    vocab: usize,
    /// Slab-width ladder, ascending, always containing 1.
    widths: Vec<usize>,
}

impl<'rt> Engine<'rt> {
    /// `program` is a decode artifact (e.g. "decode_b8" or
    /// "decode_fac_r8_b8"); its cache input fixes batch size and rank.
    /// Chunked-prefill siblings (`prefill_k{K}_b{B}` /
    /// `prefill_fac_r{r}_k{K}_b{B}`) are discovered through the manifest's
    /// `prefill_chunks` and join the step ladder automatically — cap or
    /// disable them with [`Engine::with_prefill_chunk`].
    pub fn new(rt: &'rt Runtime, config: &str, program: &str, params: ParamSet) -> Result<Self> {
        let entry = rt.manifest().config(config)?;
        let sig = entry.program(program)?.clone();
        let vocab = entry.dim("vocab")?;
        let cache = sig.inputs.iter().find(|a| a.name.ends_with("_cache"))
            .context("decode program lacks a cache input")?;
        let (l, b, h, c, r) = (
            cache.shape[0], cache.shape[1], cache.shape[2], cache.shape[3], cache.shape[4],
        );
        // Discover the chunk ladder: "decode{mid}_b{B}" has prefill
        // siblings "prefill{mid}_k{K}_b{B}" sharing its cache block.
        let mut programs = vec![(1usize, program.to_string())];
        let mut widths = vec![1usize];
        if let Some(mid) = program
            .strip_prefix("decode")
            .and_then(|rest| rest.strip_suffix(&format!("_b{b}")))
        {
            for &ck in &entry.prefill_chunks {
                let name = format!("prefill{mid}_k{ck}_b{b}");
                if entry.programs.contains_key(&name) {
                    programs.push((ck, name));
                    widths.push(ck);
                }
            }
        }
        widths.sort_unstable();
        Ok(Self {
            backing: Backing::Pjrt {
                rt,
                config: config.into(),
                programs,
                params,
            },
            kv_cfg: KvConfig {
                n_layers: l,
                n_heads: h,
                rank: r,
                max_positions: c,
                batch_slots: b,
            },
            batch_slots: b,
            vocab,
            widths,
        })
    }

    /// An engine over the deterministic host-side stub model: identical
    /// scheduling (plans, admission, cancellation, KV accounting) with the
    /// step math replaced by [`StubModel`].  This is how the serving
    /// stack's behaviour — including chunked-prefill bit-identity — is
    /// exercised on machines and CI runners without a PJRT backend.
    pub fn new_stub(spec: StubSpec) -> Engine<'static> {
        let kv_cfg = KvConfig {
            n_layers: spec.n_layers,
            n_heads: spec.n_heads,
            rank: spec.rank,
            max_positions: spec.max_positions,
            batch_slots: spec.batch_slots,
        };
        let widths = spec.widths();
        Engine {
            kv_cfg,
            batch_slots: spec.batch_slots,
            vocab: spec.vocab,
            widths,
            backing: Backing::Stub(spec),
        }
    }

    /// Cap the slab ladder at `cap` tokens (`Some(1)` disables chunked
    /// prefill entirely; `None` keeps every discovered width).  The CLI
    /// exposes this as `clover serve --prefill-chunk N`.
    pub fn with_prefill_chunk(mut self, cap: Option<usize>) -> Self {
        if let Some(cap) = cap {
            let cap = cap.max(1);
            self.widths.retain(|&w| w <= cap);
            if let Backing::Pjrt { programs, .. } = &mut self.backing {
                programs.retain(|(w, _)| *w <= cap);
            }
        }
        self
    }

    /// The slab-width ladder this engine plans over (ascending, starts
    /// at 1).
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// Widest slab a single step can consume (1 = chunking disabled).
    pub fn max_chunk(&self) -> usize {
        self.widths.last().copied().unwrap_or(1)
    }

    pub fn kv_config(&self) -> &KvConfig {
        &self.kv_cfg
    }

    /// Serve a closed set of requests to completion with continuous
    /// (slot-level) batching.  Completions come back in input order, keyed
    /// by id — ids may be arbitrary u64s, but must be unique within a call.
    pub fn serve_all(
        &self,
        requests: Vec<Request>,
        policy: BatchPolicy,
    ) -> Result<(Vec<Completion>, ServeMetrics)> {
        self.serve_with(requests, policy, Admission::Continuous)
    }

    /// [`Engine::serve_all`] with an explicit admission mode (benches use
    /// [`Admission::WaveToCompletion`] as the before-refactor baseline).
    pub fn serve_with(
        &self,
        requests: Vec<Request>,
        policy: BatchPolicy,
        admission: Admission,
    ) -> Result<(Vec<Completion>, ServeMetrics)> {
        self.serve_hooked(requests, policy, admission, &mut NoHook)
    }

    /// Closed-set serving with a per-step observer: identical scheduling to
    /// [`Engine::serve_with`] (a [`NoHook`] hook reproduces it bit-for-bit),
    /// plus streamed `on_token`/`on_done` callbacks and cancellation orders
    /// applied between decode steps.
    pub fn serve_hooked(
        &self,
        requests: Vec<Request>,
        policy: BatchPolicy,
        admission: Admission,
        hook: &mut dyn StepHook,
    ) -> Result<(Vec<Completion>, ServeMetrics)> {
        self.serve_core(requests, policy, admission, hook, false)
    }

    /// Open-loop serving: the thread-owning `server::` gateway's entry
    /// point.  Requests arrive through `hook.poll_ingress` between decode
    /// steps (blocking when the engine is idle) until the hook closes the
    /// ingress, after which the engine drains and returns its metrics.
    /// Completions are delivered exclusively through `hook.on_done` /
    /// `hook.on_cancelled` — no per-request rows are retained (only the
    /// id-uniqueness set and per-completion latency samples for the final
    /// percentiles grow with traffic).
    pub fn serve_open(&self, policy: BatchPolicy, hook: &mut dyn StepHook) -> Result<ServeMetrics> {
        let (_, metrics) = self.serve_core(Vec::new(), policy, Admission::Continuous, hook, true)?;
        Ok(metrics)
    }

    fn serve_core(
        &self,
        initial: Vec<Request>,
        policy: BatchPolicy,
        admission: Admission,
        hook: &mut dyn StepHook,
        open: bool,
    ) -> Result<(Vec<Completion>, ServeMetrics)> {
        if policy.max_batch == 0 {
            bail!("BatchPolicy.max_batch must be >= 1");
        }
        let order: Vec<u64> = initial.iter().map(|r| r.id).collect();
        let mut uniq = HashSet::new();
        for id in &order {
            if !uniq.insert(*id) {
                bail!("duplicate request id {id}");
            }
        }

        let sw = Stopwatch::new();
        let b = self.batch_slots;
        let cap = policy.max_batch.min(b);
        let cwin = self.kv_cfg.max_positions;
        let mut batcher = Batcher::new(policy);
        for r in initial {
            if r.prompt.is_empty() {
                bail!("request {}: empty prompt — rejected at admission", r.id);
            }
            batcher.push(r);
        }
        let mut kv = KvManager::new(self.kv_cfg.clone());
        let mut lanes: Vec<Option<Session>> = (0..b).map(|_| None).collect();
        let mut done: HashMap<u64, Completion> = HashMap::new();
        let mut metrics = ServeMetrics::default();
        let (mut lat, mut ttfts): (Vec<f64>, Vec<f64>) = (Vec::new(), Vec::new());
        let mut ingress_open = open;

        // Build the step backend.  PJRT: params marshalled once, KV caches
        // literal-side across the whole loop (host round-trips only on
        // lane churn), every ladder width sharing that one cache set.
        let mut backend = match &self.backing {
            Backing::Pjrt { rt, config, programs, params } => {
                let param_values: Vec<Value> =
                    params.flat().iter().map(|&t| Value::F32(t.clone())).collect();
                StepBackend::Pjrt(DecodeSession::new_planned(rt, config, programs, &param_values)?)
            }
            Backing::Stub(spec) => StepBackend::Stub(StubModel::new(spec.clone())),
        };

        loop {
            // ---- ingress: accept new work between decode steps ----
            if ingress_open {
                let idle = batcher.is_empty() && lanes.iter().all(|l| l.is_none());
                match hook.poll_ingress(idle) {
                    None => ingress_open = false,
                    Some(reqs) => {
                        for r in reqs {
                            if !uniq.insert(r.id) {
                                bail!("duplicate request id {}", r.id);
                            }
                            if r.prompt.is_empty() {
                                bail!("request {}: empty prompt — rejected at admission", r.id);
                            }
                            batcher.push(r);
                        }
                    }
                }
            }
            if !ingress_open && batcher.is_empty() && lanes.iter().all(|l| l.is_none()) {
                break; // drained
            }

            let now = Instant::now();
            // ---- cancellation: retire sessions between decode steps ----
            // A cancelled lane frees *before* this iteration's admission
            // pass, so a waiting request reclaims it without skipping a
            // decode step.
            for c in hook.take_cancellations(now) {
                let lane = lanes
                    .iter()
                    .position(|l| l.as_ref().is_some_and(|s| s.id() == c.id));
                if let Some(lane) = lane {
                    let sess = lanes[lane].take().expect("lane occupied");
                    kv.free(sess.slot())?;
                    metrics.cancelled += 1;
                    metrics.generated_tokens += sess.generated();
                    hook.on_cancelled(c.id, sess.into_tokens(), c.reason, metrics.decode_steps);
                } else if let Some(req) = batcher.remove(c.id) {
                    metrics.cancelled += 1;
                    hook.on_cancelled(c.id, req.prompt, c.reason, metrics.decode_steps);
                }
                // Unknown or already-finished id: completion won the race.
            }

            // ---- admission: refill freed lanes between decode steps ----
            let mut live = lanes.iter().filter(|l| l.is_some()).count();
            let gate_open = match admission {
                Admission::Continuous => true,
                Admission::WaveToCompletion => live == 0,
            };
            let mut fresh: Vec<usize> = Vec::new();
            if gate_open {
                while live < cap && kv.free_slots() > 0 {
                    // Admit whenever capacity exists: a fused decode step
                    // runs all B lanes whether occupied or not, so holding a
                    // waiter back never helps (max_wait is a wave-admission
                    // knob; slot-level admission ignores it).
                    let Some(req) = batcher.pop_admissible(now, true) else { break };
                    let slot = kv.allocate(req.id)?;
                    let sess = Session::new(req, slot, cwin, now);
                    metrics.admissions += 1;
                    hook.on_started(sess.id(), slot, metrics.decode_steps);
                    if sess.is_done() {
                        // Nothing to decode (max_new == 0 or the prompt
                        // already fills the window): complete immediately.
                        kv.free(slot)?;
                        metrics.completed += 1;
                        let c = sess.finish(now, metrics.decode_steps);
                        lat.push(c.latency_s);
                        ttfts.push(c.ttft_s);
                        hook.on_done(&c);
                        if !open {
                            done.insert(c.id, c);
                        }
                        continue;
                    }
                    lanes[slot] = Some(sess);
                    fresh.push(slot);
                    live += 1;
                }
            }
            if lanes.iter().all(|l| l.is_none()) {
                if batcher.is_empty() {
                    if ingress_open {
                        continue; // back to a blocking ingress poll
                    }
                    break; // everything completed at admission time
                }
                bail!("scheduler stalled: free lanes but nothing admissible");
            }
            // Zero re-assigned lanes so no stale KV rows survive a slot
            // handoff.  Skipped before the first step (caches are zeros),
            // and costs one host round-trip per churn event — not per token.
            if metrics.decode_steps > 0 && !fresh.is_empty() {
                backend.zero_lanes(&fresh)?;
            }

            // ---- one fused step over all lanes: slab build → dispatch ----
            // Every live lane contributes a slab (prompt chunk or fed-back
            // token); the plan's width picks the artifact; short slabs pad
            // by repeating their last (token, position) pair — an
            // idempotent rewrite the slab programs guarantee.
            let plan = StepPlan::build(&self.widths, &lanes);
            let w = plan.width;
            let mut toks = vec![0i32; b * w];
            let mut poss = vec![0i32; b * w];
            for (lane, slab) in plan.slabs.iter().enumerate() {
                let Some(slab) = slab else { continue };
                let row = lanes[lane].as_ref().expect("slab for occupied lane").tokens();
                for j in 0..w {
                    let jj = j.min(slab.len - 1);
                    toks[lane * w + j] = row[slab.start + jj];
                    poss[lane * w + j] = (slab.start + jj) as i32;
                }
            }
            let logits = backend.step(w, toks, poss)?;
            metrics.decode_steps += 1;
            metrics.slab_tokens += plan.tokens();

            // ---- sample / retire; finished lanes free right here ----
            let now = Instant::now();
            for lane in 0..b {
                let Some(sess) = lanes[lane].as_mut() else { continue };
                let taken = plan.slabs[lane].as_ref().expect("occupied lane planned").len;
                kv.advance_by(sess.slot(), taken)?;
                let row = &logits.data()[lane * self.vocab..(lane + 1) * self.vocab];
                let finished = sess.observe_slab(taken, row, now);
                let id = sess.id();
                if let Some((pos, tok)) = sess.last_sampled() {
                    hook.on_token(id, pos, tok, metrics.decode_steps);
                }
                if finished {
                    let sess = lanes[lane].take().expect("lane occupied");
                    kv.free(sess.slot())?;
                    metrics.completed += 1;
                    metrics.generated_tokens += sess.generated();
                    let c = sess.finish(now, metrics.decode_steps);
                    lat.push(c.latency_s);
                    ttfts.push(c.ttft_s);
                    hook.on_done(&c);
                    if !open {
                        done.insert(c.id, c);
                    }
                }
            }
        }

        // Conservation: every slot returned, every request accounted for —
        // completed or cancelled, never lost.
        if kv.free_slots() != b {
            bail!("KV slot leak: {}/{} free after drain", kv.free_slots(), b);
        }
        let (enq, adm) = batcher.counters();
        if enq != adm + batcher.removed()
            || metrics.completed + metrics.cancelled != enq as usize
        {
            bail!(
                "request conservation violated: enqueued {enq}, admitted {adm}, \
                 removed {}, completed {}, cancelled {}",
                batcher.removed(),
                metrics.completed,
                metrics.cancelled
            );
        }

        metrics.wall_s = sw.elapsed_s();
        metrics.kv_peak_bytes = kv.peak_bytes();
        metrics.observe_latencies(lat, ttfts);
        let out: Vec<Completion> = if open {
            Vec::new()
        } else {
            // Input order, cancelled requests omitted (their partial rows
            // went out through the hook).
            order.iter().filter_map(|id| done.remove(id)).collect()
        };
        Ok((out, metrics))
    }
}

/// The per-serve step executor: dispatches a plan's fused step and zeroes
/// re-assigned lanes, over whichever backing the engine was built with.
enum StepBackend<'rt> {
    Pjrt(DecodeSession<'rt>),
    Stub(StubModel),
}

impl StepBackend<'_> {
    /// Run one `width`-wide fused step; `toks`/`poss` are row-major
    /// `[B, width]`.  Returns the logits `[B, V]` at each lane's last slab
    /// index.
    fn step(&mut self, width: usize, toks: Vec<i32>, poss: Vec<i32>) -> Result<Tensor> {
        match self {
            StepBackend::Pjrt(dec) => dec
                .run_plan(width, toks, poss)?
                .into_iter()
                .next()
                .context("step returned no logits")?
                .into_f32(),
            StepBackend::Stub(m) => m.step(width, &toks, &poss),
        }
    }

    fn zero_lanes(&mut self, lanes: &[usize]) -> Result<()> {
        match self {
            StepBackend::Pjrt(dec) => dec.update_caches(|caches| {
                for cache in caches.iter_mut() {
                    for &lane in lanes {
                        zero_lane(cache, lane);
                    }
                }
                Ok(())
            }),
            StepBackend::Stub(m) => {
                m.zero_lanes(lanes);
                Ok(())
            }
        }
    }
}

/// Zero batch lane `lane` of a `[L, B, H, C, r]` cache tensor.
fn zero_lane(cache: &mut Tensor, lane: usize) {
    let shape = cache.shape().to_vec();
    debug_assert_eq!(shape.len(), 5, "cache must be [L, B, H, C, r]");
    debug_assert!(lane < shape[1]);
    let b = shape[1];
    let inner: usize = shape[2..].iter().product();
    let data = cache.data_mut();
    for l in 0..shape[0] {
        let start = (l * b + lane) * inner;
        data[start..start + inner].fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ops::init_params;
    use crate::serve::sampling::SamplingParams;
    use crate::testing::prop;
    use std::time::Duration;

    fn art() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn policy() -> BatchPolicy {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) }
    }

    #[test]
    fn zero_lane_clears_only_that_lane() {
        let mut t = Tensor::full(&[2, 3, 2, 2, 2], 1.0);
        zero_lane(&mut t, 1);
        let inner = 8;
        for l in 0..2 {
            for lane in 0..3 {
                let start = (l * 3 + lane) * inner;
                let want = if lane == 1 { 0.0 } else { 1.0 };
                assert!(t.data()[start..start + inner].iter().all(|&x| x == want),
                        "layer {l} lane {lane}");
            }
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn serves_batch_of_requests() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request::greedy(i, vec![1, 2, 3 + i as i32], 5, now))
            .collect();
        let (completions, metrics) = engine.serve_all(reqs, policy()).unwrap();
        assert_eq!(completions.len(), 3);
        for (i, c) in completions.iter().enumerate() {
            assert_eq!(c.id, i as u64);
            assert_eq!(c.tokens.len(), 8); // 3 prompt + 5 new
            assert_eq!(&c.tokens[..2], &[1, 2]);
            assert!(c.ttft_s <= c.latency_s);
            assert!(c.queue_wait_s >= 0.0);
        }
        assert_eq!(metrics.completed, 3);
        assert_eq!(metrics.generated_tokens, 15);
        assert_eq!(metrics.admissions, 3);
        // 3 prompt + 5 generated = 8 positions.  With a chunk ladder the
        // prompt collapses into one padded slab step (then 4 decode
        // steps); without prefill artifacts it is 7 single-token steps.
        let expect = if engine.max_chunk() > 1 { 5 } else { 7 };
        assert_eq!(metrics.decode_steps, expect);
        assert!(metrics.kv_peak_bytes > 0);
        assert!(metrics.tokens_per_s() > 0.0);
        assert!(metrics.latency_p99_s >= metrics.latency_p50_s);
    }

    #[test]
    fn midflight_admission_beats_waves() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        // 2× the slot count, mixed lengths finishing at different steps.
        let mk = || -> Vec<Request> {
            (0..16u64)
                .map(|i| Request::greedy(i, vec![1, 2], 2 + (i as usize % 4) * 4, now))
                .collect()
        };
        let (cont_c, cont) = engine.serve_all(mk(), policy()).unwrap();
        let (wave_c, wave) = engine
            .serve_with(mk(), policy(), Admission::WaveToCompletion)
            .unwrap();
        assert_eq!(cont_c.len(), 16);
        assert_eq!(cont.completed, 16);
        assert_eq!(wave.completed, 16);
        // Same results, fewer steps: freed lanes were refilled mid-flight.
        for (a, b) in cont_c.iter().zip(&wave_c) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "schedule must not change tokens");
        }
        assert!(
            cont.decode_steps < wave.decode_steps,
            "continuous {} vs wave {} steps",
            cont.decode_steps, wave.decode_steps
        );
        // Mixed lengths really did finish at different steps.
        let steps: HashSet<usize> = cont_c.iter().map(|c| c.finished_step).collect();
        assert!(steps.len() > 1, "all requests finished at the same step");
    }

    #[test]
    fn non_contiguous_ids_in_input_order() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let ids = [503u64, 7, 1_000_000_009, 64];
        let reqs: Vec<Request> = ids
            .iter()
            .enumerate()
            .map(|(i, &id)| Request::greedy(id, vec![1 + i as i32], 3, now))
            .collect();
        let (completions, metrics) = engine.serve_all(reqs, policy()).unwrap();
        assert_eq!(completions.len(), 4);
        for (i, c) in completions.iter().enumerate() {
            assert_eq!(c.id, ids[i], "completions must come back in input order");
            assert_eq!(c.tokens[0], 1 + i as i32);
        }
        assert_eq!(metrics.completed, 4);

        // Duplicate ids are rejected up front, not mis-keyed.
        let dup = vec![
            Request::greedy(5, vec![1], 2, now),
            Request::greedy(5, vec![2], 2, now),
        ];
        assert!(engine.serve_all(dup, policy()).is_err());
    }

    #[test]
    fn per_request_latency_not_batch_latency() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let reqs = vec![
            Request::greedy(0, vec![1, 2], 2, now),
            Request::greedy(1, vec![1, 2], 20, now),
        ];
        let (c, _) = engine.serve_all(reqs, policy()).unwrap();
        assert!(c[0].finished_step < c[1].finished_step);
        assert!(
            c[0].latency_s <= c[1].latency_s,
            "the early finisher must not be charged the long request's wall time"
        );
        assert!(c[0].steps < c[1].steps);
        // Degenerate request: completes with zero steps and ttft == latency.
        let (c, m) = engine
            .serve_all(vec![Request::greedy(2, vec![1, 2], 0, now)], policy())
            .unwrap();
        assert_eq!(c[0].tokens, vec![1, 2]);
        assert_eq!(c[0].steps, 0);
        assert_eq!(c[0].ttft_s, c[0].latency_s);
        assert_eq!(m.decode_steps, 0);
    }

    #[test]
    fn sampled_decode_is_deterministic_and_in_vocab() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let vocab = rt.manifest().config("tiny").unwrap().dim("vocab").unwrap() as i32;
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let mk = || -> Vec<Request> {
            (0..4u64)
                .map(|i| Request {
                    id: i,
                    prompt: vec![3, 4],
                    max_new: 6,
                    arrived: now,
                    sampling: SamplingParams {
                        temperature: 0.9,
                        top_k: 8,
                        seed: 17,
                        stop_token: None,
                    },
                })
                .collect()
        };
        let (a, _) = engine.serve_all(mk(), policy()).unwrap();
        let (b, _) = engine.serve_all(mk(), policy()).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "same seed must replay identically");
            assert!(x.tokens.iter().all(|&t| t >= 0 && t < vocab));
        }
        // Different request ids decorrelate even with identical prompts.
        assert!(a.windows(2).any(|w| w[0].tokens != w[1].tokens),
                "all sampled rows identical — per-request streams not decorrelated");
    }

    #[test]
    fn slot_conservation_under_churn_property() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        // serve_with itself bails on any slot leak / conservation breach;
        // this drives it with randomized churn shapes (the kv.rs property,
        // extended through the engine).
        prop("engine slot conservation", 5, |rng| {
            let now = Instant::now();
            let n = 1 + rng.below(12);
            let mut ids: Vec<u64> = Vec::new();
            while ids.len() < n {
                let id = rng.next_u64() % 1000;
                if !ids.contains(&id) {
                    ids.push(id);
                }
            }
            let reqs: Vec<Request> = ids
                .iter()
                .map(|&id| {
                    let p = 1 + rng.below(3);
                    let prompt = (0..p).map(|_| rng.below(64) as i32).collect();
                    Request::greedy(id, prompt, rng.below(7), now)
                })
                .collect();
            let (completions, metrics) = engine
                .serve_all(reqs, policy())
                .map_err(|e| e.to_string())?;
            if completions.len() != n {
                return Err(format!("{} of {n} completions", completions.len()));
            }
            for (c, &id) in completions.iter().zip(&ids) {
                if c.id != id {
                    return Err(format!("order violated: got {} want {id}", c.id));
                }
            }
            if metrics.completed != n || metrics.admissions != n {
                return Err(format!(
                    "metrics disagree: completed {} admitted {}", metrics.completed, metrics.admissions
                ));
            }
            Ok(())
        });
    }

    /// Records hook callbacks and fires one cancellation after the target
    /// request has streamed `fire_after` tokens.
    struct CancellingHook {
        target: u64,
        fire_after: usize,
        target_tokens: usize,
        fired: bool,
        started: Vec<u64>,
        tokens: Vec<(u64, usize, i32)>,
        done_ids: Vec<u64>,
        cancelled: Vec<(u64, Vec<i32>, CancelReason)>,
    }

    impl CancellingHook {
        fn new(target: u64, fire_after: usize) -> Self {
            Self {
                target,
                fire_after,
                target_tokens: 0,
                fired: false,
                started: Vec::new(),
                tokens: Vec::new(),
                done_ids: Vec::new(),
                cancelled: Vec::new(),
            }
        }
    }

    impl StepHook for CancellingHook {
        fn take_cancellations(&mut self, _now: Instant) -> Vec<Cancellation> {
            if !self.fired && self.target_tokens >= self.fire_after {
                self.fired = true;
                return vec![Cancellation { id: self.target, reason: CancelReason::User }];
            }
            Vec::new()
        }

        fn on_started(&mut self, id: u64, _lane: usize, _step: usize) {
            self.started.push(id);
        }

        fn on_token(&mut self, id: u64, pos: usize, token: i32, _step: usize) {
            if id == self.target {
                self.target_tokens += 1;
            }
            self.tokens.push((id, pos, token));
        }

        fn on_done(&mut self, completion: &Completion) {
            self.done_ids.push(completion.id);
        }

        fn on_cancelled(&mut self, id: u64, tokens: Vec<i32>, reason: CancelReason, _step: usize) {
            self.cancelled.push((id, tokens, reason));
        }
    }

    #[test]
    fn hooked_serve_streams_tokens_and_cancels_between_steps() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_params(&rt, "tiny", 9).unwrap();
        let engine = Engine::new(&rt, "tiny", "decode_b8", params).unwrap();
        let now = Instant::now();
        let prompt_len = 2;
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request::greedy(i, vec![1, 2 + i as i32], 6, now))
            .collect();
        let mut hook = CancellingHook::new(1, 2);
        let (completions, metrics) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut hook)
            .unwrap();

        // The cancelled request is gone from the completions; everyone
        // else finished in input order.
        assert_eq!(completions.iter().map(|c| c.id).collect::<Vec<_>>(), vec![0, 2, 3]);
        assert_eq!(metrics.completed, 3);
        assert_eq!(metrics.cancelled, 1);
        assert_eq!(hook.started.len(), 4, "all four admitted");
        assert_eq!(hook.done_ids.len(), 3);

        // Cancellation applied between decode steps, right after the
        // second generated token: the partial row is prompt + 2.
        assert_eq!(hook.cancelled.len(), 1);
        let (cid, partial, reason) = &hook.cancelled[0];
        assert_eq!((*cid, *reason), (1, CancelReason::User));
        assert_eq!(partial.len(), prompt_len + 2);
        assert_eq!(&partial[..prompt_len], &[1, 3]);

        // Streamed tokens reconstruct each completion's generated suffix
        // exactly — token-level delivery carries the same data wave-end
        // delivery would.
        for c in &completions {
            let streamed: Vec<i32> = hook
                .tokens
                .iter()
                .filter(|(id, _, _)| *id == c.id)
                .map(|&(_, _, t)| t)
                .collect();
            assert_eq!(streamed.as_slice(), &c.tokens[prompt_len..], "request {}", c.id);
            // Positions are the absolute row indices of the generated part.
            let positions: Vec<usize> = hook
                .tokens
                .iter()
                .filter(|(id, _, _)| *id == c.id)
                .map(|&(_, p, _)| p)
                .collect();
            let want: Vec<usize> = (prompt_len..c.tokens.len()).collect();
            assert_eq!(positions, want);
        }

        // A NoHook run of the same (uncancelled) trace is bit-identical to
        // serve_all — the hook plumbing itself changes nothing.
        let mk = |ids: &[u64]| -> Vec<Request> {
            ids.iter().map(|&i| Request::greedy(i, vec![1, 2 + i as i32], 6, now)).collect()
        };
        let (a, _) = engine.serve_all(mk(&[0, 1, 2, 3]), policy()).unwrap();
        let (b, _) = engine
            .serve_hooked(mk(&[0, 1, 2, 3]), policy(), Admission::Continuous, &mut NoHook)
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    // ---- stub-backed tests: the scheduling contract, runnable without a
    // PJRT backend (these are what CI exercises) ----

    /// Small dims keep the stub's O(V·L·H·r·C) logits cheap in debug
    /// builds; the ladder and window are what the scheduling cares about.
    fn stub_spec() -> StubSpec {
        StubSpec {
            n_layers: 1,
            n_heads: 2,
            rank: 2,
            vocab: 16,
            max_positions: 128,
            ..Default::default()
        }
    }

    fn stub_engine(cap: Option<usize>) -> Engine<'static> {
        Engine::new_stub(stub_spec()).with_prefill_chunk(cap)
    }

    #[test]
    fn chunk_width_policy() {
        let ladder = [1, 8, 32];
        assert_eq!(chunk_width(&ladder, 1), 1, "decode lanes stay single-token");
        assert_eq!(chunk_width(&ladder, 2), 8, "short remainders pad into one chunk");
        assert_eq!(chunk_width(&ladder, 8), 8);
        assert_eq!(chunk_width(&ladder, 10), 8, "biggest exact fit wins over padding");
        assert_eq!(chunk_width(&ladder, 32), 32);
        assert_eq!(chunk_width(&ladder, 100), 32);
        assert_eq!(chunk_width(&[1], 100), 1, "no chunk artifacts: single-token");
    }

    #[test]
    fn step_plan_mixes_prefill_and_decode_lanes() {
        let now = Instant::now();
        let mut lanes: Vec<Option<Session>> = vec![None; 3];
        lanes[0] = Some(Session::new(Request::greedy(7, (0..20).collect(), 4, now), 0, 64, now));
        lanes[2] = Some(Session::new(Request::greedy(9, vec![5], 4, now), 2, 64, now));
        let plan = StepPlan::build(&[1, 8], &lanes);
        assert_eq!(plan.width, 8, "the prefilling lane sets the step width");
        assert_eq!(plan.slabs[0], Some(LaneSlab { id: 7, start: 0, len: 8 }));
        assert_eq!(plan.slabs[1], None);
        assert_eq!(plan.slabs[2], Some(LaneSlab { id: 9, start: 0, len: 1 }));
        assert_eq!(plan.tokens(), 9);
    }

    #[test]
    fn chunked_prefill_bit_identity_property() {
        // For any prompt set and any chunk ladder cap, chunked prefill
        // produces exactly the tokens the single-token path does — the
        // schedule changes, the results never do.  Request counts beyond
        // the 8 lanes force lane reuse, so slab-width-dependent admission
        // timing and lane zeroing are under test too.
        prop("chunked prefill bit-identity", 8, |rng| {
            let now = Instant::now();
            let n = 1 + rng.below(12);
            let reqs: Vec<Request> = (0..n as u64)
                .map(|id| {
                    let p = 1 + rng.below(40);
                    let prompt: Vec<i32> = (0..p).map(|_| rng.below(16) as i32).collect();
                    let sampling = SamplingParams {
                        temperature: if rng.uniform() < 0.5 { 0.0 } else { 0.9 },
                        top_k: rng.below(5),
                        seed: rng.next_u64(),
                        stop_token: None,
                    };
                    Request { id, prompt, max_new: rng.below(9), arrived: now, sampling }
                })
                .collect();
            let mut runs = Vec::new();
            for cap in [Some(1), Some(8), None] {
                let engine = stub_engine(cap);
                let out = engine.serve_all(reqs.clone(), policy()).map_err(|e| e.to_string())?;
                runs.push((cap, out));
            }
            let (_, (base, base_m)) = &runs[0];
            for (cap, (c, m)) in &runs[1..] {
                if c.len() != base.len() {
                    return Err(format!("cap {cap:?}: {} vs {} completions", c.len(), base.len()));
                }
                for (x, y) in c.iter().zip(base) {
                    if x.tokens != y.tokens {
                        return Err(format!("cap {cap:?}: request {} diverged", x.id));
                    }
                }
                if m.decode_steps > base_m.decode_steps {
                    return Err(format!(
                        "cap {cap:?}: chunking took {} steps vs {} single-token",
                        m.decode_steps, base_m.decode_steps
                    ));
                }
                if m.slab_tokens != base_m.slab_tokens {
                    return Err("same trace must consume the same row tokens".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chunked_prefill_cuts_prefill_steps_4x() {
        // The acceptance bar: a 64-token prompt's prefill steps shrink
        // >= 4x at K=8 vs K=1 (8x here), with identical output tokens.
        let now = Instant::now();
        let mk = || vec![Request::greedy(0, (0..64).map(|i| i % 32).collect(), 8, now)];
        let (c1, m1) = stub_engine(Some(1)).serve_all(mk(), policy()).unwrap();
        let (c8, m8) = stub_engine(Some(8)).serve_all(mk(), policy()).unwrap();
        let (c32, m32) = stub_engine(None).serve_all(mk(), policy()).unwrap();
        assert_eq!(c1[0].tokens, c8[0].tokens);
        assert_eq!(c1[0].tokens, c32[0].tokens);
        assert_eq!(c1[0].prefill_steps, 64);
        assert_eq!(c8[0].prefill_steps, 8);
        assert_eq!(c32[0].prefill_steps, 2);
        assert!(c1[0].prefill_steps >= 4 * c8[0].prefill_steps);
        // Step totals shift by exactly the prefill saving.
        assert_eq!(m8.decode_steps, m1.decode_steps - 64 + 8);
        assert_eq!(m32.slab_tokens, m1.slab_tokens, "same tokens, fewer steps");
        assert!(m32.decode_steps < m8.decode_steps);
    }

    #[test]
    fn mixed_prefill_and_decode_share_steps() {
        // Lane 0 is generating from step 2 onward while lane 1 is still
        // prefilling its 40-token prompt — the same fused steps carry
        // both, and the tokens match the single-token schedule.
        let now = Instant::now();
        let mk = || {
            vec![
                Request::greedy(0, vec![1, 2], 12, now),
                Request::greedy(1, (0..40).map(|i| i % 32).collect(), 4, now),
            ]
        };
        let (cc, mc) = stub_engine(None).serve_all(mk(), policy()).unwrap();
        let (c1, m1) = stub_engine(Some(1)).serve_all(mk(), policy()).unwrap();
        for (a, b) in cc.iter().zip(&c1) {
            assert_eq!(a.tokens, b.tokens, "request {}", a.id);
        }
        assert!(mc.decode_steps < m1.decode_steps);
        assert_eq!(cc[1].prefill_steps, 2, "40 = 32 + 8: two chunk steps");
        assert_eq!(cc[0].prefill_steps, 1, "2-token prompt pads into one slab");
    }

    #[test]
    fn empty_prompt_rejected_at_admission() {
        let now = Instant::now();
        let engine = stub_engine(None);
        let err = engine
            .serve_all(vec![Request::greedy(0, vec![], 4, now)], policy())
            .unwrap_err();
        assert!(err.to_string().contains("empty prompt"), "{err:#}");
        // A mixed batch is rejected up front too — nothing is partially
        // served.
        let reqs = vec![
            Request::greedy(1, vec![3], 2, now),
            Request::greedy(2, vec![], 2, now),
        ];
        assert!(engine.serve_all(reqs, policy()).is_err());
    }

    /// Fires one cancellation for `target` as soon as it has been
    /// admitted — i.e. *during its prefill*, before any sampled token.
    struct PrefillCancelHook {
        target: u64,
        fired: bool,
        started: Vec<(u64, usize)>,
        target_tokens: usize,
        cancelled: Vec<(u64, Vec<i32>, CancelReason, usize)>,
    }

    impl StepHook for PrefillCancelHook {
        fn take_cancellations(&mut self, _now: Instant) -> Vec<Cancellation> {
            if !self.fired && self.started.iter().any(|&(id, _)| id == self.target) {
                self.fired = true;
                return vec![Cancellation { id: self.target, reason: CancelReason::User }];
            }
            Vec::new()
        }

        fn on_started(&mut self, id: u64, _lane: usize, step: usize) {
            self.started.push((id, step));
        }

        fn on_token(&mut self, id: u64, _pos: usize, _token: i32, _step: usize) {
            if id == self.target {
                self.target_tokens += 1;
            }
        }

        fn on_cancelled(&mut self, id: u64, tokens: Vec<i32>, reason: CancelReason, step: usize) {
            self.cancelled.push((id, tokens, reason, step));
        }
    }

    #[test]
    fn cancel_during_prefill_reclaims_lane_same_iteration() {
        // One lane, single-token ladder: the 16-token prompt needs 16
        // prefill steps, and the cancellation lands after the first one —
        // mid-prefill by construction, no timing involved.
        let spec = StubSpec { batch_slots: 1, chunk_widths: vec![1], ..Default::default() };
        let engine = Engine::new_stub(spec);
        let now = Instant::now();
        let prompt: Vec<i32> = (0..16).collect();
        let reqs = vec![
            Request::greedy(0, prompt.clone(), 4, now),
            Request::greedy(1, vec![7, 8], 2, now),
        ];
        let mut hook = PrefillCancelHook {
            target: 0,
            fired: false,
            started: Vec::new(),
            target_tokens: 0,
            cancelled: Vec::new(),
        };
        let (completions, metrics) = engine
            .serve_hooked(reqs, policy(), Admission::Continuous, &mut hook)
            .unwrap();

        // Exactly one Cancelled, with the untouched prompt as the partial
        // row (zero generated tokens — the cancel beat the first sample).
        assert_eq!(hook.cancelled.len(), 1);
        let (cid, partial, reason, cancel_step) = &hook.cancelled[0];
        assert_eq!((*cid, *reason), (0, CancelReason::User));
        assert_eq!(partial, &prompt, "no tokens were generated during prefill");
        assert_eq!(hook.target_tokens, 0);

        // The waiter reclaimed the lane in the same iteration the victim
        // was retired: its Started step equals the cancellation step.
        let waiter_started = hook
            .started
            .iter()
            .find(|&&(id, _)| id == 1)
            .map(|&(_, step)| step)
            .expect("waiter admitted");
        assert_eq!(waiter_started, *cancel_step, "same-iteration lane reclaim");
        assert_eq!(completions.iter().map(|c| c.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!((metrics.completed, metrics.cancelled), (1, 1));
    }

    #[test]
    fn factorized_engine_kv_smaller() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let entry = rt.manifest().config("tiny").unwrap().clone();
        let dense = init_params(&rt, "tiny", 9).unwrap();
        let (fac, r) = crate::coordinator::ops::prune_to_ratio(&entry, &dense, 0.5, "clover")
            .unwrap();
        let dense_engine = Engine::new(&rt, "tiny", "decode_b8", dense).unwrap();
        let fac_engine =
            Engine::new(&rt, "tiny", &format!("decode_fac_r{r}_b8"), fac).unwrap();
        let d = dense_engine.kv_config().bytes_per_token();
        let f = fac_engine.kv_config().bytes_per_token();
        assert_eq!(f * 2, d, "rank-8 cache should be half of rank-16");
    }
}
