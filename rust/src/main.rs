//! `clover` — CLI launcher for the CLOVER reproduction framework.
//!
//! Subcommands (hand-rolled arg parsing; the vendored crate set has no
//! clap):
//!
//! ```text
//! clover pretrain  [--config f.toml] [--preset tiny] [--steps N] [--out ckpt]
//! clover prune     --ckpt base.clvr [--ratio 0.5] [--method clover|vanilla]
//! clover finetune  --ckpt pruned.clvr [--mode s|attn] [--steps N]
//! clover eval      --ckpt x.clvr            # perplexity
//! clover spectra   [--all-layers]           # Fig 2 curves
//! clover serve     --ckpt x.clvr [--requests N] [--temperature T] [--top-k K] [--stop-token ID]
//!                  [--prefill-chunk K] [--prompt-len N] [--max-step-tokens N]
//!                  [--kv-codec identity|factored] [--kv-layer-budgets r0,r1,...]
//!                  [--kv-memory-budget BYTES]
//!                  [--prefix-cache-block N] [--max-pending N]
//!                  [--speculative] [--draft-rank R] [--draft-len K]
//!                  [--trace-out trace.json] [--metrics-json m.json]
//!                  [--stream] [--gap-ms N] [--deadline-ms N] [--cancel-ms N] [--queue N]
//!                  [--stats-interval SECS]
//!                  [--fault-plan k=v,...] [--retry-budget N] [--retry-backoff-ms M]
//!                  [--max-restarts N] [--breaker-degraded X] [--breaker-open Y]
//!                  [--breaker-probe-ms N]
//! clover golden    [--preset tiny]          # replay golden fixtures
//! clover check     [paths...] [--format text|json] [--check-files]
//!                  [--artifacts DIR] [--preset tiny] [+ the serve flags]
//! clover report    t1|t2|t3|t4|f1c|f1d|f2|f3|f4|f5|f6|all [--quick]
//! ```

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

use clover::config::RunConfig;
use clover::coordinator::experiments::{self, ExpOpts};
use clover::coordinator::{self, ops};
use clover::model::{load_params, save_params, Checkpoint, Manifest};
use clover::obs::{Registry, TraceSink};
use clover::runtime::stub::FaultPlan;
use clover::runtime::{golden, Runtime};
use clover::serve::{
    Admission, BatchPolicy, Engine, KvCodecSpec, Request, RetryPolicy, SamplingParams, SpecConfig,
};
use clover::server::{
    BreakerConfig, DraftSource, EngineSpec, Gateway, GatewayConfig, Obs, StreamEvent, SubmitError,
    TryNext,
};
use clover::util::human_bytes;

/// Minimal flag parser: `--key value` pairs + positional args.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    it.next().unwrap()
                } else {
                    "true".to_string()
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Self { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_or(&self, key: &str, dflt: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse::<usize>().with_context(|| format!("--{key} {v}")),
            None => Ok(dflt),
        }
    }

    fn f64_or(&self, key: &str, dflt: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse::<f64>().with_context(|| format!("--{key} {v}")),
            None => Ok(dflt),
        }
    }
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    if let Some(p) = args.get("preset") {
        cfg.model.preset = p.to_string();
    }
    if let Some(a) = args.get("artifacts") {
        cfg.model.artifacts_dir = a.to_string();
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "pretrain" => cmd_pretrain(&args),
        "prune" => cmd_prune(&args),
        "finetune" => cmd_finetune(&args),
        "eval" => cmd_eval(&args),
        "spectra" => cmd_spectra(&args),
        "serve" => cmd_serve(&args),
        "golden" => cmd_golden(&args),
        "report" => cmd_report(&args),
        "check" => cmd_check(&args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "clover — Cross-Layer Orthogonal Vectors (paper reproduction framework)

USAGE: clover <pretrain|prune|finetune|eval|spectra|serve|golden|check|report> [flags]

clover check [paths...] statically validates a deployment before anything
spawns: manifest geometry, the engine flag combination (same flags as
`clover serve`), committed run configs (*.toml) and bench documents
(*.json) given as paths.  `--format text|json`, `--check-files` to also
require HLO files on disk; exits 1 when any CLV0xx error fires (see
docs/STATIC_ANALYSIS.md for the code catalog).

Run `make artifacts` once before anything else. See README.md.";

fn cmd_pretrain(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Runtime::new(&cfg.model.artifacts_dir)?;
    let steps = args.usize_or("steps", cfg.train.steps)?;
    let lr = args.f64_or("lr", cfg.train.lr)?;
    let out = args.get("out").unwrap_or("runs/pretrained.clvr");
    let entry = rt.manifest().config(&cfg.model.preset)?.clone();
    let vocab = entry.dim("vocab")?;
    let (_tok, stream) =
        clover::data::build_lm_stream(&cfg.data.corpus, vocab, 400_000, cfg.data.seed);
    let init = ops::init_params(&rt, &cfg.model.preset, cfg.train.seed as i32)?;
    let (params, _) = ops::pretrain(&rt, &cfg.model.preset, init, &stream, &ops::PretrainOpts {
        steps, lr, seed: cfg.train.seed, tag: "pretrain".into(),
    })?;
    let ppl = coordinator::eval::perplexity(&rt, &cfg.model.preset, "nll", &params, &stream, 8)?;
    println!("final perplexity: {ppl:.2}");
    save_params(&params, &cfg.model.preset, "dense", steps, std::path::Path::new(out))?;
    println!("saved {out}");
    Ok(())
}

fn cmd_prune(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Runtime::new(&cfg.model.artifacts_dir)?;
    let ckpt_path = args.get("ckpt").context("--ckpt required")?;
    let ratio = args.f64_or("ratio", cfg.prune.ratio)?;
    let method = args.get("method").unwrap_or(&cfg.prune.method).to_string();
    let entry = rt.manifest().config(&cfg.model.preset)?.clone();
    let ck = Checkpoint::load(ckpt_path)?;
    let dense = load_params(&ck, &entry.params_dense)?;
    let (fac, r) = ops::prune_to_ratio(&entry, &dense, ratio, &method)?;
    let out = args.get("out").unwrap_or("runs/pruned.clvr");
    let mut out_ck = Checkpoint::new()
        .with_meta("config", &cfg.model.preset)
        .with_meta("kind", "factorized")
        .with_meta("rank", &r.to_string())
        .with_meta("method", &method);
    for (name, _) in fac.spec() {
        out_ck.insert(name, fac.get(name)?.clone());
    }
    out_ck.save(out)?;
    println!("pruned to rank {r} ({method}); saved {out}");
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Runtime::new(&cfg.model.artifacts_dir)?;
    let ckpt_path = args.get("ckpt").context("--ckpt required")?;
    let mode = args.get("mode").unwrap_or("s").to_string();
    let steps = args.usize_or("steps", cfg.train.steps)?;
    let lr = args.f64_or("lr", if mode == "s" { 6e-3 } else { 6e-4 })?;
    let ck = Checkpoint::load(ckpt_path)?;
    let r = ck.meta_usize("rank")?;
    let entry = rt.manifest().config(&cfg.model.preset)?.clone();
    let spec = entry.params_fac.get(&r).context("rank spec")?;
    let fac = load_params(&ck, spec)?;
    let vocab = entry.dim("vocab")?;
    let (_tok, stream) =
        clover::data::build_lm_stream(&cfg.data.corpus, vocab, 400_000, cfg.data.seed);
    let (ft, _) = ops::recover(&rt, &cfg.model.preset, fac, &stream, &ops::RecoverOpts {
        r, mode: mode.clone(), steps, lr, seed: cfg.train.seed,
    })?;
    let ppl = ops::fac_perplexity(&rt, &cfg.model.preset, &ft, r, &stream, 8)?;
    println!("post-finetune perplexity: {ppl:.2}");
    let out = args.get("out").unwrap_or("runs/finetuned.clvr");
    let mut out_ck = Checkpoint::new()
        .with_meta("config", &cfg.model.preset)
        .with_meta("kind", "factorized")
        .with_meta("rank", &r.to_string());
    for (name, _) in ft.spec() {
        out_ck.insert(name, ft.get(name)?.clone());
    }
    out_ck.save(out)?;
    println!("saved {out}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Runtime::new(&cfg.model.artifacts_dir)?;
    let ckpt_path = args.get("ckpt").context("--ckpt required")?;
    let ck = Checkpoint::load(ckpt_path)?;
    let entry = rt.manifest().config(&cfg.model.preset)?.clone();
    let vocab = entry.dim("vocab")?;
    let (_tok, stream) =
        clover::data::build_lm_stream(&cfg.data.corpus, vocab, 400_000, cfg.data.seed);
    let ppl = if ck.meta.get("kind").map(|s| s.as_str()) == Some("factorized") {
        let r = ck.meta_usize("rank")?;
        let fac = load_params(&ck, entry.params_fac.get(&r).context("rank spec")?)?;
        ops::fac_perplexity(&rt, &cfg.model.preset, &fac, r, &stream, 16)?
    } else {
        let dense = load_params(&ck, &entry.params_dense)?;
        coordinator::eval::perplexity(&rt, &cfg.model.preset, "nll", &dense, &stream, 16)?
    };
    println!("perplexity: {ppl:.2}");
    Ok(())
}

fn cmd_spectra(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Runtime::new(&cfg.model.artifacts_dir)?;
    let opts = ExpOpts {
        preset: cfg.model.preset.clone(),
        quick: args.get("quick").is_some(),
        seed: cfg.train.seed,
    };
    let table = experiments::fig2(&rt, &opts, args.get("all-layers").is_some())?;
    table.emit("fig2_spectra")
}

/// Parse `--prefill-chunk K` into the engine's ladder cap (`None` keeps
/// every exported chunk width; `1` disables chunked prefill).
fn prefill_chunk_flag(args: &Args) -> Result<Option<usize>> {
    args.get("prefill-chunk")
        .map(|v| v.parse::<usize>().with_context(|| format!("--prefill-chunk {v}")))
        .transpose()
}

/// Parse `--max-step-tokens N` — the prefill-aware per-step token budget.
fn max_step_tokens_flag(args: &Args) -> Result<Option<usize>> {
    args.get("max-step-tokens")
        .map(|v| v.parse::<usize>().with_context(|| format!("--max-step-tokens {v}")))
        .transpose()
}

/// Parse `--kv-codec identity|factored` plus the optional
/// `--kv-layer-budgets r0,r1,...` per-layer rank list (factored only;
/// validated against the model geometry at engine construction).
fn kv_codec_flags(args: &Args) -> Result<KvCodecSpec> {
    let budgets = args
        .get("kv-layer-budgets")
        .map(|v| {
            v.split(',')
                .map(|s| s.trim().parse::<usize>().with_context(|| format!("--kv-layer-budgets {v}")))
                .collect::<Result<Vec<usize>>>()
        })
        .transpose()?;
    Ok(KvCodecSpec::parse(args.get("kv-codec").unwrap_or("identity"), budgets)?)
}

/// Parse `--kv-memory-budget BYTES` — the KV admission budget (factored
/// pages fit proportionally more concurrent lanes inside it).
fn kv_memory_budget_flag(args: &Args) -> Result<Option<usize>> {
    args.get("kv-memory-budget")
        .map(|v| v.parse::<usize>().with_context(|| format!("--kv-memory-budget {v}")))
        .transpose()
}

/// Parse `--prefix-cache-block N` — the radix prefix cache's block width
/// in tokens (a page multiple the chunk ladder tiles; stub engines only,
/// mutually exclusive with `--speculative`).
fn prefix_cache_block_flag(args: &Args) -> Result<Option<usize>> {
    args.get("prefix-cache-block")
        .map(|v| v.parse::<usize>().with_context(|| format!("--prefix-cache-block {v}")))
        .transpose()
}

/// Parse `--max-pending N` — the load-shedding cap on accepted-but-not-
/// terminal requests; beyond it submits refuse with `Overloaded` instead
/// of queueing deeper.
fn max_pending_flag(args: &Args) -> Result<Option<usize>> {
    args.get("max-pending")
        .map(|v| v.parse::<usize>().with_context(|| format!("--max-pending {v}")))
        .transpose()
}

/// Write a JSON document to `path` (trace / metrics dumps).
fn write_json_file(path: &str, doc: &clover::config::json::Json) -> Result<()> {
    std::fs::write(path, clover::config::json::to_string(doc))
        .with_context(|| format!("writing {path}"))
}

/// Parse the speculative-decode flags: `--speculative` turns the
/// draft+verify pair on, `--draft-rank R` picks the draft's CLOVER rank
/// (default 4), `--draft-len K` the per-round draft length (default 4).
fn speculative_flags(args: &Args) -> Result<Option<(usize, SpecConfig)>> {
    if args.get("speculative").is_none() {
        return Ok(None);
    }
    let rank = args.usize_or("draft-rank", 4)?;
    let cfg = SpecConfig { draft_len: args.usize_or("draft-len", 4)?, adaptive: true };
    Ok(Some((rank, cfg)))
}

/// Parse `--fault-plan key=value,...` (chaos testing; stub backend only —
/// see `FaultPlan::parse` for the schema: seed, transient_rate,
/// spike_rate, spike_factor, poison_rate, fatal_after_steps,
/// crash_after_steps).  `CLOVER_FAULT_SEED` overrides the seed so CI can
/// sweep a deterministic matrix without editing flags.
fn fault_plan_flag(args: &Args) -> Result<Option<FaultPlan>> {
    let Some(spec) = args.get("fault-plan") else { return Ok(None) };
    let plan = FaultPlan::parse(spec).map_err(|e| anyhow::anyhow!("--fault-plan {spec}: {e}"))?;
    Ok(Some(plan.with_env_seed()))
}

/// Per-step retry policy from `--retry-budget N` / `--retry-backoff-ms M`
/// (defaults from [`RetryPolicy::default`]: 3 attempts, 1ms base backoff).
fn retry_policy_flags(args: &Args) -> Result<RetryPolicy> {
    let dflt = RetryPolicy::default();
    Ok(RetryPolicy {
        budget: args.usize_or("retry-budget", dflt.budget)?,
        backoff: std::time::Duration::from_millis(
            args.usize_or("retry-backoff-ms", dflt.backoff.as_millis() as usize)? as u64,
        ),
    })
}

/// Circuit-breaker tuning from `--breaker-degraded X` / `--breaker-open Y`
/// / `--breaker-probe-ms N` (router fleets; `clover check` validates the
/// same ordering constraint as CLV038).  Returns `None` when no breaker
/// flag is present.
fn breaker_flags(args: &Args) -> Result<Option<BreakerConfig>> {
    if args.get("breaker-degraded").is_none()
        && args.get("breaker-open").is_none()
        && args.get("breaker-probe-ms").is_none()
    {
        return Ok(None);
    }
    let dflt = BreakerConfig::default();
    let cfg = BreakerConfig {
        alpha: dflt.alpha,
        degraded_threshold: args.f64_or("breaker-degraded", dflt.degraded_threshold)?,
        open_threshold: args.f64_or("breaker-open", dflt.open_threshold)?,
        probe_after: std::time::Duration::from_millis(
            args.usize_or("breaker-probe-ms", dflt.probe_after.as_millis() as usize)? as u64,
        ),
    };
    if !(cfg.degraded_threshold > 0.0
        && cfg.degraded_threshold < cfg.open_threshold
        && cfg.open_threshold <= 1.0)
    {
        bail!(
            "breaker thresholds must satisfy 0 < degraded ({}) < open ({}) <= 1",
            cfg.degraded_threshold,
            cfg.open_threshold,
        );
    }
    Ok(Some(cfg))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    if args.get("stream").is_some() {
        return cmd_serve_stream(args, &cfg);
    }
    let rt = Runtime::new(&cfg.model.artifacts_dir)?;
    let entry = rt.manifest().config(&cfg.model.preset)?.clone();
    let n_requests = args.usize_or("requests", 16)?;
    let prompt_len = args.usize_or("prompt-len", 4)?.max(1);
    let ckpt_path = args.get("ckpt").context("--ckpt required")?;
    let ck = Checkpoint::load(ckpt_path)?;
    let batch = cfg.serve.max_batch.min(8);
    let (params, program) = clover::model::decode_params_for_checkpoint(&ck, &entry, batch)?;
    let kv_codec = kv_codec_flags(args)?;
    let mut engine = Engine::new(&rt, &cfg.model.preset, &program, params)?
        .with_prefill_chunk(prefill_chunk_flag(args)?)
        .with_max_step_tokens(max_step_tokens_flag(args)?)
        .with_kv_codec(kv_codec.clone())?
        .with_kv_memory_budget(kv_memory_budget_flag(args)?)
        .with_prefix_cache(prefix_cache_block_flag(args)?)?
        .with_retry_policy(retry_policy_flags(args)?);
    if let Some(plan) = fault_plan_flag(args)? {
        // Refused on PJRT engines — fault injection drives chaos tests on
        // the stub, never devices; the error says so.
        engine = engine.with_fault_plan(plan)?;
    }
    let speculative = speculative_flags(args)?;
    if let Some((draft_rank, spec_cfg)) = &speculative {
        // Self-speculative pair: the draft is the checkpoint's own dense
        // weights CLOVER-pruned to the draft rank, verified by the dense
        // engine through the all-position slab programs.
        if ck.meta.get("kind").map(|s| s.as_str()) == Some("factorized") {
            anyhow::bail!("--speculative drafts from the dense weights — use a dense checkpoint");
        }
        let dense = load_params(&ck, &entry.params_dense)?;
        let d_head = entry.dim("d_head")?;
        // Same bounds the gateway's draft builder enforces: the draft must
        // sit strictly below the dense head dim to be a cheaper proposer.
        if *draft_rank == 0 || *draft_rank >= d_head {
            anyhow::bail!("--draft-rank {draft_rank} must be in 1..{d_head}");
        }
        let ratio = 1.0 - *draft_rank as f64 / d_head as f64;
        let (fac, r) = ops::prune_to_ratio(&entry, &dense, ratio, "clover")?;
        engine =
            engine.with_speculative(&format!("decode_fac_r{r}_b{batch}"), fac, spec_cfg.clone())?;
        println!("speculative pair: draft r={r}, verify dense (draft_len {})", spec_cfg.draft_len);
    }
    println!("step ladder: {:?} (cap with --prefill-chunk)", engine.widths());
    println!(
        "kv codec: {} | {} B/token (stored ranks {:?})",
        kv_codec.name(),
        engine.kv_bytes_per_token_total(),
        engine.kv_config().stored_ranks(),
    );
    let now = std::time::Instant::now();
    let mut rng = clover::util::rng::Rng::new(cfg.train.seed);
    let vocab = entry.dim("vocab")?;
    // Per-request decode policy from flags (greedy unless --temperature).
    let sampling = SamplingParams {
        temperature: args.f64_or("temperature", 0.0)? as f32,
        top_k: args.usize_or("top-k", 0)?,
        seed: cfg.train.seed,
        stop_token: args.get("stop-token").map(|v| v.parse::<i32>()).transpose()?,
        speculative: speculative.is_some(),
    };
    let reqs: Vec<Request> = (0..n_requests as u64)
        .map(|id| Request {
            id,
            prompt: (0..prompt_len).map(|_| rng.below(vocab) as i32).collect(),
            max_new: cfg.serve.max_new_tokens,
            arrived: now,
            sampling: sampling.clone(),
        })
        .collect();
    let policy = BatchPolicy {
        max_batch: cfg.serve.max_batch,
        max_wait: std::time::Duration::from_millis(cfg.serve.max_wait_ms),
    };
    let trace_out = args.get("trace-out").map(str::to_string);
    let metrics_json = args.get("metrics-json").map(str::to_string);
    let (completions, metrics) = if trace_out.is_some() || metrics_json.is_some() {
        // Observed run: tap every step and span through a TraceSink, then
        // dump the Chrome trace / metrics registry next to the summary.
        let mut sink = TraceSink::default();
        let out = engine.serve_hooked(reqs, policy, Admission::Continuous, &mut sink)?;
        if let Some(path) = &trace_out {
            write_json_file(path, &sink.chrome_trace())?;
            println!(
                "wrote Chrome trace {path} ({} steps, {} spans) — load it in Perfetto",
                sink.steps_seen(),
                sink.spans().count(),
            );
        }
        if let Some(path) = &metrics_json {
            let reg = Registry::new();
            reg.counter_add("clover_completed_total", out.1.completed as f64);
            reg.counter_add("clover_cancelled_total", out.1.cancelled as f64);
            reg.counter_add("clover_generated_tokens_total", out.1.generated_tokens as f64);
            reg.counter_add("clover_steps_total", out.1.decode_steps as f64);
            reg.gauge_set("clover_ttft_p50_s", out.1.ttft_p50_s);
            reg.gauge_set("clover_ttft_p99_s", out.1.ttft_p99_s);
            reg.gauge_set("clover_kv_peak_bytes", out.1.kv_peak_bytes as f64);
            write_json_file(path, &reg.to_json())?;
            println!("wrote metrics JSON {path}");
        }
        out
    } else {
        engine.serve_all(reqs, policy)?
    };
    println!(
        "served {} requests | {} generated tokens | {:.1} tok/s | {} fused steps ({} slab tokens) | {} admissions | peak KV {} | freed KV {}",
        metrics.completed,
        metrics.generated_tokens,
        metrics.tokens_per_s(),
        metrics.decode_steps,
        metrics.slab_tokens,
        metrics.admissions,
        human_bytes(metrics.kv_peak_bytes),
        human_bytes(metrics.kv_freed_bytes),
    );
    let prefill_steps: usize = completions.iter().map(|c| c.prefill_steps).sum();
    println!(
        "prefill: {prompt_len}-token prompts took {:.1} steps each (ladder {:?})",
        prefill_steps as f64 / completions.len().max(1) as f64,
        engine.widths(),
    );
    if speculative.is_some() {
        let dense_decode = metrics.decode_steps.saturating_sub(prefill_steps);
        println!(
            "speculative: {} rounds | acceptance {:.0}% | {} draft steps | {} rolled back | \
             {:.2} dense steps/token",
            metrics.spec_rounds,
            100.0 * metrics.acceptance_rate(),
            metrics.draft_steps,
            metrics.rollback_tokens,
            dense_decode as f64 / metrics.generated_tokens.max(1) as f64,
        );
    }
    println!(
        "ttft p50 {:.3}s p99 {:.3}s | latency p50 {:.3}s p99 {:.3}s",
        metrics.ttft_p50_s, metrics.ttft_p99_s, metrics.latency_p50_s, metrics.latency_p99_s,
    );
    let mean_latency: f64 =
        completions.iter().map(|c| c.latency_s).sum::<f64>() / completions.len() as f64;
    println!("mean latency {:.3}s", mean_latency);
    Ok(())
}

/// `clover serve --stream`: drive the checkpoint through the thread-owning
/// gateway instead of the blocking `serve_all` call — requests are fed in
/// over time (open loop, `--gap-ms` apart), tokens print as they are
/// sampled, `--deadline-ms` attaches a per-request deadline, and
/// `--cancel-ms` fires the last request's cancel token mid-decode to show
/// its KV lane being reclaimed.  `--prefill-chunk K` caps the slab ladder
/// (1 = single-token prefill); `--prompt-len N` sizes the prompts so the
/// chunking is visible.
fn cmd_serve_stream(args: &Args, cfg: &RunConfig) -> Result<()> {
    use std::time::{Duration, Instant};

    let ckpt_path = args.get("ckpt").context("--ckpt required")?;
    let n_requests = args.usize_or("requests", 16)?;
    let prompt_len = args.usize_or("prompt-len", 4)?.max(1);
    let gap = Duration::from_millis(args.usize_or("gap-ms", 2)? as u64);
    let deadline = args
        .get("deadline-ms")
        .map(|v| v.parse::<u64>())
        .transpose()?
        .map(Duration::from_millis);
    let cancel_ms = args.get("cancel-ms").map(|v| v.parse::<u64>()).transpose()?;

    // The manifest is plain JSON — read vocab for prompt synthesis without
    // spinning up a second PJRT runtime (the gateway owns the only one).
    let manifest = Manifest::load(&cfg.model.artifacts_dir)?;
    let vocab = manifest.config(&cfg.model.preset)?.dim("vocab")?;

    let batch = cfg.serve.max_batch.min(8);
    let queue_capacity = args.usize_or("queue", 64)?;
    let speculative = speculative_flags(args)?;
    let kv_codec = kv_codec_flags(args)?;
    let prefix_block = prefix_cache_block_flag(args)?;
    let max_pending = max_pending_flag(args)?;
    let retry = retry_policy_flags(args)?;
    let breaker = breaker_flags(args)?;
    let mut spec =
        EngineSpec::checkpoint(&cfg.model.artifacts_dir, &cfg.model.preset, batch, ckpt_path)
            .with_prefill_chunk(prefill_chunk_flag(args)?)
            .with_max_step_tokens(max_step_tokens_flag(args)?)
            .with_kv_codec(kv_codec.clone())
            .with_prefix_cache(prefix_block)
            .with_retry_policy(retry);
    if let Some(plan) = fault_plan_flag(args)? {
        // Refused on the checkpoint backing — fault injection drives
        // chaos tests on the stub, never devices; the error says so.
        spec = spec.with_fault_plan(plan)?;
    }
    if let Some((draft_rank, spec_cfg)) = &speculative {
        let draft = DraftSource::PrunedRank { rank: *draft_rank };
        spec = spec.with_speculative(draft, spec_cfg.clone());
    }
    // Observability taps: any of --trace-out / --metrics-json /
    // --stats-interval hands the gateway a shared Obs (registry + trace
    // sink); without them the worker runs tap-free.
    let trace_out = args.get("trace-out").map(str::to_string);
    let metrics_json = args.get("metrics-json").map(str::to_string);
    let stats_interval = args
        .get("stats-interval")
        .map(|v| v.parse::<f64>().with_context(|| format!("--stats-interval {v}")))
        .transpose()?
        .map(Duration::from_secs_f64);
    let obs = (trace_out.is_some() || metrics_json.is_some() || stats_interval.is_some())
        .then(Obs::default);
    let max_restarts = args.usize_or("max-restarts", GatewayConfig::default().max_restarts)?;
    let gateway = Gateway::spawn_with_obs(
        "serve",
        GatewayConfig {
            queue_capacity,
            policy: BatchPolicy {
                max_batch: cfg.serve.max_batch,
                max_wait: std::time::Duration::from_millis(cfg.serve.max_wait_ms),
            },
            max_pending,
            max_restarts,
            ..GatewayConfig::default()
        },
        spec,
        obs.clone(),
    )?;
    println!(
        "gateway up: rank {}{} | kv codec {} | {} B KV/token | queue {queue_capacity}{}{} | \
         retry budget {} ({}ms backoff) | {} restarts",
        gateway.rank(),
        gateway
            .draft_rank()
            .map(|r| format!(" (+draft r={r})"))
            .unwrap_or_default(),
        kv_codec.name(),
        gateway.kv_bytes_per_token(),
        prefix_block
            .map(|b| format!(" | prefix cache {b}-token blocks"))
            .unwrap_or_default(),
        max_pending
            .map(|n| format!(" | shed beyond {n} pending"))
            .unwrap_or_default(),
        retry.budget,
        retry.backoff.as_millis(),
        max_restarts,
    );
    if let Some(b) = &breaker {
        // A single-gateway stream has no router to trip, but the flags are
        // validated here (and by `clover check`) exactly as a fleet would.
        println!(
            "breaker: degraded > {} | open > {} | probe after {}ms",
            b.degraded_threshold,
            b.open_threshold,
            b.probe_after.as_millis(),
        );
    }

    let sampling = SamplingParams {
        temperature: args.f64_or("temperature", 0.0)? as f32,
        top_k: args.usize_or("top-k", 0)?,
        seed: cfg.train.seed,
        stop_token: args.get("stop-token").map(|v| v.parse::<i32>()).transpose()?,
        speculative: speculative.is_some(),
    };
    let mut rng = clover::util::rng::Rng::new(cfg.train.seed);

    // Open-loop submission: one request per gap tick.  The bounded queue
    // applies backpressure (submit blocks); the --max-pending cap sheds —
    // an Overloaded refusal burned no id, allocated no stream, and left
    // every accepted request untouched, so the loop just moves on.
    let mut streams = Vec::new();
    let mut demo_cancel = None;
    let mut shed = 0usize;
    for i in 0..n_requests {
        let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(vocab) as i32).collect();
        let ticket = match gateway.submit(prompt, cfg.serve.max_new_tokens, sampling.clone(), deadline)
        {
            Ok(t) => t,
            Err(SubmitError::Overloaded) => {
                shed += 1;
                println!(
                    "[req  --] shed: {} requests pending at the --max-pending cap",
                    gateway.in_flight(),
                );
                std::thread::sleep(gap);
                continue;
            }
            Err(e) => bail!("submit failed: {e}"),
        };
        if i + 1 == n_requests {
            if let Some(ms) = cancel_ms {
                demo_cancel = Some((Instant::now() + Duration::from_millis(ms), ticket.cancel.clone()));
            }
        }
        streams.push(ticket.stream);
        std::thread::sleep(gap);
    }

    // Mux all event streams onto stdout until every request is terminal.
    let mut done = 0usize;
    let mut cancelled = 0usize;
    let mut failed = 0usize;
    let mut next_stats = stats_interval.map(|iv| Instant::now() + iv);
    while !streams.is_empty() {
        if let (Some(at), Some(o)) = (next_stats, obs.as_ref()) {
            if Instant::now() >= at {
                let g = |name: &str| {
                    o.registry.get(&format!("{name}{{gateway=\"serve\"}}")).unwrap_or(0.0)
                };
                println!(
                    "[stats] in-flight {} | queued prefill {} tok | KV live {} | {} steps | {} generated | prefix hits {} ({} tok) | cached {} | evicted {} | migrated {}",
                    g("clover_in_flight") as usize,
                    g("clover_queued_prefill_tokens") as usize,
                    human_bytes(g("clover_kv_live_bytes") as usize),
                    g("clover_steps_total") as usize,
                    g("clover_generated_tokens_total") as usize,
                    g("clover_prefix_hits_total") as usize,
                    g("clover_prefix_hit_tokens_total") as usize,
                    human_bytes(g("clover_prefix_cached_bytes") as usize),
                    human_bytes(g("clover_prefix_evicted_bytes_total") as usize),
                    g("clover_migrated_total") as usize,
                );
                next_stats = Some(Instant::now() + stats_interval.expect("set with next_stats"));
            }
        }
        if demo_cancel.as_ref().is_some_and(|(at, _)| Instant::now() >= *at) {
            let (_, token) = demo_cancel.take().expect("checked above");
            println!("[req {:>3}] firing cancel token", token.id());
            token.cancel();
        }
        let mut progressed = false;
        streams.retain(|s| loop {
            match s.try_next() {
                TryNext::Event(ev) => {
                    progressed = true;
                    match &ev {
                        StreamEvent::Queued { id } => println!("[req {id:>3}] queued"),
                        StreamEvent::Started { id, lane, step } => {
                            println!("[req {id:>3}] started on lane {lane} at step {step}")
                        }
                        StreamEvent::Token { id, pos, token, step } => {
                            println!("[req {id:>3}] +token {token:>4} @ pos {pos} (step {step})")
                        }
                        StreamEvent::Done { completion } => {
                            println!(
                                "[req {:>3}] done: {} tokens | ttft {:.3}s | latency {:.3}s",
                                completion.id,
                                completion.tokens.len(),
                                completion.ttft_s,
                                completion.latency_s,
                            );
                        }
                        StreamEvent::Cancelled { id, reason, tokens, step } => {
                            println!(
                                "[req {id:>3}] cancelled ({reason:?}) at step {step} with {} tokens",
                                tokens.len()
                            );
                        }
                        StreamEvent::Failed { id, reason, tokens, step } => {
                            println!(
                                "[req {id:>3}] FAILED ({reason:?}) at step {step} with {} tokens \
                                 — restart budget spent or lane poisoned",
                                tokens.len()
                            );
                        }
                    }
                    if ev.is_terminal() {
                        match ev {
                            StreamEvent::Done { .. } => done += 1,
                            StreamEvent::Failed { .. } => failed += 1,
                            _ => cancelled += 1,
                        }
                        return false;
                    }
                }
                TryNext::Empty => return true,
                TryNext::Closed => {
                    eprintln!("[req {:>3}] stream closed without terminal event", s.id());
                    return false;
                }
            }
        });
        if !progressed {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    // A worker that died for good (restart budget spent) surfaces its
    // error here — report it but still flush the trace/metrics dumps,
    // which are exactly what a post-mortem wants.
    let metrics = gateway.join().unwrap_or_else(|e| {
        eprintln!("gateway worker died: {e:#}");
        Default::default()
    });
    if let Some(o) = &obs {
        let mut sink = o.trace.lock().expect("trace sink poisoned");
        if let Some((reason, dump)) = sink.take_dump() {
            // The flight recorder armed mid-run (cancel storm, overload,
            // shutdown-with-work): persist the ring next to the trace.
            let path = trace_out
                .as_deref()
                .map(|p| format!("{p}.flight.json"))
                .unwrap_or_else(|| "flight.json".into());
            write_json_file(&path, &dump)?;
            println!("flight recorder fired ({reason}); dumped {path}");
        }
        if let Some(path) = &trace_out {
            write_json_file(path, &sink.chrome_trace())?;
            println!(
                "wrote Chrome trace {path} ({} steps, {} spans) — load it in Perfetto",
                sink.steps_seen(),
                sink.spans().count(),
            );
        }
        if let Some(path) = &metrics_json {
            write_json_file(path, &o.registry.to_json())?;
            println!("wrote metrics JSON {path}");
        }
    }
    println!(
        "served {} done + {} cancelled + {} failed + {} shed | {} generated tokens | {:.1} tok/s | {} decode steps | peak KV {} | freed KV {}",
        done,
        cancelled,
        failed,
        shed,
        metrics.generated_tokens,
        metrics.tokens_per_s(),
        metrics.decode_steps,
        human_bytes(metrics.kv_peak_bytes),
        human_bytes(metrics.kv_freed_bytes),
    );
    if metrics.step_faults > 0 || metrics.failed > 0 {
        println!(
            "chaos: {} step faults | {} retried | {} lanes quarantined | {} requests failed",
            metrics.step_faults,
            metrics.step_retries,
            metrics.quarantined_lanes,
            metrics.failed,
        );
    }
    if prefix_block.is_some() {
        println!(
            "prefix cache: {} hits skipped {} prefill tokens | cached {} | evicted {}",
            metrics.prefix_hits,
            metrics.prefix_hit_tokens,
            human_bytes(metrics.prefix_cached_bytes),
            human_bytes(metrics.prefix_evicted_bytes),
        );
    }
    if speculative.is_some() {
        println!(
            "speculative: {} rounds | acceptance {:.0}% | {} draft steps | {} rolled back",
            metrics.spec_rounds,
            100.0 * metrics.acceptance_rate(),
            metrics.draft_steps,
            metrics.rollback_tokens,
        );
    }
    println!(
        "ttft p50 {:.3}s p99 {:.3}s | latency p50 {:.3}s p99 {:.3}s",
        metrics.ttft_p50_s, metrics.ttft_p99_s, metrics.latency_p50_s, metrics.latency_p99_s,
    );
    Ok(())
}

fn cmd_golden(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Runtime::new(&cfg.model.artifacts_dir)?;
    let results = golden::check_all(&rt, &cfg.model.preset)?;
    for (prog, worst) in &results {
        println!("golden {:<24} max|Δ| = {worst:.2e}", prog);
    }
    println!("{} golden fixtures OK", results.len());
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Runtime::new(&cfg.model.artifacts_dir)?;
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let opts = ExpOpts {
        preset: cfg.model.preset.clone(),
        quick: args.get("quick").is_some(),
        seed: cfg.train.seed,
    };
    let run = |id: &str| -> Result<()> {
        match id {
            "t1" => experiments::table1(&rt, &opts)?.emit("table1"),
            "t3" => experiments::table3(&rt, &opts)?.emit("table3"),
            "t4" => experiments::table4(&opts).emit("table4"),
            "f1c" => experiments::fig1c(&rt, &opts)?.emit("fig1c"),
            "f1d" => experiments::fig1d(&rt, &opts)?.emit("fig1d"),
            "f2" => experiments::fig2(&rt, &opts, false)?.emit("fig2"),
            "f3" => experiments::fig3_whisper(&rt, &opts)?.emit("fig3"),
            "f4" => experiments::fig4(&rt, &opts)?.emit("fig4"),
            "t2" | "f5" | "f6" => {
                let (table, outcomes) = experiments::table2(&rt, &opts)?;
                table.emit("table2")?;
                experiments::fig5_from(&outcomes).emit("fig5")?;
                experiments::fig6_from(&outcomes).emit("fig6")
            }
            other => bail!("unknown report {other:?}"),
        }
    };
    if which == "all" {
        for id in ["t3", "t4", "f2", "f4", "f1c", "f1d", "t1", "t2", "f3"] {
            run(id)?;
        }
        Ok(())
    } else {
        run(which)
    }
}

/// `clover check` — the static pre-deploy gate.  Validates the manifest,
/// the engine flag combination (the same serve flags, no spawn), and any
/// paths given as positional args (`*.toml` run configs, `*.json` bench
/// documents).  Prints diagnostics in `--format text|json` and exits 1
/// when any error-severity code fires.
fn cmd_check(args: &Args) -> Result<()> {
    use clover::check::{self, ManifestCheckOpts, Report, ServeSpec};

    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    let mut report = Report::new();
    let opts = ManifestCheckOpts { check_files: args.get("check-files").is_some() };
    let manifest = check::check_manifest_dir(&mut report, std::path::Path::new(artifacts), &opts);

    if let Some(m) = &manifest {
        // Flag parse failures surface as diagnostics, not anyhow bails —
        // `check` reports on bad input instead of dying on it.
        let budgets = args
            .get("kv-layer-budgets")
            .map(|v| {
                v.split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().with_context(|| format!("--kv-layer-budgets {v}"))
                    })
                    .collect::<Result<Vec<usize>>>()
            })
            .transpose()?;
        let kv_codec = match KvCodecSpec::parse(args.get("kv-codec").unwrap_or("identity"), budgets)
        {
            Ok(c) => c,
            Err(e) => {
                report.push(23, "<flags>", "--kv-codec", e.to_string(), "identity|factored");
                KvCodecSpec::Identity
            }
        };
        let spec = ServeSpec {
            preset: args.get("preset").unwrap_or("tiny").to_string(),
            batch_slots: args.usize_or("batch-slots", 8)?,
            rank: args
                .get("rank")
                .map(|v| v.parse::<usize>().with_context(|| format!("--rank {v}")))
                .transpose()?,
            prefill_chunk: prefill_chunk_flag(args)?,
            max_step_tokens: max_step_tokens_flag(args)?,
            kv_codec,
            kv_memory_budget: kv_memory_budget_flag(args)?,
            prefix_cache_block: prefix_cache_block_flag(args)?,
            speculative: speculative_flags(args)?,
            temperature: args.f64_or("temperature", 0.0)?,
            // Chaos flags ride through raw: `check_engine_spec` parses and
            // classifies them (CLV037–CLV039) instead of bailing here.
            fault_plan: args.get("fault-plan").map(str::to_string),
            retry_budget: args.usize_or("retry-budget", RetryPolicy::default().budget)?,
            retry_backoff_ms: args.usize_or(
                "retry-backoff-ms",
                RetryPolicy::default().backoff.as_millis() as usize,
            )? as u64,
            breaker: if args.get("breaker-degraded").is_some()
                || args.get("breaker-open").is_some()
            {
                let dflt = BreakerConfig::default();
                Some((
                    args.f64_or("breaker-degraded", dflt.degraded_threshold)?,
                    args.f64_or("breaker-open", dflt.open_threshold)?,
                ))
            } else {
                None
            },
            deadline_ms: args
                .get("deadline-ms")
                .map(|v| v.parse::<u64>().with_context(|| format!("--deadline-ms {v}")))
                .transpose()?,
        };
        check::check_engine_spec(&mut report, m, &spec, "<flags>");
    }

    for path in args.positional.iter().skip(1) {
        if path.ends_with(".toml") {
            check::check_run_config(&mut report, path, manifest.as_ref());
        } else {
            check::check_bench_file(&mut report, path);
        }
    }

    report.sort();
    match args.get("format").unwrap_or("text") {
        "json" => println!("{}", clover::config::json::to_string(&report.to_json())),
        _ => print!("{}", report.render_text()),
    }
    if report.has_errors() {
        std::process::exit(1);
    }
    Ok(())
}
