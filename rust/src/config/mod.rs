//! Typed run configuration: TOML files + presets + validation.
//!
//! Every CLI subcommand takes `--config <file.toml>` (or `--preset <name>`)
//! and resolves to a [`RunConfig`].  The model *architecture* is pinned by
//! the AOT manifest — configs select which artifact family to use and the
//! training/pruning/serving knobs around it.

pub mod json;
pub mod toml;

use anyhow::{bail, Context, Result};
use std::path::Path;

use self::toml::{parse, TomlTable};

/// Which artifact family (= python `configs.py` preset) to drive.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSection {
    /// Manifest config name: "tiny" | "small" | "large" | "s2s_tiny".
    pub preset: String,
    /// artifacts/ directory root.
    pub artifacts_dir: String,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrainSection {
    pub steps: usize,
    pub lr: f64,
    pub warmup_steps: usize,
    /// "linear" | "cosine" | "constant"
    pub schedule: String,
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct PruneSection {
    /// Fraction of per-head directions to remove (0.0..1.0).
    pub ratio: f64,
    /// "clover" (orthogonalize then drop smallest singular values) or
    /// "vanilla" (drop smallest ‖Wq_i‖·‖Wk_i‖ directions without
    /// orthogonalization).
    pub method: String,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ServeSection {
    pub max_batch: usize,
    pub max_wait_ms: u64,
    pub max_new_tokens: usize,
    pub kv_rank: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct DataSection {
    /// "zipf" | "markov" | "mixture"
    pub corpus: String,
    pub seed: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub name: String,
    pub model: ModelSection,
    pub train: TrainSection,
    pub prune: PruneSection,
    pub serve: ServeSection,
    pub data: DataSection,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            model: ModelSection { preset: "tiny".into(), artifacts_dir: "artifacts".into() },
            train: TrainSection {
                steps: 200,
                lr: 1e-3,
                warmup_steps: 20,
                schedule: "linear".into(),
                seed: 42,
                log_every: 20,
                eval_every: 0,
                eval_batches: 8,
            },
            prune: PruneSection { ratio: 0.5, method: "clover".into() },
            serve: ServeSection { max_batch: 8, max_wait_ms: 5, max_new_tokens: 32, kv_rank: 0 },
            data: DataSection { corpus: "mixture".into(), seed: 1234 },
        }
    }
}

fn get_str(t: &TomlTable, sec: &str, key: &str, dflt: &str) -> Result<String> {
    match t.get(sec).and_then(|s| s.get(key)) {
        Some(v) => Ok(v.as_str()?.to_string()),
        None => Ok(dflt.to_string()),
    }
}

fn get_usize(t: &TomlTable, sec: &str, key: &str, dflt: usize) -> Result<usize> {
    match t.get(sec).and_then(|s| s.get(key)) {
        Some(v) => v.as_usize(),
        None => Ok(dflt),
    }
}

fn get_f64(t: &TomlTable, sec: &str, key: &str, dflt: f64) -> Result<f64> {
    match t.get(sec).and_then(|s| s.get(key)) {
        Some(v) => v.as_f64(),
        None => Ok(dflt),
    }
}

fn get_u64(t: &TomlTable, sec: &str, key: &str, dflt: u64) -> Result<u64> {
    Ok(get_usize(t, sec, key, dflt as usize)? as u64)
}

impl RunConfig {
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let t = parse(text)?;
        let d = RunConfig::default();
        let cfg = RunConfig {
            name: get_str(&t, "", "name", &d.name)?,
            model: ModelSection {
                preset: get_str(&t, "model", "preset", &d.model.preset)?,
                artifacts_dir: get_str(&t, "model", "artifacts_dir", &d.model.artifacts_dir)?,
            },
            train: TrainSection {
                steps: get_usize(&t, "train", "steps", d.train.steps)?,
                lr: get_f64(&t, "train", "lr", d.train.lr)?,
                warmup_steps: get_usize(&t, "train", "warmup_steps", d.train.warmup_steps)?,
                schedule: get_str(&t, "train", "schedule", &d.train.schedule)?,
                seed: get_u64(&t, "train", "seed", d.train.seed)?,
                log_every: get_usize(&t, "train", "log_every", d.train.log_every)?,
                eval_every: get_usize(&t, "train", "eval_every", d.train.eval_every)?,
                eval_batches: get_usize(&t, "train", "eval_batches", d.train.eval_batches)?,
            },
            prune: PruneSection {
                ratio: get_f64(&t, "prune", "ratio", d.prune.ratio)?,
                method: get_str(&t, "prune", "method", &d.prune.method)?,
            },
            serve: ServeSection {
                max_batch: get_usize(&t, "serve", "max_batch", d.serve.max_batch)?,
                max_wait_ms: get_u64(&t, "serve", "max_wait_ms", d.serve.max_wait_ms)?,
                max_new_tokens: get_usize(&t, "serve", "max_new_tokens", d.serve.max_new_tokens)?,
                kv_rank: get_usize(&t, "serve", "kv_rank", d.serve.kv_rank)?,
            },
            data: DataSection {
                corpus: get_str(&t, "data", "corpus", &d.data.corpus)?,
                seed: get_u64(&t, "data", "seed", d.data.seed)?,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_toml_str(&text)
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..1.0).contains(&self.prune.ratio) {
            bail!("prune.ratio must be in [0, 1), got {}", self.prune.ratio);
        }
        match self.prune.method.as_str() {
            "clover" | "vanilla" => {}
            other => bail!("prune.method must be clover|vanilla, got {other:?}"),
        }
        match self.train.schedule.as_str() {
            "linear" | "cosine" | "constant" => {}
            other => bail!("train.schedule must be linear|cosine|constant, got {other:?}"),
        }
        if self.train.lr <= 0.0 {
            bail!("train.lr must be positive");
        }
        if self.serve.max_batch == 0 {
            bail!("serve.max_batch must be >= 1");
        }
        match self.data.corpus.as_str() {
            "zipf" | "markov" | "mixture" => {}
            other => bail!("data.corpus must be zipf|markov|mixture, got {other:?}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_pass_validation() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_overrides() {
        let cfg = RunConfig::from_toml_str(
            r#"
name = "table1"
[model]
preset = "small"
[train]
steps = 500
lr = 6e-4
schedule = "cosine"
[prune]
ratio = 0.25
method = "vanilla"
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "table1");
        assert_eq!(cfg.model.preset, "small");
        assert_eq!(cfg.train.steps, 500);
        assert_eq!(cfg.train.schedule, "cosine");
        assert_eq!(cfg.prune.ratio, 0.25);
        assert_eq!(cfg.prune.method, "vanilla");
        // untouched sections keep defaults
        assert_eq!(cfg.serve.max_batch, 8);
    }

    #[test]
    fn rejects_bad_ratio() {
        let r = RunConfig::from_toml_str("[prune]\nratio = 1.5");
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_method() {
        let r = RunConfig::from_toml_str("[prune]\nmethod = \"magic\"");
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_schedule() {
        assert!(RunConfig::from_toml_str("[train]\nschedule = \"step\"").is_err());
    }
}
